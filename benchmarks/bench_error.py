"""Paper Fig. 5/7 — staleness error per layer, with and without smoothing.

error_feat[ℓ](t)  = ||B_fresh(t) − B_used(t)||_F   (boundary features)
error_grad[ℓ](t)  = ||C_fresh(t) − C_used(t)||_F   (boundary feat gradients)

No instrumentation needed: the step returns updated pipeline buffers; for
the unsmoothed variant new_buf == fresh and old_buf == used, and for the
smoothed variant fresh = (new − γ·old)/(1−γ) while used == old.
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.data import GraphDataPipeline
from repro.optim import adam


def _errors(old, new, gamma, smoothed):
    out = []
    for o, n in zip(old, new):
        o = np.asarray(o, np.float64)
        n = np.asarray(n, np.float64)
        fresh = (n - gamma * o) / (1 - gamma) if smoothed else n
        out.append(float(np.linalg.norm(fresh - o)))
    return out


def run(quick: bool = False, epochs: int = 60, gamma: float = 0.95):
    pipeline = GraphDataPipeline.build("tiny" if quick else "small",
                                       num_parts=4, kind="sage")
    # dropout=0.5 as in the paper's Reddit setup (Tab. 3): the smoothing
    # claim (Fig. 5) is about averaging out *fluctuations*; without dropout
    # the feature evolution is pure drift and EMA lags instead (see
    # EXPERIMENTS.md discussion).
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=64, num_layers=4,
                     num_classes=pipeline.dataset.num_classes, dropout=0.5)
    if quick:
        epochs = 20
    curves = {}
    for variant in ("pipegcn", "pipegcn-g", "pipegcn-f"):
        pipe = PipeConfig.named(variant, gamma=gamma)
        model = PipeGCN(mc, pipe)
        opt = adam(0.01)
        params = model.init_params(jax.random.PRNGKey(0))
        state = opt.init(params)
        bufs = model.init_buffers(pipeline.topo)
        feat_err = []
        grad_err = []
        step = jax.jit(lambda p, s, b, key: _one(model, opt, pipeline, p, s,
                                                 b, key))
        for t in range(epochs):
            old = jax.tree.map(lambda x: x, bufs)
            loss, params, state, bufs = step(params, state, bufs,
                                             jax.random.PRNGKey(t))
            feat_err.append(_errors(old["feat"], bufs["feat"], gamma,
                                    pipe.smooth_feat))
            grad_err.append(_errors(old["grad"], bufs["grad"], gamma,
                                    pipe.smooth_grad))
        fe = np.mean(np.asarray(feat_err)[epochs // 2:], axis=0)
        ge = np.mean(np.asarray(grad_err)[epochs // 2:], axis=0)
        curves[variant] = (fe, ge)
        for ell in range(mc.num_layers):
            emit(f"fig5/{variant}/layer{ell}", 0.0,
                 f"feat_err={fe[ell]:.4f},grad_err={ge[ell]:.4f}")
    # paper claim: smoothing reduces the respective error at every layer
    # (fluctuation-dominated regime, i.e. with the paper's dropout)
    for ell in range(1, mc.num_layers):
        f_ok = curves["pipegcn-f"][0][ell] <= curves["pipegcn"][0][ell] * 1.05
        g_ok = curves["pipegcn-g"][1][ell] <= curves["pipegcn"][1][ell] * 1.05
        emit(f"fig5/claim/layer{ell}", 0.0,
             f"feat_smoothing_helps={f_ok},grad_smoothing_helps={g_ok}")
    return curves


def _one(model, opt, pipeline, params, state, bufs, key):
    loss, grads, new_bufs, _ = model.train_step(pipeline.topo, params, bufs,
                                                pipeline.train_data, key)
    params, state = opt.apply(params, grads, state)
    return loss, params, state, new_bufs


if __name__ == "__main__":
    run()
