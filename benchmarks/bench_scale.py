"""Paper Tab. 5 + Appendix E — multi-server scaling (ogbn-papers100M,
32 partitions over 10GbE): PipeGCN cuts communication ~60% and total epoch
time ~35-40% vs vanilla. Measured shard stats + Ethernet hardware model.
"""
from __future__ import annotations

from benchmarks.common import PAPER_ETH, calibrate_link_bw, emit, epoch_model
from repro.core.config import ModelConfig
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template

# The paper measures comm at 63% of epoch time on the real 111M-node graph
# (Tab. 5: 6.6s / 10.5s). The 32K-node simulation has a much larger relative
# cut, so the Ethernet bandwidth is calibrated to reproduce the measured
# *vanilla* comm ratio; the PipeGCN reductions below are then predictions of
# the schedule model, compared against the paper's 0.62×/0.39× (see
# EXPERIMENTS.md).
PAPER_COMM_RATIO = 0.63


def run(quick: bool = False, parts: int = 32):
    name = "papers100m-sim"
    if quick:
        parts = 8
    pipeline = GraphDataPipeline.build(name, parts, kind="sage")
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes)
    hw = calibrate_link_bw(pipeline.pg, mc, PAPER_ETH, PAPER_COMM_RATIO)
    m = epoch_model(pipeline.pg, mc, hw)
    # Tab. 5 layout: total and communication, normalized to vanilla
    total_rel = m.t_pipegcn / m.t_vanilla
    # in the pipelined schedule the *exposed* communication is what exceeds
    # compute per layer
    exposed = m.t_pipegcn - m.t_comp - m.t_reduce
    comm_rel = max(exposed, 0.0) / max(m.t_comm, 1e-12)
    emit(f"table5/{name}/p{parts}/vanilla", m.t_vanilla * 1e6,
         f"total=1.00,comm=1.00,comm_ratio={m.comm_ratio:.2f}")
    emit(f"table5/{name}/p{parts}/pipegcn", m.t_pipegcn * 1e6,
         f"total={total_rel:.2f},comm={comm_rel:.2f}")
    # paper band: total 0.62-0.64, comm 0.39-0.42 at comm ratio ~63%
    return {"total_rel": total_rel, "comm_rel": comm_rel,
            "comm_ratio": m.comm_ratio}


if __name__ == "__main__":
    print(run())
