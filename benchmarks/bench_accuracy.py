"""Paper Tab. 4 (score columns) — accuracy parity of PipeGCN variants vs
vanilla full-graph training, on the simulated datasets (real training runs).
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import ModelConfig, PipeConfig, train_pipegcn
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template

VARIANTS = ["vanilla", "pipegcn", "pipegcn-g", "pipegcn-f", "pipegcn-gf"]


def run(quick: bool = False, dataset: str = "small", parts: int = 4,
        epochs: int = 200, signal: float = 0.35, seed: int = 0):
    from repro.graph.synthetic import make_dataset
    if quick:
        dataset, epochs = "tiny", 80
    # lower class signal so the task is non-trivial (accuracy < 1.0)
    ds = make_dataset(dataset, signal=signal)
    pipeline = GraphDataPipeline.build(ds, parts, kind="sage", seed=seed)
    tpl = model_template(dataset)
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=ds.num_classes, dropout=tpl["dropout"],
                     multilabel=ds.multilabel)
    results = {}
    for variant in VARIANTS:
        res = train_pipegcn(pipeline, mc, PipeConfig.named(variant),
                            epochs=epochs, lr=tpl["lr"], seed=seed,
                            eval_every=max(epochs // 5, 1))
        results[variant] = res
        emit(f"table4/score/{dataset}/p{parts}/{variant}",
             1e6 / res.epochs_per_sec,
             f"test={res.final_metrics['test']:.4f},"
             f"val={res.final_metrics['val']:.4f},"
             f"epochs_per_s={res.epochs_per_sec:.2f}")
    base = results["vanilla"].final_metrics["test"]
    for variant in VARIANTS[1:]:
        gap = results[variant].final_metrics["test"] - base
        emit(f"table4/gap/{dataset}/{variant}", 0.0, f"gap_pts={gap * 100:+.2f}")
    return results


if __name__ == "__main__":
    run()
