"""Paper Fig. 3 + Tab. 4 (throughput columns) — PipeGCN speedup over vanilla
partition-parallel training.

Three views:
  (a) schedule-analytic speedup on the paper's hardware model (measured
      boundary bytes + FLOPs of the real shards) — expect the paper's
      1.7×–2.2× band where comm ratio is 60–85 %;
  (b) measured epochs/s of the actual jitted JAX step on this CPU (no real
      interconnect, so (b) validates step cost parity, not overlap);
  (c) COO vs block-sparse aggregation engine step time on the SAME
      partitioned graph (the topology carries both the COO shards and the
      tile streams, so only ``ModelConfig.agg`` changes). On CPU the Pallas
      kernels run in interpret mode, so (c) is an engine-dispatch/parity
      check, not an MXU speedup measurement.
  (d) SPMD step time vs partitions-per-device (n_local) at fixed P=8 on
      forced host devices — the decoupled partition/device axis; on real
      hardware this is the knob that trades per-device memory for
      interconnect fan-out.
  (e) fused-deferred vs blocking per-layer boundary exchange (2 vs 2L-1
      collectives per step) on the same graph/model — the fused schedule
      must be no slower; on real interconnects fewer, larger messages off
      the critical path is where the win compounds.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import PAPER_GPU, emit, epoch_model
from repro.core import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.core.trainer import make_jitted_train_step
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template
from repro.optim import adam

CASES = [("reddit-sim", 2), ("reddit-sim", 4),
         ("products-sim", 5), ("products-sim", 10),
         ("yelp-sim", 3), ("yelp-sim", 6)]


def _measure_step(pipeline, mc, variant: str, iters: int,
                  pipe_kw: dict | None = None) -> float:
    model = PipeGCN(mc, dataclasses.replace(PipeConfig.named(variant),
                                            **(pipe_kw or {})))
    opt = adam(1e-2)
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(pipeline.topo)
    state = opt.init(params)
    step = make_jitted_train_step(model, opt)
    key = jax.random.PRNGKey(1)
    # warmup (buffers are donated: thread them through)
    loss, params, state, bufs = step(pipeline.topo, params, state,
                                     bufs, pipeline.train_data, key)
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(iters):
        loss, params, state, bufs = step(pipeline.topo, params, state,
                                         bufs, pipeline.train_data, key)
    jax.block_until_ready(loss)
    return (time.perf_counter() - t0) / iters


def run_engine_comparison(quick: bool = False):
    """(c): one partitioned graph, two aggregation engines."""
    name, parts = ("tiny", 2) if quick else ("small", 4)
    pipeline = GraphDataPipeline.build(name, parts, kind="sage",
                                       agg="blocksparse")
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    out = {}
    for agg in ("coo", "blocksparse"):
        t = _measure_step(pipeline, dataclasses.replace(mc, agg=agg),
                          "pipegcn", iters=2 if quick else 3)
        out[agg] = t
        detail = f"epochs_per_s={1.0 / t:.2f}"
        if agg == "blocksparse":
            detail += f",blocksparse_over_coo={t / out['coo']:.2f}x"
        emit(f"fig3/engine_step/{name}/p{parts}/{agg}", t * 1e6, detail)
    return out


def run_fuse_comparison(quick: bool = False):
    """Fused-deferred vs blocking per-layer exchange on the same graph and
    model: 2 vs 2L-1 boundary collectives per step. Acceptance: the fused
    schedule's step time is no worse than per-layer (the packed collective
    moves identical bytes in fewer, larger messages and sits off the
    critical path)."""
    name, parts = ("tiny", 2) if quick else ("small", 4)
    pipeline = GraphDataPipeline.build(name, parts, kind="sage")
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    out = {}
    # step time is a few ms; compile dominates, so generous iters are cheap
    # and keep the fused/perlayer ratio out of timer noise
    iters = 10 if quick else 20
    for fuse in (False, True):
        sched = "fused" if fuse else "perlayer"
        t = _measure_step(pipeline, mc, "pipegcn", iters,
                          pipe_kw={"fuse_exchange": fuse})
        out[sched] = t
        detail = f"epochs_per_s={1.0 / t:.2f}"
        if fuse:
            detail += f",fused_over_perlayer={t / out['perlayer']:.3f}x"
        emit(f"fig3/fuse_step/{name}/p{parts}/{sched}", t * 1e6, detail)
    # Gate, not just report: the bound is loose (1.5x) to stay clear of
    # CPU timer noise — the two schedules measure within a few percent —
    # while still failing the bench job on a real fused-path regression.
    ratio = out["fused"] / out["perlayer"]
    assert ratio < 1.5, (
        f"fused schedule regressed: {ratio:.2f}x the per-layer step time")
    return out


_LOCAL_SWEEP_SCRIPT = """
import os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.data import GraphDataPipeline
from repro.launch.mesh import make_partition_mesh

name, iters = sys.argv[1], int(sys.argv[2])
n_locals = [int(x) for x in sys.argv[3].split(",")]
P = 8
pipeline = GraphDataPipeline.build(name, P, kind="sage")
mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim, hidden=64,
                 num_layers=2, num_classes=pipeline.dataset.num_classes,
                 dropout=0.0)
model = PipeGCN(mc, PipeConfig.named("pipegcn"))
params = model.init_params(jax.random.PRNGKey(0))
key = jax.random.PRNGKey(1)
for nl in n_locals:
    mesh = make_partition_mesh(P, parts_per_device=nl)
    step = model.make_spmd_step(mesh, pipeline.topo, "parts")
    bufs = model.init_buffers(pipeline.topo)
    loss, _, _, bufs = step(pipeline.topo, params, bufs,
                            pipeline.train_data, key)   # warmup/compile
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(iters):
        loss, _, _, bufs = step(pipeline.topo, params, bufs,
                                pipeline.train_data, key)
    jax.block_until_ready(loss)
    dt = (time.perf_counter() - t0) / iters
    print(f"RESULT,{nl},{dt * 1e6:.2f}", flush=True)
"""


def run_local_sweep(quick: bool = False):
    """Step time vs partitions-per-device at fixed P=8: the same 8-partition
    graph on 8, 4, 2 (and 1) forced host devices. Needs its own process so
    the forced device count doesn't leak into the caller's jax runtime."""
    import os
    import subprocess
    import sys

    name = "tiny" if quick else "small"
    n_locals = "1,2,4" if quick else "1,2,4,8"
    iters = 2 if quick else 4
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _LOCAL_SWEEP_SCRIPT, name, str(iters),
         n_locals], env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"local sweep failed:\n{proc.stderr[-2000:]}")
    out = {}
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, nl, us = line.split(",")
            out[int(nl)] = float(us)
            emit(f"fig3/spmd_step_local/{name}/p8/nl{nl}", float(us),
                 f"n_dev={8 // int(nl)},step_per_s={1e6 / float(us):.2f}")
    return out


def run(quick: bool = False):
    cases = CASES[:2] if quick else CASES
    out = []
    for name, parts in cases:
        pipeline = GraphDataPipeline.build(name, parts, kind="sage")
        tpl = model_template(name)
        mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                         hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                         num_classes=pipeline.dataset.num_classes,
                         dropout=0.0)
        m = epoch_model(pipeline.pg, mc, PAPER_GPU)
        emit(f"fig3/speedup_model/{name}/p{parts}", m.t_vanilla * 1e6,
             f"pipegcn_speedup={m.speedup:.2f}x,comm_ratio={m.comm_ratio:.2f}")

        # measured per-step wall time of both variants (cost parity on CPU)
        wall = {}
        for variant in ("vanilla", "pipegcn"):
            t = _measure_step(pipeline, mc, variant, iters=3 if quick else 5)
            wall[variant] = t
            emit(f"fig3/measured_step/{name}/p{parts}/{variant}", t * 1e6,
                 f"epochs_per_s={1.0 / t:.2f}")
        out.append((name, parts, m.speedup, wall))
    run_engine_comparison(quick=quick)
    run_fuse_comparison(quick=quick)
    run_local_sweep(quick=quick)
    return out


if __name__ == "__main__":
    run()
