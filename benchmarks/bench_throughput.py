"""Paper Fig. 3 + Tab. 4 (throughput columns) — PipeGCN speedup over vanilla
partition-parallel training.

Three views:
  (a) schedule-analytic speedup on the paper's hardware model (measured
      boundary bytes + FLOPs of the real shards) — expect the paper's
      1.7×–2.2× band where comm ratio is 60–85 %;
  (b) measured epochs/s of the actual jitted JAX step on this CPU (no real
      interconnect, so (b) validates step cost parity, not overlap);
  (c) COO vs block-sparse aggregation engine step time on the SAME
      partitioned graph (the topology carries both the COO shards and the
      tile streams, so only ``ModelConfig.agg`` changes). On CPU the Pallas
      kernels run in interpret mode, so (c) is an engine-dispatch/parity
      check, not an MXU speedup measurement.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import PAPER_GPU, emit, epoch_model, time_fn
from repro.core import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.core.trainer import make_jitted_train_step
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template
from repro.optim import adam

CASES = [("reddit-sim", 2), ("reddit-sim", 4),
         ("products-sim", 5), ("products-sim", 10),
         ("yelp-sim", 3), ("yelp-sim", 6)]


def _measure_step(pipeline, mc, variant: str, iters: int) -> float:
    model = PipeGCN(mc, PipeConfig.named(variant))
    opt = adam(1e-2)
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(pipeline.topo)
    state = opt.init(params)
    step = make_jitted_train_step(model, opt)
    key = jax.random.PRNGKey(1)
    # warmup (buffers are donated: thread them through)
    loss, params, state, bufs = step(pipeline.topo, params, state,
                                     bufs, pipeline.train_data, key)
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(iters):
        loss, params, state, bufs = step(pipeline.topo, params, state,
                                         bufs, pipeline.train_data, key)
    jax.block_until_ready(loss)
    return (time.perf_counter() - t0) / iters


def run_engine_comparison(quick: bool = False):
    """(c): one partitioned graph, two aggregation engines."""
    name, parts = ("tiny", 2) if quick else ("small", 4)
    pipeline = GraphDataPipeline.build(name, parts, kind="sage",
                                       agg="blocksparse")
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    out = {}
    for agg in ("coo", "blocksparse"):
        t = _measure_step(pipeline, dataclasses.replace(mc, agg=agg),
                          "pipegcn", iters=2 if quick else 3)
        out[agg] = t
        detail = f"epochs_per_s={1.0 / t:.2f}"
        if agg == "blocksparse":
            detail += f",blocksparse_over_coo={t / out['coo']:.2f}x"
        emit(f"fig3/engine_step/{name}/p{parts}/{agg}", t * 1e6, detail)
    return out


def run(quick: bool = False):
    cases = CASES[:2] if quick else CASES
    out = []
    for name, parts in cases:
        pipeline = GraphDataPipeline.build(name, parts, kind="sage")
        tpl = model_template(name)
        mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                         hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                         num_classes=pipeline.dataset.num_classes,
                         dropout=0.0)
        m = epoch_model(pipeline.pg, mc, PAPER_GPU)
        emit(f"fig3/speedup_model/{name}/p{parts}", m.t_vanilla * 1e6,
             f"pipegcn_speedup={m.speedup:.2f}x,comm_ratio={m.comm_ratio:.2f}")

        # measured per-step wall time of both variants (cost parity on CPU)
        wall = {}
        for variant in ("vanilla", "pipegcn"):
            t = _measure_step(pipeline, mc, variant, iters=3 if quick else 5)
            wall[variant] = t
            emit(f"fig3/measured_step/{name}/p{parts}/{variant}", t * 1e6,
                 f"epochs_per_s={1.0 / t:.2f}")
        out.append((name, parts, m.speedup, wall))
    run_engine_comparison(quick=quick)
    return out


if __name__ == "__main__":
    run()
