"""Paper Fig. 3 + Tab. 4 (throughput columns) — PipeGCN speedup over vanilla
partition-parallel training.

Three views:
  (a) schedule-analytic speedup on the paper's hardware model (measured
      boundary bytes + FLOPs of the real shards) — expect the paper's
      1.7×–2.2× band where comm ratio is 60–85 %;
  (b) measured epochs/s of the actual jitted JAX step on this CPU (no real
      interconnect, so (b) validates step cost parity, not overlap);
  (c) COO vs block-sparse vs FUSED aggregation engine step time on the
      SAME partitioned graph (the topology carries both the COO shards and
      the tile streams, so only ``ModelConfig.agg`` changes). On CPU the
      Pallas kernels run in interpret mode, so (c) is an engine-dispatch/
      parity check, not an MXU speedup measurement — but the fused-vs-
      unfused pair is gated at 1.1× so a fused path that added real work
      fails the bench job.
  (c') matmul-ordering sweep (aggregate-first / transform-first / auto) on
      the fused engine, with the analytic per-layer FLOP totals from
      repro.analysis.cost in the derived column.
  (c'') natural vs rcm node layout under the tile engines on the same
      partitioning — the reordered tile stream is shorter, so the step is
      gated to be no slower (interleaved min-of-ratios, <=1.1x), mirroring
      the PR-4 fused-engine gate.
  (d) SPMD step time vs partitions-per-device (n_local) at fixed P=8 on
      forced host devices — the decoupled partition/device axis; on real
      hardware this is the knob that trades per-device memory for
      interconnect fan-out.
  (e) fused-deferred vs blocking per-layer boundary exchange (2 vs 2L-1
      collectives per step) on the same graph/model — the fused schedule
      must be no slower; on real interconnects fewer, larger messages off
      the critical path is where the win compounds.
  (f) split-phase overlap vs unsplit schedule on a planar lattice (the
      low-boundary regime where the split has a real interior phase):
      identical tile work re-sliced into boundary-first + interior-behind-
      the-collective, gated at <= 1.0x the unsplit step (interleaved
      min-of-ratios). The CPU sim can't show the latency hiding — the
      gate proves the re-slicing itself costs nothing.
"""
from __future__ import annotations

import dataclasses
import time

import jax

from benchmarks.common import PAPER_GPU, emit, epoch_model
from repro.core import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.core.trainer import make_jitted_train_step
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template
from repro.optim import adam

CASES = [("reddit-sim", 2), ("reddit-sim", 4),
         ("products-sim", 5), ("products-sim", 10),
         ("yelp-sim", 3), ("yelp-sim", 6)]


def _measure_step(pipeline, mc, variant: str, iters: int,
                  pipe_kw: dict | None = None, split=None) -> float:
    model = PipeGCN(mc, dataclasses.replace(PipeConfig.named(variant),
                                            **(pipe_kw or {})), split=split)
    opt = adam(1e-2)
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(pipeline.topo)
    state = opt.init(params)
    step = make_jitted_train_step(model, opt)
    key = jax.random.PRNGKey(1)
    # warmup (buffers are donated: thread them through)
    loss, params, state, bufs = step(pipeline.topo, params, state,
                                     bufs, pipeline.train_data, key)
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(iters):
        loss, params, state, bufs = step(pipeline.topo, params, state,
                                         bufs, pipeline.train_data, key)
    jax.block_until_ready(loss)
    return (time.perf_counter() - t0) / iters


def run_engine_comparison(quick: bool = False):
    """(c): one partitioned graph, three aggregation engines. The
    fused-vs-unfused record pair (`fused` vs `blocksparse` — identical tile
    streams, the only delta is whether the dense weight contracts inside
    the Pallas grid pass) is GATED: on CPU-interpret both execute the same
    math, so fused must stay ≤ 1.1× the unfused step time (parity guard —
    the interpreter can't show the MXU/HBM win, but it does catch a fused
    path that added real work). 4 partitions even in quick mode: at p2 the
    per-pallas_call dispatch constants dominate the ms-scale step and the
    ratio measures overhead, not work."""
    name, parts = ("tiny", 4) if quick else ("small", 4)
    pipeline = GraphDataPipeline.build(name, parts, kind="sage",
                                       agg="blocksparse")
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    out = {}
    # step times are a few ms; compile dominates, so generous iters are
    # cheap and keep the fused/unfused ratio out of timer noise.
    iters = 12 if quick else 10
    out["coo"] = _measure_step(pipeline, dataclasses.replace(mc, agg="coo"),
                               "pipegcn", iters=iters)
    emit(f"fig3/engine_step/{name}/p{parts}/coo", out["coo"] * 1e6,
         f"epochs_per_s={1.0 / out['coo']:.2f}")
    # The gated pair is measured INTERLEAVED (unfused, fused) per round and
    # the gate takes the min per-round ratio: machine-state drift across a
    # long bench run (cache/thermal/CI-neighbor noise) hits both sides of a
    # round roughly equally and cancels, where a sequential min-of-times
    # still failed spuriously when the fused rounds simply ran later.
    ratios = []
    for _ in range(3 if quick else 2):
        t_un = _measure_step(pipeline,
                             dataclasses.replace(mc, agg="blocksparse"),
                             "pipegcn", iters=iters)
        t_fz = _measure_step(pipeline, dataclasses.replace(mc, agg="fused"),
                             "pipegcn", iters=iters)
        out["blocksparse"] = min(out.get("blocksparse", t_un), t_un)
        out["fused"] = min(out.get("fused", t_fz), t_fz)
        ratios.append(t_fz / t_un)
    emit(f"fig3/engine_step/{name}/p{parts}/blocksparse",
         out["blocksparse"] * 1e6,
         f"epochs_per_s={1.0 / out['blocksparse']:.2f},"
         f"blocksparse_over_coo={out['blocksparse'] / out['coo']:.2f}x")
    ratio = min(ratios)
    emit(f"fig3/engine_step/{name}/p{parts}/fused", out["fused"] * 1e6,
         f"epochs_per_s={1.0 / out['fused']:.2f},"
         f"fused_over_unfused={ratio:.3f}x")
    assert ratio <= 1.1, (
        f"fused engine regressed: {ratio:.2f}x the unfused blocksparse "
        f"step time on CPU-interpret (per-round ratios {ratios})")
    return out


def run_layout_comparison(quick: bool = False):
    """(c''): natural vs rcm node layout on the SAME partitioning, stepped
    under the blocksparse and fused engines. The reorder shrinks the tile
    stream, so even CPU-interpret (which executes every grid step in
    Python) must get no slower — gated with the interleaved min-of-ratios
    discipline of the PR-4 engine gate (each round measures natural then
    rcm so machine drift cancels; rcm <= 1.1x natural)."""
    name, parts = ("tiny", 4) if quick else ("small", 4)
    tpl = model_template(name)
    pipes = {}
    for layout in ("natural", "rcm"):
        pipes[layout] = GraphDataPipeline.build(name, parts, kind="sage",
                                                agg="blocksparse",
                                                layout=layout)
    mc0 = ModelConfig(kind="sage",
                      feat_dim=pipes["natural"].dataset.feat_dim,
                      hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                      num_classes=pipes["natural"].dataset.num_classes,
                      dropout=0.0)
    iters = 10 if quick else 8
    out = {}
    for agg in ("blocksparse", "fused"):
        mcs = {lay: dataclasses.replace(mc0, agg=agg, layout=lay)
               for lay in pipes}
        ratios, best = [], {}
        for _ in range(3 if quick else 2):
            t_nat = _measure_step(pipes["natural"], mcs["natural"],
                                  "pipegcn", iters=iters)
            t_rcm = _measure_step(pipes["rcm"], mcs["rcm"], "pipegcn",
                                  iters=iters)
            best["natural"] = min(best.get("natural", t_nat), t_nat)
            best["rcm"] = min(best.get("rcm", t_rcm), t_rcm)
            ratios.append(t_rcm / t_nat)
        ratio = min(ratios)
        n_nat = pipes["natural"].topo.tile_rows.shape[-1]
        n_rcm = pipes["rcm"].topo.tile_rows.shape[-1]
        emit(f"fig3/layout_step/{name}/p{parts}/{agg}/rcm",
             best["rcm"] * 1e6,
             f"natural_us={best['natural'] * 1e6:.0f},"
             f"rcm_over_natural={ratio:.3f}x,"
             f"tile_stream={n_nat}->{n_rcm}")
        out[agg] = ratio
        assert ratio <= 1.1, (
            f"rcm layout regressed the {agg} step: {ratio:.2f}x the "
            f"natural-layout step time on CPU-interpret "
            f"(per-round ratios {ratios})")
    return out


def run_order_comparison(quick: bool = False):
    """Matmul-ordering sweep: the same graph/model stepped under
    aggregate-first, transform-first, and the cost-model "auto" choice
    (which may mix per layer). CPU step times are reported for the
    trajectory; the real signal is the analytic FLOP ratio in `derived`
    (from repro.analysis.cost), which is hardware-independent."""
    from repro.analysis.cost import gcn_order_report
    name, parts = ("tiny", 2) if quick else ("small", 4)
    pipeline = GraphDataPipeline.build(name, parts, kind="sage",
                                       agg="fused")
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes, dropout=0.0,
                     agg="fused")
    topo = pipeline.topo
    n_tiles = topo.tile_rows.shape[-1]
    combined = topo.max_inner + topo.halo_size
    from repro.kernels.gcn_spmm import TILE
    nnz_eff = n_tiles * TILE * TILE
    report = gcn_order_report(mc.layer_dims(), topo.max_inner, combined,
                              nnz_eff, train=True, fused=True)
    flops = {o: sum(r["costs"][o].flops for r in report)
             for o in ("aggregate-first", "transform-first")}
    auto_flops = sum(r["costs"][r["chosen"]].flops for r in report)
    out = {}
    for order in ("aggregate-first", "transform-first", "auto"):
        t = _measure_step(pipeline,
                          dataclasses.replace(mc, matmul_order=order),
                          "pipegcn", iters=4 if quick else 6)
        out[order] = t
        model_flops = auto_flops if order == "auto" else flops[order]
        emit(f"fig3/order_step/{name}/p{parts}/{order}", t * 1e6,
             f"epochs_per_s={1.0 / t:.2f},"
             f"model_flops_per_part={model_flops:.3e}")
    # the cost model's choice can never be worse than either fixed order
    assert auto_flops <= min(flops.values()) + 1e-6
    return out


def run_fuse_comparison(quick: bool = False):
    """Fused-deferred vs blocking per-layer exchange on the same graph and
    model: 2 vs 2L-1 boundary collectives per step. Acceptance: the fused
    schedule's step time is no worse than per-layer (the packed collective
    moves identical bytes in fewer, larger messages and sits off the
    critical path)."""
    name, parts = ("tiny", 2) if quick else ("small", 4)
    pipeline = GraphDataPipeline.build(name, parts, kind="sage")
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    out = {}
    # step time is a few ms; compile dominates, so generous iters are cheap
    # and keep the fused/perlayer ratio out of timer noise
    iters = 10 if quick else 20
    for fuse in (False, True):
        sched = "fused" if fuse else "perlayer"
        t = _measure_step(pipeline, mc, "pipegcn", iters,
                          pipe_kw={"fuse_exchange": fuse})
        out[sched] = t
        detail = f"epochs_per_s={1.0 / t:.2f}"
        if fuse:
            detail += f",fused_over_perlayer={t / out['perlayer']:.3f}x"
        emit(f"fig3/fuse_step/{name}/p{parts}/{sched}", t * 1e6, detail)
    # Gate, not just report: the bound is loose (1.5x) to stay clear of
    # CPU timer noise — the two schedules measure within a few percent —
    # while still failing the bench job on a real fused-path regression.
    ratio = out["fused"] / out["perlayer"]
    assert ratio < 1.5, (
        f"fused schedule regressed: {ratio:.2f}x the per-layer step time")
    return out


def run_overlap_comparison(quick: bool = False):
    """(f): split-phase vs unsplit schedule, same graph/model/engine. The
    lattice datasets are the only ones where the rcm layout clusters a
    boundary tail small enough for a feasible split (the power-law sims
    are 96-100% boundary, so the split degenerates there and falls back).
    The split executes the SAME tiles — a static suffix/prefix re-slicing
    of one stream into two pallas_calls with the exchange issued between
    them — so even CPU-interpret must not get slower: gated at <= 1.0x
    with the interleaved min-of-ratios discipline (each round measures
    unsplit then split so machine drift cancels; min per-round ratio)."""
    from benchmarks.common import emit_meta
    name, parts = ("grid-tiny", 4) if quick else ("grid-sim", 4)
    pipeline = GraphDataPipeline.build(name, parts, kind="sage",
                                       agg="blocksparse", layout="rcm")
    sp = pipeline.split_spec()
    assert sp is not None, f"{name} must admit a feasible split under rcm"
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes, dropout=0.0,
                     agg="blocksparse", layout="rcm")
    iters = 10 if quick else 8
    ratios, best = [], {}
    for _ in range(4 if quick else 3):
        t_un = _measure_step(pipeline, mc, "pipegcn", iters,
                             pipe_kw={"overlap": "none"})
        t_sp = _measure_step(pipeline, mc, "pipegcn", iters,
                             pipe_kw={"overlap": "split-phase"}, split=sp)
        best["unsplit"] = min(best.get("unsplit", t_un), t_un)
        best["split"] = min(best.get("split", t_sp), t_sp)
        ratios.append(t_sp / t_un)
    ratio = min(ratios)
    n_tiles = pipeline.topo.tile_rows.shape[-1]
    emit(f"fig3/overlap_step/{name}/p{parts}/split", best["split"] * 1e6,
         f"unsplit_us={best['unsplit'] * 1e6:.0f},"
         f"split_over_unsplit={ratio:.3f}x,"
         f"bnd_tiles={sp.fwd_bnd_tiles}/{n_tiles}")
    emit_meta("overlap_split", {f"{name}/p{parts}": {
        "fwd_bnd_tiles": sp.fwd_bnd_tiles, "t_bnd_tiles": sp.t_bnd_tiles,
        "n_tiles": n_tiles, "row_tail": sp.row_tail,
        "col_tail": sp.col_tail}})
    assert ratio <= 1.0, (
        f"split-phase schedule regressed: {ratio:.3f}x the unsplit step "
        f"time on CPU-interpret (per-round ratios {ratios}) — the split "
        f"re-slices the identical tile stream, so any slowdown is real "
        f"added work, not hidden latency")
    return ratio


_LOCAL_SWEEP_SCRIPT = """
import os, sys, time
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from repro.core import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.data import GraphDataPipeline
from repro.launch.mesh import make_partition_mesh

name, iters = sys.argv[1], int(sys.argv[2])
n_locals = [int(x) for x in sys.argv[3].split(",")]
P = 8
pipeline = GraphDataPipeline.build(name, P, kind="sage")
mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim, hidden=64,
                 num_layers=2, num_classes=pipeline.dataset.num_classes,
                 dropout=0.0)
model = PipeGCN(mc, PipeConfig.named("pipegcn"))
params = model.init_params(jax.random.PRNGKey(0))
key = jax.random.PRNGKey(1)
for nl in n_locals:
    mesh = make_partition_mesh(P, parts_per_device=nl)
    step = model.make_spmd_step(mesh, pipeline.topo, "parts")
    bufs = model.init_buffers(pipeline.topo)
    loss, _, _, bufs = step(pipeline.topo, params, bufs,
                            pipeline.train_data, key)   # warmup/compile
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(iters):
        loss, _, _, bufs = step(pipeline.topo, params, bufs,
                                pipeline.train_data, key)
    jax.block_until_ready(loss)
    dt = (time.perf_counter() - t0) / iters
    print(f"RESULT,{nl},{dt * 1e6:.2f}", flush=True)
"""


def run_local_sweep(quick: bool = False):
    """Step time vs partitions-per-device at fixed P=8: the same 8-partition
    graph on 8, 4, 2 (and 1) forced host devices. Needs its own process so
    the forced device count doesn't leak into the caller's jax runtime."""
    import os
    import subprocess
    import sys

    name = "tiny" if quick else "small"
    n_locals = "1,2,4" if quick else "1,2,4,8"
    iters = 2 if quick else 4
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _LOCAL_SWEEP_SCRIPT, name, str(iters),
         n_locals], env=env, capture_output=True, text=True, timeout=900)
    if proc.returncode != 0:
        raise RuntimeError(f"local sweep failed:\n{proc.stderr[-2000:]}")
    out = {}
    for line in proc.stdout.splitlines():
        if line.startswith("RESULT,"):
            _, nl, us = line.split(",")
            out[int(nl)] = float(us)
            emit(f"fig3/spmd_step_local/{name}/p8/nl{nl}", float(us),
                 f"n_dev={8 // int(nl)},step_per_s={1e6 / float(us):.2f}")
    return out


def run(quick: bool = False):
    cases = CASES[:2] if quick else CASES
    out = []
    for name, parts in cases:
        pipeline = GraphDataPipeline.build(name, parts, kind="sage")
        tpl = model_template(name)
        mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                         hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                         num_classes=pipeline.dataset.num_classes,
                         dropout=0.0)
        m = epoch_model(pipeline.pg, mc, PAPER_GPU)
        emit(f"fig3/speedup_model/{name}/p{parts}", m.t_vanilla * 1e6,
             f"pipegcn_speedup={m.speedup:.2f}x,comm_ratio={m.comm_ratio:.2f}")

        # measured per-step wall time of both variants (cost parity on CPU)
        wall = {}
        for variant in ("vanilla", "pipegcn"):
            t = _measure_step(pipeline, mc, variant, iters=3 if quick else 5)
            wall[variant] = t
            emit(f"fig3/measured_step/{name}/p{parts}/{variant}", t * 1e6,
                 f"epochs_per_s={1.0 / t:.2f}")
        out.append((name, parts, m.speedup, wall))
    run_engine_comparison(quick=quick)
    run_layout_comparison(quick=quick)
    run_order_comparison(quick=quick)
    run_fuse_comparison(quick=quick)
    run_overlap_comparison(quick=quick)
    run_local_sweep(quick=quick)
    return out


if __name__ == "__main__":
    run()
