"""Paper Tab. 2 — communication ratio of vanilla partition-parallel training.

Measured boundary bytes from the real partitioner on the simulated datasets,
evaluated on the paper's hardware model. The paper reports 61–86 %; the
reproduction should land in that band and grow with #partitions.
"""
from __future__ import annotations

from benchmarks.common import PAPER_GPU, emit, epoch_model
from repro.core.config import ModelConfig
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template

CASES = [("reddit-sim", 2), ("reddit-sim", 4),
         ("products-sim", 5), ("products-sim", 10),
         ("yelp-sim", 3), ("yelp-sim", 6)]


def run(quick: bool = False):
    cases = CASES[:2] if quick else CASES
    rows = []
    for name, parts in cases:
        pipeline = GraphDataPipeline.build(name, parts, kind="sage")
        tpl = model_template(name)
        mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                         hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                         num_classes=pipeline.dataset.num_classes)
        m = epoch_model(pipeline.pg, mc, PAPER_GPU)
        rows.append((name, parts, m.comm_ratio))
        emit(f"table2/comm_ratio/{name}/p{parts}", m.t_vanilla * 1e6,
             f"comm_ratio={m.comm_ratio:.3f}")
    # paper claim: ratio grows with #partitions per dataset
    by = {}
    for name, parts, ratio in rows:
        by.setdefault(name, []).append((parts, ratio))
    for name, xs in by.items():
        xs.sort()
        assert all(b >= a - 0.02 for (_, a), (_, b) in zip(xs, xs[1:])), (
            name, xs)
    return rows


if __name__ == "__main__":
    run()
