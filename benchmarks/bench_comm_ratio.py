"""Paper Tab. 2 — communication ratio of vanilla partition-parallel training,
plus the per-step collective COUNT of the two communication schedules.

Measured boundary bytes from the real partitioner on the simulated datasets,
evaluated on the paper's hardware model. The paper reports 61–86 %; the
reproduction should land in that band and grow with #partitions.

The collective-count sweep traces the actual SPMD train step to a jaxpr and
counts `all_to_all` eqns: the fused-deferred schedule must show exactly 2
per training step (1 forward + 1 backward) against 2L-1 for the blocking
per-layer schedule. The counts are asserted against the analytic math and
recorded into the JSON trajectory artifact (BENCH_*.json) so CI pins them.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import PAPER_GPU, emit, emit_meta, epoch_model
from repro.core.config import ModelConfig, PipeConfig
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template

CASES = [("reddit-sim", 2), ("reddit-sim", 4),
         ("products-sim", 5), ("products-sim", 10),
         ("yelp-sim", 3), ("yelp-sim", 6)]


def run_collective_counts(quick: bool = False):
    """Traced per-step boundary-collective counts, fused vs per-layer.

    Runs on a 1-device mesh hosting all partitions co-resident — the jaxpr
    still contains every `all_to_all` the multi-device program would issue,
    so the count is layout-independent.
    """
    from repro.core.pipegcn import PipeGCN
    from repro.core.trace_utils import (expected_boundary_collectives,
                                        traced_step_collectives)
    from repro.launch.mesh import make_partition_mesh

    P = 4
    pipeline = GraphDataPipeline.build("tiny", P, kind="sage")
    layer_counts = (2, 3) if quick else (2, 3, 4)
    counts_meta = {}
    for L in layer_counts:
        mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                         hidden=16, num_layers=L,
                         num_classes=pipeline.dataset.num_classes,
                         dropout=0.0)
        mesh = make_partition_mesh(P, parts_per_device=P)
        for fuse in (False, True):
            pc = dataclasses.replace(PipeConfig.named("pipegcn"),
                                     fuse_exchange=fuse)
            model = PipeGCN(mc, pc)
            got = traced_step_collectives(mesh=mesh, model=model,
                                          topo=pipeline.topo,
                                          data=pipeline.train_data,
                                          train=True)
            want = expected_boundary_collectives(L, pc.fused, train=True)
            assert got["all_to_all"] == want, (
                f"collective-count regression: L={L} fuse={fuse} traced "
                f"{got['all_to_all']} all_to_all, expected {want}")
            # counts go to meta only — the records list is the timing
            # trajectory (us_per_call), and a count is not a timing
            sched = "fused" if fuse else "perlayer"
            print(f"# collectives L{L}/{sched}: "
                  f"all_to_all={got['all_to_all']} psum={got['psum']} "
                  f"expected={want}", flush=True)
            counts_meta[f"L{L}/{sched}"] = {
                "all_to_all": got["all_to_all"], "psum": got["psum"],
                "expected_all_to_all": want}
    emit_meta("collective_counts", counts_meta)
    return counts_meta


def run_wire_sweep(quick: bool = False):
    """ISSUE 8 acceptance gate: traced bytes-on-wire per wire format.

    Traces the fused-exchange training step on reddit-sim (P=4, template
    model) and sums the all_to_all operand bytes — shape/dtype static, so
    the figure is exact and machine-independent. Gates the quantized
    codecs' traffic: bf16 exactly 0.5x f32, int8 <= 0.27x, int4 <= 0.15x
    (the slack over the ideal 1/4 and 1/8 is the per-128-column f32 scale
    region, see docs/wire-format.md).
    """
    from repro.core.pipegcn import PipeGCN
    from repro.core.trace_utils import traced_step_wire_bytes
    from repro.launch.mesh import make_partition_mesh

    P = 4
    pipeline = GraphDataPipeline.build("reddit-sim", P, kind="sage")
    tpl = model_template("reddit-sim")
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    mesh = make_partition_mesh(P, parts_per_device=P)
    got = {}
    for wire in ("f32", "bf16", "int8", "int4"):
        pc = dataclasses.replace(PipeConfig.named("pipegcn"),
                                 fuse_exchange=True, wire=wire)
        model = PipeGCN(mc, pc)
        got[wire] = traced_step_wire_bytes(model, mesh, pipeline.topo,
                                           pipeline.train_data, train=True)
        # us_per_call is 0: this is a byte count, not a timing — the
        # trajectory record pins coverage, the meta pins the exact bytes
        emit(f"table2/wire_bytes/{wire}", 0.0,
             f"bytes={got[wire]} ratio={got[wire] / got['f32']:.4f}")
    assert got["bf16"] * 2 == got["f32"], got
    assert got["int8"] <= 0.27 * got["f32"], got
    assert got["int4"] <= 0.15 * got["f32"], got
    emit_meta("wire_bytes", {
        w: {"bytes": int(b), "pct_of_f32": int(round(100.0 * b / got["f32"]))}
        for w, b in got.items()})
    return got


def run(quick: bool = False):
    cases = CASES[:2] if quick else CASES
    rows = []
    for name, parts in cases:
        pipeline = GraphDataPipeline.build(name, parts, kind="sage")
        tpl = model_template(name)
        mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                         hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                         num_classes=pipeline.dataset.num_classes)
        m = epoch_model(pipeline.pg, mc, PAPER_GPU)
        rows.append((name, parts, m.comm_ratio))
        emit(f"table2/comm_ratio/{name}/p{parts}", m.t_vanilla * 1e6,
             f"comm_ratio={m.comm_ratio:.3f}")
    # paper claim: ratio grows with #partitions per dataset
    by = {}
    for name, parts, ratio in rows:
        by.setdefault(name, []).append((parts, ratio))
    for name, xs in by.items():
        xs.sort()
        assert all(b >= a - 0.02 for (_, a), (_, b) in zip(xs, xs[1:])), (
            name, xs)
    run_collective_counts(quick=quick)
    run_wire_sweep(quick=quick)
    return rows


if __name__ == "__main__":
    run()
