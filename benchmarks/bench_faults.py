"""Fault-injection matrix (ISSUE 9): guard overhead, zero-fault identity,
and degraded-run convergence.

Three claims, each gated in-process (assertion -> bench FAILURE, not a
drifting number):

1. IDENTITY — `guard_exchange=True` with no faults is bitwise invisible:
   across (variant x wire x staleness-depth) cells the guarded step
   produces the exact same loss bits as the unguarded step and the es
   counters stay zero; the jaxpr collective counts are identical (the
   checksum column rides inside the existing wires).
2. DEGRADED CONVERGENCE — a 5% exchange-drop rate under the guard
   converges within 1 accuracy point of the fault-free run; effective
   staleness never exceeds `max_staleness`; every fallback is counted.
   The fallback/es counters are DETERMINISTIC (seeded host-side fault
   tables; drops are always detected), so they are emitted as structural
   meta ints and exact-gated against the checked-in baseline.
3. OVERHEAD — the guarded step costs <= 1.35x the unguarded step
   (checksum encode/verify + select fallback), measured interleaved on
   the running machine.
4. ELASTIC DRILL (ISSUE 10) — a device killed mid-run is detected,
   its partitions are remapped onto the survivors, and the run finishes
   within 1 accuracy point of the loss-free run; the replay window is
   bounded by checkpoint_every + detect_after. Deterministic ints
   (detection epoch, restore step, recovery count) are exact-gated.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from benchmarks.common import emit, emit_meta, time_fn
from repro.core import FaultPlan, ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.core.trainer import train_pipegcn
from repro.data import GraphDataPipeline
from repro.optim import adam

# (variant, wire, staleness_steps): the identity matrix — every wire
# format crossed with FIFO depth and smoothing.
IDENTITY_CELLS = [
    ("pipegcn", "f32", 1),
    ("pipegcn", "bf16", 1),
    ("pipegcn", "int8", 1),
    ("pipegcn", "int4", 1),
    ("pipegcn", "f32", 2),
    ("pipegcn", "int8", 2),
    ("pipegcn-gf", "f32", 1),
    ("pipegcn-gf", "int8", 1),
]

# (variant, wire, staleness_steps, fault rate): the degraded-run matrix.
DEGRADED_CELLS = [
    ("pipegcn", "f32", 1, 0.05),
    ("pipegcn-gf", "int8", 1, 0.05),
    ("pipegcn", "f32", 2, 0.05),
]


def _models(pipeline, variant, wire, k, **extra):
    ds = pipeline.dataset
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=32,
                     num_layers=3, num_classes=ds.num_classes,
                     dropout=0.0, multilabel=ds.multilabel)
    pc = dataclasses.replace(PipeConfig.named(variant, gamma=0.95),
                             wire=wire, staleness_steps=k, **extra)
    return mc, pc


def _identity(pipeline) -> dict:
    topo, data = pipeline.topo, pipeline.train_data
    facts = {"cells": len(IDENTITY_CELLS)}
    for variant, wire, k in IDENTITY_CELLS:
        mc, pc = _models(pipeline, variant, wire, k)
        ref = PipeGCN(mc, pc)
        grd = PipeGCN(mc, dataclasses.replace(pc, guard_exchange=True))
        params = ref.init_params(jax.random.PRNGKey(0))
        b_ref, b_grd = ref.init_buffers(topo), grd.init_buffers(topo)
        identical = True
        for t in range(3):
            key = jax.random.PRNGKey(t)
            l0, _, b_ref, _ = ref.train_step(topo, params, b_ref, data, key)
            l1, _, b_grd, _ = grd.train_step(topo, params, b_grd, data, key)
            identical &= float(l0) == float(l1)
            identical &= int(np.asarray(b_grd["es"]).max()) == 0
        name = f"faults/identity/{variant}/{wire}/k{k}"
        emit(name, 0.0, f"bitwise={identical}")
        assert identical, f"{name}: guard_exchange changed the zero-fault run"
        facts[f"{variant}/{wire}/k{k}"] = {"bitwise": bool(identical)}
    return facts


def _collectives(pipeline) -> dict:
    from repro.core.trace_utils import traced_step_collectives
    from repro.launch.mesh import make_partition_mesh
    P = pipeline.topo.num_parts
    mesh = make_partition_mesh(P, parts_per_device=P)
    mc, pc = _models(pipeline, "pipegcn", "f32", 1)
    c_ref = traced_step_collectives(PipeGCN(mc, pc), mesh,
                                    pipeline.topo, pipeline.train_data)
    c_grd = traced_step_collectives(
        PipeGCN(mc, dataclasses.replace(pc, guard_exchange=True)), mesh,
        pipeline.topo, pipeline.train_data)
    assert c_ref == c_grd, (
        f"guard_exchange changed the collective schedule: {c_ref} -> {c_grd}")
    emit("faults/collectives/guard_invariant", 0.0,
         ",".join(f"{k}={v}" for k, v in sorted(c_grd.items())))
    return {f"guarded_{k}": int(v) for k, v in sorted(c_grd.items())}


def _degraded(pipeline, epochs: int) -> dict:
    facts = {}
    for variant, wire, k, rate in DEGRADED_CELLS:
        mc, pc = _models(pipeline, variant, wire, k, guard_exchange=True,
                         max_staleness=max(8, k + 4))
        clean = train_pipegcn(pipeline, mc, pc, epochs=epochs,
                              eval_every=epochs)
        plan = FaultPlan(rate=rate, rate_kind="drop", seed=1)
        faulty = train_pipegcn(pipeline, mc, pc, epochs=epochs,
                               eval_every=epochs, faults=plan)
        v0, v1 = clean.final_metrics["val"], faulty.final_metrics["val"]
        gap = abs(v0 - v1)
        fb = faulty.anomalies["exchange_fallbacks"]
        es = faulty.anomalies["max_effective_staleness"]
        name = f"faults/degraded/{variant}/{wire}/k{k}/rate{rate}"
        emit(name, 0.0, f"val_clean={v0:.4f},val_faulty={v1:.4f},"
                        f"gap={gap:.4f},fallbacks={fb},es_max={es}")
        assert gap <= 0.01, (
            f"{name}: {rate:.0%} drop rate moved val accuracy by "
            f"{gap:.4f} (> 1 point): {v0:.4f} -> {v1:.4f}")
        assert es <= pc.max_staleness, (name, es, pc.max_staleness)
        assert fb > 0, f"{name}: a {rate:.0%} plan injected zero fallbacks?"
        facts[f"{variant}/{wire}/k{k}"] = {
            "fallbacks": int(fb), "es_max": int(es),
            "within_1pt": bool(gap <= 0.01)}
    return facts


def _elastic_drill(pipeline, epochs: int) -> dict:
    """Device-loss drill (ISSUE 10): kill a device mid-run, recover by
    survivor remap, and gate the availability story — exactly one
    recovery fires, the replay window is bounded by
    checkpoint_every + detect_after, and final accuracy stays within 1
    point of the loss-free run. Every emitted int is deterministic
    (seeded run, declarative fault step), so the record is exact-gated
    against the checked-in baseline."""
    import tempfile

    from repro.core import ElasticConfig, device_down_site
    mc, pc = _models(pipeline, "pipegcn", "f32", 1, guard_exchange=True,
                     max_staleness=8)
    ec = ElasticConfig(parts_per_device=1, rejoin=False)
    every, kill = 5, epochs // 2
    clean = train_pipegcn(pipeline, mc, pc, epochs=epochs,
                          eval_every=epochs, elastic=ec)
    plan = FaultPlan(sites=(device_down_site(step=kill, device=1),))
    with tempfile.TemporaryDirectory() as d:
        drilled = train_pipegcn(pipeline, mc, pc, epochs=epochs,
                                eval_every=epochs, elastic=ec, faults=plan,
                                ckpt_dir=d, checkpoint_every=every)
    v0, v1 = clean.final_metrics["val"], drilled.final_metrics["val"]
    gap = abs(v0 - v1)
    loss = drilled.anomalies["device_losses"][0]
    replay = loss["detected_epoch"] - loss["resumed_from"]
    name = "faults/elastic/device_down/P4-1dev"
    emit(name, 0.0, f"val_clean={v0:.4f},val_drilled={v1:.4f},gap={gap:.4f},"
                    f"detected={loss['detected_epoch']},"
                    f"resumed_from={loss['resumed_from']},replay={replay}")
    assert drilled.recoveries == 1, drilled.recoveries
    assert clean.recoveries == 0 and not clean.anomalies["device_losses"]
    assert replay <= every + ec.detect_after, (
        f"{name}: replay window {replay} exceeds checkpoint_every={every} "
        f"+ detect_after={ec.detect_after}")
    assert gap <= 0.01, (
        f"{name}: losing a device moved val accuracy by {gap:.4f} "
        f"(> 1 point): {v0:.4f} -> {v1:.4f}")
    return {"device": int(loss["device"]),
            "detected_epoch": int(loss["detected_epoch"]),
            "resumed_from": int(loss["resumed_from"]),
            "recoveries": int(drilled.recoveries),
            "within_1pt": bool(gap <= 0.01)}


def _overhead(pipeline) -> None:
    topo, data = pipeline.topo, pipeline.train_data
    mc, pc = _models(pipeline, "pipegcn", "f32", 1)
    opt = adam(0.01)

    def mk(model):
        params = model.init_params(jax.random.PRNGKey(0))
        state = opt.init(params)
        bufs = model.init_buffers(topo)

        @jax.jit
        def one(params, state, bufs, key):
            loss, grads, nb, _ = model.train_step(topo, params, bufs,
                                                  data, key)
            params, state = opt.apply(params, grads, state)
            return loss, params, state, nb

        return one, params, state, bufs

    key = jax.random.PRNGKey(0)
    ratios = []
    # interleaved A/B: immune to machine speed, robust to drift
    f0, p0, s0, b0 = mk(PipeGCN(mc, pc))
    f1, p1, s1, b1 = mk(PipeGCN(mc, dataclasses.replace(
        pc, guard_exchange=True)))
    for _ in range(3):
        t_ref = time_fn(f0, p0, s0, b0, key, iters=5)
        t_grd = time_fn(f1, p1, s1, b1, key, iters=5)
        ratios.append(t_grd / t_ref)
    ratio = min(ratios)
    emit("faults/overhead/guarded_step", ratio * 100.0,
         f"guarded/unguarded={ratio:.3f}x")
    assert ratio <= 1.35, (
        f"guarded step costs {ratio:.2f}x the unguarded step (gate: 1.35x)")


def run(quick: bool = False):
    pipeline = GraphDataPipeline.build("tiny" if quick else "reddit-sim",
                                       num_parts=4, kind="sage")
    epochs = 30 if quick else 60
    emit_meta("faults", {"dataset": "tiny" if quick else "reddit-sim",
                         "epochs": epochs})
    emit_meta("faults", {"identity": _identity(pipeline)})
    emit_meta("faults", {"collectives": _collectives(pipeline)})
    emit_meta("faults", {"degraded": _degraded(pipeline, epochs)})
    emit_meta("faults", {"elastic": _elastic_drill(pipeline, epochs)})
    _overhead(pipeline)


if __name__ == "__main__":
    run(quick=True)
