"""Benchmark orchestrator — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit).
``python -m benchmarks.run`` runs the quick variants; ``--full`` runs the
paper-scale versions (minutes on CPU).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def _structural_leaves(node, prefix=""):
    """Flatten META to (path, value) pairs, keeping only machine-independent
    leaves (ints / bools / strings — tile counts, collective counts,
    schedule facts). Floats are timings or derived ratios and are skipped:
    the baseline is recorded on different hardware than CI replays it on."""
    if isinstance(node, dict):
        for k, v in node.items():
            yield from _structural_leaves(v, f"{prefix}{k}/")
    elif isinstance(node, bool) or isinstance(node, int) or \
            isinstance(node, str):
        yield prefix.rstrip("/"), node


def diff_baseline(path: str, records: list[dict], meta: dict) -> int:
    """Regression gate against a checked-in BENCH_*.json baseline.

    Hard-fails on (a) record names present in the baseline but missing from
    this run — benchmark coverage silently shrank — and (b) structural META
    mismatches (per-step collective counts, tile counts, overlap phase
    sizes: deterministic facts that must reproduce exactly on any machine).
    Timing drift is reported but NOT gated here; the per-bench interleaved
    ratio gates (fused/layout/overlap) own wall-clock regressions because
    they self-normalize on the running machine. Run with the same --only
    set the baseline was recorded with."""
    import json
    with open(path) as f:
        base = json.load(f)
    failures = 0
    cur_by_name = {r["name"]: r for r in records}
    missing = [n for n in (r["name"] for r in base["records"])
               if n not in cur_by_name]
    if missing:
        failures += 1
        print(f"# baseline DIFF: {len(missing)} record(s) in {path} "
              f"missing from this run: {missing[:8]}", flush=True)
    base_leaves = dict(_structural_leaves(base.get("meta", {})))
    cur_leaves = dict(_structural_leaves(meta))
    for key, bval in base_leaves.items():
        if key not in cur_leaves:
            failures += 1
            print(f"# baseline DIFF: meta {key} missing "
                  f"(baseline {bval!r})", flush=True)
        elif cur_leaves[key] != bval:
            failures += 1
            print(f"# baseline DIFF: meta {key} = {cur_leaves[key]!r}, "
                  f"baseline {bval!r}", flush=True)
    # informational timing drift (worst 5 by ratio)
    drifts = []
    for r in base["records"]:
        cur = cur_by_name.get(r["name"])
        if cur and r["us_per_call"] > 0 and cur["us_per_call"] > 0:
            drifts.append((cur["us_per_call"] / r["us_per_call"], r["name"]))
    for ratio, name in sorted(drifts, reverse=True)[:5]:
        print(f"# baseline drift: {name} {ratio:.2f}x", flush=True)
    if not failures:
        print(f"# baseline OK: {len(base['records'])} records matched "
              f"against {path}, {len(base_leaves)} structural leaves equal",
              flush=True)
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: comm_ratio,throughput,accuracy,error,"
                         "gamma,scale,breakdown,rate,kernels,roofline,faults")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the emitted rows + structured metadata "
                         "(per-step collective counts) as a JSON artifact "
                         "(the CI perf trajectory, BENCH_*.json)")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="checked-in BENCH_*.json to diff against "
                         "(benchmarks/baselines/): fail on shrunk record "
                         "coverage or changed structural metadata; timing "
                         "drift is reported, the interleaved ratio gates "
                         "own wall-clock regressions")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (bench_accuracy, bench_breakdown, bench_comm_ratio,
                            bench_convergence, bench_error, bench_faults,
                            bench_gamma, bench_kernels, bench_rate,
                            bench_scale, bench_throughput, roofline)
    table = {
        "comm_ratio": bench_comm_ratio.run,      # Tab. 2
        "throughput": bench_throughput.run,      # Fig. 3 / Tab. 4 (thpt)
        "accuracy": bench_accuracy.run,          # Tab. 4 (scores)
        "convergence": bench_convergence.run,    # Fig. 4 / 9 (+ k ablation)
        "error": bench_error.run,                # Fig. 5 / 7
        "gamma": bench_gamma.run,                # Fig. 6
        "scale": bench_scale.run,                # Tab. 5 / App. E
        "breakdown": bench_breakdown.run,        # Tab. 6 / Fig. 8 / App. C
        "rate": bench_rate.run,                  # Thm. 3.1 / Cor. A.10
        "kernels": bench_kernels.run,            # Pallas kernels
        "roofline": roofline.run,                # §Roofline from dry-run
        "faults": bench_faults.run,              # ISSUE 9 fault tolerance
    }
    from benchmarks import common
    common.reset_records()
    only = set(args.only.split(",")) if args.only else set(table)
    unknown = only - set(table)
    if unknown:
        # A typo/rename in --only must not let the gate pass while running
        # zero benchmarks (and uploading an empty artifact).
        sys.exit(f"unknown bench name(s) {sorted(unknown)}; "
                 f"have {sorted(table)}")
    failures = 0
    durations = {}
    for name, fn in table.items():
        if name not in only:
            continue
        t0 = time.perf_counter()
        try:
            fn(quick=quick)
            durations[name] = round(time.perf_counter() - t0, 1)
            print(f"# bench {name}: done in {durations[name]}s", flush=True)
        except Exception:
            failures += 1
            print(f"# bench {name}: FAILED", flush=True)
            traceback.print_exc()
    if args.baseline:
        failures += diff_baseline(args.baseline, common.RECORDS, common.META)
    if args.json:
        import json
        payload = {"quick": quick, "benches": sorted(only),
                   "durations_s": durations, "failures": failures,
                   "records": common.RECORDS, "meta": common.META}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json} ({len(common.RECORDS)} records)",
              flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
