"""Benchmark orchestrator — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (benchmarks/common.emit).
``python -m benchmarks.run`` runs the quick variants; ``--full`` runs the
paper-scale versions (minutes on CPU).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None,
                    help="comma list: comm_ratio,throughput,accuracy,error,"
                         "gamma,scale,breakdown,rate,kernels,roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the emitted rows + structured metadata "
                         "(per-step collective counts) as a JSON artifact "
                         "(the CI perf trajectory, BENCH_*.json)")
    args, _ = ap.parse_known_args()
    quick = not args.full

    from benchmarks import (bench_accuracy, bench_breakdown, bench_comm_ratio,
                            bench_convergence, bench_error, bench_gamma,
                            bench_kernels, bench_rate, bench_scale,
                            bench_throughput, roofline)
    table = {
        "comm_ratio": bench_comm_ratio.run,      # Tab. 2
        "throughput": bench_throughput.run,      # Fig. 3 / Tab. 4 (thpt)
        "accuracy": bench_accuracy.run,          # Tab. 4 (scores)
        "convergence": bench_convergence.run,    # Fig. 4 / 9 (+ k ablation)
        "error": bench_error.run,                # Fig. 5 / 7
        "gamma": bench_gamma.run,                # Fig. 6
        "scale": bench_scale.run,                # Tab. 5 / App. E
        "breakdown": bench_breakdown.run,        # Tab. 6 / Fig. 8 / App. C
        "rate": bench_rate.run,                  # Thm. 3.1 / Cor. A.10
        "kernels": bench_kernels.run,            # Pallas kernels
        "roofline": roofline.run,                # §Roofline from dry-run
    }
    from benchmarks import common
    common.reset_records()
    only = set(args.only.split(",")) if args.only else set(table)
    unknown = only - set(table)
    if unknown:
        # A typo/rename in --only must not let the gate pass while running
        # zero benchmarks (and uploading an empty artifact).
        sys.exit(f"unknown bench name(s) {sorted(unknown)}; "
                 f"have {sorted(table)}")
    failures = 0
    durations = {}
    for name, fn in table.items():
        if name not in only:
            continue
        t0 = time.perf_counter()
        try:
            fn(quick=quick)
            durations[name] = round(time.perf_counter() - t0, 1)
            print(f"# bench {name}: done in {durations[name]}s", flush=True)
        except Exception:
            failures += 1
            print(f"# bench {name}: FAILED", flush=True)
            traceback.print_exc()
    if args.json:
        import json
        payload = {"quick": quick, "benches": sorted(only),
                   "durations_s": durations, "failures": failures,
                   "records": common.RECORDS, "meta": common.META}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {args.json} ({len(common.RECORDS)} records)",
              flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
