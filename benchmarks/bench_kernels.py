"""Kernel microbenchmarks: block-sparse SpMM (forward + transpose) vs the
COO segment_sum engine on the same partition shard, the FUSED
aggregate+transform kernels vs the composed two-op path, the offline tile
extraction, and flash attention (interpret mode on CPU — correctness +
tile statistics; wall numbers are CPU-only)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.gcn_spmm import TILE, build_tile_topology, tile_density
from repro.kernels import ops
from repro.kernels.aggregate import get_engine
from repro.kernels.ref import mha_ref


def run_fused_kernels(pipeline, comb, feat_out: int, quick: bool):
    """Fused aggregate⊗transform vs the composed (SpMM + matmul) path on
    the same shard, same tiles, same weights. On CPU both run the Pallas
    interpreter, so this is a dispatch/parity record, not an MXU number —
    the HBM round-trip the fusion removes only shows on real hardware."""
    pg, topo = pipeline.pg, pipeline.topo
    combined, feat = comb.shape
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(feat, feat_out)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(feat_out,)), jnp.float32)
    du = jnp.asarray(rng.normal(size=(pg.max_inner, feat_out)), jnp.float32)

    bs, fz = get_engine("blocksparse"), get_engine("fused")
    ts_bs = tuple(getattr(topo, f)[0] for f in bs.fields)
    iters = 3 if quick else 6
    out = {}
    for name, eng, ts in (("composed", bs, ts_bs), ("fused", fz, ts_bs)):
        t = time_fn(lambda e=eng, s=ts: e.aggregate_transform(
            s, comb, w, b, pg.max_inner)[0], iters=iters)
        out[f"{name}/fwd"] = t
        t2 = time_fn(lambda e=eng, s=ts: e.aggregate_transform_t(
            s, du, w, combined), iters=iters)
        out[f"{name}/bwd"] = t2
        detail = ""
        if name == "fused":
            detail = (f"fused_over_composed_fwd="
                      f"{t / out['composed/fwd']:.2f}x,"
                      f"fused_over_composed_bwd="
                      f"{t2 / out['composed/bwd']:.2f}x")
        emit(f"kernels/agg_transform/tiny_p0/{name}/fwd", t * 1e6, detail)
        emit(f"kernels/agg_transform/tiny_p0/{name}/bwd", t2 * 1e6, "")

    # parity of the fused kernels vs the composed path (same f32 inputs)
    u_c, z_c = bs.aggregate_transform(ts_bs, comb, w, b, pg.max_inner)
    u_f, z_f = fz.aggregate_transform(ts_bs, comb, w, b, pg.max_inner)
    d_c = bs.aggregate_transform_t(ts_bs, du, w, combined)
    d_f = fz.aggregate_transform_t(ts_bs, du, w, combined)
    err_u = float(jnp.abs(u_c - u_f).max())
    err_z = float(jnp.abs(z_c - z_f).max())
    err_d = float(jnp.abs(d_c - d_f).max())
    emit("kernels/agg_transform/tiny_p0/parity", err_u * 1e6,
         f"u_err={err_u:.2e},z_err={err_z:.2e},d_err={err_d:.2e}")
    assert err_u < 2e-4 and err_z < 2e-4 and err_d < 2e-4
    return out


def run_tile_extraction(quick: bool):
    """Offline preprocessing cost of `build_tile_topology`, plus a timing
    note comparing the scatter variants. The production path scatters over
    FLATTENED (tile, r%T, c%T) keys into a flat f32 buffer: multi-index
    `np.add.at` (the old path) pays the fancy-index ufunc loop (2-10×
    slower at large nnz), and `np.bincount(weights=...)` pays an f64
    output allocation of n_tiles·T² bins before the f32 cast — measured
    slower than the flat add.at on every regime on this stack, which is
    why it is the timing NOTE here and not the implementation."""
    rng = np.random.default_rng(11)
    nnz = 100_000 if quick else 1_000_000
    n = 4096            # 32×32 block grid → dense-ish tiles, bounded memory
    row = rng.integers(0, n, nnz)
    col = rng.integers(0, n, nnz)
    val = rng.normal(size=nnz).astype(np.float32)
    import time
    t0 = time.perf_counter()
    tt = build_tile_topology(row, col, val, n, n)
    dt = time.perf_counter() - t0

    # scatter-variant note (same inputs, scatter step only)
    tile = TILE
    ncb = -(-n // tile)
    key = (row // tile) * ncb + (col // tile)
    uk, inv = np.unique(key, return_inverse=True)
    flat = (inv.astype(np.int64) * (tile * tile)
            + (row % tile) * tile + (col % tile))
    nbins = len(uk) * tile * tile

    def t_of(fn, reps=3):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_flat = t_of(lambda: np.add.at(np.zeros(nbins, np.float32), flat, val))
    t_midx = t_of(lambda: np.add.at(
        np.zeros((len(uk), tile, tile), np.float32),
        (inv, row % tile, col % tile), val))
    t_binc = t_of(lambda: np.bincount(flat, weights=val,
                                      minlength=nbins).astype(np.float32))
    emit(f"kernels/tile_extract/nnz{nnz}", dt * 1e6,
         f"tiles={tt.n_tiles},nnz_per_s={nnz / dt:.0f},"
         f"scatter_flat_addat_us={t_flat * 1e6:.0f},"
         f"scatter_multiidx_addat_us={t_midx * 1e6:.0f},"
         f"scatter_bincount_us={t_binc * 1e6:.0f}")
    if not quick:
        # Gate only at nnz=1M: the flat-key win is robust there (2-10x);
        # at the quick size both scatters take single-digit ms and the
        # ratio is timer noise even with min-of-3.
        assert t_flat <= t_midx * 1.2, (
            "flat-key scatter regressed vs the multi-index np.add.at it "
            f"replaced: {t_flat * 1e3:.1f}ms vs {t_midx * 1e3:.1f}ms")
    return dt


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    # SpMM engines head-to-head on a real partition shard
    from repro.data import GraphDataPipeline
    pipeline = GraphDataPipeline.build("tiny", 2, kind="gcn",
                                      agg="blocksparse")
    pg, topo = pipeline.pg, pipeline.topo
    combined = pg.combined
    feat = 128
    comb = jnp.asarray(rng.normal(size=(combined, feat)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(pg.max_inner, feat)), jnp.float32)

    slices = {}
    for name in ("coo", "blocksparse", "fused"):
        eng = get_engine(name)
        ts = tuple(getattr(topo, f)[0] for f in eng.fields)
        slices[name] = (eng, ts)
        t = time_fn(lambda e=eng, s=ts: e.spmm(s, comb, pg.max_inner),
                    iters=2)
        emit(f"kernels/gcn_spmm/tiny_p0/{name}/fwd", t * 1e6, "")
        t = time_fn(lambda e=eng, s=ts: e.spmm_t(s, dz, combined), iters=2)
        emit(f"kernels/gcn_spmm/tiny_p0/{name}/transpose", t * 1e6, "")

    # parity between the engines on the same shard
    z_coo = slices["coo"][0].spmm(slices["coo"][1], comb, pg.max_inner)
    d_coo = slices["coo"][0].spmm_t(slices["coo"][1], dz, combined)
    errs = {}
    for name in ("blocksparse", "fused"):
        z_bs = slices[name][0].spmm(slices[name][1], comb, pg.max_inner)
        d_bs = slices[name][0].spmm_t(slices[name][1], dz, combined)
        errs[name] = (float(jnp.abs(z_coo - z_bs).max()),
                      float(jnp.abs(d_coo - d_bs).max()))
        assert max(errs[name]) < 2e-4, (name, errs[name])
    # the record keeps its historical meaning: blocksparse-vs-coo error
    err_f, err_t = errs["blocksparse"]

    # tile statistics of the extracted topology (built COO-direct: no dense
    # intermediate)
    tt = build_tile_topology(pg.edge_row[0], pg.edge_col[0], pg.edge_w[0],
                             pg.max_inner, combined)
    dens = tile_density(tt.rows, pg.max_inner, combined)
    flops = 2 * tt.n_tiles * TILE * TILE * feat
    emit("kernels/gcn_spmm/tiny_p0/parity", err_f * 1e6,
         f"fwd_err={err_f:.2e},t_err={err_t:.2e},tiles={tt.n_tiles},"
         f"tile_density={dens:.3f},gflop={flops / 1e9:.2f}")

    run_fused_kernels(pipeline, comb, feat_out=128, quick=quick)
    run_tile_extraction(quick=quick)

    # flash attention vs ref
    B, S, H, d = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    t = time_fn(lambda: ops.attention(q, q, q, causal=True,
                                      q_block=128, kv_block=128), iters=2)
    err = float(jnp.abs(ops.attention(q, q, q, causal=True, q_block=128,
                                      kv_block=128)
                        - mha_ref(q, q, q, causal=True)).max())
    emit("kernels/flash_attention/512x4x64", t * 1e6, f"max_err={err:.2e}")
    return True


if __name__ == "__main__":
    run()
