"""Kernel microbenchmarks: block-sparse SpMM (forward + transpose) vs the
COO segment_sum engine on the same partition shard, the FUSED
aggregate+transform kernels vs the composed two-op path, the offline tile
extraction, the locality-aware reorder sweep (natural vs rcm tile counts —
gated), the vectorized-partitioner build-time record, and flash attention
(interpret mode on CPU — correctness + tile statistics; wall numbers are
CPU-only)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, emit_meta, time_fn
from repro.kernels.gcn_spmm import TILE, build_tile_topology, tile_density
from repro.kernels import ops
from repro.kernels.aggregate import get_engine
from repro.kernels.ref import mha_ref


def run_fused_kernels(pipeline, comb, feat_out: int, quick: bool):
    """Fused aggregate⊗transform vs the composed (SpMM + matmul) path on
    the same shard, same tiles, same weights. On CPU both run the Pallas
    interpreter, so this is a dispatch/parity record, not an MXU number —
    the HBM round-trip the fusion removes only shows on real hardware."""
    pg, topo = pipeline.pg, pipeline.topo
    combined, feat = comb.shape
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(feat, feat_out)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(feat_out,)), jnp.float32)
    du = jnp.asarray(rng.normal(size=(pg.max_inner, feat_out)), jnp.float32)

    bs, fz = get_engine("blocksparse"), get_engine("fused")
    ts_bs = tuple(getattr(topo, f)[0] for f in bs.fields)
    iters = 3 if quick else 6
    out = {}
    for name, eng, ts in (("composed", bs, ts_bs), ("fused", fz, ts_bs)):
        t = time_fn(lambda e=eng, s=ts: e.aggregate_transform(
            s, comb, w, b, pg.max_inner)[0], iters=iters)
        out[f"{name}/fwd"] = t
        t2 = time_fn(lambda e=eng, s=ts: e.aggregate_transform_t(
            s, du, w, combined), iters=iters)
        out[f"{name}/bwd"] = t2
        detail = ""
        if name == "fused":
            detail = (f"fused_over_composed_fwd="
                      f"{t / out['composed/fwd']:.2f}x,"
                      f"fused_over_composed_bwd="
                      f"{t2 / out['composed/bwd']:.2f}x")
        emit(f"kernels/agg_transform/tiny_p0/{name}/fwd", t * 1e6, detail)
        emit(f"kernels/agg_transform/tiny_p0/{name}/bwd", t2 * 1e6, "")

    # parity of the fused kernels vs the composed path (same f32 inputs)
    u_c, z_c = bs.aggregate_transform(ts_bs, comb, w, b, pg.max_inner)
    u_f, z_f = fz.aggregate_transform(ts_bs, comb, w, b, pg.max_inner)
    d_c = bs.aggregate_transform_t(ts_bs, du, w, combined)
    d_f = fz.aggregate_transform_t(ts_bs, du, w, combined)
    err_u = float(jnp.abs(u_c - u_f).max())
    err_z = float(jnp.abs(z_c - z_f).max())
    err_d = float(jnp.abs(d_c - d_f).max())
    emit("kernels/agg_transform/tiny_p0/parity", err_u * 1e6,
         f"u_err={err_u:.2e},z_err={err_z:.2e},d_err={err_d:.2e}")
    assert err_u < 2e-4 and err_z < 2e-4 and err_d < 2e-4
    return out


def run_tile_extraction(quick: bool):
    """Offline preprocessing cost of `build_tile_topology`, plus a timing
    note comparing the scatter variants. The production path scatters over
    FLATTENED (tile, r%T, c%T) keys into a flat f32 buffer: multi-index
    `np.add.at` (the old path) pays the fancy-index ufunc loop (2-10×
    slower at large nnz), and `np.bincount(weights=...)` pays an f64
    output allocation of n_tiles·T² bins before the f32 cast — measured
    slower than the flat add.at on every regime on this stack, which is
    why it is the timing NOTE here and not the implementation."""
    rng = np.random.default_rng(11)
    nnz = 100_000 if quick else 1_000_000
    n = 4096            # 32×32 block grid → dense-ish tiles, bounded memory
    row = rng.integers(0, n, nnz)
    col = rng.integers(0, n, nnz)
    val = rng.normal(size=nnz).astype(np.float32)
    import time
    t0 = time.perf_counter()
    tt = build_tile_topology(row, col, val, n, n)
    dt = time.perf_counter() - t0

    # scatter-variant note (same inputs, scatter step only)
    tile = TILE
    ncb = -(-n // tile)
    key = (row // tile) * ncb + (col // tile)
    uk, inv = np.unique(key, return_inverse=True)
    flat = (inv.astype(np.int64) * (tile * tile)
            + (row % tile) * tile + (col % tile))
    nbins = len(uk) * tile * tile

    def t_of(fn, reps=3):
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    t_flat = t_of(lambda: np.add.at(np.zeros(nbins, np.float32), flat, val))
    t_midx = t_of(lambda: np.add.at(
        np.zeros((len(uk), tile, tile), np.float32),
        (inv, row % tile, col % tile), val))
    t_binc = t_of(lambda: np.bincount(flat, weights=val,
                                      minlength=nbins).astype(np.float32))
    emit(f"kernels/tile_extract/nnz{nnz}", dt * 1e6,
         f"tiles={tt.n_tiles},nnz_per_s={nnz / dt:.0f},"
         f"scatter_flat_addat_us={t_flat * 1e6:.0f},"
         f"scatter_multiidx_addat_us={t_midx * 1e6:.0f},"
         f"scatter_bincount_us={t_binc * 1e6:.0f}")
    if not quick:
        # Gate only at nnz=1M: the flat-key win is robust there (2-10x);
        # at the quick size both scatters take single-digit ms and the
        # ratio is timer noise even with min-of-3.
        assert t_flat <= t_midx * 1.2, (
            "flat-key scatter regressed vs the multi-index np.add.at it "
            f"replaced: {t_flat * 1e3:.1f}ms vs {t_midx * 1e3:.1f}ms")
    return dt


def run_reorder_sweep(quick: bool):
    """Natural vs rcm layout on the synthetic power-law benchmark graph:
    the nonempty-tile frontier the block-sparse engines pay for, plus
    bandwidth / halo-run-count from `analysis.cost.graph_layout_report`.

    GATED three ways: on power-law graphs rcm must NEVER store more
    nonempty tiles than natural (any partition count), and on the
    designated power-law graph (reddit-sim, >=4 partitions — heavy-tailed
    R-MAT overlay) the reduction must hold >=15% (the PR-5 acceptance
    bar; measured 16-22% at p4-p8). On the lattice (grid-sim — natural
    row-major order is already banded) rcm instead pays a capped tile
    increase (<=1.25x) to cluster the halo into a split-feasible tail,
    gated on bnd_tile_share < 0.6 — the overlappable-work record the
    split-phase schedule consumes. Lands in BENCH_*.json via
    emit + emit_meta."""
    from repro.analysis.cost import graph_layout_report
    from repro.graph import make_dataset, partition_graph
    from repro.graph.csr import mean_normalized
    from repro.graph.halo import build_partitioned_graph

    # grid-sim rides along in both modes: the planar lattice is the only
    # case where rcm leaves a split-feasible boundary tail, so its row
    # shows how much of the tile stream the split-phase overlap can hide
    # (bnd_tile_share << 1; the power-law sims are ~all-boundary -> 1.0).
    cases = ([("reddit-sim", 4), ("grid-sim", 4)] if quick else
             [("reddit-sim", 4), ("reddit-sim", 8), ("products-sim", 8),
              ("grid-sim", 4)])
    import time
    out = {}
    for name, parts in cases:
        ds = make_dataset(name)
        prop = mean_normalized(ds.graph)
        part = partition_graph(ds.graph, parts, seed=0)
        reports = {}
        for layout in ("natural", "rcm"):
            t0 = time.perf_counter()
            pg = build_partitioned_graph(prop, part, parts, layout=layout)
            dt = time.perf_counter() - t0
            rep = graph_layout_report(pg)
            reports[layout] = rep
            emit(f"kernels/reorder/{name}/p{parts}/{layout}", dt * 1e6,
                 f"tiles={rep['tiles']},bandwidth={rep['bandwidth']},"
                 f"halo_runs={rep['halo_runs']},"
                 f"mean_bandwidth={rep['mean_bandwidth']:.1f},"
                 f"bnd_tile_share={rep['bnd_tile_share']:.2f}")
        tn, tr = reports["natural"]["tiles"], reports["rcm"]["tiles"]
        reduction = (tn - tr) / tn
        emit(f"kernels/reorder/{name}/p{parts}/reduction", reduction * 100,
             f"tiles_natural={tn},tiles_rcm={tr}")
        emit_meta("reorder_tiles", {f"{name}/p{parts}": {
            "natural": tn, "rcm": tr, "reduction": round(reduction, 4),
            "bandwidth_natural": reports["natural"]["bandwidth"],
            "bandwidth_rcm": reports["rcm"]["bandwidth"],
            "halo_runs_natural": reports["natural"]["halo_runs"],
            "halo_runs_rcm": reports["rcm"]["halo_runs"],
            "split_feasible_rcm": reports["rcm"]["split_feasible"],
            "bnd_tiles_rcm": reports["rcm"]["bnd_tiles"]}})
        if name.startswith("grid"):
            # A row-major lattice is ALREADY banded, so rcm can't shrink
            # the stream — its halo clustering trades a bounded tile
            # increase (the serpentine band breaks at the moved boundary
            # rows) for the split-feasible tail gated below. Cap the
            # price instead of requiring a reduction.
            assert tr <= tn * 1.25, (
                f"rcm halo clustering on {name}/p{parts} costs too many "
                f"tiles: {tn} -> {tr} (> 1.25x)")
        else:
            assert tr <= tn, (
                f"rcm layout stores MORE tiles than natural on "
                f"{name}/p{parts}: {tr} vs {tn}")
        if name == "reddit-sim":
            assert reduction >= 0.15, (
                f"rcm tile reduction regressed below the 15% acceptance "
                f"bar on {name}/p{parts}: {reduction:.1%} ({tn} -> {tr})")
        if name.startswith("grid"):
            # the lattice under rcm must stay split-feasible with a
            # minority boundary tail — this is the overlappable work the
            # split-phase schedule hides the exchange behind
            rep = reports["rcm"]
            assert rep["split_feasible"] and rep["bnd_tile_share"] < 0.6, (
                f"{name}/p{parts} rcm lost its interior phase: "
                f"feasible={rep['split_feasible']}, "
                f"bnd_tile_share={rep['bnd_tile_share']:.2f}")
        out[(name, parts)] = reduction
    return out


def run_partition_build(quick: bool):
    """Build-time record for the vectorized partitioner: partition_graph's
    numpy frontier expansion + delta-updated refinement vs the per-node
    Python loop references they replaced (kept in repro.graph.partition as
    `_bfs_grow_loop`/`_refine_loop` — bit-identical output, verified here
    and in tests/test_reorder.py). Always measured on papers100m-sim (the
    largest synthetic graph — the regime where the loop baseline dominated
    pipeline build time; at reddit-sim scale refine is noise-bound and the
    record would under-sell the bfs win), with the two phases recorded
    separately so each speedup is attributed."""
    from repro.graph import make_dataset
    from repro.graph.partition import (_bfs_grow, _bfs_grow_loop, _refine,
                                       _refine_loop)
    import time
    name, parts = "papers100m-sim", 8
    g = make_dataset(name).graph
    reps = 1 if quick else 3

    def t_of(fn):
        best, out = float("inf"), None
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            best = min(best, time.perf_counter() - t0)
        return best, out

    t_bfs, part_v = t_of(lambda: _bfs_grow(g, parts,
                                           np.random.default_rng(0)))
    t_bfs_l, part_l = t_of(lambda: _bfs_grow_loop(g, parts,
                                                  np.random.default_rng(0)))
    assert np.array_equal(part_v, part_l), "vectorized _bfs_grow drifted"
    t_ref, ref_v = t_of(lambda: _refine(g, part_v, parts, 4, 0.05))
    t_ref_l, ref_l = t_of(lambda: _refine_loop(g, part_l, parts, 4, 0.05))
    assert np.array_equal(ref_v, ref_l), "vectorized _refine drifted"
    for phase, tv, tl in (("bfs", t_bfs, t_bfs_l),
                          ("refine", t_ref, t_ref_l)):
        emit(f"kernels/partition_build/{name}/p{parts}/{phase}", tv * 1e6,
             f"loop_us={tl * 1e6:.0f},speedup={tl / tv:.2f}x")
    total_v, total_l = t_bfs + t_ref, t_bfs_l + t_ref_l
    emit(f"kernels/partition_build/{name}/p{parts}/total", total_v * 1e6,
         f"loop_us={total_l * 1e6:.0f},speedup={total_l / total_v:.2f}x")
    emit_meta("partition_build", {f"{name}/p{parts}": {
        "bfs_s": round(t_bfs, 4), "bfs_loop_s": round(t_bfs_l, 4),
        "refine_s": round(t_ref, 4), "refine_loop_s": round(t_ref_l, 4),
        "speedup": round(total_l / total_v, 2)}})
    return total_v, total_l


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    # SpMM engines head-to-head on a real partition shard
    from repro.data import GraphDataPipeline
    pipeline = GraphDataPipeline.build("tiny", 2, kind="gcn",
                                      agg="blocksparse")
    pg, topo = pipeline.pg, pipeline.topo
    combined = pg.combined
    feat = 128
    comb = jnp.asarray(rng.normal(size=(combined, feat)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(pg.max_inner, feat)), jnp.float32)

    slices = {}
    for name in ("coo", "blocksparse", "fused"):
        eng = get_engine(name)
        ts = tuple(getattr(topo, f)[0] for f in eng.fields)
        slices[name] = (eng, ts)
        t = time_fn(lambda e=eng, s=ts: e.spmm(s, comb, pg.max_inner),
                    iters=2)
        emit(f"kernels/gcn_spmm/tiny_p0/{name}/fwd", t * 1e6, "")
        t = time_fn(lambda e=eng, s=ts: e.spmm_t(s, dz, combined), iters=2)
        emit(f"kernels/gcn_spmm/tiny_p0/{name}/transpose", t * 1e6, "")

    # parity between the engines on the same shard
    z_coo = slices["coo"][0].spmm(slices["coo"][1], comb, pg.max_inner)
    d_coo = slices["coo"][0].spmm_t(slices["coo"][1], dz, combined)
    errs = {}
    for name in ("blocksparse", "fused"):
        z_bs = slices[name][0].spmm(slices[name][1], comb, pg.max_inner)
        d_bs = slices[name][0].spmm_t(slices[name][1], dz, combined)
        errs[name] = (float(jnp.abs(z_coo - z_bs).max()),
                      float(jnp.abs(d_coo - d_bs).max()))
        assert max(errs[name]) < 2e-4, (name, errs[name])
    # the record keeps its historical meaning: blocksparse-vs-coo error
    err_f, err_t = errs["blocksparse"]

    # tile statistics of the extracted topology (built COO-direct: no dense
    # intermediate)
    tt = build_tile_topology(pg.edge_row[0], pg.edge_col[0], pg.edge_w[0],
                             pg.max_inner, combined)
    dens = tile_density(tt.rows, pg.max_inner, combined)
    flops = 2 * tt.n_tiles * TILE * TILE * feat
    emit("kernels/gcn_spmm/tiny_p0/parity", err_f * 1e6,
         f"fwd_err={err_f:.2e},t_err={err_t:.2e},tiles={tt.n_tiles},"
         f"tile_density={dens:.3f},gflop={flops / 1e9:.2f}")

    run_fused_kernels(pipeline, comb, feat_out=128, quick=quick)
    run_tile_extraction(quick=quick)
    run_reorder_sweep(quick=quick)
    run_partition_build(quick=quick)

    # flash attention vs ref
    B, S, H, d = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    t = time_fn(lambda: ops.attention(q, q, q, causal=True,
                                      q_block=128, kv_block=128), iters=2)
    err = float(jnp.abs(ops.attention(q, q, q, causal=True, q_block=128,
                                      kv_block=128)
                        - mha_ref(q, q, q, causal=True)).max())
    emit("kernels/flash_attention/512x4x64", t * 1e6, f"max_err={err:.2e}")
    return True


if __name__ == "__main__":
    run()
