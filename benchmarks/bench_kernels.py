"""Kernel microbenchmarks: block-sparse SpMM (forward + transpose) vs the
COO segment_sum engine on the same partition shard, and flash attention
(interpret mode on CPU — correctness + tile statistics; wall numbers are
CPU-only)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.gcn_spmm import TILE, build_tile_topology, tile_density
from repro.kernels import ops
from repro.kernels.aggregate import get_engine
from repro.kernels.ref import mha_ref


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    # SpMM engines head-to-head on a real partition shard
    from repro.data import GraphDataPipeline
    pipeline = GraphDataPipeline.build("tiny", 2, kind="gcn",
                                       agg="blocksparse")
    pg, topo = pipeline.pg, pipeline.topo
    combined = pg.combined
    feat = 128
    comb = jnp.asarray(rng.normal(size=(combined, feat)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(pg.max_inner, feat)), jnp.float32)

    slices = {}
    for name in ("coo", "blocksparse"):
        eng = get_engine(name)
        ts = tuple(getattr(topo, f)[0] for f in eng.fields)
        slices[name] = (eng, ts)
        t = time_fn(lambda e=eng, s=ts: e.spmm(s, comb, pg.max_inner),
                    iters=2)
        emit(f"kernels/gcn_spmm/tiny_p0/{name}/fwd", t * 1e6, "")
        t = time_fn(lambda e=eng, s=ts: e.spmm_t(s, dz, combined), iters=2)
        emit(f"kernels/gcn_spmm/tiny_p0/{name}/transpose", t * 1e6, "")

    # parity between the two engines on the same shard
    z_coo = slices["coo"][0].spmm(slices["coo"][1], comb, pg.max_inner)
    z_bs = slices["blocksparse"][0].spmm(slices["blocksparse"][1], comb,
                                         pg.max_inner)
    d_coo = slices["coo"][0].spmm_t(slices["coo"][1], dz, combined)
    d_bs = slices["blocksparse"][0].spmm_t(slices["blocksparse"][1], dz,
                                           combined)
    err_f = float(jnp.abs(z_coo - z_bs).max())
    err_t = float(jnp.abs(d_coo - d_bs).max())

    # tile statistics of the extracted topology (built COO-direct: no dense
    # intermediate)
    tt = build_tile_topology(pg.edge_row[0], pg.edge_col[0], pg.edge_w[0],
                             pg.max_inner, combined)
    dens = tile_density(tt.rows, pg.max_inner, combined)
    flops = 2 * tt.n_tiles * TILE * TILE * feat
    emit("kernels/gcn_spmm/tiny_p0/parity", err_f * 1e6,
         f"fwd_err={err_f:.2e},t_err={err_t:.2e},tiles={tt.n_tiles},"
         f"tile_density={dens:.3f},gflop={flops / 1e9:.2f}")
    assert err_f < 2e-4 and err_t < 2e-4

    # flash attention vs ref
    B, S, H, d = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    t = time_fn(lambda: ops.attention(q, q, q, causal=True,
                                      q_block=128, kv_block=128), iters=2)
    err = float(jnp.abs(ops.attention(q, q, q, causal=True, q_block=128,
                                      kv_block=128)
                        - mha_ref(q, q, q, causal=True)).max())
    emit("kernels/flash_attention/512x4x64", t * 1e6, f"max_err={err:.2e}")
    return True


if __name__ == "__main__":
    run()
