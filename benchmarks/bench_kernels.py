"""Kernel microbenchmarks: block-sparse SpMM and flash attention (interpret
mode on CPU — correctness + tile statistics; wall numbers are CPU-only)."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.kernels.gcn_spmm import TILE, build_tiles, tile_density
from repro.kernels import ops
from repro.kernels.ref import mha_ref


def run(quick: bool = False):
    rng = np.random.default_rng(0)
    # SpMM on a real partition shard
    from repro.data import GraphDataPipeline
    pipeline = GraphDataPipeline.build("tiny", 2, kind="gcn")
    pg = pipeline.pg
    row = pg.edge_row[0].astype(np.int64)
    col = pg.edge_col[0].astype(np.int64)
    w = pg.edge_w[0]
    combined = pg.max_inner + pg.num_parts * pg.slot
    cpad = -(-combined // TILE) * TILE
    rpad = -(-pg.max_inner // TILE) * TILE
    h = jnp.asarray(rng.normal(size=(cpad, 128)), jnp.float32)
    tr, tc, tv = build_tiles((row, col, w), pg.max_inner, combined)
    t = time_fn(lambda: ops.spmm(jnp.asarray(tr), jnp.asarray(tc),
                                 jnp.asarray(tv), h, rpad), iters=2)
    dens = tile_density(tr, pg.max_inner, combined)
    flops = 2 * len(tr) * TILE * TILE * 128
    emit("kernels/gcn_spmm/tiny_p0", t * 1e6,
         f"tiles={len(tr)},tile_density={dens:.3f},gflop={flops / 1e9:.2f}")

    # flash attention vs ref
    B, S, H, d = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    t = time_fn(lambda: ops.attention(q, q, q, causal=True,
                                      q_block=128, kv_block=128), iters=2)
    err = float(jnp.abs(ops.attention(q, q, q, causal=True, q_block=128,
                                      kv_block=128)
                        - mha_ref(q, q, q, causal=True)).max())
    emit("kernels/flash_attention/512x4x64", t * 1e6, f"max_err={err:.2e}")
    return True


if __name__ == "__main__":
    run()
