"""Roofline table from the dry-run artifacts (results/dryrun_*.json):
per (arch × shape × mesh): three roofline terms, dominant bottleneck,
MODEL_FLOPS ratio, bytes/device. Also emits the markdown for
EXPERIMENTS.md §Roofline."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def load(mesh: str):
    path = os.path.join(RESULTS, f"dryrun_{mesh}.json")
    if not os.path.exists(path):
        return []
    return [r for r in json.load(open(path)) if "error" not in r]


def fmt_s(x):
    if x >= 1.0:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.2f}ms"
    return f"{x * 1e6:.1f}µs"


def run(quick: bool = False, markdown: bool = False):
    rows = []
    for mesh in ("16x16", "2x16x16"):
        for r in load(mesh):
            rows.append(r)
            if not markdown:
                emit(f"roofline/{r['arch']}/{r['shape']}/{mesh}",
                     r.get("t_compute", 0) * 1e6,
                     f"bottleneck={r.get('bottleneck')},"
                     f"t_mem_us={r.get('t_memory', 0) * 1e6:.1f},"
                     f"t_coll_us={r.get('t_collective', 0) * 1e6:.1f},"
                     f"mf_ratio={r.get('model_flops_ratio', 0):.3f}")
    if markdown:
        print("| arch | shape | mesh | t_compute | t_memory | t_collective |"
              " bottleneck | MODEL/HLO flops | bytes/dev |")
        print("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            print(f"| {r['arch']} | {r['shape']}"
                  f"{'*' if r.get('variant') else ''} | {r['mesh']} | "
                  f"{fmt_s(r.get('t_compute', 0))} | "
                  f"{fmt_s(r.get('t_memory', 0))} | "
                  f"{fmt_s(r.get('t_collective', 0))} | "
                  f"{r.get('bottleneck')} | "
                  f"{r.get('model_flops_ratio', 0):.3f} | "
                  f"{r.get('bytes_per_device', 0) / 2**30:.2f} GiB |")
    return rows


if __name__ == "__main__":
    import sys
    run(markdown="--markdown" in sys.argv)
