"""Paper Tab. 6 + Fig. 8 — epoch-time breakdown (compute / communication /
reduce) for vanilla vs PipeGCN, and how much communication the pipeline
hides. Measured shard statistics, paper hardware model."""
from __future__ import annotations

from benchmarks.common import PAPER_GPU, emit, epoch_model
from repro.core.config import ModelConfig
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template

CASES = [("reddit-sim", 2), ("reddit-sim", 4), ("products-sim", 10),
         ("yelp-sim", 3)]


def run(quick: bool = False):
    cases = CASES[:2] if quick else CASES
    rows = []
    for name, parts in cases:
        pipeline = GraphDataPipeline.build(name, parts, kind="sage")
        tpl = model_template(name)
        mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                         hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                         num_classes=pipeline.dataset.num_classes)
        m = epoch_model(pipeline.pg, mc, PAPER_GPU)
        exposed_comm = max(m.t_pipegcn - m.t_comp - m.t_reduce, 0.0)
        hidden_frac = 1.0 - exposed_comm / max(m.t_comm, 1e-12)
        emit(f"table6/{name}/p{parts}/vanilla", m.t_vanilla * 1e6,
             f"compute={m.t_comp * 1e3:.2f}ms,comm={m.t_comm * 1e3:.2f}ms,"
             f"reduce={m.t_reduce * 1e3:.2f}ms")
        emit(f"table6/{name}/p{parts}/pipegcn", m.t_pipegcn * 1e6,
             f"exposed_comm={exposed_comm * 1e3:.2f}ms,"
             f"hidden_frac={hidden_frac:.2f}")
        rows.append((name, parts, hidden_frac))
    return rows


if __name__ == "__main__":
    run()
