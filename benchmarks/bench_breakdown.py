"""Paper Tab. 6 + Fig. 8 — epoch-time breakdown (compute / communication /
reduce) for vanilla vs PipeGCN, and how much communication the pipeline
hides. Measured shard statistics, paper hardware model.

Plus the split-phase timer: per layer, the boundary-phase SpMM (the
critical-path prefix before the exchange can be issued) vs the interior
phase (the compute the collective hides behind) — measured phase kernel
times on this CPU, hidden-latency fraction on the paper hardware model."""
from __future__ import annotations

from benchmarks.common import PAPER_GPU, emit, emit_meta, epoch_model, time_fn
from repro.core.config import ModelConfig
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template

CASES = [("reddit-sim", 2), ("reddit-sim", 4), ("products-sim", 10),
         ("yelp-sim", 3)]


def run_phase_breakdown(quick: bool = False):
    """Split-phase timer on the lattice graph (the feasible-split regime).

    Two views per layer:
      measured — wall time of the boundary- vs interior-phase Pallas
        kernels on partition 0's real tile stream (CPU-interpret: a
        work-proportionality check, boundary ~ bnd_tiles/n_tiles of the
        unsplit call);
      analytic — `analysis.cost.split_overlap_report` FLOPs + wire bytes
        on the paper hardware: hidden_frac = how much of the exchange
        latency fits under the interior phase.
    """
    import jax
    import jax.numpy as jnp

    from repro.analysis.cost import split_overlap_report
    from repro.kernels.aggregate import get_engine

    name, parts = ("grid-tiny", 4) if quick else ("grid-sim", 4)
    pipeline = GraphDataPipeline.build(name, parts, kind="sage",
                                       agg="blocksparse", layout="rcm")
    sp = pipeline.split_spec()
    assert sp is not None, f"{name} must admit a feasible split under rcm"
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes,
                     agg="blocksparse", layout="rcm")
    topo = pipeline.topo
    n_tiles = topo.tile_rows.shape[-1]
    combined = topo.max_inner + topo.halo_size
    # measured: partition 0's stream through the engine interface (the
    # engine pads rows to TILE and features to FEAT_BLOCK per call, same
    # as inside the training step)
    engine = get_engine("blocksparse")
    tslice = tuple(getattr(topo, f)[0] for f in engine.fields)
    fin = mc.layer_dims()[0][0]
    h = jax.random.normal(jax.random.PRNGKey(0), (combined, fin),
                          dtype=jnp.float32)
    kwargs = dict(iters=4 if quick else 6)
    times = {}
    for phase in ("boundary", "interior"):
        times[phase] = time_fn(
            lambda p=phase: engine.spmm_phased(tslice, h, topo.max_inner,
                                               sp, p), **kwargs)
    t_full = time_fn(
        lambda: engine.spmm(tslice, h, topo.max_inner), **kwargs)
    bnd_share = sp.fwd_bnd_tiles / n_tiles
    emit(f"table6/phase_measured/{name}/p{parts}/boundary",
         times["boundary"] * 1e6,
         f"interior_us={times['interior'] * 1e6:.0f},"
         f"unsplit_us={t_full * 1e6:.0f},"
         f"bnd_tile_share={bnd_share:.2f}")
    # analytic: paper hardware, per layer
    report = split_overlap_report(pipeline.pg, mc.layer_dims())
    assert report, "split feasible above, report must be non-empty"
    hidden = {}
    for row in report:
        t_int = row["int_flops"] / PAPER_GPU.flops
        t_wire = row["wire_bytes"] / PAPER_GPU.link_bw
        frac = min(t_int, t_wire) / max(t_wire, 1e-12)
        hidden[row["layer"]] = frac
        emit(f"table6/phase_model/{name}/p{parts}/layer{row['layer']}",
             row["bnd_flops"] / PAPER_GPU.flops * 1e6,
             f"interior_us={t_int * 1e6:.2f},wire_us={t_wire * 1e6:.2f},"
             f"hidden_frac={frac:.2f},overlappable={row['overlappable']:.2f}")
    emit_meta("overlap_phase", {f"{name}/p{parts}": {
        "n_tiles": n_tiles, "fwd_bnd_tiles": sp.fwd_bnd_tiles,
        "t_bnd_tiles": sp.t_bnd_tiles,
        "overlappable": round(report[0]["overlappable"], 4)}})
    # the lattice is the regime the split targets: most tiles interior
    assert report[0]["overlappable"] >= 0.4, (
        f"{name} rcm layout leaves only {report[0]['overlappable']:.0%} of "
        f"the tile stream overlappable — the boundary tail grew")
    return hidden


def run(quick: bool = False):
    cases = CASES[:2] if quick else CASES
    rows = []
    for name, parts in cases:
        pipeline = GraphDataPipeline.build(name, parts, kind="sage")
        tpl = model_template(name)
        mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                         hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                         num_classes=pipeline.dataset.num_classes)
        m = epoch_model(pipeline.pg, mc, PAPER_GPU)
        exposed_comm = max(m.t_pipegcn - m.t_comp - m.t_reduce, 0.0)
        hidden_frac = 1.0 - exposed_comm / max(m.t_comm, 1e-12)
        emit(f"table6/{name}/p{parts}/vanilla", m.t_vanilla * 1e6,
             f"compute={m.t_comp * 1e3:.2f}ms,comm={m.t_comm * 1e3:.2f}ms,"
             f"reduce={m.t_reduce * 1e3:.2f}ms")
        emit(f"table6/{name}/p{parts}/pipegcn", m.t_pipegcn * 1e6,
             f"exposed_comm={exposed_comm * 1e3:.2f}ms,"
             f"hidden_frac={hidden_frac:.2f}")
        rows.append((name, parts, hidden_frac))
    run_phase_breakdown(quick=quick)
    return rows


if __name__ == "__main__":
    run()
