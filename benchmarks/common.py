"""Shared benchmark utilities: analytic communication/compute model
(paper-hardware constants) + CSV emission.

This container has one CPU; wall-clock GPU/network numbers are not
measurable. Every throughput-style benchmark therefore combines
  (a) MEASURED quantities from the real implementation — boundary bytes per
      layer from the actual partitioner output, FLOP counts of the actual
      padded shards, epochs/s of the JAX step on CPU — with
  (b) the paper's hardware constants (RTX-2080Ti + PCIe3 / MI60 + 10GbE)
to evaluate the schedule analytically:
      vanilla:  T = Σ_ℓ (t_comm(ℓ) + t_comp(ℓ))         [Fig. 1(b)]
      PipeGCN:  T = max(Σ t_comm, Σ t_comp)             [Fig. 1(c)]
(fwd + bwd) + the weight-gradient all-reduce. The PipeGCN bound uses
iteration-level overlap: a deferred transfer has the WHOLE next iteration
to complete, so total comm overlaps total compute (not merely its own
layer slot). This is a conservative model: it ignores the full-duplex and
batched-transfer effects that let the paper hide even sync-measured comm
larger than compute (App. C/F), so predicted speedups are a lower bound
of the paper's measured 1.7-2.2x.
"""
from __future__ import annotations

import dataclasses
import time


from repro.core.config import ModelConfig
from repro.graph.halo import PartitionedGraph


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops: float          # effective f32 FLOP/s per device
    link_bw: float        # bytes/s per device interconnect
    reduce_bw: float      # bytes/s for the weight all-reduce

# Paper setups (Sec. 4): 2080Ti + PCIe3x16 (shared, effective), and the
# ogbn-papers100M cluster: MI60 + 10Gbps Ethernet.
# PCIe3 x16 is ~16 GB/s raw but is SHARED by 10 GPUs pairwise + CPU traffic;
# 4 GB/s effective per device reproduces the paper's Tab. 2 comm-ratio band
# (61-86%) on the simulated datasets.
PAPER_GPU = Hardware("2080Ti+PCIe3", flops=13.45e12 * 0.22,
                     link_bw=4e9, reduce_bw=4e9)
PAPER_ETH = Hardware("MI60+10GbE", flops=14.7e12 * 0.22,
                     link_bw=1.10e9, reduce_bw=1.10e9)
TPU_V5E = Hardware("TPUv5e+ICI", flops=197e12 * 0.4, link_bw=45e9,
                   reduce_bw=45e9)


def layer_flops_per_part(pg: PartitionedGraph, mc: ModelConfig) -> list[float]:
    """FLOPs per partition per layer (fwd), from the real padded shards."""
    nnz = float(pg.edge_w.size) / pg.num_parts          # padded COO work
    n = float(pg.max_inner)
    out = []
    dims = mc.layer_dims()
    for (fin, fout) in dims:
        spmm = 2.0 * nnz * fin
        fan_in = 2 * fin if mc.kind == "sage" else fin
        dense = 2.0 * n * fan_in * fout
        out.append(spmm + dense)
    return out


def layer_comm_bytes(pg: PartitionedGraph, mc: ModelConfig,
                     dtype_bytes: int = 4) -> list[float]:
    """Boundary payload per partition per layer per direction (measured)."""
    total_slots = float(pg.send_mask.sum()) / pg.num_parts
    return [total_slots * fin * dtype_bytes for (fin, _) in mc.layer_dims()]


def model_bytes(mc: ModelConfig, dtype_bytes: int = 4) -> float:
    total = 0
    for (fin, fout) in mc.layer_dims():
        fan_in = 2 * fin if mc.kind == "sage" else fin
        total += (fan_in * fout + fout) * dtype_bytes
    return total


@dataclasses.dataclass
class EpochModel:
    t_comp: float
    t_comm: float
    t_reduce: float
    t_vanilla: float
    t_pipegcn: float

    @property
    def comm_ratio(self) -> float:
        return self.t_comm / max(self.t_vanilla, 1e-12)

    @property
    def speedup(self) -> float:
        return self.t_vanilla / max(self.t_pipegcn, 1e-12)


def calibrate_link_bw(pg: PartitionedGraph, mc: ModelConfig, hw: Hardware,
                      target_comm_ratio: float) -> Hardware:
    """Solve for the link bandwidth that makes the *vanilla* comm ratio hit
    the paper's measured value — used when the simulated graph's cut
    fraction differs from the real dataset's (documented in EXPERIMENTS.md).
    """
    comp = layer_flops_per_part(pg, mc)
    comm_bytes = sum(2.0 * b for b in layer_comm_bytes(pg, mc))
    t_comp = sum(3.0 * f / hw.flops for f in comp)
    t_reduce = 2.0 * model_bytes(mc) / hw.reduce_bw
    # ratio = t_comm / (t_comm + t_comp + t_reduce)
    t_comm = target_comm_ratio * (t_comp + t_reduce) / (1 - target_comm_ratio)
    bw = comm_bytes / t_comm
    return dataclasses.replace(hw, link_bw=bw, name=hw.name + "-calibrated")


def epoch_model(pg: PartitionedGraph, mc: ModelConfig,
                hw: Hardware) -> EpochModel:
    comp = layer_flops_per_part(pg, mc)
    comm = layer_comm_bytes(pg, mc)
    # forward + backward (~2x compute, same boundary payload per direction)
    t_comp = sum(3.0 * f / hw.flops for f in comp)
    t_comm = sum(2.0 * b / hw.link_bw for b in comm)
    # ring all-reduce: 2·(p-1)/p ≈ 2 traversals of the model bytes
    t_reduce = 2.0 * model_bytes(mc) / hw.reduce_bw
    t_vanilla = t_comp + t_comm + t_reduce
    # iteration-level overlap (deferred exchange deadline = next iteration)
    t_pipe = max(t_comp, t_comm) + t_reduce
    return EpochModel(t_comp=t_comp, t_comm=t_comm, t_reduce=t_reduce,
                      t_vanilla=t_vanilla, t_pipegcn=t_pipe)


# Every emit() is also recorded here so benchmarks/run.py --json can write
# the machine-readable trajectory artifact (BENCH_*.json in CI).
RECORDS: list[dict] = []
# Structured side-channel for non-timing facts (per-step collective counts,
# schedule metadata) that belong in the JSON artifact but not the CSV rows.
META: dict = {}


def reset_records():
    RECORDS.clear()
    META.clear()


def emit(name: str, us_per_call: float, derived: str):
    """CSV contract for benchmarks/run.py: name,us_per_call,derived."""
    RECORDS.append({"name": name, "us_per_call": round(float(us_per_call), 2),
                    "derived": derived})
    print(f"{name},{us_per_call:.2f},{derived}", flush=True)


def emit_meta(key: str, value):
    """Attach a structured entry to the JSON artifact (merged per key)."""
    if isinstance(value, dict):
        META.setdefault(key, {}).update(value)
    else:
        META[key] = value


def time_fn(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters
