"""Paper Fig. 4 / Fig. 9 — epoch-to-accuracy convergence curves for vanilla
vs PipeGCN variants, plus the beyond-paper staleness-depth (k) ablation
(App. C 'increase the pipeline depth')."""
from __future__ import annotations

import dataclasses

from benchmarks.common import emit
from repro.core import ModelConfig, PipeConfig, train_pipegcn
from repro.data import GraphDataPipeline
from repro.graph.synthetic import make_dataset, model_template


def run(quick: bool = False, epochs: int = 200):
    name = "tiny" if quick else "small"
    if quick:
        epochs = 80
    ds = make_dataset(name, signal=0.35)
    pipeline = GraphDataPipeline.build(ds, 4, kind="sage")
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=tpl["hidden"],
                     num_layers=tpl["num_layers"],
                     num_classes=ds.num_classes, dropout=0.0)
    curves = {}
    for label, pc in [
        ("vanilla", PipeConfig.named("vanilla")),
        ("pipegcn", PipeConfig.named("pipegcn")),
        ("pipegcn-gf", PipeConfig.named("pipegcn-gf", gamma=0.5)),
        ("pipegcn-k2", dataclasses.replace(PipeConfig(stale=True),
                                           staleness_steps=2)),
        ("pipegcn-k4", dataclasses.replace(PipeConfig(stale=True),
                                           staleness_steps=4)),
    ]:
        res = train_pipegcn(pipeline, mc, pc, epochs=epochs, lr=tpl["lr"],
                            eval_every=max(epochs // 8, 1))
        curves[label] = res.history
        pts = ";".join(f"{e}:{a:.3f}" for e, a in
                       zip(res.history["epoch"], res.history["val_acc"]))
        emit(f"fig4/{label}", 1e6 / res.epochs_per_sec,
             f"final_test={res.final_metrics['test']:.4f},curve={pts}")
    # claim: pipegcn tracks vanilla; deeper k degrades gracefully
    v = curves["vanilla"]["val_acc"][-1]
    assert curves["pipegcn"]["val_acc"][-1] >= v - 0.06
    assert curves["pipegcn-k4"]["val_acc"][-1] >= v - 0.15
    return curves


if __name__ == "__main__":
    run()
