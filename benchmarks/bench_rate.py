"""Theorem 3.1 / Corollary A.10 validation.

(a) Staleness-induced gradient error ∝ learning rate η (Cor. A.10):
    measure ||∇L̃(θ) − ∇L(θ)|| while training PipeGCN at several η;
    the ratio error/η should be ~constant.
(b) Convergence: running-average gradient norm decays with T and the
    final average grad-norm is close to vanilla (rate O(T^-2/3) vs O(T^-1):
    both decay; staleness must not stall descent).
"""
from __future__ import annotations

import jax
import numpy as np

from benchmarks.common import emit
from repro.core import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.data import GraphDataPipeline


def _grad_error_at(model_stale, model_fresh, topo, params, bufs, data, key):
    """||stale grad − exact grad|| at the same parameters."""
    _, g_stale, new_bufs, _ = model_stale.train_step(topo, params, bufs,
                                                     data, key)
    fresh_bufs = model_fresh.init_buffers(topo)
    _, g_exact, _, _ = model_fresh.train_step(topo, params, fresh_bufs,
                                              data, key)
    err = np.sqrt(sum(float(((a - b) ** 2).sum())
                      for a, b in zip(jax.tree.leaves(g_stale),
                                      jax.tree.leaves(g_exact))))
    norm = np.sqrt(sum(float((a ** 2).sum())
                       for a in jax.tree.leaves(g_exact)))
    return err, norm, g_stale, new_bufs


def run(quick: bool = False):
    pipeline = GraphDataPipeline.build("tiny", num_parts=4, kind="gcn")
    mc = ModelConfig(kind="gcn", feat_dim=pipeline.dataset.feat_dim,
                     hidden=16, num_layers=3,
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    stale = PipeGCN(mc, PipeConfig(stale=True))
    fresh = PipeGCN(mc, PipeConfig.vanilla())
    topo, data = pipeline.topo, pipeline.train_data

    # (a) error ∝ η  (Cor. A.10): train T steps with SGD(η), average error
    etas = [0.0125, 0.025, 0.05, 0.1]
    steps = 10 if quick else 30
    ratios = []
    for eta in etas:
        params = stale.init_params(jax.random.PRNGKey(0))
        bufs = stale.init_buffers(topo)
        errs = []
        for t in range(steps):
            err, norm, grads, bufs = _grad_error_at(
                stale, fresh, topo, params, bufs, data, jax.random.PRNGKey(t))
            if t > 2:                      # skip cold-start (zero buffers)
                errs.append(err)
            params = {k: params[k] - eta * grads[k] for k in params}
        ratios.append(np.mean(errs) / eta)
        emit(f"thm31/grad_error/eta{eta}", 0.0,
             f"mean_err={np.mean(errs):.5f},err_over_eta={ratios[-1]:.3f}")
    spread = max(ratios) / min(ratios)
    emit("thm31/linear_in_eta", 0.0, f"ratio_spread={spread:.2f}")

    # (b) grad-norm decay vanilla vs pipegcn
    for name, model in (("vanilla", fresh), ("pipegcn", stale)):
        params = model.init_params(jax.random.PRNGKey(0))
        bufs = model.init_buffers(topo)
        norms = []
        T = 40 if quick else 120
        for t in range(T):
            _, grads, bufs, _ = model.train_step(topo, params, bufs, data,
                                                 jax.random.PRNGKey(t))
            params = {k: params[k] - 0.05 * grads[k] for k in params}
            norms.append(np.sqrt(sum(float((g ** 2).sum())
                                     for g in jax.tree.leaves(grads))))
        early = np.mean(norms[:T // 4])
        late = np.mean(norms[-T // 4:])
        emit(f"thm31/gradnorm/{name}", 0.0,
             f"early={early:.4f},late={late:.4f},decay={late / early:.3f}")
    return spread


if __name__ == "__main__":
    run()
