"""Paper Fig. 6 — smoothing decay-rate (γ) trade-off in PipeGCN-GF:
large γ converges fast but can overfit; small γ generalizes; γ=0 is noisy.
"""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import ModelConfig, PipeConfig, train_pipegcn
from repro.data import GraphDataPipeline
from repro.graph.synthetic import make_dataset, model_template

GAMMAS = [0.0, 0.3, 0.5, 0.7, 0.95]


def run(quick: bool = False, epochs: int = 200):
    name = "tiny" if quick else "small"
    if quick:
        epochs = 60
    ds = make_dataset(name, signal=0.3)
    pipeline = GraphDataPipeline.build(ds, 4, kind="sage")
    tpl = model_template(name)
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=tpl["hidden"],
                     num_layers=tpl["num_layers"],
                     num_classes=ds.num_classes, dropout=0.0)
    out = {}
    gammas = GAMMAS[::2] if quick else GAMMAS
    for gamma in gammas:
        res = train_pipegcn(pipeline, mc,
                            PipeConfig.named("pipegcn-gf", gamma=gamma),
                            epochs=epochs, lr=tpl["lr"],
                            eval_every=max(epochs // 10, 1))
        out[gamma] = res
        best_val = max(res.history["val_acc"])
        emit(f"fig6/gamma{gamma}", 1e6 / res.epochs_per_sec,
             f"final_test={res.final_metrics['test']:.4f},"
             f"best_val={best_val:.4f}")
    return out


if __name__ == "__main__":
    run()
