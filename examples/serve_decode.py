"""Batched serving example: prefill + decode with a KV cache on a reduced
assigned architecture (works for all 10 ids).

    PYTHONPATH=src python examples/serve_decode.py --arch qwen3-8b
    PYTHONPATH=src python examples/serve_decode.py --arch mamba2-780m
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    out = serve(args.arch, reduced=True, batch_size=args.batch,
                prompt_len=args.prompt_len, gen_tokens=args.gen,
                temperature=0.8)
    for k, v in out.items():
        print(f"{k}: {v}")


if __name__ == "__main__":
    main()
