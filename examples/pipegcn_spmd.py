"""The deployable SPMD path end-to-end: PipeGCN under `jax.shard_map` with
the partition count DECOUPLED from the device count — 8 graph partitions on
4 of the 8 forced host devices (2 co-resident partitions each, hierarchical
boundary exchange), Adam training, and a final equality check against the
single-device sim backend. Set PARTS_PER_DEVICE=1 for the classic
one-partition-per-chip layout.

    PYTHONPATH=src python examples/pipegcn_spmd.py
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.data import GraphDataPipeline
from repro.optim import adam

PARTS = 8
PARTS_PER_DEVICE = 2
EPOCHS = 60


def main():
    pipeline = GraphDataPipeline.build("tiny", num_parts=PARTS, kind="sage")
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=32, num_layers=2,
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    model = PipeGCN(mc, PipeConfig.named("pipegcn-gf", gamma=0.5))
    topo = pipeline.topo

    from repro.launch.mesh import make_partition_mesh
    mesh = make_partition_mesh(PARTS, parts_per_device=PARTS_PER_DEVICE)
    print(f"devices: {len(jax.devices())}, mesh: {mesh.shape}, "
          f"partitions: {PARTS} ({PARTS_PER_DEVICE}/device)")
    spmd_step = model.make_spmd_step(mesh, topo, "parts")

    opt = adam(0.01)
    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = opt.init(params)
    bufs = model.init_buffers(topo)
    bufs_sim = model.init_buffers(topo)
    params_sim, opt_sim = params, opt_state

    for epoch in range(EPOCHS):
        key = jax.random.PRNGKey(epoch)
        loss, _, grads, bufs = spmd_step(topo, params, bufs,
                                         pipeline.train_data, key)
        params, opt_state = opt.apply(params, grads, opt_state)
        # sim backend in lockstep (verification)
        loss_s, grads_s, bufs_sim, _ = model.train_step(
            topo, params_sim, bufs_sim, pipeline.train_data, key)
        params_sim, opt_sim = opt.apply(params_sim, grads_s, opt_sim)
        if epoch % 20 == 0:
            print(f"epoch {epoch:3d} loss {float(loss):.4f} "
                  f"(sim {float(loss_s):.4f})")

    drift = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(params_sim)))
    _, logits = model.forward(topo, params, pipeline.val_data)
    metrics = pipeline.metric(logits)
    print(f"final: test={metrics['test']:.4f} val={metrics['val']:.4f} "
          f"spmd-vs-sim param drift={drift:.2e}")
    assert drift < 1e-4, "SPMD and sim backends diverged"
    print("SPMD == sim across full training  OK")


if __name__ == "__main__":
    main()
