"""End-to-end training driver (the paper's main experiment at CPU scale):
Reddit-sim, 4 partitions, all five methods from Tab. 4, a few hundred
epochs, with checkpointing of the best model.

    PYTHONPATH=src python examples/train_reddit_sim.py [--epochs 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.checkpoint import save_checkpoint
from repro.core import ModelConfig, PipeConfig, train_pipegcn
from repro.data import GraphDataPipeline
from repro.graph.synthetic import make_dataset, model_template


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    ds = make_dataset("reddit-sim", signal=0.45)   # non-trivial difficulty
    pipeline = GraphDataPipeline.build(ds, args.partitions, kind="sage")
    tpl = model_template("reddit-sim")
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=tpl["hidden"],
                     num_layers=tpl["num_layers"],
                     num_classes=ds.num_classes, dropout=tpl["dropout"])
    print(f"reddit-sim: {ds.num_nodes} nodes, {ds.graph.num_edges} edges, "
          f"{args.partitions} partitions, "
          f"halo nodes={int(pipeline.pg.halo_counts().sum())}, "
          f"padding={pipeline.pg.padding_ratio():.2f}")

    best = None
    rows = []
    for variant in ("vanilla", "pipegcn", "pipegcn-g", "pipegcn-f",
                    "pipegcn-gf"):
        res = train_pipegcn(pipeline, mc, PipeConfig.named(variant),
                            epochs=args.epochs, lr=tpl["lr"],
                            eval_every=max(args.epochs // 10, 1),
                            log=lambda s, v=variant: print(f"[{v}] {s}"))
        rows.append((variant, res.final_metrics, res.epochs_per_sec))
        if best is None or res.final_metrics["test"] > best[1]:
            best = (variant, res.final_metrics["test"], res.params)
    print(f"\n{'variant':12s} {'test':>8s} {'val':>8s} {'epochs/s':>9s}")
    for variant, m, eps in rows:
        print(f"{variant:12s} {m['test']:8.4f} {m['val']:8.4f} {eps:9.2f}")
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.epochs, best[2])
        print(f"saved best ({best[0]}, test={best[1]:.4f}) to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
