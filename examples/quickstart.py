"""Quickstart: full-graph GCN training, vanilla vs PipeGCN vs PipeGCN-GF.

    PYTHONPATH=src python examples/quickstart.py

Trains GraphSAGE on a small synthetic community graph across 4 partitions
and prints the paper's Tab. 4-style comparison (same accuracy, pipelined
communication).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core import ModelConfig, PipeConfig, train_pipegcn
from repro.data import GraphDataPipeline
from repro.graph.synthetic import model_template


def main():
    pipeline = GraphDataPipeline.build("small", num_parts=4, kind="sage")
    tpl = model_template("small")
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=tpl["hidden"], num_layers=tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes,
                     dropout=tpl["dropout"])
    print(f"dataset=small nodes={pipeline.dataset.num_nodes} "
          f"partitions=4 halo={int(pipeline.pg.halo_counts().sum())} "
          f"boundary_bytes/layer={pipeline.pg.boundary_bytes_per_layer(mc.hidden):,}")
    print(f"{'variant':12s} {'test acc':>9s} {'val acc':>9s} {'epochs/s':>9s}")
    for variant in ("vanilla", "pipegcn", "pipegcn-gf"):
        res = train_pipegcn(pipeline, mc, PipeConfig.named(variant),
                            epochs=150, lr=tpl["lr"], eval_every=50)
        print(f"{variant:12s} {res.final_metrics['test']:9.4f} "
              f"{res.final_metrics['val']:9.4f} {res.epochs_per_sec:9.2f}")


if __name__ == "__main__":
    main()
