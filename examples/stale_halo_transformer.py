"""Beyond-paper demo: PipeGCN's deferred boundary exchange transplanted to a
sequence-parallel sliding-window transformer (see models/halo.py and
DESIGN.md §2.5).

Trains a tiny local-attention LM on a learnable copy task with the token
axis split across 4 shards, comparing:
  sync   — halo K/V ppermute on the critical path (vanilla analogue)
  stale  — halo deferred one step (PipeGCN analogue)
  stale+EMA — smoothed halo (PipeGCN-F analogue)

    PYTHONPATH=src python examples/stale_halo_transformer.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.halo import (HaloConfig, init_halo_buffers, init_params,
                               make_sim_train_step)


def batches(rng, vocab, shards, b, s_loc, steps):
    """Copy task with cross-shard dependency: every token repeats the token
    16 positions earlier — inside the window but often across the shard
    boundary, so the halo actually matters."""
    for _ in range(steps):
        total = shards * s_loc
        base = rng.integers(0, vocab, (b, total))
        base[:, 16:] = base[:, :-16]
        toks = base.reshape(b, shards, s_loc).transpose(1, 0, 2)
        labels = np.roll(base, -1, axis=1).reshape(b, shards, s_loc)
        labels = labels.transpose(1, 0, 2)
        yield (jnp.asarray(toks, jnp.int32), jnp.asarray(labels, jnp.int32))


def main():
    shards, B, S_loc, steps = 4, 16, 64, 600
    results = {}
    for name, stale, smooth in (("sync", False, False),
                                ("stale", True, False),
                                ("stale+EMA", True, True)):
        cfg = HaloConfig(stale=stale, smooth=smooth, window=32, vocab=16,
                         d_model=64, num_heads=4, num_layers=2)
        params = init_params(jax.random.PRNGKey(0), cfg)
        bufs = init_halo_buffers(cfg, S_loc, B, shards)
        opt_init, step = make_sim_train_step(cfg, shards, lr=1e-2)
        opt_state = opt_init(params)
        pos0 = jnp.arange(shards) * S_loc
        rng = np.random.default_rng(0)
        losses = []
        for toks, labels in batches(rng, cfg.vocab, shards, B, S_loc, steps):
            loss, params, opt_state, bufs = step(params, opt_state, toks,
                                                 labels, bufs, pos0)
            losses.append(float(loss))
        results[name] = losses
        print(f"{name:10s} loss: start={losses[0]:.3f} "
              f"mid={losses[steps // 2]:.3f} final={losses[-1]:.3f}")
    sync_final = results["sync"][-1]
    for name in ("stale", "stale+EMA"):
        gap = results[name][-1] - sync_final
        print(f"{name:10s} final-loss gap vs sync: {gap:+.4f} "
              f"({'parity' if abs(gap) < 0.15 else 'degraded'})")


if __name__ == "__main__":
    main()
