"""Aggregation-engine equivalence: the Pallas block-sparse engine must match
the COO/segment_sum engine (and jax.grad of a pure forward) within fp32
tolerance on the tiny pipelines, for both model kinds and both backends,
including the padded/empty-row-block edge cases."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN, shard_data, topology_from
from repro.graph import (build_partitioned_graph, extract_partition_tiles,
                         make_dataset, partition_graph)
from repro.graph.csr import mean_normalized, sym_normalized
from repro.kernels.aggregate import get_engine
from repro.kernels.gcn_spmm import (TILE, build_tile_topology,
                                    pad_tile_topology, spmm_block_sparse,
                                    spmm_block_sparse_t)

ATOL = 5e-5


def setup(kind, parts=4, layers=3, hidden=16):
    ds = make_dataset("tiny")
    norm = sym_normalized if kind == "gcn" else mean_normalized
    pg = build_partitioned_graph(norm(ds.graph),
                                 partition_graph(ds.graph, parts, seed=0),
                                 parts)
    topo = topology_from(pg, with_tiles=True)
    mc = ModelConfig(kind=kind, feat_dim=ds.feat_dim, hidden=hidden,
                     num_layers=layers, num_classes=ds.num_classes,
                     dropout=0.0)
    data = shard_data(pg, ds.features, ds.labels, ds.train_mask, ds.val_mask)
    return ds, pg, topo, mc, data


# ---------------------------------------------------------------------
# Engine-level SpMM / transpose-SpMM parity on real partition slices
# ---------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gcn", "sage"])
def test_engine_spmm_parity_on_partition_slices(kind):
    ds, pg, topo, mc, data = setup(kind)
    rng = np.random.default_rng(0)
    comb = jnp.asarray(rng.normal(size=(pg.combined, 24)), jnp.float32)
    dz = jnp.asarray(rng.normal(size=(pg.max_inner, 24)), jnp.float32)
    coo, bs = get_engine("coo"), get_engine("blocksparse")
    for i in range(pg.num_parts):
        ts_coo = tuple(getattr(topo, f)[i] for f in coo.fields)
        ts_bs = tuple(getattr(topo, f)[i] for f in bs.fields)
        np.testing.assert_allclose(
            np.asarray(bs.spmm(ts_bs, comb, pg.max_inner)),
            np.asarray(coo.spmm(ts_coo, comb, pg.max_inner)), atol=ATOL)
        np.testing.assert_allclose(
            np.asarray(bs.spmm_t(ts_bs, dz, pg.combined)),
            np.asarray(coo.spmm_t(ts_coo, dz, pg.combined)), atol=ATOL)


def test_transpose_kernel_matches_transposed_forward():
    """Pᵀ·δz from the transpose kernel == running the forward kernel on an
    explicitly transposed tile set."""
    rng = np.random.default_rng(1)
    R, C, F = 3 * TILE, 2 * TILE, 128
    dense = ((rng.random((R, C)) < 0.04)
             * rng.normal(size=(R, C))).astype(np.float32)
    row, col = np.nonzero(dense)
    tt = build_tile_topology(row, col, dense[row, col], R, C)
    dz = jnp.asarray(rng.normal(size=(R, F)), jnp.float32)
    got = np.asarray(spmm_block_sparse_t(
        jnp.asarray(tt.t_out), jnp.asarray(tt.t_in), jnp.asarray(tt.t_perm),
        jnp.asarray(tt.vals), dz, C))
    rowT, colT = np.nonzero(dense.T)
    ttT = build_tile_topology(rowT, colT, dense.T[rowT, colT], C, R)
    want = np.asarray(spmm_block_sparse(
        jnp.asarray(ttT.rows), jnp.asarray(ttT.cols), jnp.asarray(ttT.vals),
        dz, C))
    np.testing.assert_allclose(got, want, atol=2e-4)


def test_empty_row_and_col_blocks():
    """Blocks with no edges must flush zeros in BOTH kernels (filler path),
    and tile extraction must stay COO-direct for huge virtual shapes."""
    rng = np.random.default_rng(2)
    R, C, F = 3 * TILE, 3 * TILE, 128
    dense = np.zeros((R, C), np.float32)
    # only (row-block 0, col-block 2) populated: row blocks 1-2 and col
    # blocks 0-1 are empty
    dense[:TILE, 2 * TILE:] = (rng.random((TILE, TILE)) < 0.1) * 1.0
    row, col = np.nonzero(dense)
    tt = build_tile_topology(row, col, dense[row, col], R, C)
    h = jnp.asarray(rng.normal(size=(C, F)), jnp.float32)
    z = np.asarray(spmm_block_sparse(
        jnp.asarray(tt.rows), jnp.asarray(tt.cols), jnp.asarray(tt.vals),
        h, R))
    np.testing.assert_allclose(z, dense @ h, atol=2e-4)
    assert np.all(z[TILE:] == 0)
    dz = jnp.asarray(rng.normal(size=(R, F)), jnp.float32)
    d = np.asarray(spmm_block_sparse_t(
        jnp.asarray(tt.t_out), jnp.asarray(tt.t_in), jnp.asarray(tt.t_perm),
        jnp.asarray(tt.vals), dz, C))
    np.testing.assert_allclose(d, dense.T @ dz, atol=2e-4)
    assert np.all(d[:2 * TILE] == 0)


def test_tile_extraction_never_densifies():
    """A shard whose dense form would be ~3 TB must extract fine from COO."""
    n = 1_500_000                      # dense would be n*n*4 bytes ≈ 9 TB
    rng = np.random.default_rng(3)
    row = rng.integers(0, n, 2000)
    col = rng.integers(0, n, 2000)
    val = rng.normal(size=2000).astype(np.float32)
    tt = build_tile_topology(row, col, val, n, n)
    # every populated block key present, streams sorted + consistent
    assert tt.n_tiles < 2000 + tt.num_row_blocks + tt.num_col_blocks
    assert np.all(np.diff(tt.rows) >= 0)
    assert np.all(np.diff(tt.t_out) >= 0)
    assert np.array_equal(tt.rows[tt.t_perm], tt.t_in)
    assert np.array_equal(tt.cols[tt.t_perm], tt.t_out)


def test_padded_tile_streams_are_exact():
    """pad_tile_topology (used to stack unequal partitions) adds exact
    zeros to both kernels' outputs."""
    rng = np.random.default_rng(4)
    R = C = 2 * TILE
    dense = ((rng.random((R, C)) < 0.05)
             * rng.normal(size=(R, C))).astype(np.float32)
    row, col = np.nonzero(dense)
    tt = build_tile_topology(row, col, dense[row, col], R, C)
    tp = pad_tile_topology(tt, tt.n_tiles + 7)
    h = jnp.asarray(rng.normal(size=(C, 128)), jnp.float32)
    a = np.asarray(spmm_block_sparse(jnp.asarray(tt.rows),
                                     jnp.asarray(tt.cols),
                                     jnp.asarray(tt.vals), h, R))
    b = np.asarray(spmm_block_sparse(jnp.asarray(tp.rows),
                                     jnp.asarray(tp.cols),
                                     jnp.asarray(tp.vals), h, R))
    np.testing.assert_array_equal(a, b)
    c = np.asarray(spmm_block_sparse_t(jnp.asarray(tp.t_out),
                                       jnp.asarray(tp.t_in),
                                       jnp.asarray(tp.t_perm),
                                       jnp.asarray(tp.vals), h, C))
    np.testing.assert_allclose(c, dense.T @ np.asarray(h), atol=2e-4)


def test_extract_partition_tiles_consistency():
    """Stacked per-partition streams reproduce each shard's COO product."""
    ds, pg, topo, mc, data = setup("gcn", parts=4)
    pt = extract_partition_tiles(pg)
    assert pt.rows.shape[0] == pg.num_parts
    rng = np.random.default_rng(5)
    h = rng.normal(size=(pg.combined, 8)).astype(np.float32)
    for i in range(pg.num_parts):
        want = np.zeros((pg.max_inner, 8), np.float32)
        np.add.at(want, pg.edge_row[i],
                  pg.edge_w[i][:, None] * h[pg.edge_col[i]])
        got = np.asarray(get_engine("blocksparse").spmm(
            (jnp.asarray(pt.rows[i]), jnp.asarray(pt.cols[i]),
             jnp.asarray(pt.vals[i]), jnp.asarray(pt.t_out[i]),
             jnp.asarray(pt.t_in[i]), jnp.asarray(pt.t_perm[i])),
            jnp.asarray(h), pg.max_inner))
        np.testing.assert_allclose(got, want, atol=1e-4)


# ---------------------------------------------------------------------
# Full train-step parity (sim backend): blocksparse vs coo vs jax.grad
# ---------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gcn", "sage"])
@pytest.mark.parametrize("variant", ["vanilla", "pipegcn"])
def test_train_step_parity_sim(kind, variant):
    ds, pg, topo, mc, data = setup(kind)
    pipe = PipeConfig.named(variant)
    out = {}
    for agg in ("coo", "blocksparse"):
        model = PipeGCN(dataclasses.replace(mc, agg=agg), pipe)
        params = model.init_params(jax.random.PRNGKey(0))
        bufs = model.init_buffers(topo)
        # two steps so the stale (pipelined) path also exercises non-zero
        # buffers through the blocksparse transpose kernel
        for t in range(2):
            loss, grads, bufs, logits = model.train_step(
                topo, params, bufs, data, jax.random.PRNGKey(t))
        out[agg] = (float(loss), grads, np.asarray(logits))
    assert abs(out["coo"][0] - out["blocksparse"][0]) < ATOL
    for k in out["coo"][1]:
        np.testing.assert_allclose(np.asarray(out["coo"][1][k]),
                                   np.asarray(out["blocksparse"][1][k]),
                                   atol=ATOL, err_msg=f"{kind} {variant} {k}")
    np.testing.assert_allclose(out["coo"][2], out["blocksparse"][2],
                               atol=ATOL)


@pytest.mark.parametrize("kind", ["gcn", "sage"])
def test_blocksparse_matches_jax_grad(kind):
    """Vanilla mode + blocksparse engine == jax.grad of the dense full-graph
    forward (fp32)."""
    ds, pg, topo, mc, data = setup(kind)
    norm = sym_normalized if kind == "gcn" else mean_normalized
    model = PipeGCN(dataclasses.replace(mc, agg="blocksparse"),
                    PipeConfig.vanilla())
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(topo)
    loss, grads, _, _ = model.train_step(topo, params, bufs, data,
                                         jax.random.PRNGKey(1))

    P = jnp.asarray(norm(ds.graph).to_dense(), jnp.float32)
    X = jnp.asarray(ds.features, jnp.float32)
    y = jnp.asarray(ds.labels)
    m = jnp.asarray(ds.train_mask, jnp.float32)

    def ref_loss(params):
        h = X
        for ell in range(mc.num_layers):
            z = P @ h
            a = jnp.concatenate([z, h], -1) if kind == "sage" else z
            u = a @ params[f"w{ell}"] + params[f"b{ell}"]
            h = jax.nn.relu(u) if ell < mc.num_layers - 1 else u
        lse = jax.nn.logsumexp(h, -1)
        ll = jnp.take_along_axis(h, y[:, None].astype(jnp.int32), -1)[:, 0]
        return jnp.sum((lse - ll) * m) / jnp.sum(m)

    rloss, rgrads = jax.value_and_grad(ref_loss)(params)
    assert abs(float(loss - rloss)) < ATOL
    for k in grads:
        np.testing.assert_allclose(np.asarray(grads[k]),
                                   np.asarray(rgrads[k]), atol=ATOL)


def test_missing_tiles_raises():
    ds, pg, topo, mc, data = setup("gcn")
    topo_no_tiles = topology_from(pg)          # no tile streams attached
    model = PipeGCN(dataclasses.replace(mc, agg="blocksparse"),
                    PipeConfig.vanilla())
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(topo_no_tiles)
    with pytest.raises(ValueError, match="blocksparse"):
        model.train_step(topo_no_tiles, params, bufs, data,
                         jax.random.PRNGKey(0))


# ---------------------------------------------------------------------
# SPMD backend parity (subprocess: forced host devices)
# ---------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, jax, numpy as np
    import jax.numpy as jnp
    from repro.graph import make_dataset, partition_graph, build_partitioned_graph
    from repro.graph.csr import sym_normalized, mean_normalized
    from repro.core.config import ModelConfig, PipeConfig
    from repro.core.pipegcn import PipeGCN, topology_from, shard_data
    from repro.launch.mesh import make_mesh

    ds = make_dataset("tiny")
    for kind, norm in (("gcn", sym_normalized), ("sage", mean_normalized)):
        pg = build_partitioned_graph(norm(ds.graph),
                                     partition_graph(ds.graph, 4, seed=0), 4)
        topo = topology_from(pg, with_tiles=True)
        mc = ModelConfig(kind=kind, feat_dim=ds.feat_dim, hidden=16,
                         num_layers=2, num_classes=ds.num_classes,
                         dropout=0.0, agg="blocksparse")
        model = PipeGCN(mc, PipeConfig(stale=True))
        params = model.init_params(jax.random.PRNGKey(0))
        data = shard_data(pg, ds.features, ds.labels, ds.train_mask,
                          ds.val_mask)
        b1 = model.init_buffers(topo)
        b2 = model.init_buffers(topo)
        mesh = make_mesh((4,), ("parts",))
        step = model.make_spmd_step(mesh, topo, "parts")
        for t in range(3):
            key = jax.random.PRNGKey(t)
            l1, g1, b1, _ = model.train_step(topo, params, b1, data, key)
            l2, _, g2, b2 = step(topo, params, b2, data, key)
            assert abs(float(l1) - float(l2)) < 5e-5, (kind, t)
            for k in g1:
                d = float(jnp.abs(g1[k] - jnp.asarray(g2[k])).max())
                assert d < 5e-5, (kind, t, k, d)
        print(f"{kind}: OK")
    print("BLOCKSPARSE-SPMD-OK")
""")


@pytest.mark.slow
def test_blocksparse_spmd_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "BLOCKSPARSE-SPMD-OK" in proc.stdout
