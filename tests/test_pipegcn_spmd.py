"""SPMD (shard_map + all_to_all) backend equivalence vs the sim backend.

Runs in a subprocess so this test alone sees 8 forced host devices; the
rest of the suite keeps the single real device.
"""
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, numpy as np
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.graph import make_dataset, partition_graph, build_partitioned_graph
    from repro.graph.csr import mean_normalized
    from repro.core.config import ModelConfig, PipeConfig
    from repro.core.pipegcn import PipeGCN, topology_from, shard_data

    def run(nparts, axis_spec, variant):
        ds = make_dataset("tiny")
        prop = mean_normalized(ds.graph)
        part = partition_graph(ds.graph, nparts, seed=0)
        pg = build_partitioned_graph(prop, part, nparts)
        topo = topology_from(pg)
        topo = jax.tree.map(lambda x: x.astype(jnp.float64)
                            if x.dtype == jnp.float32 else x, topo)
        mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                         num_layers=3, num_classes=ds.num_classes, dropout=0.0)
        model = PipeGCN(mc, PipeConfig.named(variant, gamma=0.9))
        params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
        data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                          ds.train_mask, ds.val_mask)
        data = data._replace(x=data.x.astype(jnp.float64))
        b_sim = model.init_buffers(topo, dtype=jnp.float64)
        b_spmd = model.init_buffers(topo, dtype=jnp.float64)
        from repro.launch.mesh import make_mesh
        if axis_spec == "1d":
            mesh = make_mesh((nparts,), ("parts",))
            axis = "parts"
        else:
            mesh = make_mesh((2, nparts // 2), ("a", "b"))
            axis = ("a", "b")
        step = model.make_spmd_step(mesh, topo, axis)
        for t in range(3):
            key = jax.random.PRNGKey(t)
            l1, g1, b_sim, _ = model.train_step(topo, params, b_sim, data, key)
            l2, _, g2, b_spmd = step(topo, params, b_spmd, data, key)
            assert abs(float(l1) - float(l2)) < 1e-12, (variant, t)
            for k in g1:
                d = float(jnp.abs(g1[k] - jnp.asarray(g2[k])).max())
                assert d < 1e-12, (variant, t, k, d)
            for a, b in zip(jax.tree.leaves(b_sim), jax.tree.leaves(b_spmd)):
                assert float(jnp.abs(a - b).max()) < 1e-12
        print(f"{variant}/{axis_spec}: OK")

    run(8, "1d", "pipegcn-gf")
    run(8, "1d", "vanilla")
    run(8, "2d", "pipegcn")      # flattened ("a","b") axes = production layout
    print("ALL-OK")
""")


@pytest.mark.slow
def test_spmd_equals_sim_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ALL-OK" in proc.stdout
