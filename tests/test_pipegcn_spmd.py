"""SPMD (shard_map) backend equivalence vs the sim backend, as a full
parameterized matrix:

    (variant  ∈ {vanilla, pipegcn, pipegcn-gf})
  × (agg      ∈ {coo, blocksparse, fused})
  × (n_local  ∈ {1, 2, 4})      # co-resident partitions per device, P = 8

plus coverage cells the matrix alone misses: bf16 boundary compression and
k-step staleness FIFOs under the SPMD backend (both previously exercised
only by the sim tests), the production flattened-2D-axes layout, the
fused-deferred exchange (fuse_exchange × {agg, n_local, compression,
staleness-depth, smoothing}), and the matmul-ordering knob
(transform-first / cost-model auto) on the fused engine.

Every cell asserts 1e-12 float64 parity vs the sim backend for the loss,
every weight gradient, and every pipeline buffer, over >=3 steps. The sim
reference ALWAYS runs the blocking per-layer schedule (fuse_exchange=False)
and, for `agg="fused"` cells, the COO engine — the tile engines compute in
the caller's dtype (f64 here), so those cells are simultaneously a
cross-backend, a cross-schedule, AND a cross-ENGINE 1e-12 exactness check
of the fused Pallas kernels against segment_sum. The whole
matrix runs in ONE subprocess so it alone sees 8 forced host devices; the
rest of the suite keeps the single real device. One dataset/partitioning is
built per process and the Topology carries tile streams alongside the COO
shards, so every engine (and every n_local) runs on identical inputs.

The LAYOUT matrix additionally runs the SPMD model on the rcm-reordered
shards against the natural-layout sim reference (all variants × engines ×
n_local): node reordering must be numerically invisible, so loss / weight
grads / UNPACKED logits stay 1e-12 while the pipeline buffers (which live
in permuted coordinates and are intentionally not compared) differ.

The OVERLAP matrix runs the split-phase schedule (SPMD, rcm grid-tiny
lattice — the low-boundary regime where the split is feasible) against
the UNSPLIT sim reference on the same layout: the split re-slices each
layer's aggregation into boundary-phase → exchange → interior-phase, so
this is a cross-backend AND cross-schedule 1e-12 exactness check over
the full variants × engines × n_local product plus the wire/schedule
knob cells.
"""
import os
import subprocess
import sys
import textwrap

import pytest

# Cells are (variant, agg, n_local, overrides, axis layout); overrides are
# PipeConfig fields plus the optional "matmul_order" ModelConfig field.
MATRIX = [(v, a, nl, {}, "1d")
          for v in ("vanilla", "pipegcn", "pipegcn-gf")
          for a in ("coo", "blocksparse", "fused")
          for nl in (1, 2, 4)]
EXTRA = [
    # bf16 boundary compression under SPMD (cast happens before/after the
    # exchange in both backends, so parity stays exact)
    ("pipegcn", "coo", 1, {"compress_boundary": True}, "1d"),
    ("pipegcn", "coo", 4, {"compress_boundary": True}, "1d"),
    ("pipegcn-gf", "blocksparse", 2, {"compress_boundary": True}, "1d"),
    # k-step staleness FIFO queues under SPMD (buffer queue axis 0, local
    # partition axis 1)
    ("pipegcn", "coo", 1, {"staleness_steps": 3}, "1d"),
    ("pipegcn", "coo", 2, {"staleness_steps": 3}, "1d"),
    ("pipegcn", "blocksparse", 4, {"staleness_steps": 2}, "1d"),
    # production layout: flattened ("a","b") mesh axes as the partition
    # axis, both through the flat n_local=1 all_to_all and the
    # hierarchical n_local>1 exchange
    ("pipegcn", "coo", 1, {}, "2d"),
    ("pipegcn", "coo", 2, {}, "2d"),
    # fused-deferred exchange parity matrix (tentpole): explicit
    # fuse_exchange cells against the always-unfused sim reference, crossed
    # with agg engine, n_local, bf16 compression, staleness depth and
    # γ-smoothing; plus one unfused-SPMD cell so the per-layer schedule
    # itself stays covered under shard_map.
    ("pipegcn", "coo", 2, {"fuse_exchange": False}, "1d"),
    ("pipegcn", "coo", 1, {"fuse_exchange": True}, "1d"),
    ("pipegcn", "blocksparse", 4, {"fuse_exchange": True}, "1d"),
    ("pipegcn-gf", "coo", 2,
     {"fuse_exchange": True, "compress_boundary": True}, "1d"),
    ("pipegcn-g", "blocksparse", 2, {"fuse_exchange": True}, "1d"),
    ("pipegcn-f", "coo", 4, {"fuse_exchange": True}, "1d"),
    ("pipegcn", "coo", 2,
     {"fuse_exchange": True, "staleness_steps": 3}, "1d"),
    ("pipegcn", "coo", 2, {"fuse_exchange": True}, "2d"),
    # fused aggregate+transform engine (tentpole): its cells compare
    # against a COO sim reference (cross-engine f64 exactness), crossed
    # with compression, staleness depth, the 2-D axis layout, and both
    # non-default matmul orderings (transform-first routes the layer
    # through the plain SpMM after a dense transform; auto mixes per
    # layer via the static cost model).
    ("pipegcn", "fused", 2, {"compress_boundary": True}, "1d"),
    ("pipegcn", "fused", 4, {"staleness_steps": 2}, "1d"),
    ("pipegcn-g", "fused", 2, {"fuse_exchange": True}, "1d"),
    ("pipegcn", "fused", 2, {"matmul_order": "transform-first"}, "1d"),
    ("pipegcn", "fused", 4, {"matmul_order": "auto"}, "1d"),
    ("vanilla", "fused", 2, {"matmul_order": "auto"}, "1d"),
    ("pipegcn", "fused", 2, {}, "2d"),
    # quantized boundary wires (ISSUE 8): int8/int4 blockwise codecs under
    # shard_map, crossed with staleness depth, the fused schedule, the
    # fused engine, n_local>1, and EMA smoothing. Encode/decode run
    # outside the collective on both backends, so parity stays 1e-12.
    ("pipegcn", "coo", 2, {"wire": "int8"}, "1d"),
    ("pipegcn", "coo", 2, {"wire": "int8", "staleness_steps": 2}, "1d"),
    ("pipegcn", "blocksparse", 4,
     {"wire": "int4", "staleness_steps": 3}, "1d"),
    ("pipegcn-gf", "coo", 1, {"wire": "int4"}, "1d"),
    ("pipegcn", "fused", 2, {"wire": "int8", "fuse_exchange": True}, "1d"),
    # boundary feature slicing (ISSUE 8): post-transform-width payloads,
    # alone and co-decided with wire="auto" via the cost model
    ("pipegcn", "coo", 2,
     {"slice_boundary": True, "matmul_order": "transform-first"}, "1d"),
    ("pipegcn", "coo", 2,
     {"slice_boundary": True, "matmul_order": "auto", "wire": "auto",
      "fuse_exchange": True}, "1d"),
]
# Cross-layout cells: rcm-reordered SPMD model vs natural-layout sim
# reference — the full variants × engines × n_local product, so node
# reordering is proven numerically invisible on every code path.
LAYOUT = [(v, a, nl, {"layout": "rcm"}, "1d")
          for v in ("vanilla", "pipegcn", "pipegcn-gf")
          for a in ("coo", "blocksparse", "fused")
          for nl in (1, 2, 4)] + [
    # reordering must also commute with the wire/schedule knobs
    ("pipegcn", "coo", 2, {"layout": "rcm", "compress_boundary": True}, "1d"),
    ("pipegcn", "fused", 2, {"layout": "rcm", "staleness_steps": 2}, "1d"),
    ("pipegcn", "blocksparse", 2, {"layout": "rcm"}, "2d"),
]

# Split-phase overlap cells: SPMD split model vs unsplit sim reference,
# both on the rcm grid-tiny lattice (P=8: fwd_bnd=13/17 tiles). The full
# variant × engine × n_local product, plus knob cells (blocking per-layer
# exchange, bf16 compression, k-step staleness, matmul orders, 2-D axes).
OVERLAP = [(v, a, nl, {}, "1d")
           for v in ("vanilla", "pipegcn", "pipegcn-gf")
           for a in ("coo", "blocksparse", "fused")
           for nl in (1, 2, 4)] + [
    ("pipegcn", "blocksparse", 2, {"fuse_exchange": False}, "1d"),
    ("pipegcn", "blocksparse", 2, {"compress_boundary": True}, "1d"),
    ("pipegcn", "fused", 2, {"staleness_steps": 2}, "1d"),
    ("pipegcn-g", "blocksparse", 4, {"fuse_exchange": True}, "1d"),
    ("pipegcn", "fused", 2, {"matmul_order": "transform-first"}, "1d"),
    ("vanilla", "blocksparse", 2, {"matmul_order": "auto"}, "1d"),
    ("pipegcn", "blocksparse", 2, {}, "2d"),
]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.graph import make_dataset, partition_graph, build_partitioned_graph
    from repro.graph.csr import mean_normalized
    from repro.core.config import ModelConfig, PipeConfig
    from repro.core.pipegcn import PipeGCN, topology_from, shard_data
    from repro.launch.mesh import make_mesh, make_partition_mesh

    P = 8
    ds = make_dataset("tiny")
    prop = mean_normalized(ds.graph)
    part = partition_graph(ds.graph, P, seed=0)

    def build(layout):
        pg = build_partitioned_graph(prop, part, P, layout=layout)
        topo = topology_from(pg, with_tiles=True)
        topo = topo._replace(edge_w=topo.edge_w.astype(jnp.float64))
        data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                          ds.train_mask, ds.val_mask)
        return pg, topo, data._replace(x=data.x.astype(jnp.float64))

    # One topology per layout for every cell: COO shards in f64 for exact
    # parity; the tile engines compute in the caller's dtype, so their
    # cells are exact too.
    pg, topo, data = build("natural")
    pg_rcm, topo_rcm, data_rcm = build("rcm")

    def run(variant, agg, n_local, pipe_kw, axis_spec, steps=3):
        pipe_kw = dict(pipe_kw)
        mo = pipe_kw.pop("matmul_order", "aggregate-first")
        layout = pipe_kw.pop("layout", "natural")
        mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                         num_layers=3, num_classes=ds.num_classes,
                         dropout=0.0, agg=agg, matmul_order=mo)
        pc = dataclasses.replace(PipeConfig.named(variant, gamma=0.9),
                                 **pipe_kw)
        # The sim reference always runs the blocking per-layer schedule on
        # the NATURAL layout; the SPMD model runs the cell's schedule on
        # the cell's layout. Schedules are bit-identical by construction
        # and reordering is permutation-equivariant, so parity must stay
        # 1e-12. For the fused engine the reference additionally switches
        # to the COO engine: both run in f64 here, so the cell doubles as
        # a cross-engine exactness check of the fused Pallas kernels.
        ref_mc = dataclasses.replace(mc, agg="coo") if agg == "fused" else mc
        ref = PipeGCN(ref_mc, dataclasses.replace(pc, fuse_exchange=False))
        model = PipeGCN(mc, pc)
        topo_m, data_m = (topo_rcm, data_rcm) if layout == "rcm" \
            else (topo, data)
        params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
        b_sim = model.init_buffers(topo, dtype=jnp.float64)
        b_spmd = model.init_buffers(topo_m, dtype=jnp.float64)
        n_dev = P // n_local
        if axis_spec == "2d":
            mesh = make_mesh((2, n_dev // 2), ("a", "b"),
                             devices=jax.devices()[:n_dev])
            axis = ("a", "b")
        else:
            mesh = make_partition_mesh(P, parts_per_device=n_local)
            axis = "parts"
        step = model.make_spmd_step(mesh, topo_m, axis)
        cell = (variant, agg, f"nl{n_local}", axis_spec, layout, pipe_kw)
        for t in range(steps):
            key = jax.random.PRNGKey(t)
            l1, g1, b_sim, lg1 = ref.train_step(topo, params, b_sim, data,
                                                key)
            l2, lg2, g2, b_spmd = step(topo_m, params, b_spmd, data_m, key)
            assert abs(float(l1) - float(l2)) < 1e-12, ("loss", cell, t)
            for k in g1:
                d = float(jnp.abs(g1[k] - jnp.asarray(g2[k])).max())
                assert d < 1e-12, ("grad", cell, t, k, d)
            if layout == "natural":
                for a, b in zip(jax.tree.leaves(b_sim),
                                jax.tree.leaves(b_spmd)):
                    d = float(jnp.abs(a - jnp.asarray(b)).max())
                    assert d < 1e-12, ("buffers", cell, t, d)
            else:
                # buffers live in permuted coordinates; compare the
                # UNPACKED logits instead (the eval/metric contract)
                d = np.abs(pg.unpack_nodes(np.asarray(lg1))
                           - pg_rcm.unpack_nodes(np.asarray(lg2))).max()
                assert float(d) < 1e-12, ("logits", cell, t, d)
        print(f"OK {variant}/{agg}/{mo}/{layout}/nl{n_local}/{axis_spec}/"
              f"{pipe_kw}", flush=True)

    import json, sys
    cells = json.loads(sys.argv[1])
    for variant, agg, n_local, pipe_kw, axis_spec in cells:
        run(variant, agg, n_local, pipe_kw, axis_spec,
            steps=4 if pipe_kw.get("staleness_steps", 1) > 1 else 3)
    print("ALL-OK")
""")


SCRIPT_OVERLAP = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, numpy as np
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.graph import make_dataset, partition_graph, build_partitioned_graph
    from repro.graph.csr import mean_normalized
    from repro.core.config import ModelConfig, PipeConfig
    from repro.core.pipegcn import (PipeGCN, topology_from, shard_data,
                                    split_spec_from)
    from repro.launch.mesh import make_mesh, make_partition_mesh

    P = 8
    ds = make_dataset("grid-tiny")
    prop = mean_normalized(ds.graph)
    part = partition_graph(ds.graph, P, seed=0)
    pg = build_partitioned_graph(prop, part, P, layout="rcm")
    topo = topology_from(pg, with_tiles=True)
    topo = topo._replace(edge_w=topo.edge_w.astype(jnp.float64))
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    sp = split_spec_from(pg)
    assert sp is not None, "grid-tiny/rcm/P=8 must admit a feasible split"

    def run(variant, agg, n_local, pipe_kw, axis_spec, steps=3):
        pipe_kw = dict(pipe_kw)
        mo = pipe_kw.pop("matmul_order", "aggregate-first")
        mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                         num_layers=2, num_classes=ds.num_classes,
                         dropout=0.0, agg=agg, matmul_order=mo,
                         layout="rcm")
        pc = dataclasses.replace(PipeConfig.named(variant, gamma=0.9),
                                 **pipe_kw)
        # sim reference: UNSPLIT blocking per-layer schedule, same layout
        # (buffers stay directly comparable); fused cells reference COO so
        # they double as cross-engine exactness checks under the split.
        ref_mc = dataclasses.replace(mc, agg="coo") if agg == "fused" else mc
        ref = PipeGCN(ref_mc, dataclasses.replace(
            pc, fuse_exchange=False, overlap="none"))
        model = PipeGCN(mc, dataclasses.replace(pc, overlap="split-phase"),
                        split=sp)
        assert model._split_active() == sp
        params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
        b_sim = model.init_buffers(topo, dtype=jnp.float64)
        b_spmd = model.init_buffers(topo, dtype=jnp.float64)
        n_dev = P // n_local
        if axis_spec == "2d":
            mesh = make_mesh((2, n_dev // 2), ("a", "b"),
                             devices=jax.devices()[:n_dev])
            axis = ("a", "b")
        else:
            mesh = make_partition_mesh(P, parts_per_device=n_local)
            axis = "parts"
        step = model.make_spmd_step(mesh, topo, axis)
        cell = (variant, agg, f"nl{n_local}", axis_spec, pipe_kw)
        for t in range(steps):
            key = jax.random.PRNGKey(t)
            l1, g1, b_sim, lg1 = ref.train_step(topo, params, b_sim, data,
                                                key)
            l2, lg2, g2, b_spmd = step(topo, params, b_spmd, data, key)
            assert abs(float(l1) - float(l2)) < 1e-12, ("loss", cell, t)
            for k in g1:
                d = float(jnp.abs(g1[k] - jnp.asarray(g2[k])).max())
                assert d < 1e-12, ("grad", cell, t, k, d)
            d = float(jnp.abs(lg1 - jnp.asarray(lg2)).max())
            assert d < 1e-12, ("logits", cell, t, d)
            for a, b in zip(jax.tree.leaves(b_sim), jax.tree.leaves(b_spmd)):
                d = float(jnp.abs(a - jnp.asarray(b)).max())
                assert d < 1e-12, ("buffers", cell, t, d)
        print(f"OK split/{variant}/{agg}/{mo}/nl{n_local}/{axis_spec}/"
              f"{pipe_kw}", flush=True)

    import json, sys
    cells = json.loads(sys.argv[1])
    for variant, agg, n_local, pipe_kw, axis_spec in cells:
        run(variant, agg, n_local, pipe_kw, axis_spec,
            steps=4 if pipe_kw.get("staleness_steps", 1) > 1 else 3)
    print("ALL-OK")
""")


def _run_matrix(script, cells, timeout):
    import json
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script, json.dumps(cells)],
                          env=env, capture_output=True, text=True,
                          timeout=timeout)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "ALL-OK" in proc.stdout
    assert proc.stdout.count("OK ") == len(cells), proc.stdout


@pytest.mark.slow
def test_spmd_matrix_equals_sim_subprocess():
    # ~250 s locally for the full matrix; generous headroom for slower CI.
    _run_matrix(SCRIPT, MATRIX + EXTRA + LAYOUT, timeout=1800)


@pytest.mark.slow
def test_spmd_overlap_matrix_equals_unsplit_sim_subprocess():
    _run_matrix(SCRIPT_OVERLAP, OVERLAP, timeout=1800)
