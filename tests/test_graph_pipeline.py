"""GraphDataPipeline regression tests.

PR-1 built `train_data` and `val_data` as two identical `ShardedData`
objects (both packed with the val mask, each with its own copy of every
array). The views must instead SHARE one packed array set — x / labels /
train_mask are split-independent — and differ only in `eval_mask`, which
must be the split's own mask."""
import numpy as np


def test_split_views_share_packed_arrays(tiny_pipeline):
    p = tiny_pipeline
    for a, b in ((p.train_data, p.val_data), (p.val_data, p.test_data)):
        assert a.x is b.x
        assert a.labels is b.labels
        assert a.train_mask is b.train_mask


def test_eval_masks_differ_per_split(tiny_pipeline):
    p = tiny_pipeline
    masks = {name: np.asarray(getattr(p, f"{name}_data").eval_mask)
             for name in ("train", "val", "test")}
    assert not np.array_equal(masks["train"], masks["val"])
    assert not np.array_equal(masks["val"], masks["test"])
    assert not np.array_equal(masks["train"], masks["test"])


def test_eval_masks_unpack_to_dataset_splits(tiny_pipeline):
    p, ds = tiny_pipeline, tiny_pipeline.dataset
    for name, ref in (("train", ds.train_mask), ("val", ds.val_mask),
                      ("test", ds.test_mask)):
        packed = np.asarray(getattr(p, f"{name}_data").eval_mask)
        np.testing.assert_array_equal(p.pg.unpack_nodes(packed), ref)


def test_device_layout_view(tiny_pipeline):
    """The explicit (n_dev, n_local, ...) view flattens back to the shard
    arrays the SPMD step consumes."""
    p = tiny_pipeline
    topo_l, data_l = p.device_layout(2)
    n_local = p.topo.num_parts // 2
    assert data_l.x.shape == (2, n_local) + p.train_data.x.shape[1:]
    np.testing.assert_array_equal(
        np.asarray(data_l.x).reshape(p.train_data.x.shape),
        np.asarray(p.train_data.x))
    np.testing.assert_array_equal(
        np.asarray(topo_l.send_idx).reshape(p.topo.send_idx.shape),
        np.asarray(p.topo.send_idx))
