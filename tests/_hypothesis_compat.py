"""Hypothesis shim: re-export the real library when installed, otherwise a
deterministic fixed-seed fallback so the suite always collects and runs.

The fallback implements just the strategy surface these tests use
(integers, floats, booleans, sampled_from) and runs each @given test over
`max_examples` draws from a seeded RNG — a property *sweep* rather than a
property *search*, but fully deterministic and dependency-free.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value, max_value, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(
                lambda rng: elements[int(rng.integers(0, len(elements)))])

        @staticmethod
        def lists(elem, min_size=0, max_size=10):
            return _Strategy(
                lambda rng: [elem.draw(rng) for _ in range(
                    int(rng.integers(min_size, max_size + 1)))])

    st = _Strategies()
    strategies = st

    def settings(max_examples: int = 10, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strats):
        def deco(fn):
            # No functools.wraps: pytest must see a zero-arg signature, not
            # the strategy parameters (it would look for fixtures named n,
            # seed, ...). Name/doc are copied for readable reports.
            def wrapper():
                n = getattr(wrapper, "_max_examples", 10)
                rng = np.random.default_rng(0)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strats.items()}
                    fn(**drawn)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper._max_examples = getattr(fn, "_max_examples", 10)
            return wrapper
        return deco
