"""Boundary codecs (int8/int4 blockwise quantization) + feature slicing.

Three layers of gating for the quantized wire formats:

1. codec unit tests — wire widths agree with the analysis cost model,
   quantization error respects the documented ``scale/2`` bound (hypothesis
   property), zeros/odd-widths/empty shapes round-trip, and the int4 nibble
   layout matches the normative spec in ``docs/wire-format.md`` byte for
   byte.
2. model-level parity — feature slicing is exact (1e-12, f64) against the
   unsliced model in vanilla mode, sliced buffers take the post-transform
   width, and neither codecs nor slicing change the traced collective
   counts.
3. traffic + convergence — `traced_wire_bytes` equals the analytic
   per-row byte formula for every wire format, and an int8 wire still
   trains the tier-1 smoke model to the same bar as the f32 wire (the
   slow-tier accuracy-delta sweep covers int4 and deeper staleness).

Cross-backend (shard_map) quantized cells live in test_pipegcn_spmd.py;
fused-vs-per-layer codec parity cells live in test_fused_exchange.py.
"""
import dataclasses

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

from repro.analysis.cost import (DEFAULT_FLOPS_PER_WIRE_BYTE,
                                 choose_wire_formats, gcn_order_report,
                                 wire_bytes_per_row)
from repro.core.codec import (WIRE_BLOCK, WIRE_FORMATS, QuantCodec, byteify,
                              make_codec, unbyteify)
from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN, shard_data, topology_from
from repro.core.trace_utils import (traced_step_collectives,
                                    traced_step_wire_bytes)
from repro.graph import build_partitioned_graph, make_dataset, partition_graph
from repro.graph.csr import mean_normalized
from repro.launch.mesh import make_partition_mesh

P = 4

WIDTHS = [0, 1, 2, 7, 16, 127, 128, 129, 130, 256]


# ----------------------------------------------------------------------
# 1. codec unit tests
# ----------------------------------------------------------------------

@pytest.mark.parametrize("wire", WIRE_FORMATS)
@pytest.mark.parametrize("f", WIDTHS)
def test_wire_width_agrees_with_cost_model(wire, f):
    """codec.wire_bytes IS the analysis-side wire_bytes_per_row, and the
    encoded array really has wire_width columns."""
    codec = make_codec(wire)
    assert codec.wire_bytes(f) == wire_bytes_per_row(wire, f, WIRE_BLOCK)
    x = jax.random.normal(jax.random.PRNGKey(f), (3, f), jnp.float32)
    wire_arr = codec.encode(x)
    assert wire_arr.shape == (3, codec.wire_width(f))
    if wire in ("int8", "int4"):
        assert wire_arr.dtype == jnp.uint8
    back = codec.decode(wire_arr, f, jnp.float32)
    assert back.shape == x.shape and back.dtype == jnp.float32


@pytest.mark.parametrize("bits", [8, 4])
@pytest.mark.parametrize("f", [1, 2, 7, 16, 127, 128, 129, 130])
def test_quant_roundtrip_error_bound(bits, f):
    """|decode(encode(x)) - x| <= scale/2 per element, scale = amax/qmax
    over that element's 128-column block (the documented bound)."""
    codec = make_codec(f"int{bits}")
    x = 10.0 * jax.random.normal(jax.random.PRNGKey(bits * 1000 + f),
                                 (5, f), jnp.float64)
    back = codec.decode(codec.encode(x), f, jnp.float64)
    nb = -(-f // WIRE_BLOCK)
    xp = jnp.pad(x, ((0, 0), (0, nb * WIRE_BLOCK - f)))
    amax = jnp.max(jnp.abs(xp.reshape(5, nb, WIRE_BLOCK)), axis=-1)
    bound = jnp.repeat(amax / (2 * codec.qmax), WIRE_BLOCK, -1)[:, :f]
    err = jnp.abs(back - x)
    assert float(jnp.max(err - bound)) <= 1e-6, (bits, f, float(err.max()))


@pytest.mark.parametrize("bits", [8, 4])
def test_quant_zeros_roundtrip_exact(bits):
    """All-zero payloads (cold stale buffers at t=0) use scale 1 and must
    reconstruct exactly zero — not NaN from a 0/0 scale."""
    codec = make_codec(f"int{bits}")
    for f in (1, 130):
        z = jnp.zeros((4, f), jnp.float32)
        back = codec.decode(codec.encode(z), f, jnp.float32)
        assert float(jnp.abs(back).max()) == 0.0
    # mixed: one all-zero block next to a live block
    x = jnp.concatenate([jnp.zeros((2, WIRE_BLOCK)),
                         jnp.ones((2, 3))], axis=-1)
    back = codec.decode(codec.encode(x), x.shape[-1], jnp.float32)
    assert float(jnp.abs(back[:, :WIRE_BLOCK]).max()) == 0.0
    assert float(jnp.abs(back[:, WIRE_BLOCK:] - 1.0).max()) < 1e-6


@pytest.mark.parametrize("wire", WIRE_FORMATS)
def test_codec_zero_rows_and_zero_width(wire):
    """Degenerate boundary slots: 0 rows (an isolated partition) and 0
    feature columns both encode/decode to empty arrays of the right shape."""
    codec = make_codec(wire)
    for shape in [(0, 7), (P, 0, 7), (3, 0)]:
        f = shape[-1]
        x = jnp.zeros(shape, jnp.float32)
        wire_arr = codec.encode(x)
        assert wire_arr.shape == shape[:-1] + (codec.wire_width(f),)
        back = codec.decode(wire_arr, f, jnp.float32)
        assert back.shape == shape


def test_int4_nibble_layout_matches_spec():
    """Pin the normative docs/wire-format.md layout: low nibble = even
    column, odd trailing column zero-padded, scales trail as little-endian
    f32 bytes."""
    codec = QuantCodec(bits=4, block=WIRE_BLOCK)
    x = jnp.asarray([[3.0, -15.0, 21.0]])          # amax 21 -> scale 3
    wire = np.asarray(codec.encode(x))
    assert wire.shape == (1, 2 + 4)                # ceil(3/2) payload + 4 scale
    # q = round(x/3) = [1, -5, 7]; -5 -> 0xB two's-complement nibble
    assert wire[0, 0] == (1 | (0xB << 4))
    assert wire[0, 1] == 7                         # high nibble = zero pad
    assert np.frombuffer(wire[0, 2:].tobytes(),
                         dtype=np.float32)[0] == np.float32(3.0)
    back = np.asarray(codec.decode(jnp.asarray(wire), 3, jnp.float32))
    np.testing.assert_allclose(back, [[3.0, -15.0, 21.0]], atol=1e-6)


def test_quant_custom_block_size():
    """wire_block is honoured: block=8 over f=20 gives 3 scale blocks and
    a per-block bound tighter than one global scale could give."""
    codec = QuantCodec(bits=8, block=8)
    f = 20
    assert codec.wire_width(f) == f + 4 * 3
    x = jnp.concatenate([1e-3 * jnp.ones((2, 8)), 1e3 * jnp.ones((2, 12))],
                        axis=-1)
    back = codec.decode(codec.encode(x), f, jnp.float32)
    # the small block keeps its own scale -> relative error stays ~1/qmax
    assert float(jnp.abs(back[:, :8] - 1e-3).max()) < 1e-3 / 100


@given(f=st.integers(min_value=0, max_value=40),
       bits=st.sampled_from([8, 4]),
       block=st.sampled_from([4, 8, 128]),
       seed=st.integers(min_value=0, max_value=2**16))
def test_quant_roundtrip_property(f, bits, block, seed):
    """Property: for ANY width/block/bits, shapes agree with wire_width
    and the per-block scale/2 error bound holds."""
    codec = QuantCodec(bits=bits, block=block)
    x = 5.0 * jax.random.normal(jax.random.PRNGKey(seed), (3, f), jnp.float64)
    wire = codec.encode(x)
    assert wire.shape == (3, codec.wire_width(f)) and wire.dtype == jnp.uint8
    back = codec.decode(wire, f, jnp.float64)
    assert back.shape == x.shape
    if f == 0:
        return
    nb = -(-f // block)
    xp = jnp.pad(x, ((0, 0), (0, nb * block - f)))
    amax = jnp.max(jnp.abs(xp.reshape(3, nb, block)), axis=-1)
    bound = jnp.repeat(amax / (2 * codec.qmax), block, -1)[:, :f]
    assert float(jnp.max(jnp.abs(back - x) - bound)) <= 1e-6


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.float64,
                                   jnp.uint8])
def test_byteify_roundtrip(dtype):
    """byteify/unbyteify (the mixed-dtype fused-pack planarizer) is exact
    for every wire dtype, including the uint8 pass-through."""
    x = jnp.arange(24).reshape(2, 3, 4).astype(dtype)
    b, it, dt = byteify(x)
    assert b.dtype == jnp.uint8 and b.shape == (2, 3, 4 * it)
    assert it == jnp.dtype(dtype).itemsize and dt == x.dtype
    back = unbyteify(b, it, dt)
    assert back.dtype == x.dtype and jnp.array_equal(back, x)


def test_make_codec_rejects_unknown():
    with pytest.raises(ValueError, match="unknown wire format"):
        make_codec("fp8")


# ----------------------------------------------------------------------
# 2. config + cost-model plumbing
# ----------------------------------------------------------------------

def test_config_wire_validation():
    assert PipeConfig(wire="int8").wire == "int8"
    with pytest.raises(ValueError):
        PipeConfig(wire="fp8")
    with pytest.raises(ValueError):
        PipeConfig(wire_block=0)
    with pytest.raises(ValueError):
        PipeConfig(slice_boundary=True, overlap="split-phase")


def test_compress_boundary_is_bf16_alias():
    """The deprecated flag normalizes to wire='bf16'; combining it with a
    conflicting explicit wire is an error, with a matching wire is fine."""
    assert PipeConfig(compress_boundary=True).wire == "bf16"
    assert PipeConfig(compress_boundary=True, wire="bf16").wire == "bf16"
    with pytest.raises(ValueError, match="compress_boundary"):
        PipeConfig(compress_boundary=True, wire="int8")


def test_choose_wire_formats_prefers_fidelity_on_ties():
    """Per width: fewest bytes wins; exact byte ties go to the earliest
    candidate (bf16 before int8 -> higher fidelity at equal cost)."""
    # f=16: bf16 = 32 B, int8 = 16+4 = 20 B -> int8
    # f=4 : bf16 =  8 B, int8 =  4+4 =  8 B -> tie -> bf16
    assert choose_wire_formats((16, 4)) == ("int8", "bf16")
    assert choose_wire_formats((), candidates=("bf16",)) == ()
    assert choose_wire_formats((16,), candidates=("int4", "int8")) == ("int4",)


def test_wire_bytes_per_row_formulas():
    assert wire_bytes_per_row("f32", 10) == 40.0
    assert wire_bytes_per_row("bf16", 10) == 20.0
    assert wire_bytes_per_row("int8", 10) == 14.0       # 10 + 1 block * 4
    assert wire_bytes_per_row("int4", 11) == 10.0       # ceil(11/2) + 4
    assert wire_bytes_per_row("int8", 0) == 0.0
    assert wire_bytes_per_row("int8", 130, block=128) == 130 + 8.0
    with pytest.raises(ValueError):
        wire_bytes_per_row("fp8", 10)


def test_order_report_comm_pricing_flips_choice():
    """With boundary bytes priced in, a layer that shrinks 64->8 flips to
    transform-first once comm is expensive enough; with pricing off
    (defaults) the report is the classic FLOP argmin and still carries the
    wire_bytes figure. Layer 0 always prices fin — its payload is the raw
    input — so the shrink shows up on layer 1 only."""
    dims = [(64, 64), (64, 8)]
    kw = dict(num_rows=64, combined=128, nnz_eff=256.0, train=True)
    base = gcn_order_report(dims, **kw)
    assert all("wire_bytes" in r for r in base)
    priced = gcn_order_report(
        dims, slot_rows=1e4, slice_boundary=True,
        comm_flops_per_byte=DEFAULT_FLOPS_PER_WIRE_BYTE, **kw)
    wb0, wb1 = priced[0]["wire_bytes"], priced[1]["wire_bytes"]
    assert wb0["transform-first"] == wb0["aggregate-first"]
    assert wb1["transform-first"] < wb1["aggregate-first"]
    assert priced[1]["chosen"] == "transform-first"


# ----------------------------------------------------------------------
# 3. model-level: slicing parity, buffer widths, collective counts
# ----------------------------------------------------------------------

@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("tiny")
    prop = mean_normalized(ds.graph)
    pg = build_partitioned_graph(prop, partition_graph(ds.graph, P, seed=0), P)
    topo = topology_from(pg, with_tiles=True)
    topo = topo._replace(edge_w=topo.edge_w.astype(jnp.float64))
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    return ds, topo, data


def _pair(ds, num_layers=3, kind="sage", agg="coo", **pipe_kw):
    mc = ModelConfig(kind=kind, feat_dim=ds.feat_dim, hidden=16,
                     num_layers=num_layers, num_classes=ds.num_classes,
                     dropout=0.0, agg=agg,
                     matmul_order=pipe_kw.pop("matmul_order",
                                              "aggregate-first"))
    pc = dataclasses.replace(PipeConfig.named("pipegcn"), **pipe_kw)
    return mc, pc


@pytest.mark.parametrize("kind,agg", [("sage", "coo"), ("gcn", "blocksparse")])
def test_sliced_equals_unsliced_vanilla(setup, kind, agg):
    """Slicing reroutes WHERE the transform runs (owner side vs halo side),
    not what is computed: in vanilla (fresh-exchange) mode the sliced and
    unsliced models must agree to f64 round-off on loss and every grad."""
    ds, topo, data = setup
    mc, pc = _pair(ds, kind=kind, agg=agg, stale=False,
                   matmul_order="transform-first", overlap="none")
    ref = PipeGCN(mc, pc)
    sli = PipeGCN(mc, dataclasses.replace(pc, slice_boundary=True))
    assert sli.sliced_layers(topo), "no layer sliced — cell is vacuous"
    params = ref.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b_ref = ref.init_buffers(topo, dtype=jnp.float64)
    b_sli = sli.init_buffers(topo, dtype=jnp.float64)
    for t in range(3):
        key = jax.random.PRNGKey(t)
        l0, g0, b_ref, _ = ref.train_step(topo, params, b_ref, data, key)
        l1, g1, b_sli, _ = sli.train_step(topo, params, b_sli, data, key)
        assert abs(float(l0) - float(l1)) < 1e-12, (kind, agg, t)
        for k in g0:
            d = float(jnp.abs(g0[k] - g1[k]).max())
            assert d < 1e-12, (kind, agg, t, k, d)


def test_sliced_buffers_take_post_transform_width(setup):
    """Sliced layers ship (and buffer) fout, not fin; layer 0 is never
    sliced (its payload is the raw input feature)."""
    ds, topo, data = setup
    mc, pc = _pair(ds, matmul_order="transform-first", overlap="none",
                   slice_boundary=True)
    model = PipeGCN(mc, pc)
    sl = model.sliced_layers(topo)
    assert 0 not in sl and sl, sl
    dims = mc.layer_dims()
    pw = model.payload_widths(topo)
    for ell in range(mc.num_layers):
        assert pw[ell] == (dims[ell][1] if ell in sl else dims[ell][0])
    bufs = model.init_buffers(topo, dtype=jnp.float64)
    for ell in sl:
        assert bufs["feat"][ell].shape[-1] == dims[ell][1]
        assert bufs["grad"][ell].shape[-1] == dims[ell][1]
    # stale sliced training runs and produces finite numbers
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    for t in range(3):
        loss, grads, bufs, _ = model.train_step(topo, params, bufs, data,
                                                jax.random.PRNGKey(t))
        assert np.isfinite(float(loss))


def test_sliced_quantized_fused_equals_perlayer(setup):
    """Slicing + int8 wire + staleness: the fused one-collective schedule
    still matches the per-layer schedule bit-for-bit."""
    ds, topo, data = setup
    mc, pc = _pair(ds, matmul_order="transform-first", overlap="none",
                   slice_boundary=True, wire="int8", staleness_steps=2)
    ref = PipeGCN(mc, dataclasses.replace(pc, fuse_exchange=False))
    fus = PipeGCN(mc, dataclasses.replace(pc, fuse_exchange=True))
    params = ref.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b_ref = ref.init_buffers(topo, dtype=jnp.float64)
    b_fus = fus.init_buffers(topo, dtype=jnp.float64)
    for t in range(4):
        key = jax.random.PRNGKey(t)
        l0, g0, b_ref, _ = ref.train_step(topo, params, b_ref, data, key)
        l1, g1, b_fus, _ = fus.train_step(topo, params, b_fus, data, key)
        assert abs(float(l0) - float(l1)) < 1e-12, t
        for k in g0:
            assert float(jnp.abs(g0[k] - g1[k]).max()) < 1e-12, (t, k)


def test_single_layer_int4_trains(setup):
    """L=1 edge case: forward ships one quantized payload, the backward
    ships nothing — the empty fused grad flush must not trace a collective
    of zero operands or crash."""
    ds, topo, data = setup
    mc, pc = _pair(ds, num_layers=1, wire="int4", fuse_exchange=True)
    model = PipeGCN(mc, pc)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = model.init_buffers(topo, dtype=jnp.float64)
    loss, grads, bufs, _ = model.train_step(topo, params, bufs, data,
                                            jax.random.PRNGKey(0))
    assert np.isfinite(float(loss)) and grads


def _model(pipeline, num_layers=3, **pipe_kw):
    pipe_kw = dict(pipe_kw)
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=16, num_layers=num_layers,
                     num_classes=pipeline.dataset.num_classes, dropout=0.0,
                     matmul_order=pipe_kw.pop("matmul_order",
                                              "aggregate-first"))
    pc = dataclasses.replace(PipeConfig.named("pipegcn"), **pipe_kw)
    return PipeGCN(mc, pc)


@pytest.mark.parametrize("pipe_kw", [
    {"wire": "int8"},
    {"wire": "int4", "staleness_steps": 2},
    {"wire": "auto"},
    {"wire": "int8", "slice_boundary": True,
     "matmul_order": "transform-first", "overlap": "none"},
])
def test_codecs_preserve_collective_counts(tiny_pipeline, pipe_kw):
    """Codecs/slicing change bytes per collective, never the number of
    collectives: fused stays 1 fwd + 1 bwd, per-layer stays 2L-1."""
    mesh = make_partition_mesh(P, parts_per_device=P)
    fus = _model(tiny_pipeline, fuse_exchange=True, **pipe_kw)
    got = traced_step_collectives(fus, mesh, tiny_pipeline.topo,
                                  tiny_pipeline.train_data, train=True)
    assert got["all_to_all"] == 2, (pipe_kw, got)
    per = _model(tiny_pipeline, fuse_exchange=False, **pipe_kw)
    got = traced_step_collectives(per, mesh, tiny_pipeline.topo,
                                  tiny_pipeline.train_data, train=True)
    assert got["all_to_all"] == 5, (pipe_kw, got)


# ----------------------------------------------------------------------
# 4. traced bytes-on-wire
# ----------------------------------------------------------------------

def _analytic_row_bytes(model, topo):
    """Bytes one boundary row costs per train step: every layer forward +
    every trained layer > 0 backward, at that layer's payload width."""
    pw = model.payload_widths(topo)
    wires = [c.name for c in model.wire_codecs(topo)]
    blk = model.pipe.wire_block
    fwd = sum(wire_bytes_per_row(w, f, blk) for w, f in zip(wires, pw))
    bwd = sum(wire_bytes_per_row(w, f, blk)
              for w, f in list(zip(wires, pw))[1:])
    return fwd + bwd


@pytest.mark.parametrize("pipe_kw", [
    {"wire": "bf16"},
    {"wire": "int8"},
    {"wire": "int4"},
    {"wire": "int8", "wire_block": 8},
    {"wire": "auto"},
    {"wire": "int8", "slice_boundary": True,
     "matmul_order": "transform-first", "overlap": "none"},
])
def test_traced_wire_bytes_match_formula(tiny_pipeline, pipe_kw):
    """The traced all_to_all bytes of a fused train step factor exactly as
    (boundary rows) x (analytic per-row bytes) — the row count calibrated
    once from the f32 trace, so the check pins the codec byte math without
    assuming the exchange layout."""
    mesh = make_partition_mesh(P, parts_per_device=P)
    topo = tiny_pipeline.topo
    base = _model(tiny_pipeline, fuse_exchange=True)
    got_f32 = traced_step_wire_bytes(base, mesh, topo,
                                     tiny_pipeline.train_data)
    rows = got_f32 / _analytic_row_bytes(base, topo)
    assert rows == int(rows) and rows > 0, rows
    model = _model(tiny_pipeline, fuse_exchange=True, **pipe_kw)
    got = traced_step_wire_bytes(model, mesh, topo, tiny_pipeline.train_data)
    assert got == rows * _analytic_row_bytes(model, topo), pipe_kw
    assert got < got_f32


def test_traced_wire_bytes_ratios(tiny_pipeline):
    """Headline ratios on the tier-1 graph (every payload 16 wide): bf16
    is exactly half of f32, int8 exactly 20/64 (16 value bytes + one
    4-byte scale block per row vs 64 f32 bytes), int4 exactly 12/64. The
    reddit-sim acceptance bars (int8 <= 0.27x, int4 <= 0.15x, at widths
    128-256 where the scale region amortizes) are gated in
    benchmarks/bench_comm_ratio.py."""
    mesh = make_partition_mesh(P, parts_per_device=P)
    topo = tiny_pipeline.topo
    got = {w: traced_step_wire_bytes(
        _model(tiny_pipeline, fuse_exchange=True, wire=w),
        mesh, topo, tiny_pipeline.train_data)
        for w in ("f32", "bf16", "int8", "int4")}
    assert got["bf16"] * 2 == got["f32"]
    assert got["int8"] * 64 == got["f32"] * 20, got
    assert got["int4"] * 64 == got["f32"] * 12, got


# ----------------------------------------------------------------------
# 5. convergence
# ----------------------------------------------------------------------

def test_int8_wire_convergence_smoke(tiny_pipeline):
    """Tier-1: the int8 wire trains the staleness-smoke model to the same
    bar as the f32 wire (the slow tier sweeps int4 x staleness depths)."""
    from repro.core import train_pipegcn
    mc = ModelConfig(kind="sage", feat_dim=tiny_pipeline.dataset.feat_dim,
                     hidden=32, num_layers=2,
                     num_classes=tiny_pipeline.dataset.num_classes,
                     dropout=0.0)
    pc = dataclasses.replace(PipeConfig(stale=True), wire="int8",
                             fuse_exchange=True)
    res = train_pipegcn(tiny_pipeline, mc, pc, epochs=40, lr=0.01,
                        eval_every=40)
    assert res.final_metrics["test"] > 0.8, res.final_metrics
    hist = res.history["loss"]
    assert hist[-1] < hist[0] * 0.5, hist


@pytest.mark.slow
def test_quantized_accuracy_delta():
    """Slow tier: 120-epoch accuracy deltas vs the f32 wire stay within
    the issue bounds (int8 and the int8 x staleness cell <= 0.1 absolute,
    int4 <= 0.2)."""
    from repro.core import train_pipegcn
    from repro.data import GraphDataPipeline
    pipeline = GraphDataPipeline.build("tiny", num_parts=4, kind="sage")
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=32, num_layers=2,
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)

    def acc(**pipe_kw):
        pc = dataclasses.replace(PipeConfig(stale=True), fuse_exchange=True,
                                 **pipe_kw)
        res = train_pipegcn(pipeline, mc, pc, epochs=120, lr=0.01,
                            eval_every=120)
        return res.final_metrics["test"]

    ref = acc(wire="f32")
    assert ref > 0.9, ref
    assert abs(acc(wire="int8") - ref) <= 0.1
    assert abs(acc(wire="int8", staleness_steps=2) - ref) <= 0.1
    assert abs(acc(wire="int4") - ref) <= 0.2
