"""Composable custom_vjp wrapper: jax.grad path == hand-written backward."""
import jax
import numpy as np

from repro.core import ModelConfig, PipeConfig, make_pipegcn_loss
from repro.core.pipegcn import PipeGCN


def test_custom_vjp_equals_manual(tiny_pipeline):
    mc = ModelConfig(kind="sage", feat_dim=tiny_pipeline.dataset.feat_dim,
                     hidden=16, num_layers=3,
                     num_classes=tiny_pipeline.dataset.num_classes,
                     dropout=0.0)
    model = PipeGCN(mc, PipeConfig(stale=True))
    topo = tiny_pipeline.topo
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(topo)
    data = tiny_pipeline.train_data
    key = jax.random.PRNGKey(1)

    loss_fn = make_pipegcn_loss(model, topo)
    (loss_v, newb_v), grads_v = jax.jit(jax.value_and_grad(
        loss_fn, has_aux=True))(params, bufs, data, key)
    loss_m, grads_m, newb_m, _ = model.train_step(topo, params, bufs, data,
                                                  key)
    assert abs(float(loss_v) - float(loss_m)) < 1e-6
    for k in grads_m:
        np.testing.assert_allclose(np.asarray(grads_v[k]),
                                   np.asarray(grads_m[k]), atol=1e-6)
    for a, b in zip(jax.tree.leaves(newb_v), jax.tree.leaves(newb_m)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_custom_vjp_cotangent_scaling(tiny_pipeline):
    """Grad of 3·loss must be 3× grad of loss (ct propagation)."""
    mc = ModelConfig(kind="gcn", feat_dim=tiny_pipeline.dataset.feat_dim,
                     hidden=8, num_layers=2,
                     num_classes=tiny_pipeline.dataset.num_classes,
                     dropout=0.0)
    model = PipeGCN(mc, PipeConfig(stale=True))
    topo = tiny_pipeline.topo
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(topo)
    key = jax.random.PRNGKey(1)
    loss_fn = make_pipegcn_loss(model, topo)
    g1 = jax.grad(lambda p: loss_fn(p, bufs, tiny_pipeline.train_data,
                                    key)[0])(params)
    g3 = jax.grad(lambda p: 3.0 * loss_fn(p, bufs, tiny_pipeline.train_data,
                                          key)[0])(params)
    for k in g1:
        np.testing.assert_allclose(np.asarray(g3[k]), 3 * np.asarray(g1[k]),
                                   rtol=1e-5, atol=1e-7)
