"""Property tests for the hierarchical exchange primitive (the
multi-partition-per-device SPMD boundary shuffle) in isolation: random
(n_dev, n_local, P, slot, F) payloads evaluated through the host reference
(`hierarchical_exchange_host`, the same pack/unpack math with the
all_to_all replaced by its definition) must equal the flat global
swapaxes exchange. Plus the (n_dev, n_local) shard-layout helpers."""
import jax.numpy as jnp
import numpy as np

from _hypothesis_compat import given, settings, st
from repro.core.pipegcn import (SimBackend, flat_exchange_reference,
                                hierarchical_exchange_host)
from repro.data.graph_pipeline import from_local_layout, to_local_layout


def _payload(n_dev, n_local, slot, f, seed):
    p = n_dev * n_local
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(n_dev, n_local, p, slot, f)))


@settings(max_examples=30)
@given(n_dev=st.integers(1, 4), n_local=st.integers(1, 4),
       slot=st.integers(1, 3), f=st.integers(1, 5),
       seed=st.integers(0, 2 ** 16))
def test_hier_exchange_matches_flat_reference(n_dev, n_local, slot, f, seed):
    s = _payload(n_dev, n_local, slot, f, seed)
    np.testing.assert_array_equal(np.asarray(hierarchical_exchange_host(s)),
                                  np.asarray(flat_exchange_reference(s)))


@settings(max_examples=15)
@given(n_dev=st.integers(1, 4), n_local=st.integers(1, 3),
       seed=st.integers(0, 2 ** 16))
def test_hier_exchange_is_involution(n_dev, n_local, seed):
    """R[i, j] = S[j, i] applied twice is the identity."""
    s = _payload(n_dev, n_local, 2, 3, seed)
    twice = hierarchical_exchange_host(hierarchical_exchange_host(s))
    np.testing.assert_array_equal(np.asarray(twice), np.asarray(s))


def test_flat_reference_is_sim_backend_exchange():
    """The specification itself: the flat reference over global partition
    ids is exactly the sim backend's swapaxes exchange, resharded."""
    n_dev, n_local, slot, f = 3, 2, 6, 4
    s = _payload(n_dev, n_local, slot, f, seed=0)
    p = n_dev * n_local
    sim = SimBackend().exchange(s.reshape(p, p, slot, f))
    ref = flat_exchange_reference(s).reshape(p, p, slot, f)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(sim))


def test_single_device_exchange_is_pure_local_shuffle():
    """n_dev == 1: the whole exchange is the co-resident local shuffle."""
    s = _payload(1, 4, 2, 3, seed=1)
    got = hierarchical_exchange_host(s)[0]
    np.testing.assert_array_equal(np.asarray(got),
                                  np.asarray(jnp.swapaxes(s[0], 0, 1)))


# ---------------------------------------------------------------------------
# (n_dev, n_local) shard-layout helpers
# ---------------------------------------------------------------------------

@settings(max_examples=20)
@given(n_dev=st.integers(1, 5), n_local=st.integers(1, 4),
       seed=st.integers(0, 2 ** 16))
def test_local_layout_round_trip_and_device_major(n_dev, n_local, seed):
    p = n_dev * n_local
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(p, 3)))
    packed = to_local_layout(x, n_local)
    assert packed.shape == (n_dev, n_local, 3)
    for part in (0, p // 2, p - 1):   # partition p lives on device p//n_local
        np.testing.assert_array_equal(
            np.asarray(packed[part // n_local, part % n_local]),
            np.asarray(x[part]))
    np.testing.assert_array_equal(np.asarray(from_local_layout(packed)),
                                  np.asarray(x))


def test_local_layout_queue_axis():
    """k-step staleness buffers carry the partition axis at position 1."""
    buf = jnp.arange(3 * 8 * 2, dtype=jnp.float32).reshape(3, 8, 2)
    packed = to_local_layout(buf, 4, axis=1)
    assert packed.shape == (3, 2, 4, 2)
    np.testing.assert_array_equal(
        np.asarray(from_local_layout(packed, axis=1)), np.asarray(buf))


def test_local_layout_rejects_non_multiple():
    import pytest
    with pytest.raises(ValueError):
        to_local_layout(jnp.zeros((6, 2)), 4)
