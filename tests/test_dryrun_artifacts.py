"""Gates on the dry-run artifacts (produced by repro.launch.dryrun, which
forces 512 host devices and therefore runs standalone, not under pytest).
Skipped if the artifacts have not been generated yet."""
import json
import os

import pytest

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def _load(name):
    path = os.path.join(RESULTS, name)
    if not os.path.exists(path):
        pytest.skip(f"{name} not generated (run python -m repro.launch.dryrun)")
    return json.load(open(path))


@pytest.mark.parametrize("mesh,chips", [("16x16", 256), ("2x16x16", 512)])
def test_all_40_combos_compiled(mesh, chips):
    rows = _load(f"dryrun_{mesh}.json")
    assert len(rows) == 40
    errors = [r for r in rows if "error" in r]
    assert not errors, errors[:2]
    archs = {r["arch"] for r in rows}
    shapes = {r["shape"] for r in rows}
    assert len(archs) == 10 and len(shapes) == 4
    for r in rows:
        assert r["chips"] == chips
        assert r["compile_s"] > 0
        assert r["t_compute"] >= 0 and r["t_memory"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")


def test_roofline_terms_sane():
    rows = _load("dryrun_16x16.json")
    for r in rows:
        if r["mode"] == "train":
            # MODEL_FLOPS/analytic ratio in a sane band (0.2-1.3)
            assert 0.2 < r["model_flops_ratio"] < 1.3, (
                r["arch"], r["shape"], r["model_flops_ratio"])
        if r["mode"] == "decode":
            # decode must never be compute-bound at these batch sizes
            assert r["bottleneck"] != "compute", (r["arch"], r["shape"])


def test_pipegcn_production_dryrun():
    for name, chips in (("dryrun_pipegcn_16x16.json", 256),
                        ("dryrun_pipegcn_2x16x16.json", 512)):
        rows = _load(name)
        for r in rows:
            assert r["chips"] == chips
            assert r["collective_bytes_per_device"]["all-to-all"] > 0
