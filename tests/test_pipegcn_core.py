"""PipeGCN core semantics: exactness of the hand-written backward (vanilla),
and iteration-exact equivalence of the stale/pipelined path (with and without
smoothing) against a dense numpy oracle of Alg. 1 / Eq. 3-4 *including
parameter updates across iterations*."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN, shard_data, topology_from
from repro.graph import build_partitioned_graph, make_dataset, partition_graph
from repro.graph.csr import mean_normalized, sym_normalized

LR = 0.05


def setup(kind="gcn", parts=4, layers=3, hidden=16):
    ds = make_dataset("tiny")
    norm = sym_normalized if kind == "gcn" else mean_normalized
    prop = norm(ds.graph)
    part = partition_graph(ds.graph, parts, seed=0)
    pg = build_partitioned_graph(prop, part, parts)
    topo = topology_from(pg)
    topo = jax.tree.map(
        lambda x: x.astype(jnp.float64) if x.dtype == jnp.float32 else x, topo)
    mc = ModelConfig(kind=kind, feat_dim=ds.feat_dim, hidden=hidden,
                     num_layers=layers, num_classes=ds.num_classes,
                     dropout=0.0)
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    return ds, prop, part, pg, topo, mc, data


# ---------------------------------------------------------------------
# Vanilla mode == jax.grad of the full-graph computation (both model kinds)
# ---------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gcn", "sage"])
def test_vanilla_matches_jax_grad(kind):
    ds, prop, part, pg, topo, mc, data = setup(kind=kind)
    model = PipeGCN(mc, PipeConfig.vanilla())
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = model.init_buffers(topo, dtype=jnp.float64)
    loss, grads, _, logits = model.train_step(topo, params, bufs, data,
                                              jax.random.PRNGKey(1))

    P = jnp.asarray(prop.to_dense())
    X = jnp.asarray(ds.features, jnp.float64)
    y = jnp.asarray(ds.labels)
    m = jnp.asarray(ds.train_mask, jnp.float64)

    def ref_loss(params):
        h = X
        for ell in range(mc.num_layers):
            z = P @ h
            a = jnp.concatenate([z, h], -1) if kind == "sage" else z
            u = a @ params[f"w{ell}"] + params[f"b{ell}"]
            h = jax.nn.relu(u) if ell < mc.num_layers - 1 else u
        lse = jax.nn.logsumexp(h, -1)
        ll = jnp.take_along_axis(h, y[:, None].astype(jnp.int32), -1)[:, 0]
        return jnp.sum((lse - ll) * m) / jnp.sum(m)

    rloss, rgrads = jax.value_and_grad(ref_loss)(params)
    assert abs(float(loss - rloss)) < 1e-12
    for k in grads:
        np.testing.assert_allclose(np.asarray(grads[k]),
                                   np.asarray(rgrads[k]), atol=1e-11)


# ---------------------------------------------------------------------
# Dense numpy oracle of Alg.1 (gcn kind) with SGD updates over iterations
# ---------------------------------------------------------------------

def dense_alg1_oracle(prop_dense, part, X, y, mask, params0, pipe, T, lr,
                      num_classes, layers):
    same = part[:, None] == part[None, :]
    P_in = prop_dense * same
    P_bd = prop_dense * (~same)
    N = X.shape[0]
    W = {k: np.asarray(v, np.float64).copy() for k, v in params0.items()}

    H_store = [None] * layers      # H^{(t-1, l-1)} (stale feature source)
    C_prev = [None] * layers       # stale boundary gradient contribution
    ema_feat = [None] * layers
    ema_grad = [None] * layers
    losses, grads_hist = [], []
    total = mask.sum()

    for t in range(T):
        # ---- forward (Eq. 3)
        H = [X]
        Z = []
        used_feats = []
        for l in range(layers):
            if pipe.stale:
                src = ema_feat[l] if pipe.smooth_feat else H_store[l]
                use = src if src is not None else np.zeros_like(H[l])
            else:
                use = H[l]
            used_feats.append(use)
            z = P_in @ H[l] @ W[f"w{l}"] + P_bd @ use @ W[f"w{l}"] + W[f"b{l}"]
            Z.append(z)
            H.append(np.maximum(z, 0) if l < layers - 1 else z)
        logits = H[-1]
        # update stale feature state AFTER consumption
        for l in range(layers):
            if pipe.smooth_feat:
                prev = ema_feat[l] if ema_feat[l] is not None \
                    else np.zeros_like(H[l])
                ema_feat[l] = pipe.gamma * prev + (1 - pipe.gamma) * H[l]
            H_store[l] = H[l].copy()

        # ---- loss
        zmax = logits.max(-1, keepdims=True)
        e = np.exp(logits - zmax)
        probs = e / e.sum(-1, keepdims=True)
        lse = np.log(e.sum(-1)) + zmax[:, 0]
        ll = logits[np.arange(N), y]
        losses.append(((lse - ll) * mask).sum() / total)
        onehot = np.eye(num_classes)[y]
        J = (probs - onehot) * mask[:, None] / total

        # ---- backward (Eq. 4)
        grads = {}
        for l in reversed(range(layers)):
            M = J if l == layers - 1 else J * (Z[l] > 0)
            A_in = P_in @ H[l] + P_bd @ used_feats[l]
            grads[f"w{l}"] = A_in.T @ M
            grads[f"b{l}"] = M.sum(0)
            if l == 0:
                break
            C_cur = P_bd.T @ M @ W[f"w{l}"].T
            if pipe.stale:
                if pipe.smooth_grad:
                    src = ema_grad[l] if ema_grad[l] is not None \
                        else np.zeros_like(C_cur)
                    contrib = src
                    ema_grad[l] = pipe.gamma * (ema_grad[l]
                                                if ema_grad[l] is not None
                                                else np.zeros_like(C_cur)) \
                        + (1 - pipe.gamma) * C_cur
                else:
                    contrib = C_prev[l] if C_prev[l] is not None \
                        else np.zeros_like(C_cur)
                C_prev[l] = C_cur
            else:
                contrib = C_cur
            J = P_in.T @ M @ W[f"w{l}"].T + contrib
        grads_hist.append(grads)
        for k in W:
            W[k] -= lr * grads[k]
    return losses, grads_hist, W


@pytest.mark.parametrize("variant", ["pipegcn", "pipegcn-g", "pipegcn-f",
                                     "pipegcn-gf", "vanilla"])
def test_stale_training_matches_dense_oracle(variant):
    """5 SGD iterations: losses, gradients, and weights match the dense
    Alg.1 oracle exactly for every PipeGCN variant."""
    ds, prop, part, pg, topo, mc, data = setup(kind="gcn", layers=3)
    pipe = PipeConfig.named(variant, gamma=0.9)
    model = PipeGCN(mc, pipe)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    np_params = {k: np.asarray(v) for k, v in params.items()}

    o_losses, o_grads, o_W = dense_alg1_oracle(
        np.asarray(prop.to_dense()), part, ds.features.astype(np.float64),
        ds.labels, ds.train_mask.astype(np.float64), np_params, pipe, T=5,
        lr=LR, num_classes=ds.num_classes, layers=mc.num_layers)

    bufs = model.init_buffers(topo, dtype=jnp.float64)
    for t in range(5):
        loss, grads, bufs, _ = model.train_step(topo, params, bufs, data,
                                                jax.random.PRNGKey(t))
        assert abs(float(loss) - o_losses[t]) < 1e-10, (variant, t)
        for k in grads:
            np.testing.assert_allclose(np.asarray(grads[k]), o_grads[t][k],
                                       atol=1e-10, err_msg=f"{variant} t={t} {k}")
        params = {k: params[k] - LR * grads[k] for k in params}
    for k in params:
        np.testing.assert_allclose(np.asarray(params[k]), o_W[k], atol=1e-9)


def test_single_partition_pipe_equals_vanilla():
    """With P=1 there is no boundary, so staleness must change nothing."""
    ds = make_dataset("tiny")
    prop = sym_normalized(ds.graph)
    pg = build_partitioned_graph(prop, np.zeros(ds.num_nodes, np.int32), 1)
    topo = topology_from(pg)
    topo = jax.tree.map(
        lambda x: x.astype(jnp.float64) if x.dtype == jnp.float32 else x, topo)
    mc = ModelConfig(kind="gcn", feat_dim=ds.feat_dim, hidden=8,
                     num_layers=2, num_classes=ds.num_classes, dropout=0.0)
    data = shard_data(pg, ds.features, ds.labels, ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    out = {}
    for name in ("vanilla", "pipegcn"):
        model = PipeGCN(mc, PipeConfig.named(name))
        params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
        bufs = model.init_buffers(topo, dtype=jnp.float64)
        losses = []
        for t in range(3):
            loss, grads, bufs, _ = model.train_step(topo, params, bufs, data,
                                                    jax.random.PRNGKey(t))
            params = {k: params[k] - LR * grads[k] for k in params}
            losses.append(float(loss))
        out[name] = losses
    np.testing.assert_allclose(out["vanilla"], out["pipegcn"], atol=1e-12)


def test_first_iteration_boundary_is_zero():
    """Alg. 1 line 6: iteration 1 must behave as if boundary features are 0."""
    ds, prop, part, pg, topo, mc, data = setup(kind="gcn", layers=2)
    model = PipeGCN(mc, PipeConfig(stale=True))
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = model.init_buffers(topo, dtype=jnp.float64)
    _, _, _, logits = model.train_step(topo, params, bufs, data,
                                       jax.random.PRNGKey(0))
    same = part[:, None] == part[None, :]
    P_in = np.asarray(prop.to_dense()) * same
    h = ds.features.astype(np.float64)
    W0, b0 = np.asarray(params["w0"]), np.asarray(params["b0"])
    W1, b1 = np.asarray(params["w1"]), np.asarray(params["b1"])
    h1 = np.maximum(P_in @ h @ W0 + b0, 0)
    want = P_in @ h1 @ W1 + b1
    np.testing.assert_allclose(pg.unpack_nodes(np.asarray(logits)), want,
                               atol=1e-10)


def test_multilabel_loss_path():
    ds = make_dataset("tiny")
    rng = np.random.default_rng(0)
    labels = (rng.random((ds.num_nodes, ds.num_classes)) < 0.3).astype(np.float64)
    prop = mean_normalized(ds.graph)
    part = partition_graph(ds.graph, 2, seed=0)
    pg = build_partitioned_graph(prop, part, 2)
    topo = topology_from(pg)
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=8,
                     num_layers=2, num_classes=ds.num_classes,
                     dropout=0.0, multilabel=True)
    data = shard_data(pg, ds.features, labels, ds.train_mask, ds.val_mask)
    model = PipeGCN(mc, PipeConfig(stale=True))
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(topo)
    loss, grads, _, _ = model.train_step(topo, params, bufs, data,
                                         jax.random.PRNGKey(0))
    assert np.isfinite(float(loss))
    assert all(np.all(np.isfinite(np.asarray(g))) for g in grads.values())


def test_dropout_applied_after_communication():
    """App. F: with dropout on, the step still runs and loss stays finite;
    rate>0 changes the loss vs rate=0 (mask actually applied)."""
    ds, prop, part, pg, topo, mc, data = setup(kind="sage")
    import dataclasses
    mc_dp = dataclasses.replace(mc, dropout=0.5)
    m0 = PipeGCN(mc, PipeConfig(stale=True))
    m1 = PipeGCN(mc_dp, PipeConfig(stale=True))
    params = m0.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b0 = m0.init_buffers(topo, dtype=jnp.float64)
    b1 = m1.init_buffers(topo, dtype=jnp.float64)
    l0, _, _, _ = m0.train_step(topo, params, b0, data, jax.random.PRNGKey(5))
    l1, _, _, _ = m1.train_step(topo, params, b1, data, jax.random.PRNGKey(5))
    assert np.isfinite(float(l1))
    assert abs(float(l0) - float(l1)) > 1e-9
