"""Paper-claim validation at test scale (Tab. 4 analogue): every PipeGCN
variant reaches vanilla-level accuracy on a community graph; convergence is
not degraded beyond the paper's observed band.

Tier split: the full 120-epoch three-variant comparison is `slow` (it
dominates tier-1 wall time); tier-1 keeps a 40-epoch smoke run that still
asserts learning + near-perfect accuracy on the tiny community graph."""
import pytest

from repro.core import ModelConfig, PipeConfig, train_pipegcn
from repro.data import GraphDataPipeline


def _model_cfg(pipeline):
    return ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                       hidden=32, num_layers=2,
                       num_classes=pipeline.dataset.num_classes, dropout=0.0)


@pytest.fixture(scope="module")
def trained():
    pipeline = GraphDataPipeline.build("tiny", num_parts=4, kind="sage")
    mc = _model_cfg(pipeline)
    out = {}
    for name in ("vanilla", "pipegcn", "pipegcn-gf"):
        res = train_pipegcn(pipeline, mc, PipeConfig.named(name, gamma=0.5),
                            epochs=120, lr=0.01, eval_every=60)
        out[name] = res
    return out


def test_convergence_smoke(tiny_pipeline):
    """Tier-1: one staleness variant, 40 epochs — learns to high accuracy."""
    res = train_pipegcn(tiny_pipeline, _model_cfg(tiny_pipeline),
                        PipeConfig.named("pipegcn-gf", gamma=0.5),
                        epochs=40, lr=0.01, eval_every=40)
    assert res.final_metrics["test"] > 0.9, res.final_metrics
    hist = res.history["loss"]
    assert hist[-1] < hist[0] * 0.5, hist


@pytest.mark.slow
def test_all_variants_learn(trained):
    for name, res in trained.items():
        assert res.final_metrics["test"] > 0.9, (name, res.final_metrics)


@pytest.mark.slow
def test_pipegcn_matches_vanilla_accuracy(trained):
    """Paper Tab. 4: staleness costs at most ~0.3 accuracy points."""
    v = trained["vanilla"].final_metrics["test"]
    for name in ("pipegcn", "pipegcn-gf"):
        assert trained[name].final_metrics["test"] >= v - 0.03, (
            name, trained[name].final_metrics, v)


@pytest.mark.slow
def test_loss_decreases(trained):
    for name, res in trained.items():
        hist = res.history["loss"]
        assert hist[-1] < hist[0] * 0.5, (name, hist)
