"""Serving correctness: prefill + decode against the KV/state caches must
reproduce the full-sequence forward exactly (float32 tolerance), for every
architecture — including ring-buffer wraparound under sliding windows."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models.model import LM

RNG = np.random.default_rng(0)


def make_batch(cfg, b, s):
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    if cfg.is_encdec:
        batch["audio_embed"] = jnp.asarray(
            RNG.normal(size=(b, cfg.num_audio_frames, cfg.d_model)),
            jnp.float32)
    if cfg.num_image_tokens:
        batch["image_embed"] = jnp.asarray(
            RNG.normal(size=(b, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_decode_matches_full_forward(arch_id):
    cfg = get_arch(arch_id).reduced()
    lm = LM(cfg)
    B, S, EXTRA = 2, 16, 3
    params = lm.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, B, S)

    fwd = jax.jit(lambda p, b: lm.forward_logits(p, b, moe_dropless=True))
    full, _ = fwd(params, batch)
    caches = lm.init_caches(B, S + EXTRA + 8)
    last, caches = jax.jit(lm.prefill)(params, batch, caches)
    np.testing.assert_allclose(np.asarray(last[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               atol=2e-4, rtol=2e-4)

    toks = batch["tokens"]
    decode = jax.jit(lm.decode_step, static_argnums=3)
    for i in range(EXTRA):
        nxt = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        logits, caches = decode(params, nxt, caches, S + i)
        toks = jnp.concatenate([toks, nxt], axis=1)
        b2 = dict(batch)
        b2["tokens"] = toks
        b2["labels"] = jnp.roll(toks, -1, 1)
        full2, _ = fwd(params, b2)
        scale = float(jnp.abs(full2[:, -1]).max()) + 1e-9
        err = float(jnp.abs(logits[:, 0] - full2[:, -1]).max()) / scale
        assert err < 3e-3, (arch_id, i, err)


def test_ring_buffer_wraparound():
    """Decode past the window: ring cache slots wrap and stay exact."""
    cfg = get_arch("starcoder2-3b").reduced()      # window 16
    assert cfg.sliding_window == 16
    lm = LM(cfg)
    B, S = 1, 16
    params = lm.init_params(jax.random.PRNGKey(1))
    batch = make_batch(cfg, B, S)
    caches = lm.init_caches(B, 64)
    assert caches[0]["kv"]["k"].shape[2] == 16    # ring sized to window
    _, caches = jax.jit(lm.prefill)(params, batch, caches)
    decode = jax.jit(lm.decode_step, static_argnums=3)
    toks = batch["tokens"]
    fwd = jax.jit(lambda p, b: lm.forward_logits(p, b, moe_dropless=True))
    for i in range(20):                            # wraps slot 0 repeatedly
        nxt = jnp.asarray(RNG.integers(0, cfg.vocab_size, (B, 1)), jnp.int32)
        logits, caches = decode(params, nxt, caches, S + i)
        toks = jnp.concatenate([toks, nxt], axis=1)
    full, _ = fwd(params, {"tokens": toks, "labels": jnp.roll(toks, -1, 1)})
    scale = float(jnp.abs(full[:, -1]).max()) + 1e-9
    assert float(jnp.abs(logits[:, 0] - full[:, -1]).max()) / scale < 3e-3


def test_ssd_state_continuation():
    """SSD prefill state == state from running the recurrence token by token."""
    from repro.models.ssd import init_ssd, init_ssd_cache, ssd_decode, ssd_forward
    cfg = get_arch("mamba2-780m").reduced()
    p = init_ssd(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, L = 1, 24
    u = jnp.asarray(RNG.normal(size=(B, L, cfg.d_model)) * 0.3, jnp.float32)
    y_par, state_par = ssd_forward(p, cfg, u)
    cache = init_ssd_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        y_t, cache = ssd_decode(p, cfg, u[:, t:t + 1], cache)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=3e-4, rtol=3e-3)
    np.testing.assert_allclose(np.asarray(state_par),
                               np.asarray(cache["state"]),
                               atol=3e-4, rtol=3e-3)


def test_rglru_scan_equals_sequential():
    from repro.models.rglru import (init_rglru, init_rglru_cache,
                                    rglru_decode, rglru_forward)
    cfg = get_arch("recurrentgemma-2b").reduced()
    p = init_rglru(jax.random.PRNGKey(0), cfg, jnp.float32)
    B, L = 2, 12
    u = jnp.asarray(RNG.normal(size=(B, L, cfg.d_model)) * 0.3, jnp.float32)
    y_par, state_par = rglru_forward(p, cfg, u)
    cache = init_rglru_cache(cfg, B, jnp.float32)
    ys = []
    for t in range(L):
        y_t, cache = rglru_decode(p, cfg, u[:, t:t + 1], cache)
        ys.append(y_t)
    y_seq = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_par), np.asarray(y_seq),
                               atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(state_par),
                               np.asarray(cache["state"]), atol=1e-4,
                               rtol=1e-3)


def test_ssd_ragged_tail_padding():
    """ssd_forward pads non-chunk-multiple lengths without changing outputs."""
    from repro.models.ssd import init_ssd, ssd_forward
    cfg = get_arch("mamba2-780m").reduced()    # chunk 8
    p = init_ssd(jax.random.PRNGKey(2), cfg, jnp.float32)
    u = jnp.asarray(RNG.normal(size=(1, 19, cfg.d_model)) * 0.3, jnp.float32)
    y19, s19 = ssd_forward(p, cfg, u)
    u24 = jnp.pad(u, ((0, 0), (0, 5), (0, 0)))
    y24, _ = ssd_forward(p, cfg, u24)
    # causality: first 19 outputs identical whether padded by us or caller
    np.testing.assert_allclose(np.asarray(y19), np.asarray(y24[:, :19]),
                               atol=1e-5)
