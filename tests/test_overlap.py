"""Split-phase overlap schedule vs the unsplit reference, sim backend.

The split re-slices each layer's tile stream into a boundary phase (the
halo-clustered tail, run before the exchange is issued) and an interior
phase (computed while the collective is in flight). It is pure
re-ordering — same tiles, same arithmetic — so this tier-1 matrix pins
1e-12 float64 parity for loss, gradients, logits and pipeline buffers
across variants × engines × matmul orders × pipeline knobs on the
grid-tiny lattice (the only low-boundary regime where the split is
feasible). Schedule-shape tests trace the step to a jaxpr and assert the
exact (pallas_call | all_to_all) event sequence; degenerate-graph tests
pin the fallback to the unsplit schedule. The cross-backend (shard_map)
parity cells live in the slow-tier subprocess matrix in
test_pipegcn_spmd.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import (PipeGCN, shard_data, split_spec_from,
                                topology_from)
from repro.core.trace_utils import (check_split_schedule,
                                    expected_split_events,
                                    traced_step_events)
from repro.data.graph_pipeline import GraphDataPipeline
from repro.graph import build_partitioned_graph, make_dataset, partition_graph
from repro.graph.csr import mean_normalized, sym_normalized
from repro.launch.mesh import make_partition_mesh

P = 4


def _setup(kind):
    ds = make_dataset("grid-tiny")
    prop = (mean_normalized(ds.graph) if kind == "sage"
            else sym_normalized(ds.graph))
    part = partition_graph(ds.graph, P, seed=0)
    pg = build_partitioned_graph(prop, part, P, layout="rcm")
    topo = topology_from(pg, with_tiles=True)
    topo = topo._replace(edge_w=topo.edge_w.astype(jnp.float64))
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    sp = split_spec_from(pg)
    assert sp is not None, "grid-tiny/rcm must admit a feasible split"
    return ds, topo, data, sp


@pytest.fixture(scope="module")
def sage_setup():
    return _setup("sage")


@pytest.fixture(scope="module")
def gcn_setup():
    return _setup("gcn")


def _models(setup, kind, variant, agg, order, pipe_kw, dropout,
            num_layers=3):
    ds, topo, data, sp = setup
    mc = ModelConfig(kind=kind, feat_dim=ds.feat_dim, hidden=16,
                     num_layers=num_layers, num_classes=ds.num_classes,
                     dropout=dropout, agg=agg, matmul_order=order,
                     layout="rcm")
    base = dataclasses.replace(PipeConfig.named(variant, gamma=0.9),
                               **pipe_kw)
    ref = PipeGCN(mc, dataclasses.replace(base, overlap="none"), split=sp)
    spl = PipeGCN(mc, dataclasses.replace(base, overlap="split-phase"),
                  split=sp)
    assert ref._split_active() is None and spl._split_active() == sp
    return ref, spl, topo, data


# kind, variant, agg, matmul order, pipe knobs, dropout — every engine,
# both layer orders + auto, both exchange schedules, compression, k-step
# staleness, EMA smoothing, training noise
CELLS = [
    ("sage", "pipegcn", "coo", "aggregate-first", {}, 0.0),
    ("sage", "pipegcn", "blocksparse", "aggregate-first", {}, 0.0),
    ("sage", "pipegcn", "fused", "aggregate-first", {}, 0.0),
    ("sage", "vanilla", "blocksparse", "aggregate-first", {}, 0.0),
    ("sage", "vanilla", "coo", "transform-first", {}, 0.0),
    ("sage", "pipegcn-gf", "blocksparse", "transform-first", {}, 0.0),
    ("gcn", "pipegcn", "blocksparse", "aggregate-first", {}, 0.0),
    ("gcn", "vanilla", "fused", "transform-first", {}, 0.0),
    ("gcn", "pipegcn", "coo", "auto", {}, 0.0),
    ("sage", "pipegcn", "blocksparse", "auto", {}, 0.5),
    ("sage", "pipegcn", "blocksparse", "aggregate-first",
     {"fuse_exchange": False}, 0.0),
    ("sage", "pipegcn-g", "blocksparse", "aggregate-first",
     {"compress_boundary": True}, 0.0),
    ("sage", "pipegcn", "fused", "aggregate-first",
     {"staleness_steps": 2}, 0.0),
]


@pytest.mark.parametrize("kind,variant,agg,order,pipe_kw,dropout", CELLS)
def test_split_equals_unsplit(sage_setup, gcn_setup, kind, variant, agg,
                              order, pipe_kw, dropout):
    setup = sage_setup if kind == "sage" else gcn_setup
    ref, spl, topo, data = _models(setup, kind, variant, agg, order,
                                   pipe_kw, dropout)
    params = ref.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b_ref = ref.init_buffers(topo, dtype=jnp.float64)
    b_spl = spl.init_buffers(topo, dtype=jnp.float64)
    steps = 4 if pipe_kw.get("staleness_steps", 1) > 1 else 3
    cell = (kind, variant, agg, order, tuple(pipe_kw))
    for t in range(steps):
        key = jax.random.PRNGKey(t)
        l0, g0, b_ref, lg0 = ref.train_step(topo, params, b_ref, data, key)
        l1, g1, b_spl, lg1 = spl.train_step(topo, params, b_spl, data, key)
        assert abs(float(l0) - float(l1)) < 1e-12, (cell, t)
        assert float(jnp.abs(lg0 - lg1).max()) < 1e-12, (cell, t)
        for k in g0:
            d = float(jnp.abs(g0[k] - g1[k]).max())
            assert d < 1e-12, (cell, t, k, d)
        for a, b in zip(jax.tree.leaves(b_ref), jax.tree.leaves(b_spl)):
            assert a.dtype == b.dtype, (cell, t)
            d = float(jnp.abs(a.astype(jnp.float64)
                              - b.astype(jnp.float64)).max())
            assert d < 1e-12, (cell, t, d)
    # eval forward (runs the split under a vanilla PipeConfig internally)
    le0, lo0 = ref.forward(topo, params, data)
    le1, lo1 = spl.forward(topo, params, data)
    assert abs(float(le0) - float(le1)) < 1e-12, cell
    assert float(jnp.abs(lo0 - lo1).max()) < 1e-12, cell


def test_expected_split_events_math():
    """Hand-computed event sequences (P = phase pallas_call, A = boundary
    collective). Fused: forward sends are deferred and flushed after the
    layer-(L-2) boundary phase (L=1: pre-loop); backward flushes after
    the layer-1 transpose boundary phase. Per-layer: layer 0's features
    exchange before the loop, each non-final layer's send mid-layer, each
    backward layer ell>=1 mid-layer."""
    P_, A = "pallas_call", "all_to_all"
    assert expected_split_events(1, fused=True) == [A, P_, P_]
    assert expected_split_events(1, fused=False) == [A, P_, P_]
    assert expected_split_events(2, fused=True) == [
        P_, A, P_, P_, P_,            # fwd: flush after layer-0 boundary
        P_, A, P_]                    # bwd: layer 1, flush mid-layer
    assert expected_split_events(2, fused=False) == [
        A, P_, A, P_, P_, P_,         # fwd: pre-loop + layer-0 send
        P_, A, P_]                    # bwd: layer 1
    assert expected_split_events(3, fused=True) == [
        P_, P_, P_, A, P_, P_, P_,    # fwd: flush after layer-1 boundary
        P_, P_, P_, A, P_]            # bwd: 2 then 1 (flush at ell=1)
    assert expected_split_events(3, fused=False) == [
        A, P_, A, P_, P_, A, P_, P_, P_,
        P_, A, P_, P_, A, P_]
    assert expected_split_events(3, fused=True, train=False) == [
        P_, P_, P_, A, P_, P_, P_]
    # every fused schedule issues >=1 collective strictly between two
    # phase kernels (the overlap the tentpole exists for)
    for L in (1, 2, 3, 4):
        ev = expected_split_events(L, fused=True)
        ia = ev.index(A)
        assert 0 < ia < len(ev) - 1 or L == 1


@pytest.mark.parametrize("num_layers", [1, 2, 3])
@pytest.mark.parametrize("fuse", [True, False])
def test_sim_phase_kernel_sequence(sage_setup, num_layers, fuse):
    """Sim backend: the exchange is a transpose (no collective primitive),
    so the traced schedule check reduces to the phase-kernel sequence —
    two pallas_calls per layer forward, two per backward layer >= 1."""
    ref, spl, topo, data = _models(sage_setup, "sage", "pipegcn",
                                   "blocksparse", "aggregate-first",
                                   {"fuse_exchange": fuse}, 0.0,
                                   num_layers=num_layers)
    params = spl.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    buffers = spl.init_buffers(topo, dtype=jnp.float64)
    ev = traced_step_events(spl.train_step, topo, params, buffers, data,
                            jax.random.PRNGKey(0))
    expected = [e for e in expected_split_events(num_layers, fuse)
                if e == "pallas_call"]
    assert ev == expected, (num_layers, fuse, ev)


@pytest.mark.parametrize("num_layers,fuse", [(1, True), (2, True),
                                             (2, False)])
def test_spmd_collective_between_phases(sage_setup, num_layers, fuse):
    """SPMD backend on a 1-device mesh hosting all P partitions: the
    jaxpr contains every all_to_all the multi-device program would issue,
    and check_split_schedule asserts the full event sequence — each
    boundary collective between the boundary- and interior-phase
    pallas_calls, forward AND backward. L=1 is the edge cell: no backward
    exchange, the single forward collective issued before the loop."""
    ds, topo, data, sp = sage_setup
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=num_layers, num_classes=ds.num_classes,
                     dropout=0.0, agg="blocksparse",
                     matmul_order="aggregate-first", layout="rcm")
    pc = dataclasses.replace(PipeConfig.named("pipegcn"),
                             fuse_exchange=fuse, overlap="split-phase")
    model = PipeGCN(mc, pc, split=sp)
    mesh = make_partition_mesh(P, parts_per_device=P)
    ev = check_split_schedule(model, mesh, topo, data)
    assert ev == expected_split_events(num_layers, model.pipe.fused)


def test_auto_overlap_engine_gating(sage_setup):
    """overlap="auto": split iff the engine consumes tile streams. The
    COO engine implements the phased interface (for parity gating) but
    has no tile phases to overlap, so auto leaves it unsplit."""
    ds, topo, data, sp = sage_setup
    for agg, want_split in (("coo", False), ("blocksparse", True),
                            ("fused", True)):
        mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                         num_layers=2, num_classes=ds.num_classes,
                         dropout=0.0, agg=agg, layout="rcm")
        model = PipeGCN(mc, dataclasses.replace(PipeConfig.named("pipegcn"),
                                                overlap="auto"), split=sp)
        assert (model._split_active() is not None) == want_split, agg


@pytest.mark.parametrize("dataset,parts,layout", [
    ("grid-tiny", 1, "rcm"),       # P=1: no peers, nothing to exchange
    ("grid-tiny", 4, "natural"),   # no halo clustering -> no contiguous tail
    ("tiny", 4, "rcm"),            # power-law: ~all nodes are boundary
])
def test_degenerate_graphs_fall_back_unsplit(dataset, parts, layout):
    """No feasible split -> split_spec() is None and a forced
    overlap="split-phase" model runs the UNSPLIT schedule (identical
    trace, no zero-size boundary pallas_call, no zero-width collective)
    rather than degenerating."""
    pipeline = GraphDataPipeline.build(dataset, parts, kind="sage",
                                       agg="blocksparse", layout=layout)
    assert pipeline.split_spec() is None
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=16, num_layers=2,
                     num_classes=pipeline.dataset.num_classes,
                     dropout=0.0, agg="blocksparse", layout=layout)
    forced = PipeGCN(mc, dataclasses.replace(
        PipeConfig.named("pipegcn"), overlap="split-phase"), split=None)
    ref = PipeGCN(mc, dataclasses.replace(
        PipeConfig.named("pipegcn"), overlap="none"), split=None)
    assert forced._split_active() is None
    params = ref.init_params(jax.random.PRNGKey(0))
    bufs = ref.init_buffers(pipeline.topo)
    l0, g0, _, _ = ref.train_step(pipeline.topo, params, bufs,
                                  pipeline.train_data, jax.random.PRNGKey(1))
    l1, g1, _, _ = forced.train_step(pipeline.topo, params, bufs,
                                     pipeline.train_data,
                                     jax.random.PRNGKey(1))
    assert float(l0) == float(l1)
    for k in g0:
        assert float(jnp.abs(g0[k] - g1[k]).max()) == 0.0, k
