"""Fused aggregate+transform engine: dense-oracle parity for both fused
kernels (epilogue forward incl. bias/ReLU/with_z, prologue transpose),
engine-level handling of non-multiple-of-128 shapes, empty row/col blocks
(zero-filler flush through the fused path), float64 1e-12 parity vs the COO
engine for GCN and SAGE (subprocess, x64), and a jaxpr gate pinning ONE
pallas_call per layer direction on the fused path."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN, shard_data, topology_from
from repro.core.trace_utils import count_primitives
from repro.graph import (build_partitioned_graph, make_dataset,
                         partition_graph)
from repro.graph.csr import mean_normalized, sym_normalized
from repro.kernels.aggregate import get_engine
from repro.kernels.gcn_spmm import (TILE, build_tile_topology,
                                    spmm_block_sparse_fused,
                                    spmm_block_sparse_fused_t)

ATOL = 5e-5


def _random_block_sparse(rng, R, C, density=0.05):
    dense = ((rng.random((R, C)) < density)
             * rng.normal(size=(R, C))).astype(np.float32)
    row, col = np.nonzero(dense)
    tt = build_tile_topology(row, col, dense[row, col], R, C)
    return dense, tt


def _tslice(tt):
    return (jnp.asarray(tt.rows), jnp.asarray(tt.cols),
            jnp.asarray(tt.vals), jnp.asarray(tt.t_out),
            jnp.asarray(tt.t_in), jnp.asarray(tt.t_perm))


# ---------------------------------------------------------------------
# Kernel-level dense-oracle parity
# ---------------------------------------------------------------------

@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize("with_z", [True, False])
def test_fused_forward_matches_dense(relu, with_z):
    rng = np.random.default_rng(0)
    R, C, FI, FO = 3 * TILE, 2 * TILE, 128, 256
    dense, tt = _random_block_sparse(rng, R, C)
    h = jnp.asarray(rng.normal(size=(C, FI)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(FI, FO)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, FO)), jnp.float32)
    u, z = spmm_block_sparse_fused(
        jnp.asarray(tt.rows), jnp.asarray(tt.cols), jnp.asarray(tt.vals),
        h, w, b, R, relu=relu, with_z=with_z)
    zd = dense @ np.asarray(h)
    want = zd @ np.asarray(w) + np.asarray(b)
    if relu:
        want = np.maximum(want, 0)
    np.testing.assert_allclose(np.asarray(u), want, atol=2e-3)
    if with_z:
        np.testing.assert_allclose(np.asarray(z), zd, atol=2e-4)
    else:
        assert z is None


def test_fused_transpose_matches_dense():
    """dcomb = Pᵀ·(du @ wᵀ) from the prologue kernel == dense oracle."""
    rng = np.random.default_rng(1)
    R, C, FI, FO = 2 * TILE, 3 * TILE, 256, 128
    dense, tt = _random_block_sparse(rng, R, C)
    du = jnp.asarray(rng.normal(size=(R, FO)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(FI, FO)), jnp.float32)
    got = spmm_block_sparse_fused_t(
        jnp.asarray(tt.t_out), jnp.asarray(tt.t_in), jnp.asarray(tt.t_perm),
        jnp.asarray(tt.vals), du, w, C)
    want = dense.T @ (np.asarray(du) @ np.asarray(w).T)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-3)


def test_fused_empty_row_and_col_blocks():
    """Empty row blocks must flush u = b (z = 0 ⇒ u = 0@W + b, matching the
    dense math) and empty column blocks must flush dcomb = 0 — both via the
    zero-filler tiles build_tile_topology appends."""
    rng = np.random.default_rng(2)
    R = C = 3 * TILE
    FI = FO = 128
    dense = np.zeros((R, C), np.float32)
    # only (row-block 0, col-block 2): row blocks 1-2 / col blocks 0-1 empty
    dense[:TILE, 2 * TILE:] = (rng.random((TILE, TILE)) < 0.1) * 1.0
    row, col = np.nonzero(dense)
    tt = build_tile_topology(row, col, dense[row, col], R, C)
    h = jnp.asarray(rng.normal(size=(C, FI)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(FI, FO)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, FO)), jnp.float32)
    u, z = spmm_block_sparse_fused(
        jnp.asarray(tt.rows), jnp.asarray(tt.cols), jnp.asarray(tt.vals),
        h, w, b, R)
    want = (dense @ np.asarray(h)) @ np.asarray(w) + np.asarray(b)
    np.testing.assert_allclose(np.asarray(u), want, atol=2e-3)
    np.testing.assert_allclose(np.asarray(u)[TILE:],
                               np.broadcast_to(np.asarray(b), (2 * TILE, FO)),
                               atol=1e-6)
    assert np.all(np.asarray(z)[TILE:] == 0)
    du = jnp.asarray(rng.normal(size=(R, FO)), jnp.float32)
    d = spmm_block_sparse_fused_t(
        jnp.asarray(tt.t_out), jnp.asarray(tt.t_in), jnp.asarray(tt.t_perm),
        jnp.asarray(tt.vals), du, w, C)
    np.testing.assert_allclose(
        np.asarray(d), dense.T @ (np.asarray(du) @ np.asarray(w).T),
        atol=2e-3)
    assert np.all(np.asarray(d)[:2 * TILE] == 0)


def test_fused_engine_nonmultiple_shapes():
    """The engine pads/slices: rows, combined and both feature widths far
    from 128-multiples must round-trip exactly against the dense oracle."""
    rng = np.random.default_rng(3)
    R, C, FI, FO = 200, 300, 40, 24
    dense, tt = _random_block_sparse(rng, R, C, density=0.15)
    eng = get_engine("fused")
    ts = _tslice(tt)
    comb = jnp.asarray(rng.normal(size=(C, FI)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(FI, FO)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(FO,)), jnp.float32)
    u, z = eng.aggregate_transform(ts, comb, w, b, R)
    assert u.shape == (R, FO) and z.shape == (R, FI)
    zd = dense @ np.asarray(comb)
    np.testing.assert_allclose(np.asarray(z), zd, atol=2e-4)
    np.testing.assert_allclose(np.asarray(u),
                               zd @ np.asarray(w) + np.asarray(b),
                               atol=2e-3)
    du = jnp.asarray(rng.normal(size=(R, FO)), jnp.float32)
    d = eng.aggregate_transform_t(ts, du, w, C)
    assert d.shape == (C, FI)
    np.testing.assert_allclose(
        np.asarray(d), dense.T @ (np.asarray(du) @ np.asarray(w).T),
        atol=2e-3)


# ---------------------------------------------------------------------
# Train-step parity (f32 in-process; f64 1e-12 vs coo in a subprocess)
# ---------------------------------------------------------------------

def setup(kind, parts=4, layers=3, hidden=16):
    ds = make_dataset("tiny")
    norm = sym_normalized if kind == "gcn" else mean_normalized
    pg = build_partitioned_graph(norm(ds.graph),
                                 partition_graph(ds.graph, parts, seed=0),
                                 parts)
    topo = topology_from(pg, with_tiles=True)
    mc = ModelConfig(kind=kind, feat_dim=ds.feat_dim, hidden=hidden,
                     num_layers=layers, num_classes=ds.num_classes,
                     dropout=0.0)
    data = shard_data(pg, ds.features, ds.labels, ds.train_mask, ds.val_mask)
    return ds, pg, topo, mc, data


@pytest.mark.parametrize("kind", ["gcn", "sage"])
@pytest.mark.parametrize("order", ["aggregate-first", "transform-first",
                                   "auto"])
def test_fused_train_step_parity(kind, order):
    """Fused engine × every matmul ordering vs the COO reference, loss +
    every weight gradient + logits, over two steps of the stale pipeline."""
    ds, pg, topo, mc, data = setup(kind)
    pipe = PipeConfig.named("pipegcn")
    out = {}
    for agg in ("coo", "fused"):
        model = PipeGCN(dataclasses.replace(mc, agg=agg, matmul_order=order),
                        pipe)
        params = model.init_params(jax.random.PRNGKey(0))
        bufs = model.init_buffers(topo)
        for t in range(2):
            loss, grads, bufs, logits = model.train_step(
                topo, params, bufs, data, jax.random.PRNGKey(t))
        out[agg] = (float(loss), grads, np.asarray(logits))
    assert abs(out["coo"][0] - out["fused"][0]) < ATOL
    for k in out["coo"][1]:
        np.testing.assert_allclose(np.asarray(out["coo"][1][k]),
                                   np.asarray(out["fused"][1][k]),
                                   atol=ATOL, err_msg=f"{kind} {order} {k}")
    np.testing.assert_allclose(out["coo"][2], out["fused"][2], atol=ATOL)


def test_fused_eval_forward_matches_coo():
    """The eval path (with_z=False + in-kernel ReLU epilogue for GCN)."""
    ds, pg, topo, mc, data = setup("gcn")
    params = PipeGCN(mc, PipeConfig.vanilla()).init_params(
        jax.random.PRNGKey(0))
    outs = {}
    for agg in ("coo", "fused"):
        model = PipeGCN(dataclasses.replace(mc, agg=agg),
                        PipeConfig.named("pipegcn"))
        loss, logits = model.forward(topo, params, data)
        outs[agg] = (float(loss), np.asarray(logits))
    assert abs(outs["coo"][0] - outs["fused"][0]) < ATOL
    np.testing.assert_allclose(outs["coo"][1], outs["fused"][1], atol=ATOL)


F64_SCRIPT = textwrap.dedent("""
    import dataclasses, jax
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp, numpy as np
    from repro.core.config import ModelConfig, PipeConfig
    from repro.core.pipegcn import PipeGCN, topology_from, shard_data
    from repro.graph import (make_dataset, partition_graph,
                             build_partitioned_graph)
    from repro.graph.csr import mean_normalized, sym_normalized

    for kind, norm in (("gcn", sym_normalized), ("sage", mean_normalized)):
        ds = make_dataset("tiny")
        pg = build_partitioned_graph(
            norm(ds.graph), partition_graph(ds.graph, 4, seed=0), 4)
        topo = topology_from(pg, with_tiles=True)
        topo = topo._replace(edge_w=topo.edge_w.astype(jnp.float64))
        data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                          ds.train_mask, ds.val_mask)
        data = data._replace(x=data.x.astype(jnp.float64))
        mc = ModelConfig(kind=kind, feat_dim=ds.feat_dim, hidden=16,
                         num_layers=3, num_classes=ds.num_classes,
                         dropout=0.0)
        for order in ("aggregate-first", "auto"):
            out = {}
            for agg in ("coo", "fused"):
                m = PipeGCN(dataclasses.replace(mc, agg=agg,
                                                matmul_order=order),
                            PipeConfig.named("pipegcn-gf", gamma=0.9))
                params = m.init_params(jax.random.PRNGKey(0),
                                       dtype=jnp.float64)
                bufs = m.init_buffers(topo, dtype=jnp.float64)
                for t in range(3):
                    loss, grads, bufs, _ = m.train_step(
                        topo, params, bufs, data, jax.random.PRNGKey(t))
                out[agg] = (float(loss), grads, bufs)
            dl = abs(out["coo"][0] - out["fused"][0])
            dg = max(float(jnp.abs(out["coo"][1][k]
                                   - out["fused"][1][k]).max())
                     for k in out["coo"][1])
            db = max(float(jnp.abs(a - b).max()) for a, b in
                     zip(jax.tree.leaves(out["coo"][2]),
                         jax.tree.leaves(out["fused"][2])))
            assert dl < 1e-12 and dg < 1e-12 and db < 1e-12, \\
                (kind, order, dl, dg, db)
            print(f"OK {kind}/{order}", flush=True)
    print("FUSED-F64-OK")
""")


def test_fused_f64_parity_vs_coo_subprocess():
    """x64 needs its own process (the flag is global): the fused engine
    keeps the caller's dtype end to end, so in f64 interpret mode it must
    match the COO engine at 1e-12 — loss, grads, AND pipeline buffers."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", F64_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "FUSED-F64-OK" in proc.stdout


# ---------------------------------------------------------------------
# Jaxpr gate: the fused path emits ONE pallas_call per layer direction
# ---------------------------------------------------------------------

@pytest.mark.parametrize("layers", [2, 3])
def test_fused_path_one_pallas_call_per_layer_direction(layers):
    """Train: L forward fused kernels + (L-1) backward fused transpose
    kernels (layer 0 sends no dcomb under aggregate-first) = 2L-1
    pallas_calls. A second pallas_call appearing per layer means an
    aggregation op escaped the fusion."""
    ds, pg, topo, mc, data = setup("gcn", layers=layers)
    model = PipeGCN(dataclasses.replace(
        mc, agg="fused", matmul_order="aggregate-first"),
        PipeConfig.named("pipegcn"))
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(topo)
    jx = jax.make_jaxpr(
        lambda p, b: model.train_step(topo, p, b, data,
                                      jax.random.PRNGKey(0)))(params, bufs)
    got = count_primitives(jx, ("pallas_call",))["pallas_call"]
    assert got == 2 * layers - 1, (layers, got)


def test_fused_eval_one_pallas_call_per_layer():
    ds, pg, topo, mc, data = setup("gcn", layers=3)
    model = PipeGCN(dataclasses.replace(
        mc, agg="fused", matmul_order="aggregate-first"),
        PipeConfig.named("pipegcn"))
    params = model.init_params(jax.random.PRNGKey(0))
    jx = jax.make_jaxpr(
        lambda p: model.forward(topo, p, data))(params)
    got = count_primitives(jx, ("pallas_call",))["pallas_call"]
    assert got == 3, got


# ---------------------------------------------------------------------
# Config plumbing
# ---------------------------------------------------------------------

def test_unknown_matmul_order_rejected():
    with pytest.raises(ValueError, match="matmul_order"):
        ModelConfig(matmul_order="sideways")


def test_fused_engine_without_tiles_raises():
    ds, pg, topo, mc, data = setup("gcn")
    topo_no_tiles = topology_from(pg)
    model = PipeGCN(dataclasses.replace(mc, agg="fused"),
                    PipeConfig.vanilla())
    params = model.init_params(jax.random.PRNGKey(0))
    bufs = model.init_buffers(topo_no_tiles)
    with pytest.raises(ValueError, match="fused"):
        model.train_step(topo_no_tiles, params, bufs, data,
                         jax.random.PRNGKey(0))
