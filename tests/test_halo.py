"""Stale-halo transformer (beyond-paper transfer of the paper's technique)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.models.halo import (HaloConfig, forward, init_halo_buffers,
                               init_params, make_sim_train_step)

SHARDS, B, S = 4, 2, 32


def _setup(stale, smooth=False):
    cfg = HaloConfig(stale=stale, smooth=smooth, window=16, vocab=32,
                     d_model=32, num_heads=2, num_layers=2)
    params = init_params(jax.random.PRNGKey(0), cfg)
    bufs = init_halo_buffers(cfg, S, B, SHARDS)
    pos0 = jnp.arange(SHARDS) * S
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (SHARDS, B, S)), jnp.int32)
    return cfg, params, bufs, pos0, toks


def test_sharded_sync_equals_unsharded():
    """Sync halo across 4 shards == single-shard full sequence."""
    cfg, params, bufs, pos0, toks = _setup(stale=False)
    logits4, _ = forward(params, cfg, toks, bufs, pos0)
    # single shard: same total sequence
    full = toks.transpose(1, 0, 2).reshape(1, B, SHARDS * S)
    bufs1 = init_halo_buffers(cfg, SHARDS * S, B, 1)
    logits1, _ = forward(params, cfg, full, bufs1, jnp.zeros((1,), jnp.int32))
    got = logits4.transpose(1, 0, 2, 3).reshape(1, B, SHARDS * S, cfg.vocab)
    np.testing.assert_allclose(np.asarray(got), np.asarray(logits1),
                               atol=2e-5)


def test_stale_first_step_uses_zero_halo():
    """PipeGCN Alg.1 line 6 analogue: step 1 boundary = zeros."""
    cfg_s, params, bufs, pos0, toks = _setup(stale=True)
    out_stale, new_bufs = forward(params, cfg_s, toks, bufs, pos0)
    # fresh halos must now be stored for step 2
    assert float(jnp.abs(new_bufs[0]["k"][1:]).max()) > 0
    # shard 0 has no left neighbor: halo stays zero
    np.testing.assert_array_equal(np.asarray(new_bufs[0]["k"][0]), 0)


def test_stale_second_step_consumes_first():
    cfg, params, bufs, pos0, toks = _setup(stale=True)
    _, bufs1 = forward(params, cfg, toks, bufs, pos0)
    out2, _ = forward(params, cfg, toks, bufs1, pos0)
    # sync output with the same halo should match a manual concat compute:
    cfg_sync = HaloConfig(**{**cfg.__dict__, "stale": False})
    out_sync, _ = forward(params, cfg_sync, toks, bufs, pos0)
    # step-2 stale output uses step-1 halos == sync halos (same params)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(out_sync),
                               atol=2e-5)


def test_training_parity():
    losses = {}
    for stale in (False, True):
        cfg = HaloConfig(stale=stale, window=16, vocab=16, d_model=32,
                         num_heads=2, num_layers=2)
        params = init_params(jax.random.PRNGKey(1), cfg)
        bufs = init_halo_buffers(cfg, S, B, SHARDS)
        opt_init, step = make_sim_train_step(cfg, SHARDS, lr=5e-3)
        opt_state = opt_init(params)
        pos0 = jnp.arange(SHARDS) * S
        rng = np.random.default_rng(1)
        ls = []
        for t in range(40):
            base = rng.integers(0, cfg.vocab, (B, SHARDS * S))
            toks = jnp.asarray(base.reshape(B, SHARDS, S).transpose(1, 0, 2),
                               jnp.int32)
            loss, params, opt_state, bufs = step(params, opt_state, toks,
                                                 toks, bufs, pos0)
            ls.append(float(loss))
        losses[stale] = ls
    assert losses[True][-1] < losses[True][0]        # learns
    assert abs(losses[True][-1] - losses[False][-1]) < 0.3   # parity band
