import os
import sys

# Tests must see exactly 1 real device (the dry-run is the ONLY place that
# forces 512); guard against accidental inheritance.
os.environ.pop("XLA_FLAGS", None) if "force_host_platform" in \
    os.environ.get("XLA_FLAGS", "") else None

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import pytest

_PYPROJECT = os.path.join(os.path.dirname(__file__), "..", "pyproject.toml")


def _hypothesis_pin() -> dict:
    """The pinned profile from pyproject [tool.repro.hypothesis] (fixed
    seed / no deadline so tier-1 is deterministic in CI). tomllib is
    3.11+; fall back to a minimal key=value scan of that one section."""
    try:
        import tomllib
        with open(_PYPROJECT, "rb") as f:
            return tomllib.load(f)["tool"]["repro"]["hypothesis"]
    except Exception:
        pass
    out, in_section = {}, False
    try:
        with open(_PYPROJECT) as f:
            for line in f:
                line = line.split("#", 1)[0].strip()
                if line.startswith("["):
                    in_section = line == "[tool.repro.hypothesis]"
                elif in_section and "=" in line:
                    k, v = (s.strip() for s in line.split("=", 1))
                    out[k] = {"true": True, "false": False}.get(
                        v, int(v) if v.isdigit() else v)
    except OSError:
        pass
    return out


def _pin_hypothesis_profile():
    try:
        from hypothesis import settings
    except ImportError:
        return   # tests fall back to the fixed-seed sweep shim
    pin = _hypothesis_pin()
    deadline = pin.get("deadline_ms", 0) or None
    kw = dict(derandomize=bool(pin.get("derandomize", True)),
              deadline=deadline,
              max_examples=int(pin.get("max_examples", 50)))
    if not pin.get("database", False):
        kw["database"] = None
    settings.register_profile("repro-ci", **kw)
    settings.load_profile("repro-ci")


_pin_hypothesis_profile()


@pytest.fixture(scope="session")
def tiny_pipeline():
    from repro.data import GraphDataPipeline
    return GraphDataPipeline.build("tiny", num_parts=4, kind="sage")


@pytest.fixture(scope="session")
def tiny_pipeline_gcn():
    from repro.data import GraphDataPipeline
    return GraphDataPipeline.build("tiny", num_parts=4, kind="gcn")
