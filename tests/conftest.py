import os
import sys

# Tests must see exactly 1 real device (the dry-run is the ONLY place that
# forces 512); guard against accidental inheritance.
os.environ.pop("XLA_FLAGS", None) if "force_host_platform" in \
    os.environ.get("XLA_FLAGS", "") else None

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture(scope="session")
def tiny_pipeline():
    from repro.data import GraphDataPipeline
    return GraphDataPipeline.build("tiny", num_parts=4, kind="sage")


@pytest.fixture(scope="session")
def tiny_pipeline_gcn():
    from repro.data import GraphDataPipeline
    return GraphDataPipeline.build("tiny", num_parts=4, kind="gcn")
