"""k-step staleness (App. C 'increase the pipeline depth' — beyond-paper):
queue semantics vs a dense oracle, SPMD parity, and graceful convergence."""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN, shard_data, topology_from
from repro.graph import build_partitioned_graph, make_dataset, partition_graph
from repro.graph.csr import sym_normalized


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("tiny")
    prop = sym_normalized(ds.graph)
    part = partition_graph(ds.graph, 4, seed=0)
    pg = build_partitioned_graph(prop, part, 4)
    topo = jax.tree.map(
        lambda x: x.astype(jnp.float64) if x.dtype == jnp.float32 else x,
        topology_from(pg))
    mc = ModelConfig(kind="gcn", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes, dropout=0.0)
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    return ds, prop, part, topo, mc, data


def dense_queue_oracle(ds, prop, part, mc, params0, T, k, lr):
    Pd = np.asarray(prop.to_dense())
    same = part[:, None] == part[None, :]
    P_in, P_bd = Pd * same, Pd * (~same)
    X = ds.features.astype(np.float64)
    y, m = ds.labels, ds.train_mask.astype(np.float64)
    W = {kk: np.asarray(v).copy() for kk, v in params0.items()}
    L = mc.num_layers
    dims = [ds.feat_dim] + [mc.hidden] * (L - 1)
    featq = [[np.zeros((ds.num_nodes, dims[l]))] * k for l in range(L)]
    gradq = [[None] * k for l in range(L)]
    losses = []
    for t in range(T):
        H, Z, used = [X], [], []
        for l in range(L):
            use = featq[l][0]
            used.append(use)
            z = P_in @ H[l] @ W[f"w{l}"] + P_bd @ use @ W[f"w{l}"] + W[f"b{l}"]
            Z.append(z)
            H.append(np.maximum(z, 0) if l < L - 1 else z)
        for l in range(L):
            featq[l] = featq[l][1:] + [H[l].copy()]
        logits = H[-1]
        e = np.exp(logits - logits.max(-1, keepdims=True))
        probs = e / e.sum(-1, keepdims=True)
        lse = np.log(e.sum(-1)) + logits.max(-1)
        losses.append(((lse - logits[np.arange(len(y)), y]) * m).sum()
                      / m.sum())
        J = (probs - np.eye(mc.num_classes)[y]) * m[:, None] / m.sum()
        grads = {}
        for l in reversed(range(L)):
            M = J if l == L - 1 else J * (Z[l] > 0)
            grads[f"w{l}"] = (P_in @ H[l] + P_bd @ used[l]).T @ M
            grads[f"b{l}"] = M.sum(0)
            if l == 0:
                break
            C_cur = P_bd.T @ M @ W[f"w{l}"].T
            contrib = gradq[l][0] if gradq[l][0] is not None \
                else np.zeros_like(C_cur)
            gradq[l] = gradq[l][1:] + [C_cur]
            J = P_in.T @ M @ W[f"w{l}"].T + contrib
        for kk in W:
            W[kk] -= lr * grads[kk]
    return losses, W


@pytest.mark.parametrize("k", [2, 3])
def test_kstep_matches_queue_oracle(setup, k):
    ds, prop, part, topo, mc, data = setup
    pc = dataclasses.replace(PipeConfig(stale=True), staleness_steps=k)
    model = PipeGCN(mc, pc)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    ol, ow = dense_queue_oracle(ds, prop, part, mc,
                                {kk: np.asarray(v) for kk, v in params.items()},
                                5, k, 0.05)
    bufs = model.init_buffers(topo, dtype=jnp.float64)
    for t in range(5):
        loss, grads, bufs, _ = model.train_step(topo, params, bufs, data,
                                                jax.random.PRNGKey(t))
        assert abs(float(loss) - ol[t]) < 1e-10, (k, t)
        params = {kk: params[kk] - 0.05 * grads[kk] for kk in params}
    for kk in params:
        np.testing.assert_allclose(np.asarray(params[kk]), ow[kk], atol=1e-9)


def test_k1_queue_is_default_path(setup):
    """staleness_steps=1 must keep the original (non-queue) semantics."""
    ds, prop, part, topo, mc, data = setup
    model = PipeGCN(mc, PipeConfig(stale=True))
    bufs = model.init_buffers(topo)
    assert bufs["feat"][0].ndim == 3      # no queue axis


def test_kstep_convergence_smoke(tiny_pipeline):
    """Tier-1: depth-2 staleness still trains (40-epoch smoke run); the
    full k-sweep graceful-degradation comparison is `slow`."""
    from repro.core import train_pipegcn
    mc = ModelConfig(kind="sage", feat_dim=tiny_pipeline.dataset.feat_dim,
                     hidden=32, num_layers=2,
                     num_classes=tiny_pipeline.dataset.num_classes,
                     dropout=0.0)
    pc = dataclasses.replace(PipeConfig(stale=True), staleness_steps=2)
    res = train_pipegcn(tiny_pipeline, mc, pc, epochs=40, lr=0.01,
                        eval_every=40)
    assert res.final_metrics["test"] > 0.8, res.final_metrics
    hist = res.history["loss"]
    assert hist[-1] < hist[0] * 0.5, hist


@pytest.mark.slow
def test_kstep_convergence_graceful():
    """Deeper staleness still trains; accuracy degrades gracefully in k."""
    from repro.core import train_pipegcn
    from repro.data import GraphDataPipeline
    pipeline = GraphDataPipeline.build("tiny", num_parts=4, kind="sage")
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=32, num_layers=2,
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    accs = {}
    for k in (1, 2, 4):
        pc = dataclasses.replace(PipeConfig(stale=True), staleness_steps=k)
        res = train_pipegcn(pipeline, mc, pc, epochs=120, lr=0.01,
                            eval_every=120)
        accs[k] = res.final_metrics["test"]
    assert accs[1] > 0.9
    assert accs[4] > accs[1] - 0.1, accs     # graceful degradation


SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import jax, dataclasses, numpy as np
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.graph import make_dataset, partition_graph, build_partitioned_graph
    from repro.graph.csr import sym_normalized
    from repro.core.config import ModelConfig, PipeConfig
    from repro.core.pipegcn import PipeGCN, topology_from, shard_data

    ds = make_dataset("tiny")
    pg = build_partitioned_graph(sym_normalized(ds.graph),
                                 partition_graph(ds.graph, 4, seed=0), 4)
    topo = jax.tree.map(lambda x: x.astype(jnp.float64)
                        if x.dtype == jnp.float32 else x, topology_from(pg))
    mc = ModelConfig(kind="gcn", feat_dim=ds.feat_dim, hidden=8, num_layers=2,
                     num_classes=ds.num_classes, dropout=0.0)
    pc = dataclasses.replace(PipeConfig(stale=True), staleness_steps=3)
    model = PipeGCN(mc, pc)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    b1 = model.init_buffers(topo, dtype=jnp.float64)
    b2 = model.init_buffers(topo, dtype=jnp.float64)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((4,), ("parts",))
    step = model.make_spmd_step(mesh, topo, "parts")
    for t in range(4):
        key = jax.random.PRNGKey(t)
        l1, g1, b1, _ = model.train_step(topo, params, b1, data, key)
        l2, _, g2, b2 = step(topo, params, b2, data, key)
        assert abs(float(l1) - float(l2)) < 1e-12
        for kk in g1:
            assert float(jnp.abs(g1[kk] - jnp.asarray(g2[kk])).max()) < 1e-12
    print("KSTEP-SPMD-OK")
""")


@pytest.mark.slow
def test_kstep_spmd_parity_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=560)
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "KSTEP-SPMD-OK" in proc.stdout
