"""Locality-aware node reordering (repro.graph.reorder + layout plumbing).

Three layers of guarantees:
  1. Permutation algebra — every per-partition permutation is a bijection,
     pack/unpack round-trips under it, and the reordered shards still
     evaluate the exact partitioned SpMM (property sweeps).
  2. Layout quality — on the structured datasets, the rcm layout never
     stores MORE nonempty tiles than natural, and the halo frontier
     collapses to fewer contiguous row runs (the quantities
     `analysis.cost.graph_layout_report` tracks).
  3. Numerical invisibility — f64 training parity at 1e-12 between the
     natural and rcm layouts across aggregation engines and pipeline
     variants on the sim backend (the SPMD matrix extends this across
     shard_map in tests/test_pipegcn_spmd.py): loss, every weight
     gradient, and the UNPACKED logits must match, because the whole step
     is permutation-equivariant and the permutation is undone only at the
     eval/metric boundary.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

jax.config.update("jax_enable_x64", True)

from repro.analysis.cost import graph_layout_report
from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN, shard_data, topology_from
from repro.graph import (build_partitioned_graph, coo_to_csr, make_dataset,
                         partition_graph)
from repro.graph.csr import mean_normalized, sym_normalized, symmetrize
from repro.graph.reorder import partition_orders, rcm_order


def random_graph(n, avg_deg, seed):
    rng = np.random.default_rng(seed)
    m = max(int(n * avg_deg / 2), 1)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    return symmetrize(coo_to_csr(src[keep], dst[keep], n))


# ---------------------------------------------------------------------
# 1. Permutation algebra
# ---------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.integers(40, 160), parts=st.integers(2, 6),
       seed=st.integers(0, 10))
def test_partition_orders_are_bijections(n, parts, seed):
    g = random_graph(n, 6, seed)
    prop = sym_normalized(g)
    part = partition_graph(g, parts, seed=seed)
    orders = partition_orders(prop, part, parts)
    seen = np.concatenate(orders)
    # each partition's order is a permutation of its own node set, and the
    # union covers every node exactly once
    for i, order in enumerate(orders):
        assert np.array_equal(np.sort(order), np.flatnonzero(part == i))
    assert np.array_equal(np.sort(seen), np.arange(n))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(40, 160), parts=st.integers(2, 6),
       seed=st.integers(0, 10))
def test_perm_inverse_and_pack_unpack_roundtrip(n, parts, seed):
    g = random_graph(n, 6, seed)
    prop = sym_normalized(g)
    part = partition_graph(g, parts, seed=seed)
    pg = build_partitioned_graph(prop, part, parts, layout="rcm")
    for i in range(parts):
        k = int(pg.inner_mask[i].sum())
        fwd, inv = pg.perm[i, :k], pg.inv_perm[i, :k]
        assert np.array_equal(np.sort(fwd), np.arange(k))      # bijection
        assert np.array_equal(fwd[inv], np.arange(k))          # inverse
        assert np.array_equal(inv[fwd], np.arange(k))
    x = np.random.default_rng(seed).normal(size=(n, 3))
    np.testing.assert_array_equal(pg.unpack_nodes(pg.pack_nodes(x)), x)


def test_natural_perm_is_identity():
    ds = make_dataset("tiny")
    pg = build_partitioned_graph(sym_normalized(ds.graph),
                                 partition_graph(ds.graph, 4, seed=0), 4)
    assert pg.layout == "natural"
    for i in range(4):
        k = int(pg.inner_mask[i].sum())
        assert np.array_equal(pg.perm[i, :k], np.arange(k))
        assert np.array_equal(pg.inv_perm[i, :k], np.arange(k))


def test_rcm_order_is_permutation_with_isolated_nodes():
    """rcm_order must emit every local id once, including isolated nodes
    and multiple components."""
    indptr = np.array([0, 1, 2, 2, 4, 6, 6], dtype=np.int64)
    indices = np.array([1, 0, 4, 5, 3, 3], dtype=np.int64)   # 2 comps + iso
    order = rcm_order(indptr, indices)
    assert np.array_equal(np.sort(order), np.arange(6))


@settings(max_examples=10, deadline=None)
@given(n=st.integers(48, 128), parts=st.integers(2, 5), seed=st.integers(0, 5))
def test_partitioned_spmm_exact_under_rcm(n, parts, seed):
    """Property: reordered padded COO + halo exchange == dense P @ X (the
    natural-layout oracle of test_graph.py, under the rcm layout)."""
    g = random_graph(n, 5, seed)
    prop = sym_normalized(g)
    part = partition_graph(g, parts, seed=seed)
    pg = build_partitioned_graph(prop, part, parts, layout="rcm")
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 7))
    want = prop.to_dense() @ x

    xp = pg.pack_nodes(x)
    p = pg.num_parts
    halo = np.zeros((p, p * pg.slot, x.shape[1]))
    for i in range(p):
        for j in range(p):
            sel = xp[j, pg.send_idx[j, i]].copy()
            sel[~pg.send_mask[j, i]] = 0
            halo[i, j * pg.slot:(j + 1) * pg.slot] = sel
    comb = np.concatenate([xp, halo], axis=1)
    z = np.zeros((p, pg.max_inner, x.shape[1]))
    for i in range(p):
        np.add.at(z[i], pg.edge_row[i],
                  pg.edge_w[i][:, None] * comb[i, pg.edge_col[i]])
    np.testing.assert_allclose(pg.unpack_nodes(z), want, atol=1e-10)


def test_unknown_layout_rejected():
    ds = make_dataset("tiny")
    with pytest.raises(ValueError, match="layout"):
        build_partitioned_graph(sym_normalized(ds.graph),
                                partition_graph(ds.graph, 2, seed=0), 2,
                                layout="sideways")


# ---------------------------------------------------------------------
# 2. Layout quality (deterministic datasets)
# ---------------------------------------------------------------------

@pytest.mark.parametrize("kind", ["gcn", "sage"])
def test_rcm_never_more_tiles_and_fewer_halo_runs(kind):
    ds = make_dataset("small")
    norm = sym_normalized if kind == "gcn" else mean_normalized
    prop = norm(ds.graph)
    part = partition_graph(ds.graph, 4, seed=0)
    nat = graph_layout_report(build_partitioned_graph(prop, part, 4))
    rcm = graph_layout_report(
        build_partitioned_graph(prop, part, 4, layout="rcm"))
    assert rcm["tiles"] <= nat["tiles"], (rcm["tiles"], nat["tiles"])
    assert rcm["halo_runs"] <= nat["halo_runs"]


def test_trainer_rejects_layout_mismatch():
    """train_pipegcn must fail fast when ModelConfig.layout disagrees with
    the layout the pipeline was built with (two sources of one fact —
    drift has to be loud)."""
    from repro.core.trainer import train_pipegcn
    from repro.data import GraphDataPipeline
    pipeline = GraphDataPipeline.build("tiny", 2, kind="sage", layout="rcm")
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=8, num_layers=2,
                     num_classes=pipeline.dataset.num_classes,
                     dropout=0.0, layout="natural")
    with pytest.raises(ValueError, match="layout"):
        train_pipegcn(pipeline, mc, PipeConfig.named("pipegcn"), epochs=1)
    # the matching explicit declaration passes the check, and "auto"
    # defers to whatever the pipeline was built with — even for an engine
    # (coo) whose own auto-resolution would have picked natural, since a
    # shared reordered pipeline is numerically valid under every engine
    for layout in ("rcm", "auto"):
        mc_ok = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                            hidden=8, num_layers=2,
                            num_classes=pipeline.dataset.num_classes,
                            dropout=0.0, layout=layout)
        train_pipegcn(pipeline, mc_ok, PipeConfig.named("pipegcn"), epochs=1)


def test_tile_cache_reused_across_engine_builds():
    """extract_partition_tiles memoizes on the PartitionedGraph: repeated
    topology construction over one graph must not re-extract."""
    from repro.graph.halo import extract_partition_tiles
    ds = make_dataset("tiny")
    pg = build_partitioned_graph(sym_normalized(ds.graph),
                                 partition_graph(ds.graph, 2, seed=0), 2)
    a = extract_partition_tiles(pg)
    b = extract_partition_tiles(pg)
    assert a is b
    t1 = topology_from(pg, with_tiles=True)
    t2 = topology_from(pg, with_tiles=True)
    assert t1.tile_rows.shape == t2.tile_rows.shape
    assert len(pg.tile_cache) == 1


# ---------------------------------------------------------------------
# 3. f64 parity: natural vs rcm is numerically invisible
# ---------------------------------------------------------------------

def _build(layout, kind="sage"):
    ds = make_dataset("tiny")
    norm = mean_normalized if kind == "sage" else sym_normalized
    prop = norm(ds.graph)
    part = partition_graph(ds.graph, 4, seed=0)
    pg = build_partitioned_graph(prop, part, 4, layout=layout)
    topo = topology_from(pg, with_tiles=True)
    topo = topo._replace(edge_w=topo.edge_w.astype(jnp.float64))
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    return ds, pg, topo, data


@pytest.mark.parametrize("variant", ["vanilla", "pipegcn-gf"])
@pytest.mark.parametrize("agg", ["coo", "blocksparse", "fused"])
def test_layout_parity_f64(variant, agg):
    """loss / weight-grads / UNPACKED logits must match to 1e-12 between
    the natural and rcm layouts for >=3 steps — reordering is invisible
    modulo the permutation. (Pipeline buffers live in permuted coordinates
    and are intentionally not compared.) All three engines run in the
    caller's f64 here, so this is also a cross-layout kernel-exactness
    check; the SPMD matrix covers the shard_map backend."""
    ds, pg_n, topo_n, data_n = _build("natural")
    _, pg_r, topo_r, data_r = _build("rcm")
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes,
                     dropout=0.0, agg=agg)
    model = PipeGCN(mc, PipeConfig.named(variant, gamma=0.9))
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b_n = model.init_buffers(topo_n, dtype=jnp.float64)
    b_r = model.init_buffers(topo_r, dtype=jnp.float64)
    for t in range(3):
        key = jax.random.PRNGKey(t)
        l_n, g_n, b_n, logits_n = model.train_step(topo_n, params, b_n,
                                                   data_n, key)
        l_r, g_r, b_r, logits_r = model.train_step(topo_r, params, b_r,
                                                   data_r, key)
        assert abs(float(l_n) - float(l_r)) < 1e-12, (variant, agg, t)
        for k in g_n:
            d = float(jnp.abs(g_n[k] - g_r[k]).max())
            assert d < 1e-12, (variant, agg, t, k, d)
        un = pg_n.unpack_nodes(np.asarray(logits_n))
        ur = pg_r.unpack_nodes(np.asarray(logits_r))
        assert float(np.abs(un - ur).max()) < 1e-12, (variant, agg, t)


# ---------------------------------------------------------------------
# Vectorized partitioner == the per-node loop references (bit-identical)
# ---------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(n=st.integers(40, 300), parts=st.integers(2, 6),
       seed=st.integers(0, 8))
def test_vectorized_partitioner_bit_identical(n, parts, seed):
    from repro.graph.partition import (_bfs_grow, _bfs_grow_loop, _refine,
                                       _refine_loop)
    g = random_graph(n, 7, seed)
    a = _bfs_grow(g, parts, np.random.default_rng(seed))
    b = _bfs_grow_loop(g, parts, np.random.default_rng(seed))
    assert np.array_equal(a, b)
    assert np.array_equal(_refine(g, a, parts, 4, 0.05),
                          _refine_loop(g, b, parts, 4, 0.05))
