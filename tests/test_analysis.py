"""Analytic cost model sanity: parameter counts vs known model sizes, FLOPs
vs 6·N·D for dense training, cache sizing — plus the GCN matmul-ordering
model (hand-computed FLOP oracles for F_in ≠ F_out layers)."""
import pytest

from repro.analysis.cost import (analytic_cost, _cache_bytes,
                                 choose_gcn_orders, gcn_layer_order_cost,
                                 gcn_order_report)
from repro.configs import get_arch
from repro.models.config import INPUT_SHAPES


KNOWN_PARAMS_B = {          # published totals (±15%: padded vocab, heads)
    "qwen3-8b": 8.2,
    "qwen1.5-32b": 32.5,
    "codeqwen1.5-7b": 7.3,
    "deepseek-v2-236b": 236.0,
    "starcoder2-3b": 3.0,
    "mamba2-780m": 0.78,
    "recurrentgemma-2b": 2.7,
    "granite-moe-1b-a400m": 1.3,
    "llama-3.2-vision-11b": 9.8,   # language tower only (vision stubbed)
}


@pytest.mark.parametrize("arch_id,known", sorted(KNOWN_PARAMS_B.items()))
def test_param_counts_match_published(arch_id, known):
    cost = analytic_cost(get_arch(arch_id), INPUT_SHAPES["train_4k"])
    got = cost["params_total"] / 1e9
    assert known * 0.8 < got < known * 1.25, (arch_id, got, known)


def test_train_flops_close_to_6nd():
    cfg = get_arch("qwen3-8b")
    shape = INPUT_SHAPES["train_4k"]
    cost = analytic_cost(cfg, shape)
    tokens = shape.global_batch * shape.seq_len
    six_nd = 6.0 * cost["params_total"] * tokens
    # 4x-forward accounting (fwd+bwd+remat) ≈ 8/6 of 6ND, plus attention
    ratio = cost["flops_global"] / six_nd
    assert 1.0 < ratio < 2.5, ratio


def test_decode_flops_much_smaller_than_train():
    cfg = get_arch("qwen3-8b")
    tr = analytic_cost(cfg, INPUT_SHAPES["train_4k"])["flops_global"]
    de = analytic_cost(cfg, INPUT_SHAPES["decode_32k"])["flops_global"]
    assert de < tr / 1000


def test_mla_cache_much_smaller_than_mha():
    """DeepSeek's MLA latent cache ≈ (512+64)/ (2·128·128) of standard MHA."""
    ds = get_arch("deepseek-v2-236b")
    qw = get_arch("qwen1.5-32b")
    ds_bytes = _cache_bytes(ds, 1, 32768) / ds.num_layers
    qw_bytes = _cache_bytes(qw, 1, 32768) / qw.num_layers
    assert ds_bytes < qw_bytes / 10


def test_ssm_cache_constant_in_length():
    cfg = get_arch("mamba2-780m")
    assert _cache_bytes(cfg, 1, 1024) == _cache_bytes(cfg, 1, 524288)


# ---------------------------------------------------------------------
# GCN matmul-ordering model (aggregate-first vs transform-first)
# ---------------------------------------------------------------------

# Hand-computed oracle for fin=4, fout=2, n=8 inner rows, c=12 combined
# rows, e=10 effective sparse multiply-adds per feature column:
#
# aggregate-first (z = P·comb then z@w):
#   fwd:  spmm 2·e·fin = 80          transform 2·n·fin·fout = 128
#   bwd:  gw = zᵀdu     128          dz = du@wᵀ 128      spmm_t   80
# transform-first (comb@w then P·(comb@w)):
#   fwd:  transform 2·c·fin·fout = 192               spmm 2·e·fout = 40
#   bwd:  dhw = Pᵀdu 40      gw = combᵀdhw 192       dcomb = dhw@wᵀ 192

DIMS = dict(fin=4, fout=2, num_rows=8, combined=12, nnz_eff=10)


def test_gcn_order_flops_hand_computed_train():
    a = gcn_layer_order_cost("aggregate-first", **DIMS)
    b = gcn_layer_order_cost("transform-first", **DIMS)
    assert a.flops == 80 + 128 + 128 + 128 + 80 == 544
    assert b.flops == 192 + 40 + 40 + 192 + 192 == 656


def test_gcn_order_flops_hand_computed_first_layer():
    """Alg. 1 stops the backward at layer 0: aggregate-first drops its
    backward SpMM + dz entirely; transform-first still pays Pᵀ·du for gw."""
    a = gcn_layer_order_cost("aggregate-first", first_layer=True, **DIMS)
    b = gcn_layer_order_cost("transform-first", first_layer=True, **DIMS)
    assert a.flops == 80 + 128 + 128 == 336
    assert b.flops == 192 + 40 + 40 + 192 == 464


def test_gcn_order_flops_hand_computed_eval():
    a = gcn_layer_order_cost("aggregate-first", train=False, **DIMS)
    b = gcn_layer_order_cost("transform-first", train=False, **DIMS)
    assert a.flops == 80 + 128 == 208
    assert b.flops == 192 + 40 == 232


def test_gcn_order_fused_prologue_recompute():
    """Fused aggregate-first: dz is recomputed per tile slot (e/tile rows)
    instead of once per row block (n rows)."""
    a = gcn_layer_order_cost("aggregate-first", fused=True, tile=128, **DIMS)
    dz = 2.0 * (10 / 128) * 4 * 2
    assert a.flops == 80 + 128 + 128 + dz + 80


def test_gcn_order_unknown_rejected():
    with pytest.raises(ValueError, match="order"):
        gcn_layer_order_cost("sideways", **DIMS)


def test_choose_orders_prefers_aggregate_first_on_square_layers():
    """fin == fout: (P·H)·W is never more expensive (n < c strictly)."""
    dims = [(64, 64)] * 3
    assert choose_gcn_orders(dims, 128, 256, 10_000) == \
        ("aggregate-first",) * 3


def test_choose_orders_flips_on_shrinking_layer():
    """A wide→narrow classifier layer with heavy sparse work: transform
    first shrinks the SpMM from 2·e·256 to 2·e·8."""
    dims = [(64, 256), (256, 8)]
    orders = choose_gcn_orders(dims, 128, 256, 1_000_000)
    assert orders[1] == "transform-first"
    # expanding layer: aggregating 64-wide features first is cheaper
    assert orders[0] == "aggregate-first"


def test_gcn_order_report_chosen_is_argmin():
    rep = gcn_order_report([(32, 64), (64, 16)], 100, 220, 50_000)
    for r in rep:
        best = min(r["costs"].values(), key=lambda c: c.flops)
        assert r["costs"][r["chosen"]].flops == best.flops


# ---------------------------------------------------------------------
# Measured per-layer tile counts (PR-5): the report accepts a per-layer
# nnz_eff sequence; on a dense-uniform graph (every layer seeing the same
# measured sparse work) the "auto" decisions must be EXACTLY what the
# historical scalar form chose — regression guard for the
# uniform-density-assumption fix.
# ---------------------------------------------------------------------

def test_per_layer_nnz_uniform_matches_scalar():
    dims = [(64, 256), (256, 256), (256, 8)]
    scalar = choose_gcn_orders(dims, 128, 320, 1_000_000)
    per_layer = choose_gcn_orders(dims, 128, 320, [1_000_000] * 3)
    assert per_layer == scalar
    rep_s = gcn_order_report(dims, 128, 320, 1_000_000)
    rep_l = gcn_order_report(dims, 128, 320, [1_000_000.0] * 3)
    for a, b in zip(rep_s, rep_l):
        assert a["chosen"] == b["chosen"]
        for o in a["costs"]:
            assert a["costs"][o] == b["costs"][o]


def test_per_layer_nnz_can_flip_individual_layers():
    """Non-uniform measured work flips only the layers it prices: a huge
    measured tile count on a shrinking layer forces transform-first there
    while the cheap layers keep aggregate-first."""
    dims = [(64, 64), (64, 8)]
    uniform = choose_gcn_orders(dims, 128, 256, 1_000)
    assert uniform == ("aggregate-first", "aggregate-first")
    mixed = choose_gcn_orders(dims, 128, 256, [1_000, 5_000_000])
    assert mixed[0] == "aggregate-first"
    assert mixed[1] == "transform-first"


def test_per_layer_nnz_length_mismatch_rejected():
    with pytest.raises(ValueError, match="per-layer"):
        gcn_order_report([(8, 8)] * 3, 16, 32, [10.0, 10.0])


def test_graph_layout_report_counts_true_tiles():
    """The report counts NONEMPTY tiles over real edges only (no padding,
    no zero fillers) and measures bandwidth on intra-partition edges."""
    import numpy as np
    from repro.analysis.cost import graph_layout_report
    from repro.graph import (build_partitioned_graph, make_dataset,
                             partition_graph)
    from repro.graph.csr import sym_normalized
    ds = make_dataset("tiny")
    pg = build_partitioned_graph(sym_normalized(ds.graph),
                                 partition_graph(ds.graph, 2, seed=0), 2)
    rep = graph_layout_report(pg, tile=128)
    # oracle: per-partition unique (row//T, col//T) over w != 0
    want = 0
    ncb = -(-(pg.max_inner + pg.num_parts * pg.slot) // 128)
    for i in range(pg.num_parts):
        keep = pg.edge_w[i] != 0
        r = pg.edge_row[i][keep].astype(np.int64) // 128
        c = pg.edge_col[i][keep].astype(np.int64) // 128
        want += len(np.unique(r * ncb + c))
    assert rep["tiles"] == want
    assert rep["layout"] == "natural"
    assert len(rep["per_partition"]) == pg.num_parts
    assert all(p["halo_runs"] >= (p["halo_rows"] > 0)
               for p in rep["per_partition"])
