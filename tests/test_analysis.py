"""Analytic cost model sanity: parameter counts vs known model sizes, FLOPs
vs 6·N·D for dense training, cache sizing."""
import pytest

from repro.analysis.cost import analytic_cost, _cache_bytes
from repro.configs import get_arch
from repro.models.config import INPUT_SHAPES


KNOWN_PARAMS_B = {          # published totals (±15%: padded vocab, heads)
    "qwen3-8b": 8.2,
    "qwen1.5-32b": 32.5,
    "codeqwen1.5-7b": 7.3,
    "deepseek-v2-236b": 236.0,
    "starcoder2-3b": 3.0,
    "mamba2-780m": 0.78,
    "recurrentgemma-2b": 2.7,
    "granite-moe-1b-a400m": 1.3,
    "llama-3.2-vision-11b": 9.8,   # language tower only (vision stubbed)
}


@pytest.mark.parametrize("arch_id,known", sorted(KNOWN_PARAMS_B.items()))
def test_param_counts_match_published(arch_id, known):
    cost = analytic_cost(get_arch(arch_id), INPUT_SHAPES["train_4k"])
    got = cost["params_total"] / 1e9
    assert known * 0.8 < got < known * 1.25, (arch_id, got, known)


def test_train_flops_close_to_6nd():
    cfg = get_arch("qwen3-8b")
    shape = INPUT_SHAPES["train_4k"]
    cost = analytic_cost(cfg, shape)
    tokens = shape.global_batch * shape.seq_len
    six_nd = 6.0 * cost["params_total"] * tokens
    # 4x-forward accounting (fwd+bwd+remat) ≈ 8/6 of 6ND, plus attention
    ratio = cost["flops_global"] / six_nd
    assert 1.0 < ratio < 2.5, ratio


def test_decode_flops_much_smaller_than_train():
    cfg = get_arch("qwen3-8b")
    tr = analytic_cost(cfg, INPUT_SHAPES["train_4k"])["flops_global"]
    de = analytic_cost(cfg, INPUT_SHAPES["decode_32k"])["flops_global"]
    assert de < tr / 1000


def test_mla_cache_much_smaller_than_mha():
    """DeepSeek's MLA latent cache ≈ (512+64)/ (2·128·128) of standard MHA."""
    ds = get_arch("deepseek-v2-236b")
    qw = get_arch("qwen1.5-32b")
    ds_bytes = _cache_bytes(ds, 1, 32768) / ds.num_layers
    qw_bytes = _cache_bytes(qw, 1, 32768) / qw.num_layers
    assert ds_bytes < qw_bytes / 10


def test_ssm_cache_constant_in_length():
    cfg = get_arch("mamba2-780m")
    assert _cache_bytes(cfg, 1, 1024) == _cache_bytes(cfg, 1, 524288)
