"""Beyond-paper features: bf16 boundary compression (App. C direction) and
grouped MoE routing (the §Perf dispatch optimization)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ModelConfig, PipeConfig, train_pipegcn
from repro.core.pipegcn import PipeGCN
from repro.data import GraphDataPipeline


@pytest.fixture(scope="module")
def pipeline():
    return GraphDataPipeline.build("tiny", num_parts=4, kind="sage")


def test_bf16_boundary_close_to_f32(pipeline):
    """Compressed boundary exchange changes gradients only at bf16 noise."""
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=16, num_layers=3,
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    outs = {}
    for compress in (False, True):
        pc = dataclasses.replace(PipeConfig(stale=True),
                                 compress_boundary=compress)
        model = PipeGCN(mc, pc)
        params = model.init_params(jax.random.PRNGKey(0))
        bufs = model.init_buffers(pipeline.topo)
        for t in range(3):
            loss, grads, bufs, _ = model.train_step(
                pipeline.topo, params, bufs, pipeline.train_data,
                jax.random.PRNGKey(t))
            params = {k: params[k] - 0.05 * grads[k] for k in params}
        outs[compress] = (float(loss), params)
    rel = abs(outs[True][0] - outs[False][0]) / abs(outs[False][0])
    assert rel < 2e-2, rel
    for k in outs[False][1]:
        a, b = np.asarray(outs[False][1][k]), np.asarray(outs[True][1][k])
        assert np.abs(a - b).max() / (np.abs(a).max() + 1e-9) < 5e-2, k


def test_bf16_boundary_trains_to_parity(pipeline):
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=32, num_layers=2,
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    res = {}
    for compress in (False, True):
        pc = dataclasses.replace(PipeConfig(stale=True),
                                 compress_boundary=compress)
        r = train_pipegcn(pipeline, mc, pc, epochs=80, lr=0.01,
                          eval_every=80)
        res[compress] = r.final_metrics["test"]
    assert res[True] >= res[False] - 0.05, res


def test_grouped_moe_dropless_exact():
    from repro.configs import get_arch
    from repro.models.moe import apply_moe, init_moe
    cfg = get_arch("deepseek-v2-236b").reduced()
    p = init_moe(jax.random.PRNGKey(1), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(2, 8, cfg.d_model)),
                    jnp.float32)
    o1, _ = apply_moe(p, cfg, x, dropless=True)
    for g in (2, 4, 16):
        o2, _ = apply_moe(p, dataclasses.replace(cfg, moe_groups=g), x,
                          dropless=True)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-5)


def test_grouped_moe_capacity_finite():
    from repro.configs import get_arch
    from repro.models.moe import apply_moe, init_moe
    cfg = dataclasses.replace(get_arch("granite-moe-1b-a400m").reduced(),
                              moe_groups=4, capacity_factor=0.5)
    p = init_moe(jax.random.PRNGKey(2), cfg, jnp.float32)
    x = jnp.asarray(np.random.default_rng(2).normal(size=(2, 16, cfg.d_model)),
                    jnp.float32)
    out, aux = apply_moe(p, cfg, x)
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux) > 0


def test_grouped_moe_in_full_model_train_step():
    from repro.configs import get_arch
    from repro.models.model import LM
    cfg = dataclasses.replace(get_arch("granite-moe-1b-a400m").reduced(),
                              moe_groups=2)
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 16), jnp.int32),
             "labels": jnp.zeros((2, 16), jnp.int32)}
    loss, grads = jax.value_and_grad(lm.loss_fn)(params, batch)
    assert np.isfinite(float(loss))
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in jax.tree.leaves(grads))
