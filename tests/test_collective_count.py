"""Collective-count regression gate for the fused deferred exchange.

Traces `PipeGCN.make_spmd_step` to a jaxpr and counts `all_to_all` eqns:
with `fuse_exchange=True` a stale-mode training step must contain exactly
1 boundary collective in the forward and 1 in the backward (2 total),
against L forward + (L-1) backward = 2L-1 for the blocking per-layer
schedule. If a future change reintroduces a per-layer exchange, these
counts move and the test fails — the fusion cannot silently regress.

The trace runs on a 1-device mesh hosting all P partitions co-resident
(`parts_per_device=P`): the jaxpr still contains every `all_to_all` the
multi-device program would issue, so no forced host devices are needed
and this stays in tier-1.
"""
import dataclasses

import pytest

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.core.trace_utils import (expected_boundary_collectives,
                                    traced_step_collectives)
from repro.launch.mesh import make_partition_mesh

P = 4


def _model(pipeline, num_layers, **pipe_kw):
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=16, num_layers=num_layers,
                     num_classes=pipeline.dataset.num_classes, dropout=0.0)
    pc = dataclasses.replace(PipeConfig.named("pipegcn"), **pipe_kw)
    return PipeGCN(mc, pc)


def _counts(pipeline, model, train):
    mesh = make_partition_mesh(P, parts_per_device=P)
    return traced_step_collectives(model, mesh, pipeline.topo,
                                   pipeline.train_data, train=train)


@pytest.mark.parametrize("num_layers", [2, 3, 4])
def test_fused_train_step_has_exactly_two_collectives(tiny_pipeline,
                                                      num_layers):
    model = _model(tiny_pipeline, num_layers, fuse_exchange=True)
    got = _counts(tiny_pipeline, model, train=True)
    assert got["all_to_all"] == 2, got           # 1 forward + 1 backward


@pytest.mark.parametrize("num_layers", [2, 3, 4])
def test_perlayer_train_step_has_2L_minus_1(tiny_pipeline, num_layers):
    model = _model(tiny_pipeline, num_layers, fuse_exchange=False)
    got = _counts(tiny_pipeline, model, train=True)
    assert got["all_to_all"] == 2 * num_layers - 1, got


@pytest.mark.parametrize("fuse,expect", [(True, 1), (False, 3)])
def test_forward_only_collective_split(tiny_pipeline, fuse, expect):
    """train=False isolates the forward: 1 fused vs L per-layer exchanges —
    together with the train counts this pins 1 forward + 1 backward."""
    model = _model(tiny_pipeline, 3, fuse_exchange=fuse)
    got = _counts(tiny_pipeline, model, train=False)
    assert got["all_to_all"] == expect, got


def test_vanilla_ignores_fuse_flag(tiny_pipeline):
    """Non-stale mode needs fresh per-layer exchanges on the critical path;
    the fuse flag must not change its schedule (or its semantics)."""
    model = _model(tiny_pipeline, 3, fuse_exchange=True, stale=False)
    got = _counts(tiny_pipeline, model, train=True)
    assert got["all_to_all"] == 5, got


@pytest.mark.parametrize("pipe_kw", [
    {"staleness_steps": 3},
    {"compress_boundary": True},
    {"smooth_feat": True, "smooth_grad": True},
])
def test_fusion_survives_pipeline_variants(tiny_pipeline, pipe_kw):
    """k-step FIFOs, bf16 compression and γ-smoothing all ride the same
    two fused collectives."""
    model = _model(tiny_pipeline, 3, fuse_exchange=True, **pipe_kw)
    got = _counts(tiny_pipeline, model, train=True)
    assert got["all_to_all"] == 2, (pipe_kw, got)


def test_expected_collectives_math():
    """The analytic table the README documents."""
    for L in (1, 2, 3, 4, 8):
        assert expected_boundary_collectives(L, fused=False) == 2 * L - 1
        assert expected_boundary_collectives(
            L, fused=True) == (2 if L > 1 else 1)
        assert expected_boundary_collectives(
            L, fused=False, train=False) == L
        assert expected_boundary_collectives(L, fused=True, train=False) == 1


def test_single_layer_fused_has_no_backward_collective(tiny_pipeline):
    """L=1: Alg. 1 sends no boundary gradients, so the fused backward
    exchange must vanish entirely (not ship an empty payload)."""
    model = _model(tiny_pipeline, 1, fuse_exchange=True)
    got = _counts(tiny_pipeline, model, train=True)
    assert got["all_to_all"] == 1, got


@pytest.fixture(scope="module")
def grid_pipeline():
    """Lattice pipeline with a feasible split (rcm halo-clustered tail) —
    the regime where the split-phase overlap schedule activates."""
    from repro.data.graph_pipeline import GraphDataPipeline
    return GraphDataPipeline.build("grid-tiny", P, kind="sage",
                                   agg="blocksparse", layout="rcm")


def _overlap_model(pipeline, num_layers, **pipe_kw):
    mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                     hidden=16, num_layers=num_layers,
                     num_classes=pipeline.dataset.num_classes, dropout=0.0,
                     agg="blocksparse", layout="rcm")
    pc = dataclasses.replace(PipeConfig.named("pipegcn"),
                             overlap="split-phase", **pipe_kw)
    return PipeGCN(mc, pc, split=pipeline.split_spec())


@pytest.mark.parametrize("num_layers", [1, 2, 3])
@pytest.mark.parametrize("fuse", [True, False])
def test_overlap_preserves_collective_counts(grid_pipeline, num_layers,
                                             fuse):
    """The split-phase schedule REPOSITIONS each boundary collective (to
    between the phase kernels) but must never change how many there are:
    same 2-fused / 2L-1-per-layer table as the unsplit schedule. L=1 is
    the edge cell — the fused backward exchange vanishes (1 collective),
    split or not."""
    model = _overlap_model(grid_pipeline, num_layers, fuse_exchange=fuse)
    assert model._split_active() is not None
    got = _counts(grid_pipeline, model, train=True)
    assert got["all_to_all"] == expected_boundary_collectives(
        num_layers, model.pipe.fused), (num_layers, fuse, got)


def test_overlap_single_layer_forward_only(grid_pipeline):
    """L=1 eval under the split: exactly one forward collective."""
    model = _overlap_model(grid_pipeline, 1, fuse_exchange=True)
    got = _counts(grid_pipeline, model, train=False)
    assert got["all_to_all"] == 1, got


def test_count_primitives_sees_through_jit():
    """The counter recurses into pjit/closed-call sub-jaxprs."""
    import jax
    import jax.numpy as jnp

    from repro.core.trace_utils import count_primitives

    @jax.jit
    def inner(x):
        return jnp.sin(x) + jnp.sin(2 * x)

    def outer(x):
        return inner(x) * jnp.sin(x)

    jx = jax.make_jaxpr(outer)(1.0)
    assert count_primitives(jx, ("sin",))["sin"] == 3
