"""Graph substrate: CSR, normalization, partitioner invariants, halo builder.

Property sweeps use hypothesis when installed, else the deterministic
fixed-seed fallback in _hypothesis_compat."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.graph import (build_partitioned_graph, coo_to_csr, make_dataset,
                         partition_graph)
from repro.graph.csr import mean_normalized, sym_normalized, symmetrize
from repro.graph.partition import comm_volume, edge_cut


def random_graph(n, avg_deg, seed):
    rng = np.random.default_rng(seed)
    m = max(int(n * avg_deg / 2), 1)
    src = rng.integers(0, n, m)
    dst = rng.integers(0, n, m)
    keep = src != dst
    return symmetrize(coo_to_csr(src[keep], dst[keep], n))


def test_sym_normalization_rows():
    g = random_graph(64, 6, 0)
    p = sym_normalized(g)
    dense = p.to_dense()
    # symmetric and spectral radius <= 1 for D^-1/2 A~ D^-1/2
    assert np.allclose(dense, dense.T, atol=1e-7)
    w = np.linalg.eigvalsh(dense)
    assert w.max() <= 1.0 + 1e-6


def test_mean_normalization_rows_sum_to_one():
    g = random_graph(64, 6, 1)
    p = mean_normalized(g)
    dense = p.to_dense()
    rs = dense.sum(1)
    deg = g.degrees()
    assert np.allclose(rs[deg > 0], 1.0, atol=1e-6)
    assert np.allclose(rs[deg == 0], 0.0)


@settings(max_examples=15, deadline=None)
@given(n=st.integers(40, 160), parts=st.integers(2, 6),
       seed=st.integers(0, 10))
def test_partitioner_invariants(n, parts, seed):
    g = random_graph(n, 6, seed)
    part = partition_graph(g, parts, seed=seed)
    assert part.shape == (n,)
    assert part.min() >= 0 and part.max() < parts
    sizes = np.bincount(part, minlength=parts)
    # balance constraint (allow bfs leftovers slack)
    assert sizes.max() <= int(n / parts * 1.35) + 2


def test_refinement_reduces_cut():
    g = random_graph(512, 8, 3)
    rnd = partition_graph(g, 4, seed=0, method="random")
    ref = partition_graph(g, 4, seed=0, method="bfs+refine")
    assert edge_cut(g, ref) < edge_cut(g, rnd)
    assert comm_volume(g, ref, 4) < comm_volume(g, rnd, 4)


@settings(max_examples=10, deadline=None)
@given(n=st.integers(48, 128), parts=st.integers(2, 5), seed=st.integers(0, 5))
def test_partitioned_spmm_exact(n, parts, seed):
    """Property: padded partitioned COO + halo exchange == dense P @ X."""
    g = random_graph(n, 5, seed)
    prop = sym_normalized(g)
    part = partition_graph(g, parts, seed=seed)
    pg = build_partitioned_graph(prop, part, parts)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 7))
    want = prop.to_dense() @ x

    xp = pg.pack_nodes(x)
    p = pg.num_parts
    halo = np.zeros((p, p * pg.slot, x.shape[1]))
    for i in range(p):
        for j in range(p):
            sel = xp[j, pg.send_idx[j, i]].copy()
            sel[~pg.send_mask[j, i]] = 0
            halo[i, j * pg.slot:(j + 1) * pg.slot] = sel
    comb = np.concatenate([xp, halo], axis=1)
    z = np.zeros((p, pg.max_inner, x.shape[1]))
    for i in range(p):
        np.add.at(z[i], pg.edge_row[i],
                  pg.edge_w[i][:, None] * comb[i, pg.edge_col[i]])
    got = pg.unpack_nodes(z)
    np.testing.assert_allclose(got, want, atol=1e-10)


def test_pack_unpack_roundtrip():
    ds = make_dataset("tiny")
    part = partition_graph(ds.graph, 4, seed=0)
    pg = build_partitioned_graph(sym_normalized(ds.graph), part, 4)
    x = np.arange(ds.num_nodes, dtype=np.float64)
    assert np.array_equal(pg.unpack_nodes(pg.pack_nodes(x)), x)


def test_datasets_registry():
    for name in ("tiny", "small"):
        ds = make_dataset(name)
        assert ds.train_mask.sum() > 0
        assert not (ds.train_mask & ds.val_mask).any()
        assert not (ds.train_mask & ds.test_mask).any()
        if ds.multilabel:
            assert ds.labels.shape == (ds.num_nodes, ds.num_classes)
        else:
            assert ds.labels.max() < ds.num_classes


def test_boundary_stats():
    ds = make_dataset("tiny")
    part = partition_graph(ds.graph, 4, seed=0)
    pg = build_partitioned_graph(sym_normalized(ds.graph), part, 4)
    assert pg.boundary_bytes_per_layer(16) > 0
    assert 0.0 <= pg.padding_ratio() < 1.0
    assert pg.halo_counts().sum() == pg.halo_owner_mask.sum()
