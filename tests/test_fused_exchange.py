"""Fused deferred exchange vs the blocking per-layer reference, sim backend.

`fuse_exchange=True` packs all per-layer boundary sends into one collective
per direction; the exchange is pure data movement, so the two schedules
must agree bit-for-bit. This tier-1 matrix pins 1e-12 float64 parity for
loss, every weight gradient, and every pipeline buffer over multiple steps
across variants × aggregation engines × pipeline knobs; the cross-backend
(shard_map) cells live in the slow-tier subprocess matrix in
test_pipegcn_spmd.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, st

jax.config.update("jax_enable_x64", True)

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import (PipeGCN, pack_offsets, pack_payloads,
                                pack_widths, shard_data, topology_from,
                                unpack_payloads)
from repro.graph import build_partitioned_graph, make_dataset, partition_graph
from repro.graph.csr import mean_normalized

P = 4


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("tiny")
    prop = mean_normalized(ds.graph)
    pg = build_partitioned_graph(prop, partition_graph(ds.graph, P, seed=0), P)
    topo = topology_from(pg, with_tiles=True)
    topo = topo._replace(edge_w=topo.edge_w.astype(jnp.float64))
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    return ds, topo, data


CELLS = [
    ("pipegcn", "coo", {}),
    ("pipegcn", "blocksparse", {}),
    ("pipegcn-g", "coo", {}),
    ("pipegcn-f", "coo", {}),
    ("pipegcn-gf", "blocksparse", {}),
    ("pipegcn", "coo", {"staleness_steps": 3}),
    ("pipegcn", "blocksparse", {"staleness_steps": 2}),
    ("pipegcn", "coo", {"compress_boundary": True}),
    ("pipegcn-gf", "coo", {"compress_boundary": True}),
    ("pipegcn", "coo", {"staleness_steps": 2, "compress_boundary": True}),
    # quantized wires: encode runs before the exchange on both schedules,
    # so the 1e-12 parity bar is unchanged (see repro/core/codec.py)
    ("pipegcn", "coo", {"wire": "int8"}),
    ("pipegcn", "blocksparse", {"wire": "int4"}),
    ("pipegcn-gf", "coo", {"wire": "int8"}),
    ("pipegcn", "coo", {"wire": "int8", "staleness_steps": 2}),
    ("pipegcn", "coo", {"wire": "auto"}),
]


@pytest.mark.parametrize("variant,agg,pipe_kw", CELLS)
def test_fused_equals_perlayer(setup, variant, agg, pipe_kw):
    ds, topo, data = setup
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes,
                     dropout=0.0, agg=agg)
    base = dataclasses.replace(PipeConfig.named(variant, gamma=0.9), **pipe_kw)
    ref = PipeGCN(mc, dataclasses.replace(base, fuse_exchange=False))
    fus = PipeGCN(mc, dataclasses.replace(base, fuse_exchange=True))
    params = ref.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b_ref = ref.init_buffers(topo, dtype=jnp.float64)
    b_fus = fus.init_buffers(topo, dtype=jnp.float64)
    steps = 5 if pipe_kw.get("staleness_steps", 1) > 1 else 3
    for t in range(steps):
        key = jax.random.PRNGKey(t)
        l0, g0, b_ref, _ = ref.train_step(topo, params, b_ref, data, key)
        l1, g1, b_fus, _ = fus.train_step(topo, params, b_fus, data, key)
        assert abs(float(l0) - float(l1)) < 1e-12, (variant, agg, pipe_kw, t)
        for k in g0:
            d = float(jnp.abs(g0[k] - g1[k]).max())
            assert d < 1e-12, (variant, agg, pipe_kw, t, k, d)
        for a, b in zip(jax.tree.leaves(b_ref), jax.tree.leaves(b_fus)):
            d = float(jnp.abs(a - b).max())
            assert d < 1e-12, (variant, agg, pipe_kw, t, d)


def test_fused_with_dropout(setup):
    """Dropout masks are drawn identically under both schedules (the mask
    key never touches the exchange), so parity holds with training noise."""
    ds, topo, data = setup
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes, dropout=0.5)
    base = PipeConfig.named("pipegcn")
    ref = PipeGCN(mc, dataclasses.replace(base, fuse_exchange=False))
    fus = PipeGCN(mc, dataclasses.replace(base, fuse_exchange=True))
    params = ref.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b_ref = ref.init_buffers(topo, dtype=jnp.float64)
    b_fus = fus.init_buffers(topo, dtype=jnp.float64)
    for t in range(3):
        key = jax.random.PRNGKey(100 + t)
        l0, g0, b_ref, _ = ref.train_step(topo, params, b_ref, data, key)
        l1, g1, b_fus, _ = fus.train_step(topo, params, b_fus, data, key)
        assert abs(float(l0) - float(l1)) < 1e-12
        for k in g0:
            assert float(jnp.abs(g0[k] - g1[k]).max()) < 1e-12, (t, k)


def test_vanilla_unaffected_by_fuse_flag(setup):
    """stale=False keeps the blocking per-layer schedule regardless of the
    flag — fresh boundary features are on the critical path."""
    ds, topo, data = setup
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=2, num_classes=ds.num_classes, dropout=0.0)
    a = PipeGCN(mc, dataclasses.replace(PipeConfig.vanilla(),
                                        fuse_exchange=True))
    b = PipeGCN(mc, dataclasses.replace(PipeConfig.vanilla(),
                                        fuse_exchange=False))
    assert not a.pipe.fused and not b.pipe.fused
    params = a.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = a.init_buffers(topo, dtype=jnp.float64)
    l0, g0, _, _ = a.train_step(topo, params, bufs, data, jax.random.PRNGKey(1))
    l1, g1, _, _ = b.train_step(topo, params, bufs, data, jax.random.PRNGKey(1))
    assert float(l0) == float(l1)
    for k in g0:
        assert float(jnp.abs(g0[k] - g1[k]).max()) == 0.0


@pytest.mark.parametrize("compress", [False, True])
def test_fused_mixed_dtype_parity(setup, compress):
    """f32 inputs with f64 params promote activations layer by layer, so
    each layer's boundary payload has its own dtype. The fused unpack must
    restore every layer's per-layer-schedule dtype (packing would otherwise
    promote the whole buffer), keeping values AND buffer dtypes identical
    between schedules."""
    ds, topo, data = setup
    data = data._replace(x=data.x.astype(jnp.float32))
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes, dropout=0.0)
    base = dataclasses.replace(PipeConfig.named("pipegcn"),
                               compress_boundary=compress)
    ref = PipeGCN(mc, dataclasses.replace(base, fuse_exchange=False))
    fus = PipeGCN(mc, dataclasses.replace(base, fuse_exchange=True))
    params = ref.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b_ref = ref.init_buffers(topo, dtype=jnp.float64)
    b_fus = fus.init_buffers(topo, dtype=jnp.float64)
    for t in range(3):
        key = jax.random.PRNGKey(t)
        l0, g0, b_ref, _ = ref.train_step(topo, params, b_ref, data, key)
        l1, g1, b_fus, _ = fus.train_step(topo, params, b_fus, data, key)
        assert float(l0) == float(l1), (compress, t)
        for k in g0:
            assert float(jnp.abs(g0[k] - g1[k]).max()) == 0.0, (compress, t, k)
        for a, b in zip(jax.tree.leaves(b_ref), jax.tree.leaves(b_fus)):
            assert a.dtype == b.dtype, (compress, t, a.dtype, b.dtype)
            assert float(jnp.abs(a - b).max()) == 0.0, (compress, t)


def test_pack_unpack_roundtrip():
    """pack/unpack are exact inverses and the offset table is static."""
    key = jax.random.PRNGKey(0)
    widths = (7, 16, 3, 1)
    payloads = [jax.random.normal(jax.random.fold_in(key, i), (2, P, 5, w))
                for i, w in enumerate(widths)]
    assert pack_widths(payloads) == widths
    assert pack_offsets(widths) == (0, 7, 23, 26)
    packed = pack_payloads(payloads)
    assert packed.shape == (2, P, 5, sum(widths))
    for orig, back in zip(payloads, unpack_payloads(packed, widths)):
        assert jnp.array_equal(orig, back)


def test_pack_unpack_zero_width_payloads():
    """Zero-width entries (a layer with nothing to send — e.g. the L=1
    backward, or a degenerate no-boundary partition pre-masking) must pack
    to zero columns at a stable offset and unpack back to empty arrays,
    not crash or shift their neighbours."""
    key = jax.random.PRNGKey(1)
    widths = (0, 5, 0, 3, 0)
    payloads = [jax.random.normal(jax.random.fold_in(key, i), (P, 4, w))
                for i, w in enumerate(widths)]
    assert pack_widths(payloads) == widths
    assert pack_offsets(widths) == (0, 0, 5, 5, 8)
    packed = pack_payloads(payloads)
    assert packed.shape == (P, 4, 8)
    back = unpack_payloads(packed, widths)
    for orig, got in zip(payloads, back):
        assert got.shape == orig.shape
        assert jnp.array_equal(orig, got)
    # all-empty: the degenerate fused send carries zero columns
    empty = [jnp.zeros((P, 4, 0)) for _ in range(3)]
    packed = pack_payloads(empty)
    assert packed.shape == (P, 4, 0)
    assert all(b.shape == (P, 4, 0)
               for b in unpack_payloads(packed, (0, 0, 0)))


@given(widths=st.lists(st.integers(min_value=0, max_value=9), min_size=1,
                       max_size=6),
       seed=st.integers(min_value=0, max_value=2**16))
def test_pack_unpack_roundtrip_property(widths, seed):
    """Property: for ANY width profile (zero-width entries included),
    unpack(pack(x)) == x exactly and the offsets are the prefix sums."""
    key = jax.random.PRNGKey(seed)
    payloads = [jax.random.normal(jax.random.fold_in(key, i), (P, 3, w))
                for i, w in enumerate(widths)]
    offs = pack_offsets(tuple(widths))
    assert offs == tuple(int(sum(widths[:i])) for i in range(len(widths)))
    packed = pack_payloads(payloads)
    assert packed.shape == (P, 3, sum(widths))
    for orig, back in zip(payloads, unpack_payloads(packed, tuple(widths))):
        assert jnp.array_equal(orig, back)
