"""Elastic runtime: survivor-remapped partitions + staleness-escalated
recovery (ISSUE 10).

The load-bearing guarantee is the BITWISE gate: after a device loss the
trainer restores the last checkpoint, remaps the lost device's partitions
onto the survivors, and from that point on produces exactly the floats a
fresh launch at the smaller device count produces from the same
checkpoint — recovery is a re-sharding, never a numerical event. The
zero-fault identity pins the other direction: an armed elastic runtime
that never fires is bitwise invisible.

Property tests (hypothesis, or the fixed-seed sweep shim) pin the plan
algebra: every real partition is hosted exactly once for ARBITRARY
survivor subsets, and remap → unmap round-trips data and pipeline buffers
bitwise. The SPMD drill lives in a subprocess so only it sees forced host
devices.
"""
import dataclasses
import os
import shutil
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from _hypothesis_compat import given, settings, st
from repro.core import (DeviceLossError, ElasticConfig, ElasticPlan,
                        FaultPlan, ModelConfig, PipeConfig, device_down_site)
from repro.core.elastic import (detect_device_loss, mask_pad_faults,
                                remap_buffers, remap_data, remap_topology,
                                unmap_buffers, unmap_data, unmap_topology,
                                warm_mark)
from repro.core.faults import FWD
from repro.core.pipegcn import PipeGCN
from repro.core.trainer import train_pipegcn
from repro.data import GraphDataPipeline

P = 4


@pytest.fixture(scope="module")
def pipeline():
    return GraphDataPipeline.build("tiny", P, seed=0)


def _cfgs(pipeline, **pipe_kw):
    ds = pipeline.dataset
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes, dropout=0.0)
    pipe_kw.setdefault("guard_exchange", True)
    pipe_kw.setdefault("max_staleness", 8)
    pc = dataclasses.replace(PipeConfig.named("pipegcn"), **pipe_kw)
    return mc, pc


def _bitwise(a_tree, b_tree):
    la, lb = jax.tree.leaves(a_tree), jax.tree.leaves(b_tree)
    assert len(la) == len(lb)
    return all(bool((np.asarray(a) == np.asarray(b)).all())
               for a, b in zip(la, lb))


# ---------------------------------------------------------------------------
# plan algebra (property tests)
# ---------------------------------------------------------------------------

@settings(max_examples=40)
@given(n_local=st.sampled_from([1, 2, 4]),
       orig=st.integers(min_value=2, max_value=5),
       mask=st.integers(min_value=1, max_value=31))
def test_plan_covers_every_partition_exactly_once(n_local, orig, mask):
    """Whatever subset of devices survives, the plan's device-major
    assignment hosts every REAL partition exactly once, pads fill the
    remainder, and all-survive is the identity plan."""
    survivors = tuple(d for d in range(orig) if (mask >> d) & 1) or (0,)
    plan = ElasticPlan(num_parts=orig * n_local, orig_devices=orig,
                       survivors=survivors)
    hosted = [p for dev in plan.assignment() for p in dev]
    assert sorted(hosted) == list(range(plan.num_parts))      # exactly once
    assert len(plan.assignment()) == plan.n_devices
    assert all(len(dev) <= plan.n_local for dev in plan.assignment())
    assert plan.padded_parts == plan.n_devices * plan.n_local
    assert 0 <= plan.pad_parts < plan.n_devices
    assert plan.moved_partitions() <= set(range(plan.num_parts))
    assert set(plan.lost) | set(plan.survivors) == set(range(orig))
    if len(plan.survivors) == orig:
        assert plan.pad_parts == 0
        assert not plan.moved_partitions()


@settings(max_examples=25)
@given(n_local=st.sampled_from([1, 2, 4]),
       orig=st.integers(min_value=2, max_value=4),
       mask=st.integers(min_value=1, max_value=15),
       k=st.sampled_from([0, 2]))
def test_remap_unmap_roundtrip_buffers_and_data(n_local, orig, mask, k):
    """remap → unmap is bitwise identity on synthetic buffers shaped like
    the pipeline state ((k?, P, P*slot, w) feat / (k?, P, m, w) grad /
    (P,2,L,P) es) and on leading-partition data arrays — for arbitrary
    survivor subsets and FIFO depths."""
    survivors = tuple(d for d in range(orig) if (mask >> d) & 1) or (0,)
    num_parts = orig * n_local
    plan = ElasticPlan(num_parts=num_parts, orig_devices=orig,
                       survivors=survivors)
    rng = np.random.default_rng(num_parts * 131 + mask)
    slot, w, L, m = 3, 5, 2, 6
    lead = (k,) if k else ()
    bufs = {
        "feat": tuple(jnp.asarray(rng.normal(
            size=lead + (num_parts, num_parts * slot, w))) for _ in range(L)),
        "grad": tuple(jnp.asarray(rng.normal(
            size=lead + (num_parts, m, w))) for _ in range(L)),
        "es": jnp.asarray(rng.integers(
            0, 3, size=(num_parts, 2, L, num_parts)), dtype=jnp.int32),
    }
    rb = remap_buffers(bufs, plan)
    assert rb["feat"][0].shape[-3] == plan.padded_parts
    assert rb["feat"][0].shape[-2] == plan.padded_parts * slot
    assert rb["es"].shape == (plan.padded_parts, 2, L, plan.padded_parts)
    assert _bitwise(unmap_buffers(rb, plan), bufs)
    data = {"x": jnp.asarray(rng.normal(size=(num_parts, m, w)))}
    assert _bitwise(jax.tree.map(lambda a: a[:num_parts],
                                 remap_data(data, plan)), data)


def test_plan_validates(pipeline):
    with pytest.raises(ValueError, match="multiple"):
        ElasticPlan(num_parts=4, orig_devices=3, survivors=(0,))
    with pytest.raises(ValueError, match="empty"):
        ElasticPlan(num_parts=4, orig_devices=4, survivors=())
    with pytest.raises(ValueError, match="out of range"):
        ElasticPlan(num_parts=4, orig_devices=4, survivors=(0, 7))
    # survivors are sorted + deduped
    plan = ElasticPlan(num_parts=4, orig_devices=4, survivors=(3, 0, 2, 2))
    assert plan.survivors == (0, 2, 3)
    assert plan.lost == (1,)
    assert plan.n_local == 2 and plan.padded_parts == 6 and plan.pad_parts == 2
    assert plan.assignment() == ((0, 1), (2, 3), ())


def test_elastic_config_validates():
    with pytest.raises(ValueError, match="detect_after"):
        ElasticConfig(detect_after=0)
    with pytest.raises(ValueError, match="warm_staleness"):
        ElasticConfig(detect_after=2, warm_staleness=2)
    with pytest.raises(ValueError, match="max_recoveries"):
        ElasticConfig(max_recoveries=-1)


# ---------------------------------------------------------------------------
# topology / pipeline-state remap on the real graph
# ---------------------------------------------------------------------------

def test_topology_remap_roundtrip_and_masks(pipeline):
    plan = ElasticPlan(num_parts=P, orig_devices=P, survivors=(0, 2, 3))
    topo = pipeline.topo
    rt = remap_topology(topo, plan)
    assert rt.num_parts == plan.padded_parts
    assert rt.send_idx.shape[:2] == (plan.padded_parts, plan.padded_parts)
    # pads are idle: no sends, no inner nodes
    assert not np.asarray(rt.send_mask)[P:].any()
    assert not np.asarray(rt.send_mask)[:, P:].any()
    assert not np.asarray(rt.inner_mask)[P:].any()
    assert _bitwise(tuple(x for x in unmap_topology(rt, plan) if x is not None),
                    tuple(x for x in topo if x is not None))
    # data round-trip + pads contribute no labelled nodes
    rd = remap_data(pipeline.train_data, plan)
    assert not np.asarray(rd.train_mask)[P:].any()
    assert _bitwise(unmap_data(rd, plan), pipeline.train_data)


def test_buffer_remap_matches_padded_init(pipeline):
    """remap_buffers(init(flat topo)) must shape-match init(remapped topo):
    the trainer builds one and restores into the other."""
    mc, pc = _cfgs(pipeline, staleness_steps=2)
    model = PipeGCN(mc, pc)
    plan = ElasticPlan(num_parts=P, orig_devices=P, survivors=(0, 2, 3))
    flat = model.init_buffers(pipeline.topo)
    padded = model.init_buffers(remap_topology(pipeline.topo, plan))
    got = remap_buffers(flat, plan)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(padded)):
        assert a.shape == b.shape and a.dtype == b.dtype
    assert _bitwise(unmap_buffers(got, plan), flat)


def test_warm_mark_touches_moved_rows_only():
    plan = ElasticPlan(num_parts=P, orig_devices=P, survivors=(0, 2, 3))
    moved = plan.moved_partitions()
    assert moved                       # the lost device's partition moved
    L = 3
    es = jnp.zeros((plan.padded_parts, 2, L, plan.padded_parts), jnp.int32)
    es = es.at[0, FWD, 0, 2].set(5)    # pre-existing deeper streak survives
    out = warm_mark({"es": es, "feat": (), "grad": ()}, moved, 1, P)["es"]
    out = np.array(out)
    assert out[0, FWD, 0, 2] == 5      # maximum, not overwrite
    out[0, FWD, 0, 2] = 0              # exclude it from the block checks
    for dst in range(plan.padded_parts):
        for src in range(plan.padded_parts):
            touched = ((dst in moved or src in moved)
                       and dst < P and src < P)
            assert (out[dst, :, :, src] == (1 if touched else 0)).all()
    # warm=0 and empty moved are no-ops
    bufs = {"es": es, "feat": (), "grad": ()}
    assert warm_mark(bufs, moved, 0, P) is bufs
    assert warm_mark(bufs, frozenset(), 1, P) is bufs


# ---------------------------------------------------------------------------
# detection
# ---------------------------------------------------------------------------

def test_detect_device_loss_whole_device_only():
    L = 3
    es = np.zeros((P, 2, L, P), np.int32)
    assert detect_device_loss(es, 1, P, 2) is None
    # scattered single-pair fault: never a device loss
    es[0, FWD, :, 1] = 9
    assert detect_device_loss(es, 1, P, 2) is None
    # every off-device forward dst hits the threshold -> device 1 down
    for dst in range(P):
        if dst != 1:
            es[dst, FWD, :, 1] = 2
    assert detect_device_loss(es, 1, P, 2) == 1
    # one healthy layer on one dst keeps it alive (min over the block)
    es[2, FWD, 1, 1] = 1
    assert detect_device_loss(es, 1, P, 2) is None


def test_detect_device_loss_multilocal_and_pads():
    """n_local=2 on a padded (6-part) layout: only real partitions count,
    and BOTH of a device's partitions must be blanketed."""
    L, pp = 2, 6       # plan (0,2,3) of P=4: pads 4,5 on survivor 2
    es = np.zeros((pp, 2, L, pp), np.int32)
    # survivor 0 hosts parts (0,1); its dsts are real parts 2,3
    es[2:4, FWD, :, 0] = 3
    assert detect_device_loss(es, 2, P, 2) is None     # part 1 still healthy
    es[2:4, FWD, :, 1] = 3
    assert detect_device_loss(es, 2, P, 2) == 0
    # backward-only streaks never trip detection
    es2 = np.zeros((pp, 2, L, pp), np.int32)
    es2[:, 1 - FWD] = 9
    assert detect_device_loss(es2, 2, P, 2) is None


# ---------------------------------------------------------------------------
# device_down fault compilation
# ---------------------------------------------------------------------------

def test_device_down_compiles_outbound_cross_device_window():
    plan_f = FaultPlan(sites=(device_down_site(step=2, device=1, until=4),))
    tab = plan_f.compile(6, 2, P, parts_per_device=2)   # 2 devices
    drop = np.asarray(tab.drop)
    on = np.zeros(P, bool)
    on[2:4] = True                                      # device 1's block
    want = np.outer(on, ~on)
    for t in range(6):
        if 2 <= t < 4:
            assert (drop[t] == want[None]).all()        # outbound only
        else:
            assert not drop[t].any()
    assert not np.asarray(tab.corrupt).any()
    assert plan_f.downed_devices(2) == frozenset({1})
    assert plan_f.downed_devices(4) == frozenset()
    assert plan_f.without_device_down().is_empty()


def test_device_down_site_validates():
    with pytest.raises(ValueError, match="until"):
        device_down_site(step=5, device=0, until=5)
    plan_f = FaultPlan(sites=(device_down_site(step=0, device=7),))
    with pytest.raises(ValueError, match="device"):
        plan_f.compile(4, 2, P, parts_per_device=1)


def test_mask_pad_faults_zeroes_pad_rows():
    plan_f = FaultPlan(sites=(device_down_site(step=0, device=1),))
    tab = mask_pad_faults(plan_f.compile(2, 2, 6, parts_per_device=2), P)
    drop = np.asarray(tab.drop)
    assert not drop[..., P:, :].any() and not drop[..., :, P:].any()
    assert drop[..., :P, :P].any()     # real sites survive the mask


# ---------------------------------------------------------------------------
# the drill: loss -> remap -> bitwise-identical recovery (sim backend)
# ---------------------------------------------------------------------------

EC = ElasticConfig(parts_per_device=1, rejoin=False)


def _drill_runs(pipeline, tmp_path):
    mc, pc = _cfgs(pipeline)
    plan_f = FaultPlan(sites=(device_down_site(step=5, device=1),))
    d_a = str(tmp_path / "a")
    res_a = train_pipegcn(pipeline, mc, pc, epochs=12, eval_every=1,
                          elastic=EC, faults=plan_f, ckpt_dir=d_a,
                          checkpoint_every=4)
    assert res_a.recoveries == 1
    loss = res_a.anomalies["device_losses"][0]
    assert loss["device"] == 1 and loss["survivors"] == [0, 2, 3]
    assert loss["resumed_from"] == 4
    # downtime bound: detection lands within detect_after steps of the kill
    assert loss["detected_epoch"] <= 5 + EC.detect_after
    # fresh survivor-layout launch from the SAME checkpoint
    plan = ElasticPlan(num_parts=P, orig_devices=P, survivors=(0, 2, 3))
    d_b = str(tmp_path / "b")
    os.makedirs(d_b)
    shutil.copytree(os.path.join(d_a, "step_00000004"),
                    os.path.join(d_b, "step_00000004"))
    res_b = train_pipegcn(pipeline, mc, pc, epochs=12, eval_every=1,
                          elastic=EC, elastic_plan=plan, ckpt_dir=d_b,
                          checkpoint_every=4, resume=True)
    return res_a, res_b


def test_sim_recovery_bitwise_equals_fresh_shrunk_run(pipeline, tmp_path):
    """THE gate: a mid-run recovery (restore + remap + warm-mark) and a
    fresh launch on the survivor layout from the same checkpoint produce
    bitwise-identical params and per-epoch histories."""
    res_a, res_b = _drill_runs(pipeline, tmp_path)
    assert _bitwise(res_a.params, res_b.params)
    ep = res_b.history["epoch"]
    for k in ("loss", "val_acc", "test_acc"):
        tail_a = [res_a.history[k][res_a.history["epoch"].index(e)]
                  for e in ep]
        assert tail_a == res_b.history[k]
    assert res_b.recoveries == 0 and res_b.resumed_from == 4


def test_zero_fault_elastic_is_bitwise_invisible(pipeline):
    """Armed-but-idle elasticity must not perturb a single bit."""
    mc, pc = _cfgs(pipeline)
    plain = train_pipegcn(pipeline, mc, pc, epochs=6, eval_every=2)
    armed = train_pipegcn(pipeline, mc, pc, epochs=6, eval_every=2,
                          elastic=EC)
    assert armed.recoveries == 0
    assert not armed.anomalies["device_losses"]
    assert _bitwise(plain.params, armed.params)
    assert plain.history == armed.history


def test_rejoin_scales_back_up_at_checkpoint(pipeline, tmp_path):
    """Bounded outage: device 2 down for steps [5, 9) -> recovery at the
    detection epoch, rejoin at the first checkpoint boundary after the
    device returns, run finishes on the full layout."""
    mc, pc = _cfgs(pipeline)
    ec = ElasticConfig(parts_per_device=1, rejoin=True)
    plan_f = FaultPlan(sites=(device_down_site(step=5, device=2, until=9),))
    res = train_pipegcn(pipeline, mc, pc, epochs=16, eval_every=2,
                        elastic=ec, faults=plan_f,
                        ckpt_dir=str(tmp_path), checkpoint_every=4)
    assert res.recoveries == 1
    assert res.anomalies["rejoins"] == 1
    assert res.final_metrics["val"] > 0.5


def test_recovery_budget_reraises(pipeline, tmp_path):
    """max_recoveries=0: the loss surfaces as DeviceLossError (still a
    StalenessExceededError) instead of recovering."""
    mc, pc = _cfgs(pipeline)
    ec = ElasticConfig(parts_per_device=1, max_recoveries=0)
    plan_f = FaultPlan(sites=(device_down_site(step=3, device=1),))
    with pytest.raises(DeviceLossError) as e:
        train_pipegcn(pipeline, mc, pc, epochs=8, eval_every=4,
                      elastic=ec, faults=plan_f,
                      ckpt_dir=str(tmp_path), checkpoint_every=2)
    assert e.value.device == 1 and e.value.survivors == (0, 2, 3)


def test_loss_before_first_checkpoint_is_fatal(pipeline, tmp_path):
    mc, pc = _cfgs(pipeline)
    plan_f = FaultPlan(sites=(device_down_site(step=0, device=1),))
    with pytest.raises(RuntimeError, match="first checkpoint"):
        train_pipegcn(pipeline, mc, pc, epochs=8, eval_every=4,
                      elastic=EC, faults=plan_f,
                      ckpt_dir=str(tmp_path), checkpoint_every=100)


def test_elastic_requires_guarded_exchange(pipeline):
    mc, pc = _cfgs(pipeline, guard_exchange=False)
    with pytest.raises(ValueError, match="guard_exchange"):
        train_pipegcn(pipeline, mc, pc, epochs=1, elastic=EC)


def test_plan_requires_enabled_elastic(pipeline):
    mc, pc = _cfgs(pipeline)
    plan = ElasticPlan(num_parts=P, orig_devices=P, survivors=(0, 2, 3))
    with pytest.raises(ValueError, match="ElasticConfig"):
        train_pipegcn(pipeline, mc, pc, epochs=1, elastic_plan=plan)


# ---------------------------------------------------------------------------
# collective counts on the shrunk layout
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fused", [True, False])
def test_remapped_step_collective_count(pipeline, fused):
    """The padded survivor layout issues exactly the boundary collectives
    the comm model prices — pads add zero collectives (they ride the same
    all_to_all slots, masked)."""
    from repro.core.trace_utils import (expected_boundary_collectives,
                                        traced_step_collectives)
    from repro.launch.mesh import make_partition_mesh
    mc, pc = _cfgs(pipeline, fuse_exchange=fused)
    model = PipeGCN(mc, pc)
    plan = ElasticPlan(num_parts=P, orig_devices=P, survivors=(0, 2, 3))
    topo_r, train_r, _ = pipeline.elastic_views(plan)
    mesh = make_partition_mesh(plan.padded_parts,
                               parts_per_device=plan.padded_parts)
    got = traced_step_collectives(model, mesh, topo_r, train_r, train=True)
    want = expected_boundary_collectives(mc.num_layers, fused, train=True)
    assert got["all_to_all"] == want, (got, want)


# ---------------------------------------------------------------------------
# SPMD drill (subprocess: forced host devices)
# ---------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses, shutil, tempfile
    import jax
    jax.config.update("jax_enable_x64", True)
    from repro.core import (ElasticConfig, ElasticPlan, FaultPlan,
                            ModelConfig, PipeConfig, device_down_site)
    from repro.core.trainer import train_pipegcn
    from repro.data import GraphDataPipeline
    from repro.launch.mesh import make_partition_mesh, make_survivor_mesh

    P = 4
    pipeline = GraphDataPipeline.build("tiny", P, kind="sage")
    ds = pipeline.dataset
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes, dropout=0.0)
    pc = dataclasses.replace(PipeConfig.named("pipegcn"),
                             guard_exchange=True, max_staleness=8)
    ec = ElasticConfig(parts_per_device=1, rejoin=False)
    plan_f = FaultPlan(sites=(device_down_site(step=3, device=1),))
    d_a = tempfile.mkdtemp()
    res_a = train_pipegcn(pipeline, mc, pc, epochs=8, eval_every=1,
                          mesh=make_partition_mesh(P, 1), elastic=ec,
                          faults=plan_f, ckpt_dir=d_a, checkpoint_every=2)
    assert res_a.recoveries == 1, res_a.recoveries
    loss = res_a.anomalies["device_losses"][0]
    assert loss["device"] == 1 and loss["survivors"] == [0, 2, 3], loss
    plan = ElasticPlan(num_parts=P, orig_devices=P, survivors=(0, 2, 3))
    d_b = tempfile.mkdtemp()
    step_dir = "step_%08d" % loss["resumed_from"]
    shutil.copytree(os.path.join(d_a, step_dir), os.path.join(d_b, step_dir))
    res_b = train_pipegcn(pipeline, mc, pc, epochs=8, eval_every=1,
                          mesh=make_survivor_mesh(plan), elastic=ec,
                          elastic_plan=plan, ckpt_dir=d_b,
                          checkpoint_every=2, resume=True)
    same = all(bool((a == b).all()) for a, b in
               zip(jax.tree.leaves(res_a.params),
                   jax.tree.leaves(res_b.params)))
    assert same, "post-remap SPMD params != fresh shrunk-mesh run"
    assert res_a.history["loss"][-len(res_b.history["loss"]):] \\
        == res_b.history["loss"]
    print("SPMD_ELASTIC_OK")
""")


@pytest.mark.slow
def test_spmd_elastic_drill_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SPMD_ELASTIC_OK" in proc.stdout
