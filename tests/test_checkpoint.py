"""Atomic checkpoint/restore and bit-exact resume (ISSUE 9 satellites).

`save_checkpoint` must be crash-safe (stage + fsync + rename; no torn
`step_N` is ever visible to `latest_step`), `restore_checkpoint` must be
strict (treedef + per-leaf dtype validated, errors naming the offending
leaf path), and the trainer's checkpoint/resume loop must be BIT-EXACT:
an interrupted run resumed from disk produces the same floats as an
uninterrupted one. Real PipeGCN state — k-step staleness FIFOs, EMA
buffers, es counters, bf16 leaves — round-trips bitwise; the SPMD
save → sim restore cell lives in a subprocess so only it sees forced
host devices.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.core.trainer import train_pipegcn
from repro.data import GraphDataPipeline

P = 4


@pytest.fixture(scope="module")
def pipeline():
    return GraphDataPipeline.build("tiny", P, seed=0)


def _cfgs(pipeline, **pipe_kw):
    ds = pipeline.dataset
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes, dropout=0.0)
    pc = dataclasses.replace(PipeConfig.named("pipegcn"), **pipe_kw)
    return mc, pc


# ---------------------------------------------------------------------------
# atomicity + validation
# ---------------------------------------------------------------------------

def test_save_is_atomic_no_tmp_left(tmp_path):
    d = str(tmp_path)
    path = save_checkpoint(d, 3, {"w": jnp.arange(4.0)})
    assert os.path.isdir(path)
    assert not any(n.endswith(".tmp") for n in os.listdir(d))
    assert latest_step(d) == 3


def test_latest_step_ignores_tmp_and_junk(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 2, {"w": jnp.zeros(2)})
    # a crashed save's staging dir + unrelated noise must be invisible
    os.makedirs(os.path.join(d, "step_00000099.tmp"))
    os.makedirs(os.path.join(d, "step_xyz"))
    open(os.path.join(d, "notes.txt"), "w").close()
    assert latest_step(d) == 2
    got = restore_checkpoint(d, None, {"w": jnp.zeros(2)})
    assert (np.asarray(got["w"]) == 0).all()


def test_save_clears_leftover_tmp_and_overwrites(tmp_path):
    d = str(tmp_path)
    # leftover staging dir from a crashed save at the SAME step
    junk = os.path.join(d, "step_00000001.tmp")
    os.makedirs(junk)
    open(os.path.join(junk, "arrays.npz"), "w").close()
    save_checkpoint(d, 1, {"w": jnp.ones(3)})
    got = restore_checkpoint(d, 1, {"w": jnp.zeros(3)})
    assert (np.asarray(got["w"]) == 1).all()
    save_checkpoint(d, 1, {"w": jnp.full((3,), 2.0)})   # overwrite=True
    got = restore_checkpoint(d, 1, {"w": jnp.zeros(3)})
    assert (np.asarray(got["w"]) == 2).all()
    with pytest.raises(FileExistsError):
        save_checkpoint(d, 1, {"w": jnp.ones(3)}, overwrite=False)


def test_restore_validates_treedef_same_leaf_count(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, {"a": jnp.zeros(2), "b": jnp.ones(3)})
    with pytest.raises(ValueError, match="treedef"):
        restore_checkpoint(d, 0, {"a": jnp.zeros(2), "c": jnp.ones(3)})


def test_restore_validates_leaf_count(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, {"a": jnp.zeros(2)})
    with pytest.raises(ValueError, match="leaves"):
        restore_checkpoint(d, 0, {"a": jnp.zeros(2), "b": jnp.ones(3)})


def test_restore_validates_dtype_naming_leaf(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, {"outer": {"weights": jnp.zeros(2, jnp.float32),
                                     "steps": jnp.zeros((), jnp.int32)}})
    bad = {"outer": {"weights": jnp.zeros(2, jnp.float32),
                     "steps": jnp.zeros((), jnp.int64)}}
    with pytest.raises(ValueError) as e:
        restore_checkpoint(d, 0, bad)
    assert "steps" in str(e.value) and "dtype" in str(e.value)


def test_restore_validates_shape_naming_leaf(tmp_path):
    d = str(tmp_path)
    save_checkpoint(d, 0, {"weights": jnp.zeros((2, 3))})
    with pytest.raises(ValueError) as e:
        restore_checkpoint(d, 0, {"weights": jnp.zeros((3, 2))})
    assert "weights" in str(e.value) and "shape" in str(e.value)


def test_restore_missing_dir(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), None, {"w": jnp.zeros(1)})


# ---------------------------------------------------------------------------
# retry + retention (ISSUE 10 satellites)
# ---------------------------------------------------------------------------

def test_save_retries_transient_oserror(tmp_path, monkeypatch):
    """A twice-flaky os.replace (transient filesystem error) still lands a
    complete, restorable checkpoint on the third attempt — and the retries
    restage from scratch, so nothing torn is ever visible."""
    import repro.checkpoint.checkpoint as ckpt_mod
    real_replace = os.replace
    fails = {"n": 0}

    def flaky(src, dst):
        if fails["n"] < 2:
            fails["n"] += 1
            raise OSError("injected transient failure")
        return real_replace(src, dst)

    monkeypatch.setattr(ckpt_mod.os, "replace", flaky)
    monkeypatch.setattr(ckpt_mod.time, "sleep", lambda _s: None)
    d = str(tmp_path)
    save_checkpoint(d, 1, {"w": jnp.arange(3.0)})
    assert fails["n"] == 2
    assert latest_step(d) == 1
    got = restore_checkpoint(d, 1, {"w": jnp.zeros(3)})
    assert (np.asarray(got["w"]) == np.arange(3.0)).all()


def test_save_retry_budget_exhausts(tmp_path, monkeypatch):
    """Permanent failure: the original OSError surfaces after `retries`
    attempts and no committed step dir exists."""
    import repro.checkpoint.checkpoint as ckpt_mod
    calls = {"n": 0}

    def broken(_src, _dst):
        calls["n"] += 1
        raise OSError("disk on fire")

    monkeypatch.setattr(ckpt_mod.os, "replace", broken)
    monkeypatch.setattr(ckpt_mod.time, "sleep", lambda _s: None)
    with pytest.raises(OSError, match="disk on fire"):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)}, retries=3)
    assert calls["n"] == 3
    assert latest_step(str(tmp_path)) is None


def test_save_does_not_retry_fileexists(tmp_path, monkeypatch):
    """FileExistsError under overwrite=False is a caller error, not a
    transient fault: exactly one attempt, no sleeping."""
    import repro.checkpoint.checkpoint as ckpt_mod

    def no_sleep(_s):
        raise AssertionError("must not back off on FileExistsError")

    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)})
    monkeypatch.setattr(ckpt_mod.time, "sleep", no_sleep)
    with pytest.raises(FileExistsError):
        save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros(2)},
                        overwrite=False)


def test_keep_last_prunes_committed_only(tmp_path):
    """keep_last retention: oldest committed dirs go, the newest N stay,
    interleaved `.tmp` staging leftovers neither count toward the budget
    nor shadow `latest_step`, and orphan `.tmp`s of SURVIVING steps are
    left alone (a concurrent save may own them)."""
    d = str(tmp_path)
    for s in (1, 2, 3):
        save_checkpoint(d, s, {"w": jnp.full((2,), float(s))})
    # interleaved staging leftovers: one for a pruned step, one orphan
    os.makedirs(os.path.join(d, "step_00000001.tmp"))
    os.makedirs(os.path.join(d, "step_00000007.tmp"))
    save_checkpoint(d, 4, {"w": jnp.full((2,), 4.0)}, keep_last=2)
    names = set(os.listdir(d))
    assert names == {"step_00000003", "step_00000004",
                     "step_00000007.tmp"}, names
    assert latest_step(d) == 4
    got = restore_checkpoint(d, None, {"w": jnp.zeros(2)})
    assert (np.asarray(got["w"]) == 4.0).all()


def test_keep_last_never_prunes_just_written(tmp_path):
    """Even a save whose step number sorts OLDEST keeps its own dir —
    pruning must never eat the checkpoint that was just committed."""
    d = str(tmp_path)
    for s in (5, 6):
        save_checkpoint(d, s, {"w": jnp.zeros(1)})
    save_checkpoint(d, 2, {"w": jnp.ones(1)}, keep_last=1)
    names = {n for n in os.listdir(d) if n.startswith("step_")}
    assert "step_00000002" in names
    with pytest.raises(ValueError, match="keep_last"):
        save_checkpoint(d, 9, {"w": jnp.zeros(1)}, keep_last=0)


def test_trainer_checkpoint_keep(tmp_path, pipeline):
    """checkpoint_keep threads through the trainer loop: only the newest
    N step dirs survive a run, and the retained latest restores."""
    mc, pc = _cfgs(pipeline)
    d = str(tmp_path)
    train_pipegcn(pipeline, mc, pc, epochs=8, eval_every=4,
                  ckpt_dir=d, checkpoint_every=2, checkpoint_keep=2)
    names = sorted(n for n in os.listdir(d) if n.startswith("step_"))
    assert names == ["step_00000006", "step_00000008"]


# ---------------------------------------------------------------------------
# graceful preemption (SIGTERM/SIGINT)
# ---------------------------------------------------------------------------

def test_sigterm_finishes_epoch_checkpoints_and_resumes_bitwise(
        tmp_path, pipeline):
    """SIGTERM mid-run: the in-flight epoch completes, a final checkpoint
    lands, the result is flagged `preempted` — and resuming reproduces the
    uninterrupted run bitwise. The signal is raised from the `log`
    callback after a fixed number of epoch lines, so delivery is
    deterministic (handled on the next loop iteration's bytecode)."""
    import signal
    mc, pc = _cfgs(pipeline, guard_exchange=True)
    full = train_pipegcn(pipeline, mc, pc, epochs=6, eval_every=1)
    seen = {"epochs": 0}

    def kill_after_three(line):
        if line.startswith("epoch "):
            seen["epochs"] += 1
            if seen["epochs"] == 3:
                os.kill(os.getpid(), signal.SIGTERM)

    d = str(tmp_path)
    res = train_pipegcn(pipeline, mc, pc, epochs=6, eval_every=1,
                        log=kill_after_three, ckpt_dir=d,
                        checkpoint_every=100)
    assert res.preempted
    assert res.history["epoch"] == [0, 1, 2]
    assert latest_step(d) == 3          # final checkpoint despite every=100
    # the process-level handler was restored, not left pointing at the
    # trainer's accumulator
    assert signal.getsignal(signal.SIGTERM) is not None
    res2 = train_pipegcn(pipeline, mc, pc, epochs=6, eval_every=1,
                         ckpt_dir=d, checkpoint_every=100, resume=True)
    assert res2.resumed_from == 3 and not res2.preempted
    for a, b in zip(jax.tree.leaves(res2.params),
                    jax.tree.leaves(full.params)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert res2.final_metrics == full.final_metrics


def test_sigint_without_checkpointing_still_exits_cleanly(tmp_path, pipeline):
    """Preemption with no ckpt_dir configured: no crash, clean early
    return with preempted=True and the completed-epoch history."""
    import signal
    mc, pc = _cfgs(pipeline)
    seen = {"epochs": 0}

    def kill_after_two(line):
        if line.startswith("epoch "):
            seen["epochs"] += 1
            if seen["epochs"] == 2:
                os.kill(os.getpid(), signal.SIGINT)

    res = train_pipegcn(pipeline, mc, pc, epochs=6, eval_every=1,
                        log=kill_after_two)
    assert res.preempted
    assert res.history["epoch"] == [0, 1]


# ---------------------------------------------------------------------------
# real PipeGCN state round-trips
# ---------------------------------------------------------------------------

def test_roundtrip_pipegcn_fifo_guard_state(tmp_path, pipeline):
    """k=2 staleness FIFOs + guard es counters, saved mid-run: restore is
    bitwise AND the next step from the restored state is bitwise too."""
    mc, pc = _cfgs(pipeline, staleness_steps=2, guard_exchange=True)
    model = PipeGCN(mc, pc)
    topo, data = pipeline.topo, pipeline.train_data
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = model.init_buffers(topo, dtype=jnp.float64)
    for t in range(2):
        _, _, bufs, _ = model.train_step(topo, params, bufs, data,
                                         jax.random.PRNGKey(t))
    state = {"params": params, "buffers": bufs, "key": jax.random.PRNGKey(9)}
    save_checkpoint(str(tmp_path), 2, state)
    template = jax.tree.map(jnp.zeros_like, state)
    got = restore_checkpoint(str(tmp_path), 2, template)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(state)):
        assert a.dtype == b.dtype
        assert (np.asarray(a) == np.asarray(b)).all()
    # FIFO queue axis survived (k=2 leading axis on the feat buffers)
    assert got["buffers"]["feat"][0].shape[0] == 2
    assert got["buffers"]["es"].dtype == jnp.int32
    l0, g0, b0, _ = model.train_step(topo, state["params"], state["buffers"],
                                     data, state["key"])
    l1, g1, b1, _ = model.train_step(topo, got["params"], got["buffers"],
                                     data, got["key"])
    assert float(l0) == float(l1)
    for a, b in zip(jax.tree.leaves((g0, b0)), jax.tree.leaves((g1, b1))):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_roundtrip_ema_state(tmp_path, pipeline):
    """pipegcn-gf EMA buffers round-trip bitwise after real steps."""
    mc, pc = _cfgs(pipeline)
    pc = dataclasses.replace(PipeConfig.named("pipegcn-gf", gamma=0.9))
    model = PipeGCN(mc, pc)
    topo, data = pipeline.topo, pipeline.train_data
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = model.init_buffers(topo, dtype=jnp.float64)
    for t in range(3):
        _, _, bufs, _ = model.train_step(topo, params, bufs, data,
                                         jax.random.PRNGKey(t))
    save_checkpoint(str(tmp_path), 3, bufs)
    got = restore_checkpoint(str(tmp_path), 3,
                             jax.tree.map(jnp.zeros_like, bufs))
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(bufs)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_roundtrip_bf16_leaves(tmp_path):
    """bf16 leaves (no native numpy dtype — stored as uint16 views)
    round-trip bitwise, mixed with f32/int leaves in one tree."""
    key = jax.random.PRNGKey(0)
    state = {"h": jax.random.normal(key, (8, 5)).astype(jnp.bfloat16),
             "w": jax.random.normal(key, (4,), dtype=jnp.float32),
             "n": jnp.arange(3, dtype=jnp.int32)}
    save_checkpoint(str(tmp_path), 0, state)
    got = restore_checkpoint(str(tmp_path), 0,
                             jax.tree.map(jnp.zeros_like, state))
    assert got["h"].dtype == jnp.bfloat16
    assert (np.asarray(got["h"]).view(np.uint16)
            == np.asarray(state["h"]).view(np.uint16)).all()
    assert (np.asarray(got["w"]) == np.asarray(state["w"])).all()
    assert (np.asarray(got["n"]) == np.asarray(state["n"])).all()


# ---------------------------------------------------------------------------
# trainer kill-and-resume
# ---------------------------------------------------------------------------

def test_trainer_resume_is_bit_exact(tmp_path, pipeline):
    """6 uninterrupted epochs == 3 epochs + kill + resume for 3 more:
    params bitwise, histories of the resumed tail matching."""
    mc, pc = _cfgs(pipeline, guard_exchange=True)
    full = train_pipegcn(pipeline, mc, pc, epochs=6, eval_every=1)
    d = str(tmp_path / "ckpt")
    train_pipegcn(pipeline, mc, pc, epochs=3, eval_every=1,
                  ckpt_dir=d, checkpoint_every=3)
    assert latest_step(d) == 3
    res = train_pipegcn(pipeline, mc, pc, epochs=6, eval_every=1,
                        ckpt_dir=d, checkpoint_every=3, resume=True)
    assert res.resumed_from == 3
    assert res.history["epoch"] == [3, 4, 5]
    for i, e in enumerate(res.history["epoch"]):
        j = full.history["epoch"].index(e)
        assert res.history["loss"][i] == full.history["loss"][j]
    for a, b in zip(jax.tree.leaves(res.params),
                    jax.tree.leaves(full.params)):
        assert (np.asarray(a) == np.asarray(b)).all()
    assert res.final_metrics == full.final_metrics


def test_trainer_resume_requires_ckpt_dir(pipeline):
    mc, pc = _cfgs(pipeline)
    with pytest.raises(ValueError, match="ckpt_dir"):
        train_pipegcn(pipeline, mc, pc, epochs=1, resume=True)


def test_trainer_resume_empty_dir_starts_fresh(tmp_path, pipeline):
    mc, pc = _cfgs(pipeline)
    res = train_pipegcn(pipeline, mc, pc, epochs=2, eval_every=1,
                        ckpt_dir=str(tmp_path / "empty"), resume=True)
    assert res.resumed_from is None
    assert res.history["epoch"] == [0, 1]


# ---------------------------------------------------------------------------
# SPMD save -> sim restore (subprocess: forced host devices)
# ---------------------------------------------------------------------------

SPMD_SCRIPT = textwrap.dedent("""
    import os, sys, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import dataclasses
    import jax, numpy as np
    jax.config.update("jax_enable_x64", True)
    import jax.numpy as jnp
    from repro.checkpoint import restore_checkpoint, save_checkpoint
    from repro.core.config import ModelConfig, PipeConfig
    from repro.core.pipegcn import PipeGCN, topology_from, shard_data
    from repro.graph import (build_partitioned_graph, make_dataset,
                             partition_graph)
    from repro.graph.csr import mean_normalized
    from repro.launch.mesh import make_partition_mesh

    P = 4
    ds = make_dataset("tiny")
    prop = mean_normalized(ds.graph)
    pg = build_partitioned_graph(prop, partition_graph(ds.graph, P, seed=0), P)
    topo = topology_from(pg, with_tiles=True)
    topo = topo._replace(edge_w=topo.edge_w.astype(jnp.float64))
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes, dropout=0.0)
    pc = dataclasses.replace(PipeConfig.named("pipegcn"),
                             staleness_steps=2, guard_exchange=True)
    model = PipeGCN(mc, pc)
    mesh = make_partition_mesh(P, parts_per_device=2)
    spmd = model.make_spmd_step(mesh, topo, train=True)
    params = model.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = model.init_buffers(topo, dtype=jnp.float64)
    # two SPMD steps, then checkpoint the (sharded) state
    for t in range(2):
        _, _, _, bufs = spmd(topo, params, bufs, data, jax.random.PRNGKey(t))
    d = tempfile.mkdtemp()
    save_checkpoint(d, 2, {"params": params, "buffers": bufs})
    got = restore_checkpoint(
        d, 2, {"params": jax.tree.map(jnp.zeros_like, params),
               "buffers": model.init_buffers(topo, dtype=jnp.float64)})
    # next step on the SIM backend from the restored state vs the SPMD
    # backend from the live state: cross-backend parity bar (1e-12)
    l_sim, g_sim, b_sim, _ = model.train_step(
        topo, got["params"], got["buffers"], data, jax.random.PRNGKey(5))
    l_spmd, _, g_spmd, b_spmd = spmd(topo, params, bufs, data,
                                     jax.random.PRNGKey(5))
    assert abs(float(l_sim) - float(l_spmd)) < 1e-12, (l_sim, l_spmd)
    for k in g_sim:
        dmax = float(jnp.abs(g_sim[k] - g_spmd[k]).max())
        assert dmax < 1e-12, (k, dmax)
    es_sim = np.asarray(b_sim["es"]); es_spmd = np.asarray(b_spmd["es"])
    assert (es_sim == es_spmd).all()
    for a, b in zip(jax.tree.leaves(b_sim["feat"]),
                    jax.tree.leaves(b_spmd["feat"])):
        dmax = float(jnp.abs(jnp.asarray(a) - jnp.asarray(b)).max())
        assert dmax < 1e-12, dmax
    print("SPMD_CKPT_OK")
""")


@pytest.mark.slow
def test_spmd_save_sim_restore_subprocess():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", SPMD_SCRIPT], env=env,
                          capture_output=True, text=True, timeout=600)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "SPMD_CKPT_OK" in proc.stdout
