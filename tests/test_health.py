"""Numerical health guard: jitted verdict, bitwise rollback, escalation.

The guard's contract mirrors the exchange guard's: INVISIBLE when healthy
(a guarded step returns bit-identical params/opt_state/buffers to an
unguarded one), a pure select when not (the poisoned update is discarded
and the previous state survives bitwise), and loud once the run can no
longer make progress (TrainingAnomalyError after N consecutive skips).
Also covers the final-eval-reuse satellite: `train_pipegcn` must not
re-run the eval forward pass when the last epoch already evaluated.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core.config import ModelConfig, PipeConfig
from repro.core.health import (HealthConfig, TrainingAnomalyError,
                               health_check, tree_select)
from repro.core.pipegcn import PipeGCN
from repro.core.trainer import make_jitted_train_step, train_pipegcn
from repro.data import GraphDataPipeline
from repro.optim import adam

P = 4


@pytest.fixture(scope="module")
def pipeline():
    return GraphDataPipeline.build("tiny", P, seed=0)


def _model(pipeline, **pipe_kw):
    ds = pipeline.dataset
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=2, num_classes=ds.num_classes, dropout=0.0)
    pc = dataclasses.replace(PipeConfig.named("pipegcn"), **pipe_kw)
    return PipeGCN(mc, pc)


# ---------------------------------------------------------------------------
# health_check verdicts
# ---------------------------------------------------------------------------

def test_health_check_finite_ok():
    grads = {"w": jnp.ones((3, 3)), "b": jnp.zeros((3,))}
    rep = health_check(jnp.float32(0.5), grads)
    assert bool(rep["ok"])
    assert float(rep["grad_norm"]) == pytest.approx(3.0)


@pytest.mark.parametrize("bad", [jnp.nan, jnp.inf, -jnp.inf])
def test_health_check_nonfinite_loss(bad):
    rep = health_check(jnp.float32(bad), {"w": jnp.ones(2)})
    assert not bool(rep["ok"])


def test_health_check_nonfinite_grad_leaf():
    grads = {"w": jnp.ones((2, 2)), "b": jnp.array([1.0, jnp.nan])}
    rep = health_check(jnp.float32(0.1), grads)
    assert not bool(rep["ok"])


def test_health_check_buffers():
    grads = {"w": jnp.ones(2)}
    bufs = {"feat": (jnp.ones((P, 3)),), "es": jnp.zeros((P,), jnp.int32)}
    assert bool(health_check(jnp.float32(0.1), grads, bufs)["ok"])
    bufs["feat"] = (bufs["feat"][0].at[0, 0].set(jnp.inf),)
    assert not bool(health_check(jnp.float32(0.1), grads, bufs)["ok"])
    # integer leaves (the es counters) are exempt from finiteness — an
    # int32 has no Inf and must not break the predicate
    assert bool(health_check(jnp.float32(0.1), grads,
                             {"es": jnp.full((2,), 2**31 - 1, jnp.int32)}
                             )["ok"])


def test_health_check_grad_norm_limit():
    grads = {"w": jnp.full((4,), 10.0)}
    assert bool(health_check(jnp.float32(0.1), grads)["ok"])
    rep = health_check(jnp.float32(0.1), grads, grad_norm_limit=1.0)
    assert not bool(rep["ok"])
    assert bool(health_check(jnp.float32(0.1), grads,
                             grad_norm_limit=100.0)["ok"])


def test_tree_select_bitwise():
    a = {"x": jnp.array([1.0, 2.0]), "y": (jnp.int32(3),)}
    b = {"x": jnp.array([-1.0, -2.0]), "y": (jnp.int32(-3),)}
    t = tree_select(jnp.bool_(True), a, b)
    f = tree_select(jnp.bool_(False), a, b)
    for got, want in zip(jax.tree.leaves(t), jax.tree.leaves(a)):
        assert (np.asarray(got) == np.asarray(want)).all()
    for got, want in zip(jax.tree.leaves(f), jax.tree.leaves(b)):
        assert (np.asarray(got) == np.asarray(want)).all()


def test_health_config_validation():
    with pytest.raises(ValueError):
        HealthConfig(grad_norm_limit=0.0)
    with pytest.raises(ValueError):
        HealthConfig(grad_norm_limit=-1.0)
    with pytest.raises(ValueError):
        HealthConfig(max_consecutive_anomalies=0)
    HealthConfig(grad_norm_limit=None)


# ---------------------------------------------------------------------------
# guarded step: invisible when healthy, pure rollback when not
# ---------------------------------------------------------------------------

def test_guarded_step_healthy_is_bitwise_unguarded(pipeline):
    model = _model(pipeline)
    topo, data = pipeline.topo, pipeline.train_data
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adam(0.01)
    opt_state = opt.init(params)
    plain = make_jitted_train_step(model, opt)
    guard = make_jitted_train_step(model, opt, health=HealthConfig())
    key = jax.random.PRNGKey(1)
    l0, p0, s0, b0 = plain(topo, params, opt_state,
                           model.init_buffers(topo), data, key)
    l1, p1, s1, b1, rep = guard(topo, params, opt_state,
                                model.init_buffers(topo), data, key)
    assert bool(rep["ok"])
    assert float(l0) == float(l1)
    for a, b in zip(jax.tree.leaves((p0, s0, b0)),
                    jax.tree.leaves((p1, s1, b1))):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_guarded_step_rolls_back_on_nan(pipeline):
    model = _model(pipeline)
    topo, data = pipeline.topo, pipeline.train_data
    data = data._replace(x=jnp.full_like(data.x, jnp.nan))
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adam(0.01)
    opt_state = opt.init(params)
    step = make_jitted_train_step(model, opt, health=HealthConfig())
    # host copies first: buffers are donated into the step
    want = jax.tree.map(np.asarray, (params, opt_state))
    want_buf = jax.tree.map(np.asarray, model.init_buffers(topo))
    loss, p1, s1, b1, rep = step(topo, params, opt_state,
                                 model.init_buffers(topo), data,
                                 jax.random.PRNGKey(1))
    assert not bool(rep["ok"])
    assert not np.isfinite(float(loss))
    for a, b in zip(jax.tree.leaves((p1, s1)), jax.tree.leaves(want)):
        assert (np.asarray(a) == b).all()
    for a, b in zip(jax.tree.leaves(b1), jax.tree.leaves(want_buf)):
        assert (np.asarray(a) == b).all()


def test_guarded_step_grad_norm_limit_rolls_back(pipeline):
    model = _model(pipeline)
    topo, data = pipeline.topo, pipeline.train_data
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adam(0.01)
    opt_state = opt.init(params)
    step = make_jitted_train_step(
        model, opt, health=HealthConfig(grad_norm_limit=1e-12))
    want = jax.tree.map(np.asarray, params)
    loss, p1, _, _, rep = step(topo, params, opt_state,
                               model.init_buffers(topo), data,
                               jax.random.PRNGKey(1))
    assert np.isfinite(float(loss))        # the step itself is fine...
    assert not bool(rep["ok"])             # ...but over the bound
    assert float(rep["grad_norm"]) > 1e-12
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(want)):
        assert (np.asarray(a) == b).all()


# ---------------------------------------------------------------------------
# trainer loop: counting, escalation, opt-out, final-eval reuse
# ---------------------------------------------------------------------------

def _cfgs(pipeline, **pipe_kw):
    ds = pipeline.dataset
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=2, num_classes=ds.num_classes, dropout=0.0)
    pc = dataclasses.replace(PipeConfig.named("pipegcn"), **pipe_kw)
    return mc, pc


def test_trainer_healthy_run_counts_zero(pipeline):
    mc, pc = _cfgs(pipeline)
    res = train_pipegcn(pipeline, mc, pc, epochs=3, eval_every=2)
    assert res.anomalies["skipped_steps"] == 0
    assert res.anomalies["max_consecutive"] == 0
    assert res.resumed_from is None


def test_trainer_escalates_on_poisoned_run(pipeline):
    mc, pc = _cfgs(pipeline)
    poisoned = dataclasses.replace(
        pipeline,
        train_data=pipeline.train_data._replace(
            x=jnp.full_like(pipeline.train_data.x, jnp.nan)))
    with pytest.raises(TrainingAnomalyError, match="3 consecutive"):
        train_pipegcn(poisoned, mc, pc, epochs=10, eval_every=100,
                      health=HealthConfig(max_consecutive_anomalies=3))


def test_trainer_health_optout_keeps_running(pipeline):
    mc, pc = _cfgs(pipeline)
    poisoned = dataclasses.replace(
        pipeline,
        train_data=pipeline.train_data._replace(
            x=jnp.full_like(pipeline.train_data.x, jnp.nan)))
    res = train_pipegcn(poisoned, mc, pc, epochs=3, eval_every=100,
                        health=HealthConfig(enabled=False))
    assert res.anomalies["skipped_steps"] == 0   # nobody counted
    assert not np.isfinite(res.history["loss"][-1])


def test_trainer_default_health_skips_and_reports(pipeline):
    """Default policy (health=None -> HealthConfig()): a poisoned run
    below the escalation bound finishes, every step skipped, params
    bitwise at their init values."""
    mc, pc = _cfgs(pipeline)
    poisoned = dataclasses.replace(
        pipeline,
        train_data=pipeline.train_data._replace(
            x=jnp.full_like(pipeline.train_data.x, jnp.nan)))
    res = train_pipegcn(poisoned, mc, pc, epochs=4, eval_every=2)
    assert res.anomalies["skipped_steps"] == 4
    assert res.anomalies["max_consecutive"] == 4
    model = PipeGCN(mc, pc)
    init = model.init_params(jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(res.params), jax.tree.leaves(init)):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_trainer_reuses_last_epoch_eval(pipeline):
    """The final metric is the last epoch's eval (the loop always
    evaluates epoch == epochs-1), never a duplicate forward pass."""
    mc, pc = _cfgs(pipeline)
    calls = []
    counted = dataclasses.replace(pipeline)
    orig = pipeline.metric

    def counting_metric(logits):
        m = orig(logits)
        calls.append(m)
        return m

    counted.metric = counting_metric
    res = train_pipegcn(counted, mc, pc, epochs=5, eval_every=2)
    # evals at epochs 0, 2, 4 — and 4 == epochs-1 doubles as the final
    assert len(calls) == 3
    assert res.final_metrics is calls[-1]
    assert res.history["epoch"] == [0, 2, 4]
