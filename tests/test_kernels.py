"""Pallas kernels vs pure-jnp oracles: property sweeps over shapes, dtypes,
densities, and masking modes (interpret mode on CPU). Sweeps use hypothesis
when installed, else the deterministic fallback in _hypothesis_compat."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.kernels import ops
from repro.kernels.gcn_spmm import TILE, build_tiles, spmm_block_sparse
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import mha_ref, spmm_ref

ATOL = {jnp.float32: 2e-5, jnp.bfloat16: 5e-2}


# ------------------------------------------------------------------ SpMM

@settings(max_examples=12, deadline=None)
@given(rb=st.integers(1, 3), cb=st.integers(1, 3),
       fmul=st.integers(1, 2), density=st.floats(0.005, 0.08),
       seed=st.integers(0, 100))
def test_spmm_sweep(rb, cb, fmul, density, seed):
    rng = np.random.default_rng(seed)
    R, C, F = rb * TILE, cb * TILE, fmul * 128
    dense = ((rng.random((R, C)) < density)
             * rng.normal(size=(R, C))).astype(np.float32)
    h = rng.normal(size=(C, F)).astype(np.float32)
    tr, tc, tv = build_tiles(dense, R, C)
    got = spmm_block_sparse(jnp.asarray(tr), jnp.asarray(tc), jnp.asarray(tv),
                            jnp.asarray(h), R)
    np.testing.assert_allclose(np.asarray(got), dense @ h, atol=2e-4)
    ref = spmm_ref(jnp.asarray(tr), jnp.asarray(tc), jnp.asarray(tv),
                   jnp.asarray(h), R)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_spmm_empty_row_blocks():
    """Row blocks with no edges must produce zeros (filler-tile path)."""
    rng = np.random.default_rng(0)
    R, C, F = 3 * TILE, 2 * TILE, 128
    dense = np.zeros((R, C), np.float32)
    dense[:TILE] = (rng.random((TILE, C)) < 0.05) * 1.0   # only block-row 0
    h = rng.normal(size=(C, F)).astype(np.float32)
    tr, tc, tv = build_tiles(dense, R, C)
    got = np.asarray(spmm_block_sparse(jnp.asarray(tr), jnp.asarray(tc),
                                       jnp.asarray(tv), jnp.asarray(h), R))
    np.testing.assert_allclose(got, dense @ h, atol=2e-4)
    assert np.all(got[TILE:] == 0)


def test_spmm_real_graph_partition():
    """End to end: a real partition's local propagation as block-sparse."""
    from repro.graph import make_dataset, partition_graph, build_partitioned_graph
    from repro.graph.csr import sym_normalized
    ds = make_dataset("tiny")
    prop = sym_normalized(ds.graph)
    pg = build_partitioned_graph(prop, partition_graph(ds.graph, 2, seed=0), 2)
    i = 0
    row = pg.edge_row[i].astype(np.int64)
    col = pg.edge_col[i].astype(np.int64)
    w = pg.edge_w[i]
    combined = pg.max_inner + pg.num_parts * pg.slot
    rng = np.random.default_rng(1)
    h = rng.normal(size=(-(-combined // TILE) * TILE, 128)).astype(np.float32)
    tr, tc, tv = build_tiles((row, col, w), pg.max_inner, combined)
    rpad = -(-pg.max_inner // TILE) * TILE
    got = np.asarray(ops.spmm(jnp.asarray(tr), jnp.asarray(tc),
                              jnp.asarray(tv), jnp.asarray(h), rpad))
    want = np.zeros((rpad, 128), np.float32)
    np.add.at(want, row, w[:, None] * h[col])
    np.testing.assert_allclose(got, want, atol=2e-4)


# ------------------------------------------------------------ attention

@settings(max_examples=12, deadline=None)
@given(b=st.integers(1, 2), smul=st.integers(1, 3),
       h=st.sampled_from([2, 4]), g=st.sampled_from([1, 2]),
       d=st.sampled_from([32, 64]),
       causal=st.booleans(), windowed=st.booleans(),
       seed=st.integers(0, 100))
def test_flash_attention_sweep(b, smul, h, g, d, causal, windowed, seed):
    rng = np.random.default_rng(seed)
    S = smul * 256
    kh = h // g
    window = 192 if windowed else 0
    q = jnp.asarray(rng.normal(size=(b, S, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, S, kh, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, S, kh, d)), jnp.float32)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          q_block=128, kv_block=128)
    want = mha_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)


def test_flash_attention_bf16():
    rng = np.random.default_rng(3)
    B, S, H, d = 1, 256, 4, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.bfloat16)
    got = flash_attention(q, k, v, causal=True, q_block=128, kv_block=128)
    want = mha_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                   v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=5e-2)


def test_flash_matches_model_blockwise_path():
    """Kernel vs the model's jnp blockwise path (the serving oracle)."""
    from repro.models.attention import blockwise_attention
    rng = np.random.default_rng(4)
    B, S, H, K, d = 1, 512, 4, 2, 64
    q = jnp.asarray(rng.normal(size=(B, S, H, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, S, K, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, S, K, d)), jnp.float32)
    a = flash_attention(q, k, v, causal=True, window=100,
                        q_block=128, kv_block=128)
    b_ = blockwise_attention(q, k, v, jnp.arange(S), True, 100,
                             q_block=128, kv_block=128)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5)


def test_ops_wrappers_jit():
    rng = np.random.default_rng(5)
    q = jnp.asarray(rng.normal(size=(1, 256, 2, 32)), jnp.float32)
    out = ops.attention(q, q, q, causal=True, q_block=128, kv_block=128)
    assert out.shape == q.shape
    assert bool(jnp.all(jnp.isfinite(out)))
