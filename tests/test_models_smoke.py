"""Per-architecture smoke tests: REDUCED variant (≤2 layers / d_model ≤ 128 /
≤4 experts), one forward + one Adam train step on CPU; shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch
from repro.models.model import LM
from repro.optim import adam

RNG = np.random.default_rng(0)


def make_batch(cfg, b, s):
    batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab_size, (b, s)),
                                   jnp.int32)}
    batch["labels"] = jnp.roll(batch["tokens"], -1, 1)
    if cfg.is_encdec:
        batch["audio_embed"] = jnp.asarray(
            RNG.normal(size=(b, cfg.num_audio_frames, cfg.d_model)),
            jnp.float32)
    if cfg.num_image_tokens:
        batch["image_embed"] = jnp.asarray(
            RNG.normal(size=(b, cfg.num_image_tokens, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_reduced_forward_and_train_step(arch_id):
    cfg = get_arch(arch_id).reduced()
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, 2, 16)

    logits, aux = jax.jit(lm.forward_logits)(params, batch)
    assert logits.shape == (2, 16, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits[..., :cfg.vocab_size])))

    opt = adam(1e-3)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lm.loss_fn)(params, batch)
        params, opt_state = opt.apply(params, grads, opt_state)
        return loss, params, opt_state

    loss0, params, opt_state = step(params, opt_state, batch)
    loss1, params, opt_state = step(params, opt_state, batch)
    assert np.isfinite(float(loss0)) and np.isfinite(float(loss1))
    # same batch twice with Adam must reduce loss at init
    assert float(loss1) < float(loss0)


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_matches_assignment(arch_id):
    """Exact published shapes from the assignment table."""
    cfg = get_arch(arch_id)
    table = {
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "deepseek-v2-236b": (60, 5120, 128, 128, 12288, 102400),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
        "mamba2-780m": (48, 1536, 1, 1, 0, 50280),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen3-8b": (36, 4096, 32, 8, 12288, 151936),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
    }
    L, d, h, kv, dff, vocab = table[arch_id]
    assert cfg.num_layers == L and cfg.d_model == d
    assert cfg.num_heads == h and cfg.num_kv_heads == kv
    assert cfg.d_ff == dff and cfg.vocab_size == vocab
    if arch_id == "deepseek-v2-236b":
        assert cfg.kv_lora_rank == 512 and cfg.num_experts == 160 \
            and cfg.experts_per_tok == 6 and cfg.num_shared_experts == 2
    if arch_id == "granite-moe-1b-a400m":
        assert cfg.num_experts == 32 and cfg.experts_per_tok == 8
    if arch_id == "mamba2-780m":
        assert cfg.ssm_state == 128 and cfg.family == "ssm"
    if arch_id == "recurrentgemma-2b":
        assert cfg.pattern == ("rglru", "rglru", "attn")
    if arch_id == "qwen3-8b":
        assert cfg.qk_norm
    if arch_id == "starcoder2-3b":
        assert cfg.sliding_window == 4096
    if arch_id == "qwen1.5-32b" or arch_id == "codeqwen1.5-7b":
        assert cfg.qkv_bias


def test_layer_grouping_patterns():
    """Heterogeneous archs group correctly (scan units / singletons)."""
    lm = LM(get_arch("recurrentgemma-2b"))
    kinds = [s.mixer for s, n in lm.groups for _ in range(n)]
    assert len(kinds) == 26
    assert kinds[:6] == ["rglru", "rglru", "attn"] * 2
    lm = LM(get_arch("llama-3.2-vision-11b"))
    kinds = [s.mixer for s, n in lm.groups for _ in range(n)]
    assert len(kinds) == 40
    assert kinds.count("xattn") == 8
    assert all(k == "xattn" for i, k in enumerate(kinds) if (i + 1) % 5 == 0)
    lm = LM(get_arch("deepseek-v2-236b"))
    specs = [(s.mixer, s.ffn, n) for s, n in lm.groups]
    assert specs == [("mla", "dense", 1), ("mla", "moe", 59)]


def test_moe_router_properties():
    from repro.models.moe import apply_moe, init_moe
    cfg = get_arch("granite-moe-1b-a400m").reduced()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(RNG.normal(size=(2, 8, cfg.d_model)), jnp.float32)
    out, aux = apply_moe(p, cfg, x, dropless=True)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3   # Switch aux loss lower bound ≈ 1
    # capacity dropping path: tiny capacity must not NaN
    import dataclasses
    cfg2 = dataclasses.replace(cfg, capacity_factor=0.05)
    out2, _ = apply_moe(p, cfg2, x)
    assert bool(jnp.all(jnp.isfinite(out2)))


def test_padded_vocab_logits_masked():
    cfg = get_arch("granite-moe-1b-a400m")   # vocab 49155 -> padded 51200
    assert cfg.padded_vocab == 51200
    red = cfg.reduced()
    lm = LM(red)
    params = lm.init_params(jax.random.PRNGKey(0))
    batch = make_batch(red, 1, 8)
    logits, _ = lm.forward_logits(params, batch)
    if red.padded_vocab > red.vocab_size:
        assert float(logits[..., red.vocab_size:].max()) <= -1e29
