"""Fault injection + guarded exchange (ISSUE 9), sim backend.

Three contracts, in order of importance:

1. ZERO-FAULT PARITY: with `guard_exchange=True` and no faults injected,
   the step is bit-identical to the unguarded step — loss, every weight
   gradient, every feat/grad buffer leaf — across variants × engines ×
   wire formats, and the jaxpr collective counts are unchanged (the
   checksum column rides inside the existing wires; the fallback is a
   pure select; the "es" counters are partition-local).
2. DEGRADED SEMANTICS: a dropped/corrupted payload is detected by the
   per-row checksum, the receiver falls back to its last-good stale
   entry (one extra step of staleness), and the "es" counters track
   consecutive fallbacks exactly.
3. PLAN COMPILATION: FaultPlan validation, delay ≡ drop lowering, and
   deterministic seeded tables.

Cross-backend faulted parity lives in the subprocess SPMD matrix
(test_pipegcn_spmd.py); trainer-level escalation in test_health.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from repro.core.config import ModelConfig, PipeConfig
from repro.core.faults import FWD, BWD, FaultPlan, FaultSite
from repro.core.pipegcn import PipeGCN, shard_data, topology_from
from repro.graph import build_partitioned_graph, make_dataset, partition_graph
from repro.graph.csr import mean_normalized

P = 4


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("tiny")
    prop = mean_normalized(ds.graph)
    pg = build_partitioned_graph(prop, partition_graph(ds.graph, P, seed=0), P)
    topo = topology_from(pg, with_tiles=True)
    topo = topo._replace(edge_w=topo.edge_w.astype(jnp.float64))
    data = shard_data(pg, ds.features.astype(np.float64), ds.labels,
                      ds.train_mask, ds.val_mask)
    data = data._replace(x=data.x.astype(jnp.float64))
    return ds, topo, data


def _model(ds, agg="coo", variant="pipegcn", **pipe_kw):
    mc = ModelConfig(kind="sage", feat_dim=ds.feat_dim, hidden=16,
                     num_layers=3, num_classes=ds.num_classes,
                     dropout=0.0, agg=agg)
    pc = dataclasses.replace(PipeConfig.named(variant, gamma=0.9), **pipe_kw)
    return PipeGCN(mc, pc)


# ---------------------------------------------------------------------------
# 1. zero-fault parity
# ---------------------------------------------------------------------------

PARITY_CELLS = [
    ("pipegcn", "coo", {}),
    ("pipegcn", "blocksparse", {}),
    ("pipegcn-gf", "coo", {}),
    ("pipegcn", "coo", {"staleness_steps": 3}),
    ("pipegcn", "coo", {"wire": "bf16"}),
    ("pipegcn", "coo", {"wire": "int8"}),
    ("pipegcn-g", "blocksparse", {"wire": "int4"}),
    ("pipegcn", "coo", {"fuse_exchange": False}),
    ("pipegcn", "coo", {"wire": "auto", "staleness_steps": 2}),
]


@pytest.mark.parametrize("variant,agg,pipe_kw", PARITY_CELLS)
def test_guard_zero_fault_bitwise_parity(setup, variant, agg, pipe_kw):
    """guard_exchange with an empty fault plan is bitwise invisible."""
    ds, topo, data = setup
    ref = _model(ds, agg, variant, **pipe_kw)
    grd = _model(ds, agg, variant, guard_exchange=True, **pipe_kw)
    params = ref.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b_ref = ref.init_buffers(topo, dtype=jnp.float64)
    b_grd = grd.init_buffers(topo, dtype=jnp.float64)
    steps = 5 if pipe_kw.get("staleness_steps", 1) > 1 else 3
    for t in range(steps):
        key = jax.random.PRNGKey(t)
        l0, g0, b_ref, _ = ref.train_step(topo, params, b_ref, data, key)
        l1, g1, b_grd, _ = grd.train_step(topo, params, b_grd, data, key)
        assert float(l0) == float(l1), (variant, agg, pipe_kw, t)
        for k in g0:
            assert float(jnp.abs(g0[k] - g1[k]).max()) == 0.0, (pipe_kw, t, k)
        for k in ("feat", "grad"):
            for a, b in zip(b_ref[k], b_grd[k]):
                assert a.dtype == b.dtype
                assert float(jnp.abs(a - b).max()) == 0.0, (pipe_kw, t, k)
        assert int(np.asarray(b_grd["es"]).max()) == 0, (pipe_kw, t)


def test_guard_collective_counts_unchanged(setup):
    """The guard adds a wire COLUMN, never a collective: jaxpr counts of
    all_to_all AND psum are identical with and without it (tier-1 via a
    1-device mesh — the eqn count is layout-independent)."""
    ds, topo, data = setup
    from repro.core.trace_utils import traced_step_collectives
    from repro.launch.mesh import make_partition_mesh
    mesh = make_partition_mesh(P, parts_per_device=P)
    for fuse in (True, False):
        ref = _model(ds, fuse_exchange=fuse)
        grd = _model(ds, fuse_exchange=fuse, guard_exchange=True)
        c0 = traced_step_collectives(ref, mesh, topo, data)
        c1 = traced_step_collectives(grd, mesh, topo, data)
        assert c0 == c1, (fuse, c0, c1)


def test_faults_none_matches_no_fault_args(setup):
    """Passing step_idx/faults=None is the exact historical trace: same
    results as calling train_step without the new arguments."""
    ds, topo, data = setup
    m = _model(ds)
    params = m.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    b0 = m.init_buffers(topo, dtype=jnp.float64)
    key = jax.random.PRNGKey(7)
    l0, g0, _, _ = m.train_step(topo, params, b0, data, key)
    l1, g1, _, _ = m.train_step(topo, params, b0, data, key,
                                step_idx=None, faults=None)
    assert float(l0) == float(l1)
    for k in g0:
        assert float(jnp.abs(g0[k] - g1[k]).max()) == 0.0


# ---------------------------------------------------------------------------
# 2. degraded semantics
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fuse", [True, False])
def test_dropped_feature_falls_back_to_stale_entry(setup, fuse):
    """A dropped forward payload leaves the destination's buffer rows for
    that (layer, peer) EXACTLY at their previous value; everything else
    updates normally; es counts the event and resets on recovery."""
    ds, topo, data = setup
    m = _model(ds, fuse_exchange=fuse, guard_exchange=True)
    plan = FaultPlan(sites=(FaultSite(step=1, layer=1, src=0, dst=2,
                                      direction="fwd", kind="drop"),))
    tables = plan.compile(4, 3, P)
    params = m.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = m.init_buffers(topo, dtype=jnp.float64)
    clean = m.init_buffers(topo, dtype=jnp.float64)
    slot = topo.slot
    for t in range(3):
        key = jax.random.PRNGKey(t)
        prev = bufs["feat"][1]
        _, _, bufs, _ = m.train_step(topo, params, bufs, data, key,
                                     jnp.int32(t), tables)
        _, _, clean, _ = m.train_step(topo, params, clean, data, key)
        es = np.asarray(bufs["es"])
        cur, ref = np.asarray(bufs["feat"][1]), np.asarray(clean["feat"][1])
        if t == 1:
            # dst partition 2's rows from peer 0 kept the previous value
            # (here: the zero-init state), everything else matches clean
            assert es[2, FWD, 1, 0] == 1
            assert es.sum() == 1
            got = cur[2, 0 * slot:(0 + 1) * slot]
            old = np.asarray(prev)[2, 0 * slot:(0 + 1) * slot]
            assert (got == old).all()
            mask = np.ones_like(cur, bool)
            mask[2, 0 * slot:(0 + 1) * slot] = False
            assert (cur[mask] == ref[mask]).all()
        else:
            assert es.sum() == 0, t
            # one stale row diverges the downstream compute, so only
            # compare the buffers BEFORE any fault has fired
            if t == 0:
                assert (cur == ref).all()


def test_consecutive_drops_accumulate_es(setup):
    """es counts CONSECUTIVE fallbacks: three drops in a row reach 3,
    one valid arrival resets to 0."""
    ds, topo, data = setup
    m = _model(ds, guard_exchange=True, max_staleness=8)
    sites = tuple(FaultSite(step=t, layer=2, src=1, dst=0,
                            direction="bwd", kind="drop") for t in range(3))
    tables = FaultPlan(sites=sites).compile(5, 3, P)
    params = m.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = m.init_buffers(topo, dtype=jnp.float64)
    seen = []
    for t in range(4):
        _, _, bufs, _ = m.train_step(topo, params, bufs, data,
                                     jax.random.PRNGKey(t), jnp.int32(t),
                                     tables)
        seen.append(int(np.asarray(bufs["es"])[0, BWD, 2, 1]))
    assert seen == [1, 2, 3, 0]


def test_corruption_detected_by_checksum(setup):
    """Seeded bit-flips into the wire bytes trip the per-row checksum:
    the victim (dst, dir, layer, src) site — and only it — falls back."""
    ds, topo, data = setup
    for wire in ("f32", "bf16", "int8"):
        m = _model(ds, wire=wire, guard_exchange=True)
        plan = FaultPlan(sites=(FaultSite(step=0, layer=1, src=3, dst=1,
                                          direction="fwd", kind="corrupt"),),
                         density=0.2, seed=3)
        tables = plan.compile(2, 3, P)
        params = m.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
        bufs = m.init_buffers(topo, dtype=jnp.float64)
        _, _, bufs, _ = m.train_step(topo, params, bufs, data,
                                     jax.random.PRNGKey(0), jnp.int32(0),
                                     tables)
        es = np.asarray(bufs["es"])
        assert es[1, FWD, 1, 3] == 1, wire
        assert es.sum() == 1, wire


def test_drop_without_guard_lands_zeros(setup):
    """Chaos mode: with guard_exchange OFF a dropped payload lands as
    zeros silently — the step still runs, es does not exist, and the
    result differs from the clean run (that detection gap is exactly
    what the checksum column buys)."""
    ds, topo, data = setup
    m = _model(ds)     # guard off
    plan = FaultPlan(sites=(FaultSite(step=0, layer=0, src=0, dst=1,
                                      direction="fwd", kind="drop"),))
    tables = plan.compile(2, 3, P)
    params = m.init_params(jax.random.PRNGKey(0), dtype=jnp.float64)
    bufs = m.init_buffers(topo, dtype=jnp.float64)
    key = jax.random.PRNGKey(0)
    _, _, b_fault, _ = m.train_step(topo, params, bufs, data, key,
                                    jnp.int32(0), tables)
    _, _, b_clean, _ = m.train_step(topo, params, bufs, data, key)
    assert "es" not in b_fault
    d = float(jnp.abs(b_fault["feat"][0] - b_clean["feat"][0]).max())
    assert d > 0.0


# ---------------------------------------------------------------------------
# 3. plan compilation
# ---------------------------------------------------------------------------

def test_delay_compiles_as_drop():
    """Every step re-sends fresh boundary data, so a one-step-late payload
    is superseded on arrival: delay and drop lower to the same tables."""
    site = dict(step=2, layer=1, src=0, dst=3, direction="fwd")
    t_delay = FaultPlan(sites=(FaultSite(kind="delay", **site),)).compile(4, 3, P)
    t_drop = FaultPlan(sites=(FaultSite(kind="drop", **site),)).compile(4, 3, P)
    assert (np.asarray(t_delay.drop) == np.asarray(t_drop.drop)).all()
    assert not np.asarray(t_delay.corrupt).any()


def test_background_rate_tables():
    """rate faults are deterministic in the seed, never hit the self-pair
    diagonal, and never hit the (bwd, layer 0) plane (no such exchange)."""
    t1 = FaultPlan(rate=0.3, seed=7).compile(10, 3, P)
    t2 = FaultPlan(rate=0.3, seed=7).compile(10, 3, P)
    t3 = FaultPlan(rate=0.3, seed=8).compile(10, 3, P)
    d1 = np.asarray(t1.drop)
    assert (d1 == np.asarray(t2.drop)).all()
    assert (d1 != np.asarray(t3.drop)).any()
    assert d1.any()
    eye = np.eye(P, dtype=bool)
    assert not d1[..., eye].any()
    assert not d1[:, BWD, 0].any()


def test_faultplan_validation():
    with pytest.raises(ValueError):
        FaultPlan(rate=1.5)
    with pytest.raises(ValueError):
        FaultPlan(rate_kind="meteor")
    with pytest.raises(ValueError):
        FaultPlan(density=0.0)
    with pytest.raises(ValueError):
        FaultSite(step=0, layer=0, src=0, dst=1, direction="sideways")
    with pytest.raises(ValueError):
        FaultSite(step=0, layer=0, src=0, dst=1, kind="gamma-ray")
    with pytest.raises(ValueError):  # out-of-range site caught at compile
        FaultPlan(sites=(FaultSite(step=0, layer=9, src=0, dst=1),)
                  ).compile(4, 3, P)
    assert FaultPlan().is_empty()
    assert not FaultPlan(rate=0.1).is_empty()


def test_pipeconfig_guard_validation():
    with pytest.raises(ValueError):  # vanilla has no stale fallback
        PipeConfig(stale=False, guard_exchange=True)
    with pytest.raises(ValueError):  # bound below the FIFO depth
        PipeConfig(guard_exchange=True, staleness_steps=4, max_staleness=2)
    with pytest.raises(ValueError):  # split schedule has no mask path
        PipeConfig(guard_exchange=True, overlap="split-phase")
    PipeConfig(guard_exchange=True, staleness_steps=2, max_staleness=2)
