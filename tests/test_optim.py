"""Optimizer semantics vs reference math; schedules; clipping; checkpoint
round-trips; data pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data import synthetic_token_batches
from repro.optim import (adam, adamw, clip_by_global_norm, cosine_schedule,
                         constant_schedule, global_norm, linear_warmup_cosine,
                         sgd)


def test_adam_matches_reference_math():
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    opt = adam(0.1, b1=0.9, b2=0.999, eps=1e-8)
    state = opt.init(params)
    p, state = opt.apply(params, g, state)
    # reference, step 1
    m = 0.1 * np.asarray(g["w"])
    v = 0.001 * np.square(np.asarray(g["w"]))
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.999)
    want = np.asarray(params["w"]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(p["w"]), want, atol=1e-6)

    # second step with same grads
    p2, state = opt.apply(p, g, state)
    m = 0.9 * m + 0.1 * np.asarray(g["w"])
    v = 0.999 * v + 0.001 * np.square(np.asarray(g["w"]))
    want2 = want - 0.1 * (m / (1 - 0.9 ** 2)) / (
        np.sqrt(v / (1 - 0.999 ** 2)) + 1e-8)
    np.testing.assert_allclose(np.asarray(p2["w"]), want2, atol=5e-6)


def test_adam_converges_quadratic():
    opt = adam(0.1)
    params = {"x": jnp.asarray(5.0)}
    state = opt.init(params)
    f = lambda p: (p["x"] - 2.0) ** 2
    for _ in range(200):
        g = jax.grad(f)(params)
        params, state = opt.apply(params, g, state)
    assert abs(float(params["x"]) - 2.0) < 1e-2


def test_sgd_momentum():
    opt = sgd(0.1, momentum=0.9)
    params = {"x": jnp.asarray(1.0)}
    state = opt.init(params)
    g = {"x": jnp.asarray(1.0)}
    p1, state = opt.apply(params, g, state)
    assert abs(float(p1["x"]) - 0.9) < 1e-6
    p2, state = opt.apply(p1, g, state)
    # momentum: m = 0.9*1 + 1 = 1.9 ; x = 0.9 - 0.19
    assert abs(float(p2["x"]) - 0.71) < 1e-6


def test_adamw_decoupled_decay():
    opt_nw = adam(0.1)
    opt_w = adamw(0.1, weight_decay=0.1)
    params = {"w": jnp.asarray([10.0])}
    g = {"w": jnp.asarray([0.0])}
    p1, _ = opt_nw.apply(params, g, opt_nw.init(params))
    p2, _ = opt_w.apply(params, g, opt_w.init(params))
    assert float(p1["w"][0]) == pytest.approx(10.0)     # zero grad, no decay
    assert float(p2["w"][0]) == pytest.approx(10.0 - 0.1 * 0.1 * 10.0)


def test_clipping_and_norm():
    tree = {"a": jnp.full((4,), 3.0), "b": jnp.full((9,), -2.0)}
    n = float(global_norm(tree))
    assert n == pytest.approx(np.sqrt(4 * 9 + 9 * 4))
    clipped, _ = clip_by_global_norm(tree, 1.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    s = cosine_schedule(1.0, 100)
    assert float(s(0)) == pytest.approx(1.0)
    assert float(s(100)) == pytest.approx(0.0, abs=1e-6)
    w = linear_warmup_cosine(1.0, 10, 110, final_frac=0.1)
    assert float(w(5)) == pytest.approx(0.5)
    assert float(w(10)) == pytest.approx(1.0)
    assert float(w(110)) == pytest.approx(0.1, abs=1e-6)
    assert float(constant_schedule(0.3)(7)) == pytest.approx(0.3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"params": {"w": jnp.arange(6.0).reshape(2, 3),
                       "b": jnp.zeros((3,), jnp.bfloat16)},
            "bufs": (jnp.ones((4,)), jnp.full((2, 2), 7, jnp.int32))}
    path = save_checkpoint(str(tmp_path), 42, tree)
    assert os.path.isdir(path)
    assert latest_step(str(tmp_path)) == 42
    restored = restore_checkpoint(str(tmp_path), None, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    save_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        restore_checkpoint(str(tmp_path), 1, {"w": jnp.zeros((3,))})


def test_token_stream_deterministic():
    a = synthetic_token_batches(128, 32, 4, 3, seed=7)
    b = synthetic_token_batches(128, 32, 4, 3, seed=7)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x["tokens"], y["tokens"])
    assert a[0]["tokens"].shape == (4, 32)
    assert a[0]["tokens"].max() < 128
    # labels are next-token
    np.testing.assert_array_equal(a[0]["labels"][:, :-1],
                                  a[0]["tokens"][:, 1:])


def test_graph_pipeline_metrics(tiny_pipeline):
    logits = np.zeros((tiny_pipeline.pg.num_parts,
                       tiny_pipeline.pg.max_inner,
                       tiny_pipeline.dataset.num_classes), np.float32)
    m = tiny_pipeline.metric(logits)
    assert set(m) == {"train", "val", "test"}
    assert 0.0 <= m["test"] <= 1.0
