#!/usr/bin/env python
"""Docs gate: public-API docstring presence + markdown cross-link checker.

Two stdlib-only checks (runnable in any environment, no ruff/jax needed —
CI additionally runs the pinned ruff's pydocstyle subset on the same
files):

1. every public module and public top-level class under the PUBLIC
   prefixes of src/repro has a docstring (the same surface the CI docs
   job gates with ruff --select D100,D101,D419; names with a leading
   underscore are exempt);
2. every relative markdown link in README.md and docs/*.md resolves — the
   target file exists, and an ``#anchor`` fragment matches a heading slug
   in the target (GitHub's slug rules: lowercase, punctuation stripped,
   spaces to hyphens).

Exit status is the number of problems; each is printed as file:line.
"""
from __future__ import annotations

import ast
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# Prefixes whose top-level API is documentation-gated. kernels/ and the
# LM-architecture pool carry their own inline conventions and are covered
# by review, not this gate.
PUBLIC_PREFIXES = (
    "src/repro/core",
    "src/repro/data",
    "src/repro/analysis",
    "src/repro/graph",
    "src/repro/launch",
    "src/repro/optim",
    "src/repro/models",
)

MARKDOWN = ["README.md", "docs/architecture.md", "docs/wire-format.md"]

LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*$", re.MULTILINE)


def check_docstrings() -> list[str]:
    problems = []
    for prefix in PUBLIC_PREFIXES:
        base = os.path.join(ROOT, prefix)
        for dirpath, _, files in os.walk(base):
            for fn in sorted(files):
                if not fn.endswith(".py") or fn.startswith("_"):
                    continue
                path = os.path.join(dirpath, fn)
                rel = os.path.relpath(path, ROOT)
                with open(path) as f:
                    tree = ast.parse(f.read(), filename=rel)
                if not ast.get_docstring(tree):
                    problems.append(f"{rel}:1 missing module docstring")
                for node in tree.body:
                    if not isinstance(node, ast.ClassDef):
                        continue
                    if node.name.startswith("_"):
                        continue
                    ds = ast.get_docstring(node)
                    if not (ds and ds.strip()):
                        problems.append(f"{rel}:{node.lineno} public class "
                                        f"{node.name!r} missing docstring")
    return problems


def _slug(heading: str) -> str:
    """GitHub-style anchor slug of one heading line."""
    # drop inline code/link markup, then non-word punctuation
    h = re.sub(r"[`*]", "", heading)
    h = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", h)
    h = h.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h)
    return h.replace(" ", "-")


def _anchors(md_path: str) -> set[str]:
    with open(md_path) as f:
        text = f.read()
    # strip fenced code blocks — '# comment' lines inside are not headings
    text = re.sub(r"```.*?```", "", text, flags=re.DOTALL)
    return {_slug(m.group(1)) for m in HEADING_RE.finditer(text)}


def check_links() -> list[str]:
    problems = []
    for md in MARKDOWN:
        src = os.path.join(ROOT, md)
        if not os.path.exists(src):
            problems.append(f"{md}:1 file listed in check_docs.MARKDOWN "
                            "does not exist")
            continue
        with open(src) as f:
            lines = f.read().splitlines()
        in_fence = False
        for ln, line in enumerate(lines, 1):
            if line.lstrip().startswith("```"):
                in_fence = not in_fence
            if in_fence:
                continue
            for m in LINK_RE.finditer(line):
                target = m.group(1)
                if target.startswith(("http://", "https://", "mailto:")):
                    continue  # offline container: external URLs unchecked
                path, _, frag = target.partition("#")
                if path:
                    dest = os.path.normpath(
                        os.path.join(os.path.dirname(src), path))
                else:
                    dest = src
                if not os.path.exists(dest):
                    problems.append(f"{md}:{ln} broken link {target!r} "
                                    f"(no such file {path!r})")
                    continue
                if frag and dest.endswith(".md"):
                    if _slug(frag) not in _anchors(dest):
                        problems.append(f"{md}:{ln} broken anchor "
                                        f"{target!r} (no heading slugs to "
                                        f"#{_slug(frag)})")
    return problems


def main() -> int:
    problems = check_docstrings() + check_links()
    for p in problems:
        print(p)
    print(f"check_docs: {len(problems)} problem(s)")
    return min(len(problems), 99)


if __name__ == "__main__":
    sys.exit(main())
