#!/usr/bin/env bash
# Fast tier-1 subset: everything except the slow (subprocess / convergence)
# tests. Full suite: PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q -m "not slow" "$@"
