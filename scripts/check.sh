#!/usr/bin/env bash
# Fast tier-1 subset: everything except the slow (subprocess / convergence)
# tests. Full suite: PYTHONPATH=src python -m pytest -q
set -euo pipefail
cd "$(dirname "$0")/.."

# Preflight: a broken/missing jax install otherwise surfaces as a wall of
# pytest collection errors. Fail loudly with the actual import error instead.
if ! python -c "import jax" 2>/tmp/jax_import_err.$$; then
  cat /tmp/jax_import_err.$$ >&2
  rm -f /tmp/jax_import_err.$$
  echo "" >&2
  echo "FATAL: 'import jax' failed (see traceback above)." >&2
  echo "Install the pinned deps first, e.g.:" >&2
  echo "    pip install \"jax[cpu]==0.4.37\" \"numpy<2.2\" pytest hypothesis" >&2
  exit 1
fi
rm -f /tmp/jax_import_err.$$

# Preflight: trace-level proof that the split-phase overlap schedule issues
# every boundary collective between the phase kernels, on both backends.
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m repro.launch.check_schedule

# Preflight: public-API docstrings + README/docs cross-links (stdlib-only;
# the CI docs job additionally runs the pinned ruff's pydocstyle subset).
python scripts/check_docs.py

PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} python -m pytest -q -m "not slow" "$@"
