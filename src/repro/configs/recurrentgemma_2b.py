"""recurrentgemma-2b [arXiv:2402.19427].

Hybrid Griffin architecture: 26L, d_model=2560, pattern 2 recurrent
(RG-LRU, lru_width=2560) : 1 local attention (10 heads, MQA kv=1,
window=2048), d_ff=7680 GeGLU, vocab=256000, tied embeddings,
sqrt(d_model) embedding scale.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    head_dim=256, d_ff=7680, vocab_size=256000,
    act="geglu", sliding_window=2048, tie_embeddings=True,
    scale_embed=True,
    pattern=("rglru", "rglru", "attn"), lru_width=2560, conv1d_width=4,
)
