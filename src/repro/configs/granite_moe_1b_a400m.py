"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base].

MoE decoder: 24L, d_model=1024, 16 heads (GQA kv=8), 32 experts top-8,
expert d_ff=512, vocab=49155. RMSNorm + SwiGLU + RoPE, tied embeddings.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=512, vocab_size=49155,
    num_experts=32, experts_per_tok=8, moe_d_ff=512,
    tie_embeddings=True, rope_theta=10000.0,
)
