"""mamba2-780m [arXiv:2405.21060; state-spaces/mamba2-780m card].

Attention-free SSD: 48L, d_model=1536, expand=2 (d_inner=3072),
headdim=64 (48 SSD heads), d_state=128, conv=4, vocab=50280,
tied embeddings, no FFN blocks (the mixer IS the block).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="mamba2-780m", family="ssm",
    num_layers=48, d_model=1536, num_heads=1, num_kv_heads=1,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_groups=1,
    ssm_conv=4, ssm_chunk=256, tie_embeddings=True,
)
