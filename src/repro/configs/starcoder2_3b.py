"""starcoder2-3b [arXiv:2402.19173].

Dense decoder: 30L, d_model=3072, 24 heads (GQA kv=2), d_ff=12288,
vocab=49152, LayerNorm + GELU + bias, RoPE, native sliding window 4096
— the one assigned dense arch whose *published* config is sub-quadratic,
so `long_500k` runs in its native configuration.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="starcoder2-3b", family="dense",
    num_layers=30, d_model=3072, num_heads=24, num_kv_heads=2,
    d_ff=12288, vocab_size=49152,
    qkv_bias=True, norm="layernorm", act="gelu",
    sliding_window=4096, rope_theta=999999.4,
)
