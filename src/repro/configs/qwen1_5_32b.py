"""qwen1.5-32b [hf:Qwen/Qwen1.5-32B; arch per hf:Qwen/Qwen1.5-0.5B family].

Dense decoder: 64L, d_model=5120, 40 heads (kv=40 -> MHA), d_ff=27392,
vocab=152064, RMSNorm + SwiGLU + RoPE, QKV bias (the Qwen1.5 signature).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen1.5-32b", family="dense",
    num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
    d_ff=27392, vocab_size=152064,
    qkv_bias=True, rope_theta=1_000_000.0,
)
