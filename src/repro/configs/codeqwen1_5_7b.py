"""codeqwen1.5-7b [hf:Qwen/CodeQwen1.5-7B].

Qwen1.5 architecture: 32L, d_model=4096, 32 heads (kv=32), d_ff=13440,
vocab=92416, QKV bias, RMSNorm + SwiGLU + RoPE.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    qkv_bias=True, rope_theta=1_000_000.0,
)
