"""qwen3-8b [hf:Qwen/Qwen3-8B].

Dense decoder: 36L, d_model=4096, 32 heads (GQA kv=8, head_dim=128),
d_ff=12288, vocab=151936, per-head q/k RMSNorm (qk_norm), no bias,
RMSNorm + SwiGLU + RoPE(1e6).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="qwen3-8b", family="dense",
    num_layers=36, d_model=4096, num_heads=32, num_kv_heads=8,
    head_dim=128, d_ff=12288, vocab_size=151936,
    qk_norm=True, rope_theta=1_000_000.0,
)
