"""deepseek-v2-236b [arXiv:2405.04434].

60L, d_model=5120, 128 heads, MLA (kv_lora_rank=512, q_lora_rank=1536,
qk_nope=128, qk_rope=64, v_head=128). MoE: 2 shared + 160 routed experts,
top-6, expert d_ff=1536; first layer dense FFN (d_ff=12288). vocab=102400.
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="deepseek-v2-236b", family="moe",
    num_layers=60, d_model=5120, num_heads=128, num_kv_heads=128,
    d_ff=12288, vocab_size=102400,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128,
    num_experts=160, experts_per_tok=6, num_shared_experts=2,
    moe_d_ff=1536, first_dense_layers=1,
)
