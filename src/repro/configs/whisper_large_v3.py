"""whisper-large-v3 [arXiv:2212.04356, openai/whisper-large-v3 card].

Enc-dec audio transformer backbone: 32 encoder + 32 decoder layers,
d_model=1280, 20 heads (kv=20, i.e. MHA), d_ff=5120, vocab=51866,
LayerNorm + GELU, sinusoidal positions (no RoPE), qkv bias.
The mel-spectrogram + conv2 frontend is STUBBED: `input_specs()` feeds
precomputed frame embeddings (B, 1500, 1280).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="whisper-large-v3", family="audio",
    num_layers=32, d_model=1280, num_heads=20, num_kv_heads=20,
    d_ff=5120, vocab_size=51866,
    qkv_bias=True, norm="layernorm", act="gelu", use_rope=False,
    tie_embeddings=True,
    encoder_layers=32, num_audio_frames=1500,
)
