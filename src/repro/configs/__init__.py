"""Assigned architecture configs (+ the paper's own GraphSAGE setups).

Each module defines `CONFIG: ArchConfig` with the exact published shape,
citing its source in the docstring. `get_arch(id)` is the registry entry
point used by --arch flags everywhere.
"""
from __future__ import annotations

import importlib

ARCH_IDS = [
    "whisper-large-v3",
    "qwen1.5-32b",
    "deepseek-v2-236b",
    "codeqwen1.5-7b",
    "granite-moe-1b-a400m",
    "mamba2-780m",
    "llama-3.2-vision-11b",
    "recurrentgemma-2b",
    "qwen3-8b",
    "starcoder2-3b",
]


def get_arch(arch_id: str):
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; have {ARCH_IDS}")
    mod = importlib.import_module(
        "repro.configs." + arch_id.replace("-", "_").replace(".", "_"))
    return mod.CONFIG
