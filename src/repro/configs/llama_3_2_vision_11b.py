"""llama-3.2-vision-11b [hf:meta-llama/Llama-3.2-11B-Vision].

VLM language backbone: 40L, d_model=4096, 32 heads (GQA kv=8), d_ff=14336,
vocab=128256; a gated cross-attention layer every 5th layer (8 total)
attending to projected vision tokens. The ViT encoder + projector is
STUBBED: `input_specs()` feeds projected patch embeddings (B, 1600, 4096).
"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    arch_id="llama-3.2-vision-11b", family="vlm",
    num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_every=5, num_image_tokens=1600,
)
