from repro.analysis.cost import analytic_cost, graph_layout_report

__all__ = ["analytic_cost", "graph_layout_report"]
