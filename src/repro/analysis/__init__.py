from repro.analysis.cost import analytic_cost

__all__ = ["analytic_cost"]
