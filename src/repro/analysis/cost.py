"""Analytic FLOP / HBM-byte models: the assigned LM architectures, plus the
PipeGCN layer matmul-ordering model (aggregate-first vs transform-first —
see the GCN section at the bottom).

XLA's `compiled.cost_analysis()` counts `while` (lax.scan) bodies ONCE, so
its totals under-count layer-stacked models by ~L× (verified in
EXPERIMENTS.md §Dry-run). The roofline compute/memory terms therefore come
from this analytic model — exact for the matmul-dominated terms, explicit
approximations elsewhere — while the HLO text still provides the collective
traffic (with while-body trip-count correction in launch/dryrun.py).

All counts are GLOBAL per step; divide by chip count for per-device terms.
"""
from __future__ import annotations


from repro.models.config import ArchConfig, InputShape
from repro.models.model import LM, decoder_layer_specs


def _attn_flops_per_tok(cfg: ArchConfig, kv_len: float, causal: bool) -> float:
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    d = cfg.d_model
    proj = 2 * d * (2 * h * hd + 2 * k * hd)          # q,o + k,v
    eff = kv_len / 2 if causal and cfg.sliding_window == 0 else \
        min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len
    scores = 2 * eff * h * hd * 2                      # QK^T and PV
    return proj + scores


def _mla_flops_per_tok(cfg: ArchConfig, kv_len: float) -> float:
    h = cfg.num_heads
    d = cfg.d_model
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    r = cfg.kv_lora_rank
    if cfg.q_lora_rank:
        q = 2 * d * cfg.q_lora_rank + 2 * cfg.q_lora_rank * h * qk
    else:
        q = 2 * d * h * qk
    kv = 2 * d * r + 2 * d * cfg.qk_rope_dim \
        + 2 * r * h * cfg.qk_nope_dim + 2 * r * h * cfg.v_head_dim
    eff = min(kv_len, cfg.sliding_window) if cfg.sliding_window else kv_len / 2
    scores = 2 * eff * h * (qk + cfg.v_head_dim)
    out = 2 * h * cfg.v_head_dim * d
    return q + kv + scores + out


def _mlp_flops_per_tok(cfg: ArchConfig) -> float:
    mult = 3 if cfg.act in ("swiglu", "geglu") else 2
    return 2.0 * cfg.d_model * cfg.d_ff * mult


def _moe_flops_per_tok(cfg: ArchConfig, dropless: bool) -> float:
    d, f = cfg.d_model, cfg.moe_d_ff
    router = 2.0 * d * cfg.num_experts
    factor = 1.0 if dropless else cfg.capacity_factor
    routed = 2.0 * d * f * 3 * cfg.experts_per_tok * factor
    shared = 2.0 * d * f * cfg.num_shared_experts * 3
    return router + routed + shared


def _ssd_flops_per_tok(cfg: ArchConfig, decode: bool) -> float:
    d, di = cfg.d_model, cfg.d_inner
    g, n, h, hp = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads, cfg.ssm_headdim
    proj = 2.0 * d * (2 * di + 2 * g * n + h) + 2.0 * di * d
    conv = 2.0 * cfg.ssm_conv * (di + 2 * g * n)
    if decode:
        scan = 2.0 * h * hp * n * 2                      # state update + out
    else:
        q = cfg.ssm_chunk
        # intra-chunk dual form + chunk states + inter-chunk contribution
        scan = 2.0 * q * h * (n + hp) + 4.0 * h * hp * n
    return proj + conv + scan


def _rglru_flops_per_tok(cfg: ArchConfig) -> float:
    d = cfg.d_model
    w = cfg.lru_width or d
    return 2.0 * d * w * 2 + 2.0 * w * w * 2 + 2.0 * w * d \
        + 2.0 * cfg.conv1d_width * w


def _xattn_flops_per_tok(cfg: ArchConfig, mem_len: float,
                         cached: bool) -> float:
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    d = cfg.d_model
    proj = 2 * d * 2 * h * hd                          # q,o every call
    kv = 0.0 if cached else 2 * d * 2 * k * hd * 1.0   # amortized at prefill
    scores = 2 * mem_len * h * hd * 2
    return proj + kv + scores


def analytic_cost(cfg: ArchConfig, shape: InputShape) -> dict:
    """Global FLOPs and HBM bytes for one step of the given mode."""
    specs = decoder_layer_specs(cfg)
    mem_len = cfg.num_audio_frames if cfg.is_encdec else cfg.num_image_tokens
    b, s = shape.global_batch, shape.seq_len
    decode = shape.mode == "decode"
    toks = b * (1 if decode else s)
    kv_len = s if not decode else s                     # cache length
    if decode and cfg.sliding_window:
        kv_len = min(s, cfg.sliding_window)

    per_tok = 0.0
    for spec in specs:
        if spec.mixer == "attn":
            per_tok += _attn_flops_per_tok(cfg, kv_len, causal=True)
        elif spec.mixer == "mla":
            per_tok += _mla_flops_per_tok(cfg, kv_len)
        elif spec.mixer == "ssd":
            per_tok += _ssd_flops_per_tok(cfg, decode)
        elif spec.mixer == "rglru":
            per_tok += _rglru_flops_per_tok(cfg)
        elif spec.mixer == "xattn":
            per_tok += _xattn_flops_per_tok(cfg, mem_len, cached=decode)
        if spec.cross:
            per_tok += _xattn_flops_per_tok(cfg, mem_len, cached=decode)
        if spec.ffn == "dense":
            per_tok += _mlp_flops_per_tok(cfg)
        elif spec.ffn == "moe":
            per_tok += _moe_flops_per_tok(cfg, dropless=decode)
    per_tok += 2.0 * cfg.d_model * cfg.padded_vocab     # logits

    fwd = per_tok * toks
    if cfg.is_encdec and not decode:
        enc_tok = b * cfg.num_audio_frames
        enc_per_tok = (_attn_flops_per_tok(cfg, cfg.num_audio_frames, False)
                       + _mlp_flops_per_tok(cfg))
        fwd += enc_per_tok * enc_tok * cfg.encoder_layers

    lm = LM(cfg)
    import jax
    import numpy as np
    sds = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0)))
    p_total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(sds))

    if shape.mode == "train":
        flops = 4.0 * fwd            # fwd + bwd(2x) + remat re-fwd(1x)
        # params: read fwd + read bwd + remat (bf16) ; grads write (bf16);
        # adam state read+write (f32 m,v) + param update
        bytes_params = p_total * (3 * 2 + 2 + 4 * 4 + 2 * 2)
        act_bytes = toks * cfg.d_model * 2 * len(specs) * 6
        bytes_total = bytes_params + act_bytes \
            + toks * cfg.padded_vocab * 2 * 2
    else:
        flops = fwd
        bytes_params = p_total * 2                     # one read, bf16
        if decode:
            cache_bytes = _cache_bytes(cfg, b, kv_len)
            bytes_total = bytes_params + cache_bytes * 2   # read + write
            act_bytes = toks * cfg.d_model * 2 * len(specs) * 4
            bytes_total += act_bytes
        else:
            act_bytes = toks * cfg.d_model * 2 * len(specs) * 6
            bytes_total = bytes_params + act_bytes \
                + _cache_bytes(cfg, b, min(s, kv_len))
    return {"flops_global": float(flops), "hbm_bytes_global": float(bytes_total),
            "params_total": p_total}


def _cache_bytes(cfg: ArchConfig, batch: int, length: int) -> float:
    specs = decoder_layer_specs(cfg)
    total = 0.0
    for spec in specs:
        if spec.mixer == "attn":
            total += 2 * batch * length * cfg.num_kv_heads \
                * cfg.resolved_head_dim * 2
        elif spec.mixer == "mla":
            total += batch * length * (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
        elif spec.mixer == "ssd":
            total += batch * cfg.ssm_nheads * cfg.ssm_headdim \
                * cfg.ssm_state * 4
        elif spec.mixer == "rglru":
            total += batch * (cfg.lru_width or cfg.d_model) * 4
        if spec.cross or spec.mixer == "xattn":
            mem = cfg.num_audio_frames if cfg.is_encdec else cfg.num_image_tokens
            total += 2 * batch * mem * cfg.num_kv_heads \
                * cfg.resolved_head_dim * 2
    return total


# ----------------------------------------------------------------------
# PipeGCN layer matmul ordering (Demirci et al., "Scalable GCN Training on
# Distributed-Memory Systems"): the Eq. 3/4 layer pair P·H·W can contract
# in two orders —
#
#   aggregate-first  z = P·H   (sparse, 2·e·F_in)  then  u = z·W
#   transform-first  hw = H·W  (dense)             then  u = P·hw (2·e·F_out)
#
# with e = effective sparse multiply-adds of the local propagation shard
# per feature column: the padded COO length for the "coo" engine, or
# n_tiles·T² = tile_density·(row_blocks·col_blocks)·T² for the block-sparse
# engines (padded tiles do real MXU work). The same knob applies transposed
# in the manual backward. FLOPs below are exact for the matmul terms
# (multiply-adds ×2, per partition, fwd + bwd of ONE layer); HBM bytes are
# the major operand reads/writes — an explicit approximation, matching the
# style of the LM model above.
# ----------------------------------------------------------------------

import dataclasses

GCN_ORDERS = ("aggregate-first", "transform-first")
_TILE = 128       # adjacency tile edge (repro.kernels.gcn_spmm.TILE)


@dataclasses.dataclass(frozen=True)
class GcnLayerCost:
    """FLOPs + approximate HBM traffic of one layer under one ordering."""

    flops: float
    hbm_bytes: float


def gcn_layer_order_cost(order: str, fin: int, fout: int, num_rows: int,
                         combined: int, nnz_eff: float,
                         first_layer: bool = False, train: bool = True,
                         fused: bool = False, tile: int = _TILE,
                         dtype_bytes: int = 4) -> GcnLayerCost:
    """Cost of one GCN layer (fwd + manual bwd) under `order`.

    num_rows: inner (output) rows n; combined: [inner; halo] rows c of the
    aggregation input; nnz_eff: effective sparse multiply-adds per feature
    column. `first_layer`: Alg. 1 stops the backward at layer 0 —
    aggregate-first then skips its backward SpMM entirely, while
    transform-first still needs Pᵀ·du for the weight gradient
    (gw = combᵀ·(Pᵀ·du)). `fused` (aggregate-first only): the fused kernels
    skip the HBM round-trips of the (rows, F_in) intermediates (z re-read
    fwd; dz write+read bwd) but the backward prologue recomputes du@wᵀ once
    per TILE-row tile slot instead of once per row block — e/tile
    transformed rows instead of n.
    """
    if order not in GCN_ORDERS:
        raise ValueError(f"unknown order {order!r}; have {GCN_ORDERS}")
    n, c, e = float(num_rows), float(combined), float(nnz_eff)
    spmm_in, spmm_out = 2.0 * e * fin, 2.0 * e * fout
    if order == "aggregate-first":
        # fwd: z = P·comb (spmm_in), u = z@w.
        # bwd: gw = zᵀ·du; dz = du@wᵀ; dcomb = Pᵀ·dz (spmm_in).
        flops = spmm_in + 2.0 * n * fin * fout
        bytes_ = (c * fin                          # read comb
                  + e                              # tile/edge values
                  + n * fin                        # write z (residual)
                  + (0.0 if fused else n * fin)    # re-read z for the matmul
                  + fin * fout + n * fout)         # weight + write u
        if train:
            flops += 2.0 * n * fin * fout          # gw
            bytes_ += n * fout + n * fin + fin * fout      # du, z, gw
            if not first_layer:
                # dz rows: per row block once (unfused) vs per tile slot
                # (fused prologue recompute, e/tile rows total)
                dz_rows = (e / tile) if fused else n
                flops += 2.0 * dz_rows * fin * fout + spmm_in
                bytes_ += (fin * fout                          # w for dz
                           + (0.0 if fused else 2.0 * n * fin)  # dz rt
                           + e + c * fin)                      # tiles+dcomb
        return GcnLayerCost(flops=flops, hbm_bytes=bytes_ * dtype_bytes)
    # transform-first (always composed: dense matmul + SpMM over F_out)
    # fwd: hw = comb@w, u = P·hw.
    # bwd: dhw = Pᵀ·du (always — gw = combᵀ·dhw needs it); dcomb = dhw@wᵀ.
    flops = 2.0 * c * fin * fout + spmm_out
    bytes_ = (c * fin + fin * fout             # read comb + w
              + 2.0 * c * fout                 # hw write + read
              + e + n * fout)                  # tiles + write u
    if train:
        flops += spmm_out + 2.0 * c * fin * fout           # dhw, gw
        bytes_ += (n * fout + e + 2.0 * c * fout           # du, tiles, dhw
                   + c * fin + fin * fout)                 # comb + gw
        if not first_layer:
            flops += 2.0 * c * fin * fout                  # dcomb = dhw@wᵀ
            bytes_ += fin * fout + c * fin                 # w + write dcomb
    return GcnLayerCost(flops=flops, hbm_bytes=bytes_ * dtype_bytes)


def _nnz_per_layer(nnz_eff, num_layers: int) -> list[float]:
    """Normalize `nnz_eff` to one measured value per layer.

    A scalar is broadcast (the historical uniform-density assumption — the
    propagation matrix is shared across layers, so this is exact when the
    caller passes a MEASURED count); a sequence is taken as per-layer
    measured sparse work and must match the layer count.
    """
    if hasattr(nnz_eff, "__len__"):
        vals = [float(v) for v in nnz_eff]
        if len(vals) != num_layers:
            raise ValueError(
                f"per-layer nnz_eff has {len(vals)} entries for "
                f"{num_layers} layers")
        return vals
    return [float(nnz_eff)] * num_layers


# -- boundary wire pricing (ISSUE 8: quantized + sliced traffic) -------

#: Bytes one boundary-payload row of width f occupies under each wire
#: format — must match the codec layouts in repro.core.codec (the int8/
#: int4 figures include the trailing per-block f32 scale region).
def wire_bytes_per_row(wire: str, f: int, block: int = 128) -> float:
    """Wire bytes of one f-wide boundary row under `wire` (f32 payload)."""
    nb = -(-f // block) if f else 0
    if wire == "f32":
        return 4.0 * f
    if wire == "bf16":
        return 2.0 * f
    if wire == "int8":
        return float(f + 4 * nb)
    if wire == "int4":
        return float((f + 1) // 2 + 4 * nb)
    raise ValueError(f"unknown wire format {wire!r}")


def choose_wire_formats(widths, candidates=("bf16", "int8"),
                        block: int = 128) -> tuple[str, ...]:
    """Per-layer wire format `wire="auto"` resolves to: the candidate with
    the fewest bytes for each payload width, earliest-listed winning ties.

    The default candidate set deliberately leads with bf16 (byte ties
    prefer fidelity) and excludes int4 — its accuracy cost is large enough
    that shipping nibbles stays an explicit per-run decision."""
    out = []
    for f in widths:
        out.append(min(candidates,
                       key=lambda w: (wire_bytes_per_row(w, int(f), block),
                                      candidates.index(w))))
    return tuple(out)


#: Comm-to-compute exchange rate for the order/wire co-decision: FLOPs one
#: wire byte is worth on the paper-normalized GPU (sustained matmul
#: throughput / link bandwidth — benchmarks.common.PAPER_GPU's
#: 13.45e12 * 0.22 flops over 4e9 B/s).
DEFAULT_FLOPS_PER_WIRE_BYTE = 13.45e12 * 0.22 / 4e9


def gcn_order_report(layer_dims, num_rows: int, combined: int,
                     nnz_eff, train: bool = True,
                     fused: bool = False, tile: int = _TILE,
                     slot_rows: float = 0.0, wire_bytes_fn=None,
                     slice_boundary: bool = False,
                     comm_flops_per_byte: float = 0.0) -> list[dict]:
    """Per-layer cost table: {order: GcnLayerCost} + the argmin choice.

    `layer_dims` is ``ModelConfig.layer_dims()`` — [(fin, fout)] per layer.
    `nnz_eff` is the measured effective sparse multiply-adds per feature
    column — a scalar (broadcast to every layer) or a per-layer sequence;
    for the tile engines pass the measured post-layout tile count × T²
    (PipeGCN.layer_orders does), NOT a uniform-density estimate — a
    reordered graph has measurably fewer tiles and the argmin can differ.
    The choice minimizes FLOPs; HBM bytes break exact FLOP ties (and are
    reported for the roofline-minded reader either way). Callers with the
    real kernel tile size in hand pass it through — it prices the fused
    backward's prologue recompute.

    Boundary-byte pricing (all off by default, so the classic FLOP argmin
    is unchanged): with `slot_rows` (boundary rows per exchange payload,
    P·slot per partition) and `comm_flops_per_byte` > 0, each order is
    charged `comm_flops_per_byte × wire_bytes` in the argmin key, where
    wire_bytes prices the payload width that order ships — fin, or fout
    under transform-first when `slice_boundary` and fout <= fin (layer 0
    always ships fin: its payload is the raw input) — through
    `wire_bytes_fn(layer, width)` (default: 4 bytes/element), once forward
    plus once backward for trained layers > 0. The per-order byte figure
    lands in the report as "wire_bytes" either way."""
    per_layer_nnz = _nnz_per_layer(nnz_eff, len(layer_dims))
    if wire_bytes_fn is None:
        wire_bytes_fn = lambda ell, f: 4.0 * f     # noqa: E731
    out = []
    for ell, (fin, fout) in enumerate(layer_dims):
        costs = {}
        wire_bytes = {}
        for order in GCN_ORDERS:
            costs[order] = gcn_layer_order_cost(
                order, fin, fout, num_rows, combined, per_layer_nnz[ell],
                first_layer=(ell == 0), train=train,
                fused=(fused and order == "aggregate-first"), tile=tile)
            width = (fout if (slice_boundary and ell > 0 and fout <= fin
                              and order == "transform-first") else fin)
            n_dir = 1 + (1 if train and ell > 0 else 0)
            wire_bytes[order] = slot_rows * wire_bytes_fn(ell, width) * n_dir
        chosen = min(GCN_ORDERS,
                     key=lambda o: (costs[o].flops
                                    + comm_flops_per_byte * wire_bytes[o],
                                    costs[o].hbm_bytes))
        out.append({"layer": ell, "costs": costs, "chosen": chosen,
                    "wire_bytes": wire_bytes})
    return out


def choose_gcn_orders(layer_dims, num_rows: int, combined: int,
                      nnz_eff, train: bool = True,
                      fused: bool = False,
                      tile: int = _TILE, **wire_kw) -> tuple[str, ...]:
    """The static per-layer ordering the "auto" matmul_order resolves to.

    `nnz_eff` follows `gcn_order_report`: scalar or per-layer measured
    sparse work (tile count × T² for the tile engines); `wire_kw` passes
    the boundary-byte pricing knobs through (slot_rows / wire_bytes_fn /
    slice_boundary / comm_flops_per_byte)."""
    return tuple(r["chosen"] for r in gcn_order_report(
        layer_dims, num_rows, combined, nnz_eff, train=train, fused=fused,
        tile=tile, **wire_kw))


# ----------------------------------------------------------------------
# Graph-layout report: how well a PartitionedGraph's intra-partition node
# order packs the tile frontier the block-sparse engines pay for. Consumed
# by the trainer log line, benchmarks/bench_kernels.run_reorder_sweep (the
# BENCH_*.json natural-vs-rcm record + gate), and tests/test_reorder.py.
# ----------------------------------------------------------------------

def graph_layout_report(pg, tile: int = _TILE) -> dict:
    """Layout-quality metrics of the padded partition shards.

    Per partition (and aggregated):
      tiles       nonempty tile×tile blocks of the local [P_in | P_bd]
                  shard (TRUE count over real edges — no padding, no
                  zero fillers; the quantity the reorder shrinks)
      bandwidth   max |row − col| over intra-partition edges (the RCM
                  objective); `mean_bandwidth` alongside
      halo_rows   rows with at least one halo-column edge
      halo_runs   maximal contiguous runs of those rows — 1 means the halo
                  frontier is perfectly clustered
      bnd_tiles   nonempty tiles whose output rows land in the boundary
                  tail (row block >= the split-phase cut b0) — the
                  critical-path prefix the split schedule must run BEFORE
                  issuing the exchange; when the split is infeasible the
                  whole stream is the prefix (bnd_tiles == tiles)
    Aggregated: `bnd_tile_share` = Σbnd_tiles / Σtiles (the fraction of
    sparse work that is NOT overlappable — 1.0 when infeasible), so the
    reorder sweep shows how much of the tile stream each layout exposes
    to the split-phase overlap.
    """
    import numpy as np

    from repro.graph.halo import boundary_row_split
    split = boundary_row_split(pg, tile)
    b0 = split["b0"] if split["feasible"] else 0
    combined = pg.max_inner + pg.num_parts * pg.slot
    ncb = -(-combined // tile)
    per = []
    for i in range(pg.num_parts):
        keep = pg.edge_w[i] != 0
        row = pg.edge_row[i][keep].astype(np.int64)
        col = pg.edge_col[i][keep].astype(np.int64)
        tile_ids = np.unique((row // tile) * ncb + (col // tile))
        tiles = len(tile_ids)
        intra = col < pg.max_inner
        span = np.abs(row[intra] - col[intra])
        halo_rows = np.unique(row[~intra])
        per.append({
            "tiles": int(tiles),
            "bnd_tiles": int(np.sum(tile_ids // ncb >= b0)
                             if split["feasible"] else tiles),
            "bandwidth": int(span.max()) if span.size else 0,
            "mean_bandwidth": float(span.mean()) if span.size else 0.0,
            "halo_rows": int(len(halo_rows)),
            "halo_runs": (int(np.sum(np.diff(halo_rows) > 1) + 1)
                          if len(halo_rows) else 0),
        })
    tiles_total = sum(p["tiles"] for p in per)
    bnd_total = sum(p["bnd_tiles"] for p in per)
    return {
        "layout": getattr(pg, "layout", "natural"),
        "tile": tile,
        "per_partition": per,
        "tiles": tiles_total,
        "bandwidth": max(p["bandwidth"] for p in per),
        "mean_bandwidth": float(np.mean([p["mean_bandwidth"] for p in per])),
        "halo_runs": sum(p["halo_runs"] for p in per),
        "split_feasible": bool(split["feasible"]),
        "bnd_tiles": bnd_total,
        "bnd_tile_share": float(bnd_total / max(tiles_total, 1)),
    }


def split_overlap_report(pg, layer_dims, tile: int = _TILE,
                         dtype_bytes: int = 4) -> list[dict]:
    """Static per-layer price of the split-phase schedule.

    For each layer: the MXU FLOPs of the boundary phase (the critical-path
    prefix that must finish before the exchange can be issued), the
    interior-phase FLOPs available to hide the collective behind, and the
    per-partition bytes each direction puts on the wire (forward feature
    send of width fin; the backward gradient send has the same width —
    layer 0 sends no gradient). `overlappable` is the interior share of
    the padded tile stream — what fraction of the layer's sparse work the
    schedule moves behind the in-flight collective. Tile counts are the
    PADDED per-partition stream (every partition executes the same grid),
    from the same memoized extraction the Topology uses; returns [] when
    the split is infeasible for this graph."""
    from repro.graph.halo import extract_partition_tiles
    pt = extract_partition_tiles(pg, tile)
    if pt.fwd_bnd is None:
        return []
    n_tiles = pt.rows.shape[-1]
    wire_rows = pg.num_parts * pg.slot
    out = []
    for ell, (fin, fout) in enumerate(layer_dims):
        mxu = 2.0 * tile * tile          # multiply-adds per tile per column
        out.append({
            "layer": ell,
            "bnd_flops": pt.fwd_bnd * mxu * fin,
            "int_flops": (n_tiles - pt.fwd_bnd) * mxu * fin,
            "t_bnd_flops": pt.t_bnd * mxu * fin,
            "t_int_flops": (n_tiles - pt.t_bnd) * mxu * fin,
            "wire_bytes": wire_rows * fin * dtype_bytes,
            "grad_wire_bytes": (wire_rows * fin * dtype_bytes
                                if ell > 0 else 0),
            "overlappable": float((n_tiles - pt.fwd_bnd) / n_tiles),
        })
    return out
