"""Pallas TPU kernels for the compute hot spots:
  gcn_spmm         block-sparse neighbor aggregation (the paper's SpMM)
  flash_attention  blockwise online-softmax GQA attention (prefill path)
Each has a pure-jnp oracle in ref.py and a jitted wrapper in ops.py.
"""
