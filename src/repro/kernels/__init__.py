"""Pallas TPU kernels for the compute hot spots:
  gcn_spmm         block-sparse neighbor aggregation, forward + transpose
                   (the paper's SpMM, Eq. 3/4), plus COO→tile extraction
  flash_attention  blockwise online-softmax GQA attention (prefill path)
  aggregate        pluggable aggregation engines ("coo" | "blocksparse")
                   behind one spmm/spmm_t interface for the train path
Each kernel has a pure-jnp oracle in ref.py and a jitted wrapper in ops.py.
"""
from repro.kernels.aggregate import ENGINES, get_engine

__all__ = ["ENGINES", "get_engine"]
