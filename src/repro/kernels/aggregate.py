"""Pluggable aggregation engines for the PipeGCN hot path (Eq. 3/4 SpMM).

The training loop calls aggregation through a narrow interface:

    z      = engine.spmm(tslice, comb, num_rows)      # z = P_local · comb
    dcomb  = engine.spmm_t(tslice, dz, num_cols)      # δcomb = P_localᵀ · δz
    u, z   = engine.aggregate_transform(tslice, comb, w, b, num_rows)
             #  u = (P_local · comb) @ w + b   (aggregate-first layer fwd)
    dcomb  = engine.aggregate_transform_t(tslice, du, w, num_cols)
             #  δcomb = P_localᵀ · (du @ wᵀ)   (aggregate-first layer bwd)

`tslice` is the tuple of per-partition Topology fields named by
``engine.fields`` — the model layer stays agnostic to the storage format.
The ``aggregate_transform*`` pair defaults to COMPOSING the two primitive
ops (an SpMM plus a dense matmul, with the (rows, F_in) intermediate
materialized between them), so "coo" and plain "blocksparse" behave exactly
as before; the "fused" engine overrides it with single-pass Pallas kernels
in which the intermediate never leaves VMEM. Three implementations:

  coo         padded COO + `jax.ops.segment_sum` (the verified fallback;
              exact in float64, works for any shape).
  blocksparse MXU-shaped Pallas kernels over TILE×TILE tiles
              (`repro.kernels.gcn_spmm`). Inputs are zero-padded to tile /
              feature-block multiples only when needed (topology-padded
              shapes skip the pad entirely) and the result is sliced back,
              so callers never see the padded shapes. Computes in the
              caller's dtype (f32 in production; f64 under the x64
              exactness tests).
  fused       blocksparse storage + the fused aggregate+transform kernels:
              forward epilogue matmul (u = z@w + b on the run-flush, with
              optional fused bias+ReLU and z as an optional second output)
              and backward prologue matmul (dcomb = Pᵀ·(du@wᵀ)). Computes
              in the caller's dtype (f32 in production; f64 under the x64
              exactness tests, where it matches "coo" to 1e-12).

Select with ``ModelConfig.agg`` ("coo" | "blocksparse" | "fused"); the tile
engines need tile fields on the Topology (``topology_from(pg,
with_tiles=True)`` or ``GraphDataPipeline.build(..., agg="blocksparse")``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.gcn_spmm import FEAT_BLOCK, TILE


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


def _phase_mask(in_boundary, phase: str):
    """Edge-level phase membership from a boundary predicate."""
    if phase == "boundary":
        return in_boundary
    if phase == "interior":
        return ~in_boundary
    raise ValueError(f"phase must be 'boundary' or 'interior', got {phase!r}")


def _pad2(x, rows: int, cols: int):
    """Zero-pad a 2-D array up to (rows, cols), skipping the op entirely
    when the shape already matches (the common case after topology padding:
    `jnp.pad` is not free even for zero-width pads — it still emits a
    copy)."""
    r, c = x.shape
    if (r, c) == (rows, cols):
        return x
    return jnp.pad(x, ((0, rows - r), (0, cols - c)))


class AggregationEngine:
    """Interface + default fused-op composition shared by all engines."""

    name: str
    fields: tuple[str, ...]

    def spmm(self, tslice, comb, num_rows: int):
        raise NotImplementedError

    def spmm_t(self, tslice, dz, num_cols: int):
        raise NotImplementedError

    def aggregate_transform(self, tslice, comb, w, b, num_rows: int,
                            relu: bool = False, with_z: bool = True):
        """u = (P·comb) @ w + b (optionally ReLU'd), plus the aggregation
        residual z = P·comb (None when `with_z=False`, e.g. at eval).
        Default: compose the primitive SpMM with a dense matmul — the
        (num_rows, F_in) intermediate round-trips through HBM."""
        z = self.spmm(tslice, comb, num_rows)
        u = z @ w + b
        if relu:
            u = jax.nn.relu(u)
        return u, (z if with_z else None)

    def aggregate_transform_t(self, tslice, du, w, num_cols: int):
        """δcomb = Pᵀ·(du @ wᵀ). Default: compose — the (rows, F_in)
        dz intermediate round-trips through HBM."""
        return self.spmm_t(tslice, du @ w.T, num_cols)

    def spmm_phased(self, tslice, comb, num_rows: int, split, phase: str):
        """One phase of z = P·comb under the split-phase overlap schedule
        (`split` is a kernels.gcn_spmm.SplitSpec, `phase` is "boundary" |
        "interior"). Contract shared by all engines: rows OUTSIDE the
        phase (below split.row_tail for "boundary", at/above it for
        "interior") are unspecified; each phase's own rows are
        bit-identical to the unsplit `spmm` on the same inputs."""
        raise NotImplementedError

    def spmm_t_phased(self, tslice, dz, num_cols: int, split, phase: str):
        """One phase of δcomb = Pᵀ·δz; the phase cut is at
        split.col_tail. Same unspecified-rows contract as spmm_phased."""
        raise NotImplementedError


class CooEngine(AggregationEngine):
    """Padded-COO aggregation via segment_sum (scatter-add)."""

    name = "coo"
    fields = ("edge_row", "edge_col", "edge_w")

    def spmm(self, tslice, comb, num_rows: int):
        edge_row, edge_col, edge_w = tslice
        vals = comb[edge_col] * edge_w[:, None]
        return jax.ops.segment_sum(vals, edge_row, num_segments=num_rows)

    def spmm_t(self, tslice, dz, num_cols: int):
        edge_row, edge_col, edge_w = tslice
        vals = dz[edge_row] * edge_w[:, None]
        return jax.ops.segment_sum(vals, edge_col, num_segments=num_cols)

    # Phased variants compose via index masks rather than stream slices:
    # out-of-phase edges get weight 0, so each phase's own rows see the
    # IDENTICAL segment_sum term sequence as the unsplit call (zeroed
    # terms add exact 0.0) — bitwise parity engine-cross-engine with the
    # tile engines' sliced streams, which is what the SPMD parity matrix
    # gates on. Out-of-phase rows come out zero (a valid value for
    # "unspecified").
    def spmm_phased(self, tslice, comb, num_rows: int, split, phase: str):
        edge_row, edge_col, edge_w = tslice
        keep = _phase_mask(edge_row >= split.row_tail, phase)
        vals = comb[edge_col] * jnp.where(keep, edge_w, 0)[:, None]
        return jax.ops.segment_sum(vals, edge_row, num_segments=num_rows)

    def spmm_t_phased(self, tslice, dz, num_cols: int, split, phase: str):
        edge_row, edge_col, edge_w = tslice
        keep = _phase_mask(edge_col >= split.col_tail, phase)
        vals = dz[edge_row] * jnp.where(keep, edge_w, 0)[:, None]
        return jax.ops.segment_sum(vals, edge_col, num_segments=num_cols)


class BlockSparseEngine(AggregationEngine):
    """Block-sparse aggregation on the Pallas SpMM kernels.

    Pads rows to TILE and features to FEAT_BLOCK multiples per call when
    the caller's shapes are not already multiples (the tile grid is fixed
    offline by `build_tile_topology`, so row padding is only about matching
    the kernel's static output shape). Computes in the CALLER'S dtype —
    f32 in production; f64 under the `jax_enable_x64` exactness tests,
    where the tile values are upcast and the result stays
    1e-12-comparable to the COO engine even across node layouts (the
    cross-layout parity bar of tests/test_reorder.py and the SPMD matrix).
    """

    name = "blocksparse"
    fields = ("tile_rows", "tile_cols", "tile_vals",
              "tile_t_out", "tile_t_in", "tile_t_perm")

    def _vals(self, tslice, like):
        tile_vals = tslice[2]
        return tile_vals.astype(like.dtype)

    def spmm(self, tslice, comb, num_rows: int):
        tile_rows, tile_cols = tslice[:2]
        combined, f = comb.shape
        rpad = _ceil_to(num_rows, TILE)
        fpad = _ceil_to(f, FEAT_BLOCK)
        combp = _pad2(comb, _ceil_to(combined, TILE), fpad)
        z = ops.spmm(tile_rows, tile_cols, self._vals(tslice, comb),
                     combp, rpad)
        assert z.shape == (rpad, fpad), (z.shape, rpad, fpad)
        return z[:num_rows, :f]

    def spmm_t(self, tslice, dz, num_cols: int):
        t_out, t_in, t_perm = tslice[3:]
        num_rows, f = dz.shape
        cpad = _ceil_to(num_cols, TILE)
        fpad = _ceil_to(f, FEAT_BLOCK)
        dzp = _pad2(dz, _ceil_to(num_rows, TILE), fpad)
        d = ops.spmm_t(t_out, t_in, t_perm, self._vals(tslice, dz),
                       dzp, cpad)
        assert d.shape == (cpad, fpad), (d.shape, cpad, fpad)
        return d[:num_cols, :f]

    # Phased variants: static suffix/prefix slices of the streams (the
    # phase-aware topology padding makes the cut uniform across
    # partitions). Tiles of one output block live entirely in one phase,
    # so each phase's own rows are BITWISE the unsplit result — same
    # tiles, same accumulation order. Out-of-phase rows are unwritten
    # kernel output (garbage, never to be read).
    def spmm_phased(self, tslice, comb, num_rows: int, split, phase: str):
        tile_rows, tile_cols = tslice[:2]
        combined, f = comb.shape
        rpad = _ceil_to(num_rows, TILE)
        fpad = _ceil_to(f, FEAT_BLOCK)
        combp = _pad2(comb, _ceil_to(combined, TILE), fpad)
        z = ops.spmm_phased(tile_rows, tile_cols, self._vals(tslice, comb),
                            combp, rpad, split.fwd_bnd_tiles, phase)
        assert z.shape == (rpad, fpad), (z.shape, rpad, fpad)
        return z[:num_rows, :f]

    def spmm_t_phased(self, tslice, dz, num_cols: int, split, phase: str):
        t_out, t_in, t_perm = tslice[3:]
        num_rows, f = dz.shape
        cpad = _ceil_to(num_cols, TILE)
        fpad = _ceil_to(f, FEAT_BLOCK)
        dzp = _pad2(dz, _ceil_to(num_rows, TILE), fpad)
        d = ops.spmm_t_phased(t_out, t_in, t_perm, self._vals(tslice, dz),
                              dzp, cpad, split.t_bnd_tiles, phase)
        assert d.shape == (cpad, fpad), (d.shape, cpad, fpad)
        return d[:num_cols, :f]


class FusedBlockSparseEngine(BlockSparseEngine):
    """Blocksparse tiles + fused aggregate⊗transform Pallas kernels.

    The primitive spmm/spmm_t (used by the transform-first ordering) are
    inherited; the `aggregate_transform*` pair runs the single-pass fused
    kernels, in the caller's dtype like the parent. The phased variants
    are inherited too: under the split-phase overlap schedule the layer
    falls back to the composed (aggregate, then dense transform) path —
    the fused epilogue would write out-of-phase garbage rows through the
    dense weight — and the cost model is told `fused=False` accordingly
    (see PipeGCN.layer_orders).
    """

    name = "fused"

    def aggregate_transform(self, tslice, comb, w, b, num_rows: int,
                            relu: bool = False, with_z: bool = True):
        tile_rows, tile_cols = tslice[:2]
        combined, fin = comb.shape
        fout = w.shape[1]
        rpad = _ceil_to(num_rows, TILE)
        fin_p = _ceil_to(fin, FEAT_BLOCK)
        fout_p = _ceil_to(fout, FEAT_BLOCK)
        combp = _pad2(comb, _ceil_to(combined, TILE), fin_p)
        wp = _pad2(w, fin_p, fout_p)
        bp = _pad2(b.reshape(1, -1), 1, fout_p)
        u, z = ops.spmm_fused(tile_rows, tile_cols, self._vals(tslice, comb),
                              combp, wp, bp, rpad, relu=relu, with_z=with_z)
        assert u.shape == (rpad, fout_p), (u.shape, rpad, fout_p)
        u = u[:num_rows, :fout]
        if with_z:
            assert z.shape == (rpad, fin_p), (z.shape, rpad, fin_p)
            z = z[:num_rows, :fin]
        return u, z

    def aggregate_transform_t(self, tslice, du, w, num_cols: int):
        t_out, t_in, t_perm = tslice[3:]
        num_rows, fout = du.shape
        fin = w.shape[0]
        cpad = _ceil_to(num_cols, TILE)
        fin_p = _ceil_to(fin, FEAT_BLOCK)
        fout_p = _ceil_to(fout, FEAT_BLOCK)
        dup = _pad2(du, _ceil_to(num_rows, TILE), fout_p)
        wp = _pad2(w, fin_p, fout_p)
        d = ops.spmm_fused_t(t_out, t_in, t_perm, self._vals(tslice, du),
                             dup, wp, cpad)
        assert d.shape == (cpad, fin_p), (d.shape, cpad, fin_p)
        return d[:num_cols, :fin]


ENGINES = {e.name: e for e in (CooEngine(), BlockSparseEngine(),
                               FusedBlockSparseEngine())}


def get_engine(name: str):
    """Look up an aggregation engine ("coo" | "blocksparse" | "fused")."""
    try:
        return ENGINES[name]
    except KeyError:
        raise KeyError(
            f"unknown aggregation engine {name!r}; have {sorted(ENGINES)}"
        ) from None
