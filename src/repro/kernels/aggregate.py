"""Pluggable aggregation engines for the PipeGCN hot path (Eq. 3/4 SpMM).

The training loop calls aggregation through a narrow two-method interface:

    z     = engine.spmm(tslice, comb, num_rows)     # z = P_local · comb
    dcomb = engine.spmm_t(tslice, dz, num_cols)     # δcomb = P_localᵀ · δz

`tslice` is the tuple of per-partition Topology fields named by
``engine.fields`` — the model layer stays agnostic to the storage format.
Two implementations:

  coo         padded COO + `jax.ops.segment_sum` (the verified fallback;
              exact in float64, works for any shape).
  blocksparse MXU-shaped Pallas kernels over TILE×TILE tiles
              (`repro.kernels.gcn_spmm`). Inputs are zero-padded to tile /
              feature-block multiples on the fly and the result is sliced
              back, so callers never see the padded shapes. Compute is f32.

Select with ``ModelConfig.agg`` ("coo" | "blocksparse"); blocksparse needs
tile fields on the Topology (``topology_from(pg, with_tiles=True)`` or
``GraphDataPipeline.build(..., agg="blocksparse")``).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.kernels.gcn_spmm import FEAT_BLOCK, TILE


def _ceil_to(n: int, m: int) -> int:
    return -(-n // m) * m


class CooEngine:
    """Padded-COO aggregation via segment_sum (scatter-add)."""

    name = "coo"
    fields = ("edge_row", "edge_col", "edge_w")

    def spmm(self, tslice, comb, num_rows: int):
        edge_row, edge_col, edge_w = tslice
        vals = comb[edge_col] * edge_w[:, None]
        return jax.ops.segment_sum(vals, edge_row, num_segments=num_rows)

    def spmm_t(self, tslice, dz, num_cols: int):
        edge_row, edge_col, edge_w = tslice
        vals = dz[edge_row] * edge_w[:, None]
        return jax.ops.segment_sum(vals, edge_col, num_segments=num_cols)


class BlockSparseEngine:
    """Block-sparse aggregation on the Pallas SpMM kernels.

    Pads rows to TILE and features to FEAT_BLOCK multiples per call (the
    tile grid is fixed offline by `build_tile_topology`, so row padding is
    only about matching the kernel's static output shape), computes in
    float32, and slices/casts back to the caller's shape and dtype.
    """

    name = "blocksparse"
    fields = ("tile_rows", "tile_cols", "tile_vals",
              "tile_t_out", "tile_t_in", "tile_t_perm")

    def spmm(self, tslice, comb, num_rows: int):
        tile_rows, tile_cols, tile_vals = tslice[:3]
        combined, f = comb.shape
        rpad = _ceil_to(num_rows, TILE)
        cpad = _ceil_to(combined, TILE)
        fpad = _ceil_to(f, FEAT_BLOCK)
        combp = jnp.pad(comb.astype(jnp.float32),
                        ((0, cpad - combined), (0, fpad - f)))
        z = ops.spmm(tile_rows, tile_cols, tile_vals, combp, rpad)
        return z[:num_rows, :f].astype(comb.dtype)

    def spmm_t(self, tslice, dz, num_cols: int):
        tile_vals = tslice[2]
        t_out, t_in, t_perm = tslice[3:]
        num_rows, f = dz.shape
        rpad = _ceil_to(num_rows, TILE)
        cpad = _ceil_to(num_cols, TILE)
        fpad = _ceil_to(f, FEAT_BLOCK)
        dzp = jnp.pad(dz.astype(jnp.float32),
                      ((0, rpad - num_rows), (0, fpad - f)))
        d = ops.spmm_t(t_out, t_in, t_perm, tile_vals, dzp, cpad)
        return d[:num_cols, :f].astype(dz.dtype)


ENGINES = {e.name: e for e in (CooEngine(), BlockSparseEngine())}


def get_engine(name: str):
    """Look up an aggregation engine by name ("coo" | "blocksparse")."""
    try:
        return ENGINES[name]
    except KeyError:
        raise KeyError(
            f"unknown aggregation engine {name!r}; have {sorted(ENGINES)}"
        ) from None
