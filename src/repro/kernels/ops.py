"""Jitted public wrappers for the Pallas kernels.

`interpret` defaults to auto: True on CPU (this container — kernel bodies
execute in Python for validation), False on real TPU.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.kernels import flash_attention as _fa
from repro.kernels import gcn_spmm as _spmm


# Single source of truth for the auto-detect lives next to the kernels, so
# direct callers of gcn_spmm.py get the same resolution as these wrappers.
_auto_interpret = _spmm.resolve_interpret


@partial(jax.jit, static_argnames=("num_rows", "interpret"))
def spmm(tile_rows, tile_cols, tile_vals, h, num_rows: int,
         interpret: bool | None = None):
    """Block-sparse aggregation z = P·h (see gcn_spmm.py)."""
    return _spmm.spmm_block_sparse(tile_rows, tile_cols, tile_vals, h,
                                   num_rows, interpret=interpret)


@partial(jax.jit, static_argnames=("num_cols", "interpret"))
def spmm_t(t_out, t_in, t_perm, tile_vals, dz, num_cols: int,
           interpret: bool | None = None):
    """Block-sparse transpose aggregation δcomb = Pᵀ·δz (see gcn_spmm.py)."""
    return _spmm.spmm_block_sparse_t(t_out, t_in, t_perm, tile_vals, dz,
                                     num_cols, interpret=interpret)


@partial(jax.jit, static_argnames=("num_rows", "n_bnd", "phase", "interpret"))
def spmm_phased(tile_rows, tile_cols, tile_vals, h, num_rows: int,
                n_bnd: int, phase: str, interpret: bool | None = None):
    """One phase (interior | boundary) of z = P·h — static suffix/prefix
    slice of the tile stream; out-of-phase rows are unspecified (see
    gcn_spmm.spmm_block_sparse_phased)."""
    return _spmm.spmm_block_sparse_phased(tile_rows, tile_cols, tile_vals,
                                          h, num_rows, n_bnd, phase,
                                          interpret=interpret)


@partial(jax.jit, static_argnames=("num_cols", "n_bnd", "phase", "interpret"))
def spmm_t_phased(t_out, t_in, t_perm, tile_vals, dz, num_cols: int,
                  n_bnd: int, phase: str, interpret: bool | None = None):
    """One phase of δcomb = Pᵀ·δz (see gcn_spmm.spmm_block_sparse_t_phased)."""
    return _spmm.spmm_block_sparse_t_phased(t_out, t_in, t_perm, tile_vals,
                                            dz, num_cols, n_bnd, phase,
                                            interpret=interpret)


@partial(jax.jit, static_argnames=("num_rows", "relu", "with_z", "interpret"))
def spmm_fused(tile_rows, tile_cols, tile_vals, h, w, b, num_rows: int,
               relu: bool = False, with_z: bool = True,
               interpret: bool | None = None):
    """Fused u = (P·h)@w + b (+ReLU), z optional (see gcn_spmm.py)."""
    return _spmm.spmm_block_sparse_fused(tile_rows, tile_cols, tile_vals,
                                         h, w, b, num_rows, relu=relu,
                                         with_z=with_z, interpret=interpret)


@partial(jax.jit, static_argnames=("num_cols", "interpret"))
def spmm_fused_t(t_out, t_in, t_perm, tile_vals, du, w, num_cols: int,
                 interpret: bool | None = None):
    """Fused δcomb = Pᵀ·(du@wᵀ), prologue matmul (see gcn_spmm.py)."""
    return _spmm.spmm_block_sparse_fused_t(t_out, t_in, t_perm, tile_vals,
                                           du, w, num_cols,
                                           interpret=interpret)


@partial(jax.jit, static_argnames=("causal", "window", "q_block", "kv_block",
                                   "interpret"))
def attention(q, k, v, causal: bool = True, window: int = 0,
              q_block: int = _fa.DEFAULT_Q_BLOCK,
              kv_block: int = _fa.DEFAULT_KV_BLOCK,
              interpret: bool | None = None):
    """Flash GQA attention (see flash_attention.py)."""
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_block=q_block, kv_block=kv_block,
                               interpret=_auto_interpret(interpret))


build_tiles = _spmm.build_tiles
build_tile_topology = _spmm.build_tile_topology
tile_density = _spmm.tile_density
