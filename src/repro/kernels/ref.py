"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth
swept by the hypothesis tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def spmm_ref(tile_rows, tile_cols, tile_vals, h, num_rows: int):
    """Dense oracle for the block-sparse SpMM kernel."""
    tile = tile_vals.shape[-1]
    f = h.shape[1]
    hb = h.reshape(-1, tile, f)
    contrib = jnp.einsum("tij,tjf->tif", tile_vals, hb[tile_cols])
    out = jnp.zeros((num_rows // tile, tile, f), h.dtype)
    out = out.at[tile_rows].add(contrib.astype(h.dtype))
    return out.reshape(num_rows, f)


def mha_ref(q, k, v, causal: bool = True, window: int = 0,
            positions=None):
    """Dense attention oracle (GQA): q (B,S,H,d), k/v (B,T,K,d)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    g = h // kh
    if positions is None:
        positions = jnp.arange(s)
    tpos = jnp.arange(t)
    qg = q.reshape(b, s, kh, g, d)
    scores = jnp.einsum("bskgd,btkd->bskgt", qg, k) / jnp.sqrt(d)
    mask = jnp.ones((s, t), bool)
    if causal:
        mask &= tpos[None, :] <= positions[:, None]
    if window:
        mask &= positions[:, None] - tpos[None, :] < window
    scores = jnp.where(mask[None, :, None, None, :],
                       scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bskgt,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, d)
