"""Block-sparse SpMM Pallas TPU kernels — the GCN neighbor-aggregation
hot spot, forward (z = P·H, Eq. 3) and transpose (δcomb = Pᵀ·δz, Eq. 4 /
Alg. 1 lines 17–30), plus the offline tile extraction that feeds them.

TPU adaptation (DESIGN.md §2.4): CSR gather/scatter is VPU-hostile; instead
the propagation matrix is tiled into TILE×TILE *dense* blocks (MXU-shaped),
only nonzero tiles are stored, and the kernel contracts each nonzero tile
against the matching feature row-block on the MXU:

    out[r·T:(r+1)·T, :] += tile_vals[t] @ h[c·T:(c+1)·T, :]

Tiles are sorted by output block; the (row-major) grid revisits the same
output block for consecutive tiles of one run, accumulating in VMEM, and
flushes when the output block changes — the canonical TPU block-sparse
reduction pattern. Tile coordinates arrive via scalar prefetch
(PrefetchScalarGridSpec) so the index stream is resident before the DMA of
each tile.

The transpose kernel (`spmm_block_sparse_t`) reuses the SAME tile values:
it walks the tiles in a column-major order (a prefetched permutation into
`tile_vals`) and contracts each tile transposed (dot_general over dim 0),
accumulating into the *column* block — so the manual backward runs
block-sparse without storing a second copy of P.

Tile extraction (`build_tile_topology`) works directly on COO triples and
never materializes a dense (N, N) matrix: tiles are bucketed with one
`np.unique` over block keys and one scatter-add into the (n_tiles, T, T)
value array — O(nnz + n_tiles·T²) memory, the block-sparse footprint.

Both engines behind one interface live in `repro.kernels.aggregate`; the
training path selects them via ``ModelConfig.agg``.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128          # MXU-shaped adjacency tile
FEAT_BLOCK = 128    # feature columns per grid step


# ----------------------------------------------------------------------
# Forward kernel: z = P · h
# ----------------------------------------------------------------------

def _kernel(rows_ref, cols_ref, vals_ref, h_ref, out_ref, acc_ref):
    """Grid: (num_feature_blocks, num_tiles) — tiles innermost so the output
    block for one row-run stays resident in VMEM."""
    t = pl.program_id(1)

    first_of_run = jnp.logical_or(
        t == 0, rows_ref[t] != rows_ref[jnp.maximum(t - 1, 0)])

    @pl.when(first_of_run)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(vals_ref[...], h_ref[...],
                            preferred_element_type=jnp.float32)

    last = t == pl.num_programs(1) - 1
    last_of_run = jnp.logical_or(
        last, rows_ref[t] != rows_ref[jnp.minimum(t + 1,
                                                  pl.num_programs(1) - 1)])

    @pl.when(last_of_run)
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spmm_block_sparse(tile_rows, tile_cols, tile_vals, h, num_rows: int,
                      interpret: bool = True):
    """z = P_blocksparse · h.

    tile_rows/cols: (n_tiles,) int32 sorted by row; tile_vals: (n_tiles,T,T);
    h: (C, F) with C = num_col_blocks·T, F % FEAT_BLOCK == 0.
    num_rows: output rows (multiple of T). Rows with no tiles stay zero only
    if every row-block has ≥1 tile — callers pad with an explicit zero tile
    per empty row-block (build_tile_topology does this).
    """
    n_tiles = tile_rows.shape[0]
    f = h.shape[1]
    assert f % FEAT_BLOCK == 0 and num_rows % TILE == 0
    grid = (f // FEAT_BLOCK, n_tiles)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,      # tile_rows, tile_cols
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, TILE, TILE),
                             lambda fb, t, rows, cols: (t, 0, 0)),
                pl.BlockSpec((TILE, FEAT_BLOCK),
                             lambda fb, t, rows, cols: (cols[t], fb)),
            ],
            out_specs=pl.BlockSpec((TILE, FEAT_BLOCK),
                                   lambda fb, t, rows, cols: (rows[t], fb)),
            scratch_shapes=[pltpu.VMEM((TILE, FEAT_BLOCK), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((num_rows, f), h.dtype),
        interpret=interpret,
    )(tile_rows, tile_cols, tile_vals, h)


# ----------------------------------------------------------------------
# Transpose kernel: δcomb = Pᵀ · δz  (same tiles, column-major walk)
# ----------------------------------------------------------------------

def _kernel_t(out_ref_s, in_ref_s, perm_ref, vals_ref, dz_ref, out_ref,
              acc_ref):
    """Grid: (num_feature_blocks, num_tiles). The tile stream is sorted by
    Pᵀ's output block (= P's column block); `perm` points each stream slot
    at its tile in the forward `tile_vals`, so no transposed copy of P is
    ever stored. The contraction  valsᵀ @ dz  is a dot_general over dim 0
    of both operands (MXU-friendly, no in-kernel transpose)."""
    t = pl.program_id(1)

    first_of_run = jnp.logical_or(
        t == 0, out_ref_s[t] != out_ref_s[jnp.maximum(t - 1, 0)])

    @pl.when(first_of_run)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        vals_ref[...], dz_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    last = t == pl.num_programs(1) - 1
    last_of_run = jnp.logical_or(
        last, out_ref_s[t] != out_ref_s[jnp.minimum(t + 1,
                                                    pl.num_programs(1) - 1)])

    @pl.when(last_of_run)
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spmm_block_sparse_t(t_out, t_in, t_perm, tile_vals, dz, num_cols: int,
                        interpret: bool = True):
    """δcomb = Pᵀ_blocksparse · δz, reusing the forward tile values.

    t_out:  (n_tiles,) int32 output (column) block per stream slot, sorted
            ascending — every column block must appear ≥ once (zero fillers).
    t_in:   (n_tiles,) int32 input (row) block of δz consumed per slot.
    t_perm: (n_tiles,) int32 index into tile_vals for each slot.
    tile_vals: (n_tiles, T, T) forward tile values (NOT transposed).
    dz: (R, F) with R = num_row_blocks·T, F % FEAT_BLOCK == 0.
    num_cols: output rows of the transpose product (multiple of T).
    """
    n_tiles = t_out.shape[0]
    f = dz.shape[1]
    assert f % FEAT_BLOCK == 0 and num_cols % TILE == 0
    grid = (f // FEAT_BLOCK, n_tiles)

    return pl.pallas_call(
        _kernel_t,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,      # t_out, t_in, t_perm
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, TILE, TILE),
                             lambda fb, t, to, ti, tp: (tp[t], 0, 0)),
                pl.BlockSpec((TILE, FEAT_BLOCK),
                             lambda fb, t, to, ti, tp: (ti[t], fb)),
            ],
            out_specs=pl.BlockSpec((TILE, FEAT_BLOCK),
                                   lambda fb, t, to, ti, tp: (to[t], fb)),
            scratch_shapes=[pltpu.VMEM((TILE, FEAT_BLOCK), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((num_cols, f), dz.dtype),
        interpret=interpret,
    )(t_out, t_in, t_perm, tile_vals, dz)


# ----------------------------------------------------------------------
# Tile extraction (numpy, offline preprocessing — never densifies)
# ----------------------------------------------------------------------

class TileTopology(NamedTuple):
    """Block-sparse topology of one propagation shard, for P and Pᵀ.

    The forward stream (rows/cols/vals) is sorted by (row_block, col_block);
    the transpose stream (t_out/t_in/t_perm) walks the SAME vals array in
    (col_block, row_block) order via `t_perm`. Both streams carry ≥1 tile
    per output block (zero fillers) so every output block gets flushed.
    """

    rows: np.ndarray        # (n_tiles,) int32 row block, sorted
    cols: np.ndarray        # (n_tiles,) int32 col block
    vals: np.ndarray        # (n_tiles, T, T) float32
    t_out: np.ndarray       # (n_tiles,) int32 Pᵀ output block, sorted
    t_in: np.ndarray        # (n_tiles,) int32 Pᵀ input (δz) block
    t_perm: np.ndarray      # (n_tiles,) int32 index into vals
    num_row_blocks: int
    num_col_blocks: int

    @property
    def n_tiles(self) -> int:
        return len(self.rows)


def build_tile_topology(row, col, val, num_rows: int, num_cols: int,
                        tile: int = TILE) -> TileTopology:
    """Bucket a COO triple into TILE×TILE tiles without densifying.

    Memory is O(nnz + n_tiles·T²) — the block-sparse footprint itself —
    never O(num_rows·num_cols). Explicit zeros (padded edges) are dropped.
    Zero filler tiles are appended for row blocks with no tiles (so the
    forward kernel flushes them) and for column blocks with no tiles (so
    the transpose kernel flushes those).
    """
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    val = np.asarray(val, np.float32)
    keep = val != 0
    row, col, val = row[keep], col[keep], val[keep]

    nrb = -(-num_rows // tile)
    ncb = -(-num_cols // tile)
    key = (row // tile) * ncb + (col // tile)
    uk, inv = np.unique(key, return_inverse=True)
    vals = np.zeros((len(uk), tile, tile), np.float32)
    np.add.at(vals, (inv, row % tile, col % tile), val)
    rows = (uk // ncb).astype(np.int32)
    cols = (uk % ncb).astype(np.int32)

    # Zero fillers: one per empty row block (forward flush) and per empty
    # column block (transpose flush).
    fill_r = np.setdiff1d(np.arange(nrb, dtype=np.int32), rows)
    fill_c = np.setdiff1d(np.arange(ncb, dtype=np.int32), cols)
    if len(fill_r) or len(fill_c):
        rows = np.concatenate([rows, fill_r,
                               np.zeros(len(fill_c), np.int32)])
        cols = np.concatenate([cols, np.zeros(len(fill_r), np.int32),
                               fill_c])
        vals = np.concatenate(
            [vals, np.zeros((len(fill_r) + len(fill_c), tile, tile),
                            np.float32)])

    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    t_perm = np.lexsort((rows, cols)).astype(np.int32)
    return TileTopology(rows=rows, cols=cols, vals=vals,
                        t_out=cols[t_perm], t_in=rows[t_perm], t_perm=t_perm,
                        num_row_blocks=nrb, num_col_blocks=ncb)


def pad_tile_topology(tt: TileTopology, n_tiles: int) -> TileTopology:
    """Pad the tile streams to `n_tiles` with zero tiles (uniform shapes
    across partitions for SPMD stacking). Padding appends zero tiles at the
    tail of both streams pointing at the last output block of each, which
    preserves sortedness and adds exact zeros."""
    k = n_tiles - tt.n_tiles
    if k < 0:
        raise ValueError(f"cannot shrink tile topology {tt.n_tiles}->{n_tiles}")
    if k == 0:
        return tt
    tile = tt.vals.shape[-1]
    pad_i = np.arange(tt.n_tiles, tt.n_tiles + k, dtype=np.int32)
    return TileTopology(
        rows=np.concatenate([tt.rows, np.full(k, tt.rows[-1], np.int32)]),
        cols=np.concatenate([tt.cols, np.zeros(k, np.int32)]),
        vals=np.concatenate([tt.vals, np.zeros((k, tile, tile), np.float32)]),
        t_out=np.concatenate([tt.t_out, np.full(k, tt.t_out[-1], np.int32)]),
        t_in=np.concatenate([tt.t_in, np.zeros(k, np.int32)]),
        t_perm=np.concatenate([tt.t_perm, pad_i]),
        num_row_blocks=tt.num_row_blocks, num_col_blocks=tt.num_col_blocks)


def build_tiles(dense_or_coo, num_rows: int, num_cols: int,
                tile: int = TILE):
    """Legacy forward-only extraction: (tile_rows, tile_cols, tile_vals).

    Accepts a dense (R, C) matrix or a (row, col, val) COO triple. The COO
    path never densifies (see build_tile_topology); the dense path simply
    converts the caller's existing matrix to COO first.
    """
    if isinstance(dense_or_coo, tuple):
        row, col, val = dense_or_coo
    else:
        dense = np.asarray(dense_or_coo)
        row, col = np.nonzero(dense)
        val = dense[row, col]
    tt = build_tile_topology(row, col, val, num_rows, num_cols, tile)
    return tt.rows, tt.cols, tt.vals


def tile_density(tile_rows, num_rows: int, num_cols: int,
                 tile: int = TILE) -> float:
    """Fraction of tiles stored vs the dense tile grid."""
    nrb = -(-num_rows // tile)
    ncb = -(-num_cols // tile)
    return len(tile_rows) / float(nrb * ncb)
