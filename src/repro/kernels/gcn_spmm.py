"""Block-sparse SpMM Pallas TPU kernel — the GCN neighbor-aggregation
hot spot (z = P·H), adapted from the paper's CUDA/DGL CSR SpMM to TPU.

TPU adaptation (DESIGN.md §2.4): CSR gather/scatter is VPU-hostile; instead
the propagation matrix is tiled into TILE×TILE *dense* blocks (MXU-shaped),
only nonzero tiles are stored, and the kernel contracts each nonzero tile
against the matching feature row-block on the MXU:

    out[r·T:(r+1)·T, :] += tile_vals[t] @ h[c·T:(c+1)·T, :]

Tiles are sorted by row-block; the (row-major) grid revisits the same output
block for consecutive tiles of one row, accumulating in VMEM, and flushes
when the row-block changes — the canonical TPU block-sparse reduction
pattern. Tile coordinates arrive via scalar prefetch (PrefetchScalarGridSpec)
so the index stream is resident before the DMA of each tile.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128          # MXU-shaped adjacency tile
FEAT_BLOCK = 128    # feature columns per grid step


def _kernel(rows_ref, cols_ref, vals_ref, h_ref, out_ref, acc_ref):
    """Grid: (num_feature_blocks, num_tiles) — tiles innermost so the output
    block for one row-run stays resident in VMEM."""
    t = pl.program_id(1)

    first_of_run = jnp.logical_or(
        t == 0, rows_ref[t] != rows_ref[jnp.maximum(t - 1, 0)])

    @pl.when(first_of_run)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(vals_ref[...], h_ref[...],
                            preferred_element_type=jnp.float32)

    last = t == pl.num_programs(1) - 1
    last_of_run = jnp.logical_or(
        last, rows_ref[t] != rows_ref[jnp.minimum(t + 1,
                                                  pl.num_programs(1) - 1)])

    @pl.when(last_of_run)
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spmm_block_sparse(tile_rows, tile_cols, tile_vals, h, num_rows: int,
                      interpret: bool = True):
    """z = P_blocksparse · h.

    tile_rows/cols: (n_tiles,) int32 sorted by row; tile_vals: (n_tiles,T,T);
    h: (C, F) with C = num_col_blocks·T, F % FEAT_BLOCK == 0.
    num_rows: output rows (multiple of T). Rows with no tiles stay zero only
    if every row-block has ≥1 tile — callers pad with an explicit zero tile
    per empty row-block (build_tiles does this).
    """
    n_tiles = tile_rows.shape[0]
    f = h.shape[1]
    assert f % FEAT_BLOCK == 0 and num_rows % TILE == 0
    grid = (f // FEAT_BLOCK, n_tiles)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,      # tile_rows, tile_cols
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, TILE, TILE),
                             lambda fb, t, rows, cols: (t, 0, 0)),
                pl.BlockSpec((TILE, FEAT_BLOCK),
                             lambda fb, t, rows, cols: (cols[t], fb)),
            ],
            out_specs=pl.BlockSpec((TILE, FEAT_BLOCK),
                                   lambda fb, t, rows, cols: (rows[t], fb)),
            scratch_shapes=[pltpu.VMEM((TILE, FEAT_BLOCK), jnp.float32)],
        ),
        out_shape=jax.ShapeDtypeStruct((num_rows, f), h.dtype),
        interpret=interpret,
    )(tile_rows, tile_cols, tile_vals, h)


def build_tiles(dense_or_coo, num_rows: int, num_cols: int,
                tile: int = TILE):
    """Extract nonzero TILE×TILE tiles (numpy, offline preprocessing).

    Accepts a dense (R, C) matrix or a (row, col, val) COO triple.
    Guarantees ≥1 tile per row-block (zero filler) and returns tiles sorted
    by (row_block, col_block).
    """
    rpad = -(-num_rows // tile) * tile
    cpad = -(-num_cols // tile) * tile
    if isinstance(dense_or_coo, tuple):
        row, col, val = dense_or_coo
        dense = np.zeros((rpad, cpad), np.float32)
        np.add.at(dense, (row, col), val)
    else:
        dense = np.zeros((rpad, cpad), np.float32)
        dense[:num_rows, :num_cols] = dense_or_coo
    nrb, ncb = rpad // tile, cpad // tile
    blocks = dense.reshape(nrb, tile, ncb, tile).transpose(0, 2, 1, 3)
    nz = np.abs(blocks).sum(axis=(2, 3)) > 0
    rows, cols, vals = [], [], []
    for rb in range(nrb):
        cbs = np.flatnonzero(nz[rb])
        if len(cbs) == 0:
            cbs = np.array([0])         # zero filler keeps the run present
        for cb in cbs:
            rows.append(rb)
            cols.append(cb)
            vals.append(blocks[rb, cb])
    return (np.asarray(rows, np.int32), np.asarray(cols, np.int32),
            np.stack(vals).astype(np.float32))


def tile_density(tile_rows, num_rows: int, num_cols: int,
                 tile: int = TILE) -> float:
    """Fraction of tiles stored vs the dense tile grid."""
    nrb = -(-num_rows // tile)
    ncb = -(-num_cols // tile)
    return len(tile_rows) / float(nrb * ncb)
