"""Block-sparse SpMM Pallas TPU kernels — the GCN neighbor-aggregation
hot spot, forward (z = P·H, Eq. 3) and transpose (δcomb = Pᵀ·δz, Eq. 4 /
Alg. 1 lines 17–30), plus the offline tile extraction that feeds them.

TPU adaptation (DESIGN.md §2.4): CSR gather/scatter is VPU-hostile; instead
the propagation matrix is tiled into TILE×TILE *dense* blocks (MXU-shaped),
only nonzero tiles are stored, and the kernel contracts each nonzero tile
against the matching feature row-block on the MXU:

    out[r·T:(r+1)·T, :] += tile_vals[t] @ h[c·T:(c+1)·T, :]

Tiles are sorted by output block; the (row-major) grid revisits the same
output block for consecutive tiles of one run, accumulating in VMEM, and
flushes when the output block changes — the canonical TPU block-sparse
reduction pattern. Tile coordinates arrive via scalar prefetch
(PrefetchScalarGridSpec) so the index stream is resident before the DMA of
each tile.

The transpose kernel (`spmm_block_sparse_t`) reuses the SAME tile values:
it walks the tiles in a column-major order (a prefetched permutation into
`tile_vals`) and contracts each tile transposed (dot_general over dim 0),
accumulating into the *column* block — so the manual backward runs
block-sparse without storing a second copy of P.

The FUSED kernels (`spmm_block_sparse_fused` / `spmm_block_sparse_fused_t`)
additionally contract the dense layer weight in the same grid pass, so the
(rows, F_in)-sized aggregation intermediates never round-trip through HBM:

  forward   u[r] = z[r] @ W + b   with z[r] = Σ_run tile @ h[c]   (epilogue
            matmul on the run-flush: the z accumulator lives in VMEM and the
            (TILE, F_out) output block is produced in the same pass, with
            optional fused bias+ReLU; z is an optional second output for the
            backward's weight-gradient residual)
  backward  dcomb[c] += tileᵀ @ (du[r] @ Wᵀ)                      (prologue
            matmul per tile slot: du's row block is transformed to F_in
            inside the kernel, so the (rows, F_in) dz intermediate is never
            materialized; the MXU recompute per extra tile in a row block is
            the price, accounted by the `analysis.cost` ordering model)

Tile extraction (`build_tile_topology`) works directly on COO triples and
never materializes a dense (N, N) matrix: tiles are bucketed with one
`np.unique` over block keys and one flat-key scatter-add into the
(n_tiles·T·T,) value buffer — O(nnz + n_tiles·T²) memory, the block-sparse
footprint (multi-index `np.add.at` was 2-10× slower at large nnz; see
benchmarks/bench_kernels.py for the extraction timing record).

The engines behind one interface live in `repro.kernels.aggregate`; the
training path selects them via ``ModelConfig.agg``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

TILE = 128          # MXU-shaped adjacency tile
FEAT_BLOCK = 128    # feature columns per grid step


def resolve_interpret(interpret: bool | None) -> bool:
    """`interpret=None` auto-detect shared by every kernel entry point (the
    jitted ops.py wrappers AND direct callers): interpret on CPU (kernel
    bodies execute in Python for validation), compiled on real TPU."""
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _acc_dtype(dtype) -> jnp.dtype:
    """VMEM accumulator dtype: f32 for f32/bf16 inputs (MXU-native), f64
    when the caller runs in f64 (interpret mode only — used by the exactness
    tests, where the fused engine must match the COO engine at 1e-12)."""
    return jnp.promote_types(dtype, jnp.float32)


# ----------------------------------------------------------------------
# Forward kernel: z = P · h
# ----------------------------------------------------------------------

def _kernel(rows_ref, cols_ref, vals_ref, h_ref, out_ref, acc_ref):
    """Grid: (num_feature_blocks, num_tiles) — tiles innermost so the output
    block for one row-run stays resident in VMEM."""
    t = pl.program_id(1)

    first_of_run = jnp.logical_or(
        t == 0, rows_ref[t] != rows_ref[jnp.maximum(t - 1, 0)])

    @pl.when(first_of_run)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(vals_ref[...], h_ref[...],
                            preferred_element_type=acc_ref.dtype)

    last = t == pl.num_programs(1) - 1
    last_of_run = jnp.logical_or(
        last, rows_ref[t] != rows_ref[jnp.minimum(t + 1,
                                                  pl.num_programs(1) - 1)])

    @pl.when(last_of_run)
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spmm_block_sparse(tile_rows, tile_cols, tile_vals, h, num_rows: int,
                      interpret: bool | None = None):
    """z = P_blocksparse · h.

    tile_rows/cols: (n_tiles,) int32 sorted by row; tile_vals: (n_tiles,T,T);
    h: (C, F) with C = num_col_blocks·T, F % FEAT_BLOCK == 0.
    num_rows: output rows (multiple of T). Rows with no tiles stay zero only
    if every row-block has ≥1 tile — callers pad with an explicit zero tile
    per empty row-block (build_tile_topology does this).
    interpret=None auto-detects (True on CPU, False on TPU).
    """
    n_tiles = tile_rows.shape[0]
    f = h.shape[1]
    assert f % FEAT_BLOCK == 0 and num_rows % TILE == 0
    grid = (f // FEAT_BLOCK, n_tiles)

    return pl.pallas_call(
        _kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,      # tile_rows, tile_cols
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, TILE, TILE),
                             lambda fb, t, rows, cols: (t, 0, 0)),
                pl.BlockSpec((TILE, FEAT_BLOCK),
                             lambda fb, t, rows, cols: (cols[t], fb)),
            ],
            out_specs=pl.BlockSpec((TILE, FEAT_BLOCK),
                                   lambda fb, t, rows, cols: (rows[t], fb)),
            scratch_shapes=[pltpu.VMEM((TILE, FEAT_BLOCK),
                                       _acc_dtype(h.dtype))],
        ),
        out_shape=jax.ShapeDtypeStruct((num_rows, f), h.dtype),
        interpret=resolve_interpret(interpret),
    )(tile_rows, tile_cols, tile_vals, h)


# ----------------------------------------------------------------------
# Transpose kernel: δcomb = Pᵀ · δz  (same tiles, column-major walk)
# ----------------------------------------------------------------------

def _kernel_t(out_ref_s, in_ref_s, perm_ref, vals_ref, dz_ref, out_ref,
              acc_ref):
    """Grid: (num_feature_blocks, num_tiles). The tile stream is sorted by
    Pᵀ's output block (= P's column block); `perm` points each stream slot
    at its tile in the forward `tile_vals`, so no transposed copy of P is
    ever stored. The contraction  valsᵀ @ dz  is a dot_general over dim 0
    of both operands (MXU-friendly, no in-kernel transpose)."""
    t = pl.program_id(1)

    first_of_run = jnp.logical_or(
        t == 0, out_ref_s[t] != out_ref_s[jnp.maximum(t - 1, 0)])

    @pl.when(first_of_run)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        vals_ref[...], dz_ref[...],
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    last = t == pl.num_programs(1) - 1
    last_of_run = jnp.logical_or(
        last, out_ref_s[t] != out_ref_s[jnp.minimum(t + 1,
                                                    pl.num_programs(1) - 1)])

    @pl.when(last_of_run)
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spmm_block_sparse_t(t_out, t_in, t_perm, tile_vals, dz, num_cols: int,
                        interpret: bool | None = None):
    """δcomb = Pᵀ_blocksparse · δz, reusing the forward tile values.

    t_out:  (n_tiles,) int32 output (column) block per stream slot, sorted
            ascending — every column block must appear ≥ once (zero fillers).
    t_in:   (n_tiles,) int32 input (row) block of δz consumed per slot.
    t_perm: (n_tiles,) int32 index into tile_vals for each slot.
    tile_vals: (n_tiles, T, T) forward tile values (NOT transposed).
    dz: (R, F) with R = num_row_blocks·T, F % FEAT_BLOCK == 0.
    num_cols: output rows of the transpose product (multiple of T).
    interpret=None auto-detects (True on CPU, False on TPU).
    """
    n_tiles = t_out.shape[0]
    f = dz.shape[1]
    assert f % FEAT_BLOCK == 0 and num_cols % TILE == 0
    grid = (f // FEAT_BLOCK, n_tiles)

    return pl.pallas_call(
        _kernel_t,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,      # t_out, t_in, t_perm
            grid=grid,
            in_specs=[
                pl.BlockSpec((None, TILE, TILE),
                             lambda fb, t, to, ti, tp: (tp[t], 0, 0)),
                pl.BlockSpec((TILE, FEAT_BLOCK),
                             lambda fb, t, to, ti, tp: (ti[t], fb)),
            ],
            out_specs=pl.BlockSpec((TILE, FEAT_BLOCK),
                                   lambda fb, t, to, ti, tp: (to[t], fb)),
            scratch_shapes=[pltpu.VMEM((TILE, FEAT_BLOCK),
                                       _acc_dtype(dz.dtype))],
        ),
        out_shape=jax.ShapeDtypeStruct((num_cols, f), dz.dtype),
        interpret=resolve_interpret(interpret),
    )(t_out, t_in, t_perm, tile_vals, dz)


# ----------------------------------------------------------------------
# Split-phase entry points: boundary tiles first, interior tiles second,
# so the boundary exchange can be issued between the two pallas_calls.
# ----------------------------------------------------------------------

class SplitSpec(NamedTuple):
    """Static description of the interior/boundary phase split of one
    partitioned graph's tile streams (uniform across partitions — the
    phase-aware padding in `pad_tile_topology_phased` makes it so).

    The RCM+halo-clustered layout (graph/reorder.py) packs every
    boundary-destined row into one contiguous tail run per partition, so a
    row threshold splits the forward stream and a column threshold splits
    the transpose stream. All four fields are plain python ints: phase
    boundaries are trace-time constants, the phased kernels below are
    ordinary static slices of the prefetched streams.
    """

    row_tail: int       # first forward boundary-phase output row (B0·T)
    col_tail: int       # first transpose boundary-phase output row (HB0·T)
    fwd_bnd_tiles: int  # boundary-suffix length of the forward stream
    t_bnd_tiles: int    # boundary-suffix length of the transpose stream


def spmm_block_sparse_phased(tile_rows, tile_cols, tile_vals, h,
                             num_rows: int, n_bnd: int, phase: str,
                             interpret: bool | None = None):
    """One phase of z = P·h: the boundary phase runs the last `n_bnd`
    stream slots (output row blocks ≥ row_tail//T — the halo-clustered
    tail runs), the interior phase runs the rest. The output has the FULL
    (num_rows, f) shape but only the phase's own row blocks are written:
    rows outside the phase are UNSPECIFIED (not zero) and must never be
    read — callers combine the two phases' row ranges before any
    cross-row reduction. Running boundary then interior touches each
    output block exactly once, so the pair costs the same tile work as
    one unsplit pass.
    """
    n = tile_rows.shape[0]
    if not 0 < n_bnd < n:
        raise ValueError(f"phase split needs 0 < n_bnd < n_tiles, got "
                         f"{n_bnd}/{n}")
    sl = _phase_slice(n, n_bnd, phase)
    return spmm_block_sparse(tile_rows[sl], tile_cols[sl], tile_vals[sl],
                             h, num_rows, interpret)


def spmm_block_sparse_t_phased(t_out, t_in, t_perm, tile_vals, dz,
                               num_cols: int, n_bnd: int, phase: str,
                               interpret: bool | None = None):
    """One phase of δcomb = Pᵀ·δz. The transpose boundary phase is the
    last `n_bnd` slots of the column-major stream: output rows ≥
    col_tail — the inner tail feeding the gradient send plus the halo
    rows themselves. `tile_vals` is passed whole (t_perm indexes the full
    array); only the slot streams are sliced. Same unspecified-rows
    contract as the forward phases.
    """
    n = t_out.shape[0]
    if not 0 < n_bnd < n:
        raise ValueError(f"phase split needs 0 < n_bnd < n_tiles, got "
                         f"{n_bnd}/{n}")
    sl = _phase_slice(n, n_bnd, phase)
    return spmm_block_sparse_t(t_out[sl], t_in[sl], t_perm[sl], tile_vals,
                               dz, num_cols, interpret)


def _phase_slice(n: int, n_bnd: int, phase: str) -> slice:
    if phase == "boundary":
        return slice(n - n_bnd, n)
    if phase == "interior":
        return slice(0, n - n_bnd)
    raise ValueError(f"phase must be 'boundary' or 'interior', got {phase!r}")


def boundary_rdma_supported() -> bool:
    """Whether the in-kernel RDMA boundary push is available. The split
    schedule itself is backend-agnostic (the collective is issued between
    the two phases either way); on real TPU the send can additionally be
    initiated from inside the boundary-phase kernel via
    `start_boundary_rdma` so it overlaps even the boundary flush."""
    return jax.default_backend() == "tpu"


def start_boundary_rdma(src_ref, dst_ref, send_sem, recv_sem, neighbor):
    """Start an async device-to-device copy of gathered boundary rows
    (TPU-only follow-up path; the interpret-mode schedule uses the XLA
    collective between the phases instead). Returns the started copy —
    callers `.wait()` at the next sync point, after the interior phase.
    """
    if not boundary_rdma_supported():
        raise NotImplementedError(
            "in-kernel RDMA needs a real TPU backend; the split-phase "
            "schedule falls back to the XLA collective between phases")
    copy = pltpu.make_async_remote_copy(
        src_ref=src_ref, dst_ref=dst_ref, send_sem=send_sem,
        recv_sem=recv_sem, device_id=(neighbor,),
        device_id_type=pltpu.DeviceIdType.LOGICAL)
    copy.start()
    return copy


# ----------------------------------------------------------------------
# Fused aggregate+transform kernels: the dense weight contraction happens
# in the SAME grid pass as the block-sparse aggregation, so the
# (rows, F_in)-sized intermediates (z forward, du·Wᵀ backward) never
# round-trip through HBM between two ops.
# ----------------------------------------------------------------------

def _kernel_fused(rows_ref, cols_ref, vals_ref, h_ref, w_ref, b_ref,
                  u_ref, *rest, relu: bool, with_z: bool):
    """Grid: (n_tiles,). The z-accumulator holds one output row block over
    the FULL (padded) F_in axis in VMEM; on the last tile of a row run the
    epilogue matmul contracts it against the resident weight block and adds
    the bias (u = acc @ W + b, optional ReLU) straight into the (TILE,
    F_out) output block — also VMEM-resident across the run — so z is never
    read back from HBM for the transform. With `with_z` the accumulator is
    additionally flushed as a second output (the residual the training
    backward needs for the weight gradient)."""
    if with_z:
        z_ref, acc_ref = rest
    else:
        (acc_ref,) = rest
    t = pl.program_id(0)

    first_of_run = jnp.logical_or(
        t == 0, rows_ref[t] != rows_ref[jnp.maximum(t - 1, 0)])

    @pl.when(first_of_run)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(vals_ref[...], h_ref[...],
                            preferred_element_type=acc_ref.dtype)

    last = t == pl.num_programs(0) - 1
    last_of_run = jnp.logical_or(
        last, rows_ref[t] != rows_ref[jnp.minimum(t + 1,
                                                  pl.num_programs(0) - 1)])

    @pl.when(last_of_run)
    def _():
        u = jnp.dot(acc_ref[...], w_ref[...],
                    preferred_element_type=acc_ref.dtype) + b_ref[...]
        if relu:
            u = jnp.maximum(u, 0)
        u_ref[...] = u.astype(u_ref.dtype)
        if with_z:
            z_ref[...] = acc_ref[...].astype(z_ref.dtype)


def spmm_block_sparse_fused(tile_rows, tile_cols, tile_vals, h, w, b,
                            num_rows: int, relu: bool = False,
                            with_z: bool = True,
                            interpret: bool | None = None):
    """Fused u = (P_blocksparse · h) @ w + b (optional ReLU epilogue).

    h: (C, F_in), w: (F_in, F_out), b: (1, F_out); C and num_rows multiples
    of TILE, F_in/F_out multiples of FEAT_BLOCK (zero-padded by the engine).
    Returns (u, z) with z = P·h when `with_z` (the backward residual),
    else (u, None). VMEM per grid step is one (TILE, F_in) accumulator +
    the (F_in, F_out) weight + one (TILE, F_out) output block — GCN layer
    widths (≤ a few thousand features) fit comfortably in 16 MB.
    """
    n_tiles = tile_rows.shape[0]
    fin = h.shape[1]
    fout = w.shape[1]
    assert w.shape[0] == fin and b.shape == (1, fout)
    assert fin % FEAT_BLOCK == 0 and fout % FEAT_BLOCK == 0
    assert num_rows % TILE == 0
    acc = _acc_dtype(h.dtype)

    out_shape = [jax.ShapeDtypeStruct((num_rows, fout), h.dtype)]
    out_specs = [pl.BlockSpec((TILE, fout),
                              lambda t, rows, cols: (rows[t], 0))]
    if with_z:
        out_shape.append(jax.ShapeDtypeStruct((num_rows, fin), h.dtype))
        out_specs.append(pl.BlockSpec((TILE, fin),
                                      lambda t, rows, cols: (rows[t], 0)))

    outs = pl.pallas_call(
        partial(_kernel_fused, relu=relu, with_z=with_z),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,      # tile_rows, tile_cols
            grid=(n_tiles,),
            in_specs=[
                pl.BlockSpec((None, TILE, TILE),
                             lambda t, rows, cols: (t, 0, 0)),
                pl.BlockSpec((TILE, fin),
                             lambda t, rows, cols: (cols[t], 0)),
                pl.BlockSpec((fin, fout), lambda t, rows, cols: (0, 0)),
                pl.BlockSpec((1, fout), lambda t, rows, cols: (0, 0)),
            ],
            out_specs=out_specs,
            scratch_shapes=[pltpu.VMEM((TILE, fin), acc)],
        ),
        out_shape=out_shape,
        interpret=resolve_interpret(interpret),
    )(tile_rows, tile_cols, tile_vals, h, w, b)
    return (outs[0], outs[1]) if with_z else (outs[0], None)


def _kernel_fused_t(out_ref_s, in_ref_s, perm_ref, vals_ref, du_ref, w_ref,
                    out_ref, acc_ref):
    """Grid: (n_tiles,), column-major tile walk (see `_kernel_t`). Each slot
    transforms its du row block to F_in as a PROLOGUE (du @ Wᵀ via
    dot_general over the F_out axes of both operands — no transposed W is
    materialized) and contracts the tile transposed against the result, so
    the (rows, F_in) dz intermediate never exists in HBM. A row block
    revisited by k tiles pays the prologue k times — MXU FLOPs traded for
    an HBM round-trip, priced by the `analysis.cost` ordering model."""
    t = pl.program_id(0)

    first_of_run = jnp.logical_or(
        t == 0, out_ref_s[t] != out_ref_s[jnp.maximum(t - 1, 0)])

    @pl.when(first_of_run)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dz = jax.lax.dot_general(           # (TILE, F_out) @ (F_in, F_out)ᵀ
        du_ref[...], w_ref[...],
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=acc_ref.dtype)
    acc_ref[...] += jax.lax.dot_general(
        vals_ref[...], dz,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    last = t == pl.num_programs(0) - 1
    last_of_run = jnp.logical_or(
        last, out_ref_s[t] != out_ref_s[jnp.minimum(t + 1,
                                                    pl.num_programs(0) - 1)])

    @pl.when(last_of_run)
    def _():
        out_ref[...] = acc_ref[...].astype(out_ref.dtype)


def spmm_block_sparse_fused_t(t_out, t_in, t_perm, tile_vals, du, w,
                              num_cols: int, interpret: bool | None = None):
    """Fused δcomb = Pᵀ_blocksparse · (du @ wᵀ), reusing forward tiles.

    du: (R, F_out), w: (F_in, F_out); R and num_cols multiples of TILE,
    F_in/F_out multiples of FEAT_BLOCK. The transpose stream (t_out sorted,
    ≥1 tile per column block via zero fillers) is the same one
    `spmm_block_sparse_t` consumes.
    """
    n_tiles = t_out.shape[0]
    fout = du.shape[1]
    fin = w.shape[0]
    assert w.shape[1] == fout
    assert fin % FEAT_BLOCK == 0 and fout % FEAT_BLOCK == 0
    assert num_cols % TILE == 0

    return pl.pallas_call(
        _kernel_fused_t,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=3,      # t_out, t_in, t_perm
            grid=(n_tiles,),
            in_specs=[
                pl.BlockSpec((None, TILE, TILE),
                             lambda t, to, ti, tp: (tp[t], 0, 0)),
                pl.BlockSpec((TILE, fout),
                             lambda t, to, ti, tp: (ti[t], 0)),
                pl.BlockSpec((fin, fout), lambda t, to, ti, tp: (0, 0)),
            ],
            out_specs=pl.BlockSpec((TILE, fin),
                                   lambda t, to, ti, tp: (to[t], 0)),
            scratch_shapes=[pltpu.VMEM((TILE, fin), _acc_dtype(du.dtype))],
        ),
        out_shape=jax.ShapeDtypeStruct((num_cols, fin), du.dtype),
        interpret=resolve_interpret(interpret),
    )(t_out, t_in, t_perm, tile_vals, du, w)


# ----------------------------------------------------------------------
# Tile extraction (numpy, offline preprocessing — never densifies)
# ----------------------------------------------------------------------

class TileTopology(NamedTuple):
    """Block-sparse topology of one propagation shard, for P and Pᵀ.

    The forward stream (rows/cols/vals) is GROUPED by row_block (ascending
    runs — the kernels' flush contract) with the col_blocks of each run
    serpentine (ascending in even runs, descending in odd ones — see
    `_run_major_order`; do NOT assume cols ascend within a run); the
    transpose stream (t_out/t_in/t_perm) walks the SAME vals array grouped
    by col_block via `t_perm`, rows serpentine likewise. Both streams
    carry ≥1 tile per output block (zero fillers) so every output block
    gets flushed.
    """

    rows: np.ndarray        # (n_tiles,) int32 row block, sorted
    cols: np.ndarray        # (n_tiles,) int32 col block
    vals: np.ndarray        # (n_tiles, T, T) float32
    t_out: np.ndarray       # (n_tiles,) int32 Pᵀ output block, sorted
    t_in: np.ndarray        # (n_tiles,) int32 Pᵀ input (δz) block
    t_perm: np.ndarray      # (n_tiles,) int32 index into vals
    num_row_blocks: int
    num_col_blocks: int

    @property
    def n_tiles(self) -> int:
        return len(self.rows)


def build_tile_topology(row, col, val, num_rows: int, num_cols: int,
                        tile: int = TILE) -> TileTopology:
    """Bucket a COO triple into TILE×TILE tiles without densifying.

    Memory is O(nnz + n_tiles·T²) — the block-sparse footprint itself —
    never O(num_rows·num_cols). Explicit zeros (padded edges) are dropped.
    Zero filler tiles are appended for row blocks with no tiles (so the
    forward kernel flushes them) and for column blocks with no tiles (so
    the transpose kernel flushes those).
    """
    row = np.asarray(row, np.int64)
    col = np.asarray(col, np.int64)
    val = np.asarray(val, np.float32)
    keep = val != 0
    row, col, val = row[keep], col[keep], val[keep]

    nrb = -(-num_rows // tile)
    ncb = -(-num_cols // tile)
    key = (row // tile) * ncb + (col // tile)
    uk, inv = np.unique(key, return_inverse=True)
    # Scatter-add over FLATTENED (tile, r%T, c%T) keys into a flat f32
    # buffer: multi-index np.add.at was the preprocessing bottleneck at
    # large nnz (2-10x slower — the fancy-index ufunc loop), and
    # np.bincount(weights=...) loses to the flat add.at on every measured
    # regime because it allocates an f64 output of n_tiles·T² bins before
    # the f32 cast (see benchmarks/bench_kernels.run_tile_extraction).
    # Duplicate (r, c) entries still sum, matching COO semantics.
    flat = (inv.astype(np.int64) * (tile * tile)
            + (row % tile) * tile + (col % tile))
    vals = np.zeros(len(uk) * tile * tile, np.float32)
    np.add.at(vals, flat, val)
    vals = vals.reshape(len(uk), tile, tile)
    rows = (uk // ncb).astype(np.int32)
    cols = (uk % ncb).astype(np.int32)

    # Zero fillers: one per empty row block (forward flush) and per empty
    # column block (transpose flush).
    fill_r = np.setdiff1d(np.arange(nrb, dtype=np.int32), rows)
    fill_c = np.setdiff1d(np.arange(ncb, dtype=np.int32), cols)
    if len(fill_r) or len(fill_c):
        rows = np.concatenate([rows, fill_r,
                               np.zeros(len(fill_c), np.int32)])
        cols = np.concatenate([cols, np.zeros(len(fill_r), np.int32),
                               fill_c])
        vals = np.concatenate(
            [vals, np.zeros((len(fill_r) + len(fill_c), tile, tile),
                            np.float32)])

    # Run-major ordering with a serpentine minor axis: the stream stays
    # grouped by output block (the kernels' flush contract — rows ascending
    # for P, cols ascending for Pᵀ), but the input-block order alternates
    # direction between consecutive runs. The last input block of one run
    # then tends to equal the first of the next, and Pallas skips the
    # input-block DMA whenever the block index is unchanged between
    # consecutive grid steps — longer flush-free, fetch-free sequences on a
    # bandwidth-reduced layout whose runs overlap near the diagonal. Any
    # within-run order is valid (the accumulator is per run), so this only
    # permutes the floating-point accumulation order.
    order = _run_major_order(rows, cols)
    rows, cols, vals = rows[order], cols[order], vals[order]
    t_perm = _run_major_order(cols, rows).astype(np.int32)
    return TileTopology(rows=rows, cols=cols, vals=vals,
                        t_out=cols[t_perm], t_in=rows[t_perm], t_perm=t_perm,
                        num_row_blocks=nrb, num_col_blocks=ncb)


def _run_major_order(major, minor) -> np.ndarray:
    """Sort by `major` ascending (run grouping), `minor` serpentine: minor
    ascends in even runs and descends in odd runs (run parity = rank of the
    major value among the distinct majors present)."""
    _, inv = np.unique(major, return_inverse=True)
    minor = minor.astype(np.int64)
    return np.lexsort((np.where(inv % 2 == 1, -minor, minor), major))


def pad_tile_topology(tt: TileTopology, n_tiles: int) -> TileTopology:
    """Pad the tile streams to `n_tiles` with zero tiles (uniform shapes
    across partitions for SPMD stacking). Padding appends zero tiles at the
    tail of both streams pointing at the last output block of each, which
    preserves sortedness and adds exact zeros."""
    k = n_tiles - tt.n_tiles
    if k < 0:
        raise ValueError(f"cannot shrink tile topology {tt.n_tiles}->{n_tiles}")
    if k == 0:
        return tt
    tile = tt.vals.shape[-1]
    pad_i = np.arange(tt.n_tiles, tt.n_tiles + k, dtype=np.int32)
    return TileTopology(
        rows=np.concatenate([tt.rows, np.full(k, tt.rows[-1], np.int32)]),
        cols=np.concatenate([tt.cols, np.zeros(k, np.int32)]),
        vals=np.concatenate([tt.vals, np.zeros((k, tile, tile), np.float32)]),
        t_out=np.concatenate([tt.t_out, np.full(k, tt.t_out[-1], np.int32)]),
        t_in=np.concatenate([tt.t_in, np.zeros(k, np.int32)]),
        t_perm=np.concatenate([tt.t_perm, pad_i]),
        num_row_blocks=tt.num_row_blocks, num_col_blocks=tt.num_col_blocks)


def pad_tile_topology_phased(tt: TileTopology, b0: int, hb0: int,
                             n_int_f: int, n_bnd_f: int,
                             n_int_t: int, n_bnd_t: int) -> TileTopology:
    """Pad each PHASE GROUP of both streams independently to the given
    uniform lengths (cross-partition maxima), so the interior/boundary
    suffix split lands at the same static slot in every partition's
    stream and the phased kernels can slice with trace-time constants.

    The forward stream is cut at the first slot with row block ≥ `b0`,
    the transpose stream at the first slot with col block ≥ `hb0`. Pads
    are zero tiles appended at the END of their group, addressed at the
    group's LAST output block so run grouping stays intact in both
    streams (interior fwd pads: row b0-1; boundary fwd pads: row nrb-1;
    interior transpose pads: col hb0-1; boundary transpose pads: col
    ncb-1 — every output block carries ≥1 real-or-filler tile, so those
    runs exist). A pad occupies one slot in EACH stream; its (row, col)
    pair is chosen from the four group combinations so both streams pad
    to their target group lengths with one shared vals entry. The
    concatenated [interior; boundary] streams remain valid inputs for
    the unsplit kernels — zero tiles add exact 0.0, so split and unsplit
    schedules on the same padded topology are bit-identical.
    """
    cut_f = int(np.searchsorted(tt.rows, b0))
    cut_t = int(np.searchsorted(tt.t_out, hb0))
    fi = n_int_f - cut_f                       # fwd interior pads
    fb = n_bnd_f - (tt.n_tiles - cut_f)        # fwd boundary pads
    ti = n_int_t - cut_t                       # transpose interior pads
    tb = n_bnd_t - (tt.n_tiles - cut_t)        # transpose boundary pads
    if min(fi, fb, ti, tb) < 0 or fi + fb != ti + tb:
        raise ValueError(f"inconsistent phase pad targets: "
                         f"{(fi, fb, ti, tb)} for {tt.n_tiles} tiles")
    if fi + fb == 0:
        return tt
    # Pair the group memberships: bb pads sit in both boundary groups,
    # then leftovers pair boundary-with-interior, the rest is (int, int).
    bb = min(fb, tb)
    bi = fb - bb            # (fwd boundary, transpose interior)
    ib = tb - bb            # (fwd interior, transpose boundary)
    ii = fi - ib
    tile = tt.vals.shape[-1]
    nrb, ncb = tt.num_row_blocks, tt.num_col_blocks
    # Pad coordinates in fwd-stream placement order: interior group tail
    # first (ii + ib pads), then boundary group tail (bi + bb pads).
    pad_rows = np.array([b0 - 1] * (ii + ib) + [nrb - 1] * (bi + bb),
                        np.int32)
    pad_cols = np.array([hb0 - 1] * ii + [ncb - 1] * ib
                        + [hb0 - 1] * bi + [ncb - 1] * bb, np.int32)
    rows = np.concatenate([tt.rows[:cut_f], pad_rows[:fi],
                           tt.rows[cut_f:], pad_rows[fi:]])
    cols = np.concatenate([tt.cols[:cut_f], pad_cols[:fi],
                           tt.cols[cut_f:], pad_cols[fi:]])
    zi = np.zeros((fi, tile, tile), np.float32)
    zb = np.zeros((fb, tile, tile), np.float32)
    vals = np.concatenate([tt.vals[:cut_f], zi, tt.vals[cut_f:], zb])
    # Original slot i of the unpadded vals now lives at remap[i]; pads at
    # pad_idx (fwd placement order, aligned with pad_rows/pad_cols).
    remap = np.arange(tt.n_tiles, dtype=np.int64)
    remap[cut_f:] += fi
    pad_idx = np.concatenate([
        np.arange(cut_f, cut_f + fi, dtype=np.int64),
        np.arange(tt.n_tiles + fi, tt.n_tiles + fi + fb, dtype=np.int64)])
    t_int_pads = np.concatenate([pad_idx[:ii], pad_idx[fi:fi + bi]])
    t_bnd_pads = np.concatenate([pad_idx[ii:fi], pad_idx[fi + bi:]])
    t_perm = np.concatenate([remap[tt.t_perm[:cut_t]], t_int_pads,
                             remap[tt.t_perm[cut_t:]],
                             t_bnd_pads]).astype(np.int32)
    return TileTopology(
        rows=rows, cols=cols, vals=vals,
        t_out=cols[t_perm], t_in=rows[t_perm], t_perm=t_perm,
        num_row_blocks=nrb, num_col_blocks=ncb)


def build_tiles(dense_or_coo, num_rows: int, num_cols: int,
                tile: int = TILE):
    """Legacy forward-only extraction: (tile_rows, tile_cols, tile_vals).

    Accepts a dense (R, C) matrix or a (row, col, val) COO triple. The COO
    path never densifies (see build_tile_topology); the dense path simply
    converts the caller's existing matrix to COO first.
    """
    if isinstance(dense_or_coo, tuple):
        row, col, val = dense_or_coo
    else:
        dense = np.asarray(dense_or_coo)
        row, col = np.nonzero(dense)
        val = dense[row, col]
    tt = build_tile_topology(row, col, val, num_rows, num_cols, tile)
    return tt.rows, tt.cols, tt.vals


def tile_density(tile_rows, num_rows: int, num_cols: int,
                 tile: int = TILE) -> float:
    """Fraction of tiles stored vs the dense tile grid."""
    nrb = -(-num_rows // tile)
    ncb = -(-num_cols // tile)
    return len(tile_rows) / float(nrb * ncb)
