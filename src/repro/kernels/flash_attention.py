"""Flash attention Pallas TPU kernel: blockwise online-softmax GQA attention
with causal and sliding-window masking — the prefill hot path.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks), kv innermost. The
running max / denominator / accumulator live in VMEM scratch across the kv
sweep; the output block is written on the last kv step. BlockSpec tiling
keeps one (Bq × d) query tile and one (Bk × d) kv tile resident per step —
VMEM working set = Bq·d + 2·Bk·d + Bq·Bk floats, MXU-aligned for d ≥ 128.

GQA is expressed in the index maps (kv head = q head // group) so no
repeated-KV materialization happens.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_Q_BLOCK = 512
DEFAULT_KV_BLOCK = 512
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, window: int, q_block: int,
            kv_block: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                                # (Bq, d)
    k = k_ref[...]                                # (Bk, d)
    v = v_ref[...]
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32,
                                                   (q_block, kv_block), 0)
    kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32,
                                                    (q_block, kv_block), 1)
    mask = jnp.ones((q_block, kv_block), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = (acc_ref[...] * alpha[:, None]
                    + jnp.dot(p.astype(v.dtype), v,
                              preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(ki == pl.num_programs(3) - 1)
    def _():
        o_ref[...] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q, k, v, causal: bool = True, window: int = 0,
                    q_block: int = DEFAULT_Q_BLOCK,
                    kv_block: int = DEFAULT_KV_BLOCK,
                    interpret: bool = True):
    """q: (B, S, H, d), k/v: (B, T, K, d) with H % K == 0 -> (B, S, H, d)."""
    b, s, h, d = q.shape
    t, kh = k.shape[1], k.shape[2]
    assert s % q_block == 0 and t % kv_block == 0, (s, t, q_block, kv_block)
    g = h // kh
    scale = 1.0 / (d ** 0.5)
    nq, nk = s // q_block, t // kv_block

    qh = jnp.moveaxis(q, 2, 1)       # (B, H, S, d)
    kh_ = jnp.moveaxis(k, 2, 1)      # (B, K, T, d)
    vh = jnp.moveaxis(v, 2, 1)

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, q_block=q_block,
                               kv_block=kv_block)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, nq, nk),
        in_specs=[
            pl.BlockSpec((None, None, q_block, d),
                         lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
            pl.BlockSpec((None, None, kv_block, d),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((None, None, kv_block, d),
                         lambda bi, hi, qi, ki, g=g: (bi, hi // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((None, None, q_block, d),
                               lambda bi, hi, qi, ki: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block,), jnp.float32),
            pltpu.VMEM((q_block, d), jnp.float32),
        ],
        interpret=interpret,
    )(qh.reshape(b, h, nq * q_block, d),
      kh_.reshape(b, kh, nk * kv_block, d),
      vh.reshape(b, kh, nk * kv_block, d))
    return jnp.moveaxis(out, 1, 2)
