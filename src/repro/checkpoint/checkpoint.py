"""Sharding-aware numpy checkpointing.

Layout:  <dir>/step_<N>/
           manifest.json     pytree structure + dtypes/shapes + sharding specs
           arrays.npz        flattened leaves (key = leaf index)

Works for any pytree (params, optimizer state, PipeGCN pipeline buffers).
Sharded arrays are gathered to host before save (fine at the scales this
container runs); the manifest records the logical PartitionSpec so a restore
on a different mesh can re-shard.
"""
from __future__ import annotations

import json
import os
import re

import jax
import numpy as np


def _spec_of(x) -> str:
    try:
        return str(x.sharding.spec)  # type: ignore[attr-defined]
    except Exception:
        return ""


def save_checkpoint(ckpt_dir: str, step: int, tree, overwrite: bool = True) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = {}
    manifest = {"treedef": str(treedef), "num_leaves": len(leaves),
                "step": step, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype_str = str(arr.dtype)
        if arr.dtype.kind not in "biufc":   # ml_dtypes (bfloat16, fp8, ...)
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        arrays[f"leaf_{i}"] = arr
        manifest["leaves"].append({
            "index": i, "shape": list(arr.shape), "dtype": dtype_str,
            "spec": _spec_of(leaf)})
    np.savez_compressed(os.path.join(path, "arrays.npz"), **arrays)
    with open(os.path.join(path, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return path


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int | None, like):
    """Restore into the structure of `like` (a template pytree)."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = jax.tree.flatten(like)
    if len(leaves_like) != manifest["num_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, template has "
            f"{len(leaves_like)}")
    import ml_dtypes  # noqa: F401 — registers bf16 etc. with numpy
    out = []
    for i, tmpl in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        want_dtype = np.dtype(manifest["leaves"][i]["dtype"])
        if arr.dtype != want_dtype and arr.dtype.kind == "u":
            arr = arr.view(want_dtype)
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(f"leaf {i}: shape {arr.shape} != {np.shape(tmpl)}")
        out.append(jax.numpy.asarray(arr, dtype=tmpl.dtype))
    return treedef.unflatten(out)
