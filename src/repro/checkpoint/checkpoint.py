"""Sharding-aware numpy checkpointing.

Layout:  <dir>/step_<N>/
           manifest.json     pytree structure + dtypes/shapes + sharding specs
           arrays.npz        flattened leaves (key = leaf index)

Works for any pytree (params, optimizer state, PipeGCN pipeline buffers).
Sharded arrays are gathered to host before save (fine at the scales this
container runs); the manifest records the logical PartitionSpec so a restore
on a different mesh can re-shard.

Saves are ATOMIC: everything is written and fsynced into a `step_<N>.tmp`
staging directory, which is `os.replace`d onto the final name only once
complete — a crash mid-save can never leave a truncated `arrays.npz` under
a name `latest_step` would pick (the `step_(\\d+)` match rejects `.tmp`).

Restores VALIDATE: the stored treedef string and every leaf's manifest
dtype are compared against the template, and a mismatch error names the
first offending leaf path — restoring yesterday's run into today's
refactored state must fail loudly, not reinterpret bytes.
"""
from __future__ import annotations

import json
import os
import random
import re
import shutil
import time

import jax
import numpy as np


def _spec_of(x) -> str:
    try:
        return str(x.sharding.spec)  # type: ignore[attr-defined]
    except Exception:
        return ""


def _fsync_dir_tree(path: str) -> None:
    """fsync every file under `path`, then the directory itself, so the
    subsequent rename publishes fully durable contents."""
    for name in os.listdir(path):
        fd = os.open(os.path.join(path, name), os.O_RDONLY)
        try:
            os.fsync(fd)
        finally:
            os.close(fd)
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def _leaf_paths(tree) -> list[str]:
    """Human-readable path string per leaf, in flatten order."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [jax.tree_util.keystr(kp) for kp, _ in flat]


def save_checkpoint(ckpt_dir: str, step: int, tree, overwrite: bool = True,
                    keep_last: int | None = None, retries: int = 3,
                    retry_delay: float = 0.05) -> str:
    """Atomically save `tree` as `<ckpt_dir>/step_<N>` (see module
    docstring for the stage-fsync-rename protocol).

    Transient ``OSError``s (a flaky or briefly-full filesystem) are
    retried up to `retries` total attempts with jittered exponential
    backoff — each attempt restages from scratch, so a landed save is
    always complete. `FileExistsError` under ``overwrite=False`` is a
    caller error, never retried. With `keep_last`, all but the newest
    `keep_last` fully-committed step dirs are pruned after the save lands
    (the dir just written is never pruned; `.tmp` staging leftovers are
    not counted as checkpoints and are swept only for pruned steps)."""
    if retries < 1:
        raise ValueError(f"retries must be >= 1, got {retries}")
    for attempt in range(retries):
        try:
            path = _write_checkpoint(ckpt_dir, step, tree, overwrite)
            break
        except FileExistsError:
            raise
        except OSError:
            if attempt == retries - 1:
                raise
            delay = retry_delay * (2 ** attempt)
            time.sleep(delay * (1.0 + random.random()))
    if keep_last is not None:
        _prune_checkpoints(ckpt_dir, keep_last, just_wrote=step)
    return path


def _write_checkpoint(ckpt_dir: str, step: int, tree,
                      overwrite: bool = True) -> str:
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = path + ".tmp"
    os.makedirs(ckpt_dir, exist_ok=True)
    if os.path.isdir(tmp):            # leftover from a crashed save
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, treedef = jax.tree.flatten(tree)
    paths = _leaf_paths(tree)
    arrays = {}
    manifest = {"treedef": str(treedef), "num_leaves": len(leaves),
                "step": step, "leaves": []}
    for i, leaf in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        dtype_str = str(arr.dtype)
        if arr.dtype.kind not in "biufc":   # ml_dtypes (bfloat16, fp8, ...)
            arr = arr.view(np.uint16 if arr.dtype.itemsize == 2 else np.uint8)
        arrays[f"leaf_{i}"] = arr
        manifest["leaves"].append({
            "index": i, "path": paths[i], "shape": list(arr.shape),
            "dtype": dtype_str, "spec": _spec_of(leaf)})
    np.savez_compressed(os.path.join(tmp, "arrays.npz"), **arrays)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    # durability before visibility: fsync the staged files, atomically
    # swap the directory into place, then fsync the parent so the rename
    # itself survives a crash
    _fsync_dir_tree(tmp)
    if os.path.isdir(path):
        if not overwrite:
            raise FileExistsError(f"checkpoint exists: {path}")
        shutil.rmtree(path)
    os.replace(tmp, path)
    fd = os.open(ckpt_dir, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)
    return path


def _prune_checkpoints(ckpt_dir: str, keep_last: int, just_wrote: int):
    """Remove all but the newest `keep_last` committed `step_*` dirs.

    Only fully-committed dirs count toward (and are eligible for) the
    retention budget: a `step_N.tmp` staging leftover is neither a
    checkpoint nor retention-countable, and is swept only alongside its
    pruned step. The dir just written is never pruned, whatever its
    step number."""
    if keep_last < 1:
        raise ValueError(f"keep_last must be >= 1, got {keep_last}")
    steps = sorted(int(m.group(1)) for d in os.listdir(ckpt_dir)
                   if (m := re.fullmatch(r"step_(\d+)", d)))
    for s in steps[:-keep_last] if keep_last < len(steps) else []:
        if s == just_wrote:
            continue
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)
        tmp = os.path.join(ckpt_dir, f"step_{s:08d}.tmp")
        if os.path.isdir(tmp):
            shutil.rmtree(tmp, ignore_errors=True)


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for d in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)", d))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int | None, like):
    """Restore into the structure of `like` (a template pytree).

    The template must MATCH the saved state: same treedef (string
    compare), same per-leaf shape, and — when the manifest carries real
    dtypes (every checkpoint written by this module) — same dtype per
    leaf. Errors name the first mismatching leaf path."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))
    leaves_like, treedef = jax.tree.flatten(like)
    if len(leaves_like) != manifest["num_leaves"]:
        raise ValueError(
            f"checkpoint has {manifest['num_leaves']} leaves, template has "
            f"{len(leaves_like)}")
    if manifest["treedef"] != str(treedef):
        raise ValueError(
            "checkpoint treedef does not match the template structure:\n"
            f"  saved:    {manifest['treedef']}\n"
            f"  template: {treedef}")
    paths = _leaf_paths(like)
    import ml_dtypes  # noqa: F401 — registers bf16 etc. with numpy
    out = []
    for i, tmpl in enumerate(leaves_like):
        arr = data[f"leaf_{i}"]
        want_dtype = np.dtype(manifest["leaves"][i]["dtype"])
        if arr.dtype != want_dtype and arr.dtype.kind == "u":
            arr = arr.view(want_dtype)
        if tuple(arr.shape) != tuple(np.shape(tmpl)):
            raise ValueError(
                f"leaf {paths[i]}: checkpoint shape {tuple(arr.shape)} != "
                f"template shape {tuple(np.shape(tmpl))}")
        tmpl_dtype = np.dtype(getattr(tmpl, "dtype", np.asarray(tmpl).dtype))
        if want_dtype != tmpl_dtype:
            raise ValueError(
                f"leaf {paths[i]}: checkpoint dtype {want_dtype} != "
                f"template dtype {tmpl_dtype} — restore into the state "
                "layout the checkpoint was saved from")
        out.append(jax.numpy.asarray(arr, dtype=tmpl_dtype))
    return treedef.unflatten(out)
