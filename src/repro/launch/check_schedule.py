"""Split-phase schedule preflight: prove the traced step issues each
boundary collective BETWEEN the boundary- and interior-phase kernels.

Builds the grid-tiny pipeline (a 4-neighbor lattice — the O(sqrt n)
boundary regime the split needs; rcm layout, blocksparse tiles), then:

  spmd backend: traces `make_spmd_step` and asserts the full
      (pallas_call | all_to_all) event sequence equals
      `expected_split_events` — forward AND backward, fused and
      per-layer schedules, train and eval.
  sim backend: the exchange is a transpose (no collective primitive), so
      the check reduces to the phase-kernel sequence: the same expected
      events with the all_to_all entries dropped.

Run by scripts/check.sh ahead of the test suite (and usable standalone:
``python -m repro.launch.check_schedule``). Exits nonzero on mismatch.
"""
from __future__ import annotations

import dataclasses

import jax

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN
from repro.core.trace_utils import (check_split_schedule,
                                    expected_split_events,
                                    traced_step_events)
from repro.data.graph_pipeline import GraphDataPipeline
from repro.launch.mesh import make_partition_mesh

P = 4
CELLS = [
    # (variant, fuse_exchange, train)
    ("pipegcn", True, True),
    ("pipegcn", True, False),
    ("pipegcn", False, True),
    ("vanilla", True, True),
    ("vanilla", False, False),
]


def check_backends(num_layers: int = 2) -> int:
    pipeline = GraphDataPipeline.build("grid-tiny", P, kind="sage",
                                       agg="blocksparse", layout="rcm")
    sp = pipeline.split_spec()
    assert sp is not None, "grid-tiny must admit a feasible split"
    mesh = make_partition_mesh(P, parts_per_device=P)
    checked = 0
    for variant, fuse, train in CELLS:
        mc = ModelConfig(kind="sage", feat_dim=pipeline.dataset.feat_dim,
                         hidden=16, num_layers=num_layers,
                         num_classes=pipeline.dataset.num_classes,
                         dropout=0.0, agg="blocksparse",
                         matmul_order="aggregate-first", layout="rcm")
        pc = dataclasses.replace(PipeConfig.named(variant),
                                 fuse_exchange=fuse, overlap="split-phase")
        model = PipeGCN(mc, pc, split=sp)
        expected = expected_split_events(num_layers, model.pipe.fused,
                                         train=train)
        # spmd: full event sequence, collectives included
        ev = check_split_schedule(model, mesh, pipeline.topo,
                                  pipeline.train_data, train=train)
        # sim: phase kernels only (the exchange is a transpose)
        params = model.init_params(jax.random.PRNGKey(0))
        buffers = model.init_buffers(pipeline.topo)
        if train:
            sim_ev = traced_step_events(
                model.train_step, pipeline.topo, params, buffers,
                pipeline.train_data, jax.random.PRNGKey(0))
        else:
            sim_ev = traced_step_events(
                model.forward, pipeline.topo, params, pipeline.train_data)
        sim_expected = [e for e in expected if e == "pallas_call"]
        if sim_ev != sim_expected:
            raise AssertionError(
                f"sim-backend phase sequence mismatch "
                f"({variant}, fuse={fuse}, train={train}):\n"
                f"  traced   {sim_ev}\n  expected {sim_expected}")
        checked += 1
        print(f"[schedule OK] {variant} fuse={fuse} train={train} "
              f"L={num_layers}: "
              + " ".join("A" if e == "all_to_all" else "P" for e in ev),
              flush=True)
    return checked


def main():
    n = check_backends()
    print(f"[check_schedule OK] {n} cells, both backends", flush=True)


if __name__ == "__main__":
    main()
