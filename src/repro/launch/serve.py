"""Batched serving driver: prefill a batch of prompts, then decode N tokens
per request with greedy/temperature sampling against the KV/state caches.

  python -m repro.launch.serve --arch qwen3-8b --reduced --batch 4 \
      --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models.model import LM


def add_stubs(batch, cfg, b, dtype):
    if cfg.is_encdec:
        batch["audio_embed"] = jnp.zeros(
            (b, cfg.num_audio_frames, cfg.d_model), dtype)
    if cfg.num_image_tokens:
        batch["image_embed"] = jnp.zeros(
            (b, cfg.num_image_tokens, cfg.d_model), dtype)
    return batch


def serve(arch: str, reduced: bool, batch_size: int, prompt_len: int,
          gen_tokens: int, temperature: float = 0.0, seed: int = 0) -> dict:
    cfg = get_arch(arch)
    if reduced:
        cfg = cfg.reduced()
    lm = LM(cfg)
    rng = np.random.default_rng(seed)
    params = lm.init_params(jax.random.PRNGKey(seed))
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (batch_size, prompt_len)), jnp.int32)
    batch = add_stubs({"tokens": prompts}, cfg, batch_size, lm.dtype)

    max_len = prompt_len + gen_tokens
    caches = lm.init_caches(batch_size, max_len)

    prefill = jax.jit(lm.prefill)
    decode = jax.jit(lm.decode_step, static_argnums=3)

    t0 = time.perf_counter()
    logits, caches = prefill(params, batch, caches)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    key = jax.random.PRNGKey(seed + 1)
    generated = []
    t1 = time.perf_counter()
    for i in range(gen_tokens):
        if temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(sub, logits[:, -1] / temperature)
        else:
            tok = jnp.argmax(logits[:, -1], axis=-1)
        tok = tok[:, None].astype(jnp.int32)
        generated.append(np.asarray(tok))
        logits, caches = decode(params, tok, caches, prompt_len + i)
    jax.block_until_ready(logits)
    t_decode = time.perf_counter() - t1

    out_tokens = np.concatenate(generated, axis=1)
    return {
        "arch": arch, "batch": batch_size, "prompt_len": prompt_len,
        "gen_tokens": gen_tokens,
        "prefill_s": round(t_prefill, 3),
        "decode_s": round(t_decode, 3),
        "decode_tok_per_s": round(batch_size * gen_tokens / t_decode, 1),
        "sample_output": out_tokens[0, :8].tolist(),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = serve(args.arch, args.reduced, args.batch, args.prompt_len,
                args.gen, args.temperature, args.seed)
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
