"""Training launcher.

Two workload kinds behind one CLI:

  GCN full-graph training (the paper):
    python -m repro.launch.train --workload gcn --dataset reddit-sim \
        --partitions 4 --variant pipegcn-gf --epochs 300 \
        --agg blocksparse      # Pallas block-sparse aggregation engine

  Transformer LM training (assigned archs, reduced or full config):
    python -m repro.launch.train --workload lm --arch qwen3-8b --reduced \
        --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.core import ModelConfig, PipeConfig, train_pipegcn
from repro.data import GraphDataPipeline, TokenStream
from repro.graph.synthetic import model_template
from repro.models.model import LM
from repro.optim import adamw, linear_warmup_cosine


def run_gcn(args) -> dict:
    pipeline = GraphDataPipeline.build(args.dataset, args.partitions,
                                       kind=args.gcn_kind, seed=args.seed,
                                       agg=args.agg, layout=args.layout)
    mesh = None
    if args.spmd:
        # Partition count is a convergence knob, device count a hardware
        # fact: the mesh is sized partitions // parts_per_device and each
        # device hosts parts_per_device co-resident partitions.
        from repro.launch.mesh import make_partition_mesh
        mesh = make_partition_mesh(args.partitions, args.parts_per_device)
    tpl = model_template(args.dataset)
    mc = ModelConfig(kind=args.gcn_kind, feat_dim=pipeline.dataset.feat_dim,
                     hidden=args.hidden or tpl["hidden"],
                     num_layers=args.layers or tpl["num_layers"],
                     num_classes=pipeline.dataset.num_classes,
                     dropout=tpl["dropout"],
                     multilabel=pipeline.dataset.multilabel,
                     agg=args.agg, matmul_order=args.matmul_order,
                     layout=pipeline.layout)
    import dataclasses
    pc = dataclasses.replace(PipeConfig.named(args.variant, gamma=args.gamma),
                             fuse_exchange=not args.no_fuse_exchange,
                             overlap=args.overlap, wire=args.wire,
                             slice_boundary=args.slice_boundary,
                             guard_exchange=args.guard_exchange,
                             max_staleness=args.max_staleness)
    faults = None
    if args.fault_rate > 0.0:
        from repro.core import FaultPlan
        faults = FaultPlan(rate=args.fault_rate, rate_kind=args.fault_kind,
                           seed=args.fault_seed)
    health = None
    if args.no_health:
        from repro.core import HealthConfig
        health = HealthConfig(enabled=False)
    elastic = None
    if args.elastic:
        from repro.core import ElasticConfig
        elastic = ElasticConfig(detect_after=args.elastic_detect_after,
                                warm_staleness=args.elastic_warm,
                                max_recoveries=args.elastic_max_recoveries,
                                rejoin=not args.elastic_no_rejoin,
                                parts_per_device=args.parts_per_device)
    res = train_pipegcn(pipeline, mc, pc, epochs=args.epochs,
                        lr=args.lr or tpl["lr"], seed=args.seed,
                        eval_every=args.eval_every, log=print, mesh=mesh,
                        health=health, faults=faults,
                        ckpt_dir=args.ckpt_dir,
                        checkpoint_every=args.ckpt_every,
                        resume=args.resume,
                        checkpoint_keep=args.ckpt_keep or None,
                        elastic=elastic)
    out = {"workload": "gcn", "dataset": args.dataset,
           "partitions": args.partitions, "variant": args.variant,
           "spmd": bool(args.spmd),
           "parts_per_device": args.parts_per_device,
           "agg": args.agg,
           "matmul_order": args.matmul_order,
           "layout": pipeline.layout,
           "fuse_exchange": pc.fuse_exchange,
           "overlap": pc.overlap,
           "wire": pc.wire,
           "slice_boundary": pc.slice_boundary,
           "guard_exchange": pc.guard_exchange,
           "fault_rate": args.fault_rate,
           "split_feasible": pipeline.split_spec() is not None,
           "elastic": bool(args.elastic),
           "anomalies": res.anomalies,
           "resumed_from": res.resumed_from,
           "recoveries": res.recoveries,
           "preempted": res.preempted,
           "final": res.final_metrics, "epochs_per_sec": res.epochs_per_sec,
           "history": res.history}
    if args.ckpt_dir and not args.ckpt_every:
        # legacy params-only export; with --ckpt-every the trainer already
        # wrote full-state step dirs into the same directory
        save_checkpoint(args.ckpt_dir, args.epochs, res.params)
    print(json.dumps({k: out[k] for k in
                      ("final", "epochs_per_sec")}, indent=1))
    return out


def run_lm(args) -> dict:
    from repro.configs import get_arch
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    lm = LM(cfg)
    params = lm.init_params(jax.random.PRNGKey(args.seed))
    opt = adamw(linear_warmup_cosine(args.lr or 3e-4, 10, args.steps),
                max_grad_norm=1.0)
    opt_state = opt.init(params)

    def add_stubs(batch, b):
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.is_encdec:
            batch["audio_embed"] = jnp.zeros(
                (b, cfg.num_audio_frames, cfg.d_model), lm.dtype)
        if cfg.num_image_tokens:
            batch["image_embed"] = jnp.zeros(
                (b, cfg.num_image_tokens, cfg.d_model), lm.dtype)
        return batch

    @jax.jit
    def step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(lm.loss_fn)(params, batch)
        params, opt_state = opt.apply(params, grads, opt_state)
        return loss, params, opt_state

    stream = iter(TokenStream(cfg.vocab_size, args.seq, args.batch,
                              seed=args.seed))
    losses = []
    t0 = time.perf_counter()
    for i in range(args.steps):
        batch = add_stubs(next(stream), args.batch)
        loss, params, opt_state = step(params, opt_state, batch)
        losses.append(float(loss))
        if i % max(args.steps // 10, 1) == 0:
            print(f"step {i:5d} loss {losses[-1]:.4f}", flush=True)
    dt = time.perf_counter() - t0
    out = {"workload": "lm", "arch": args.arch, "reduced": args.reduced,
           "first_loss": losses[0], "last_loss": losses[-1],
           "steps_per_sec": args.steps / dt}
    if args.ckpt_dir:
        save_checkpoint(args.ckpt_dir, args.steps, params)
    print(json.dumps(out, indent=1))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", choices=["gcn", "lm"], default="gcn")
    # gcn
    ap.add_argument("--dataset", default="reddit-sim")
    ap.add_argument("--partitions", type=int, default=4)
    ap.add_argument("--variant", default="pipegcn",
                    help="vanilla|pipegcn|pipegcn-g|pipegcn-f|pipegcn-gf")
    ap.add_argument("--gcn-kind", default="sage", choices=["sage", "gcn"])
    ap.add_argument("--agg", default="coo",
                    choices=["coo", "blocksparse", "fused"],
                    help="aggregation engine for the Eq. 3/4 SpMM (fused = "
                         "blocksparse tiles + single-pass aggregate+"
                         "transform Pallas kernels)")
    ap.add_argument("--matmul-order", default="auto",
                    choices=["auto", "aggregate-first", "transform-first"],
                    help="layer contraction order for P·H·W: (P·H)·W costs "
                         "2·nnz·F_in, P·(H·W) costs 2·nnz·F_out; auto picks "
                         "per layer via the static FLOP model")
    ap.add_argument("--layout", default="auto",
                    choices=["auto", "natural", "rcm"],
                    help="intra-partition node layout: rcm = bandwidth-"
                         "reducing reorder + halo clustering (fewer "
                         "nonempty tiles for the tile engines, numerically "
                         "invisible); auto = rcm iff --agg uses tiles")
    ap.add_argument("--spmd", action="store_true",
                    help="run the step under shard_map on a device mesh "
                         "instead of the single-device sim backend")
    ap.add_argument("--parts-per-device", type=int, default=1,
                    help="co-resident partitions per device for --spmd "
                         "(partitions must be a multiple; mesh size = "
                         "partitions // parts_per_device)")
    ap.add_argument("--overlap", default="auto",
                    choices=["auto", "none", "split-phase"],
                    help="split-phase exchange/compute overlap: run the "
                         "boundary-tile phase first, issue the collective, "
                         "and compute the interior phase while it is in "
                         "flight; auto = on iff the layout clusters a "
                         "boundary tail and --agg consumes tiles")
    ap.add_argument("--no-fuse-exchange", action="store_true",
                    help="revert stale variants to the blocking per-layer "
                         "boundary exchange (2L-1 collectives/step instead "
                         "of the fused-deferred 2)")
    ap.add_argument("--wire", default="f32",
                    choices=["f32", "bf16", "int8", "int4", "auto"],
                    help="boundary wire format (default f32 = native "
                         "dtype): bf16 halves the exchanged bytes; "
                         "int8/int4 are blockwise-scaled quantization "
                         "(~4x/~8x smaller, per-128-column f32 scales ride "
                         "in the payload — see docs/wire-format.md); auto "
                         "picks bf16-vs-int8 per layer by wire bytes")
    ap.add_argument("--slice-boundary", action="store_true",
                    help="feature-dimension slicing: layers the cost model "
                         "runs transform-first ship the post-transform "
                         "width F_out <= F_in instead of F_in (default "
                         "off; incompatible with --overlap split-phase)")
    ap.add_argument("--guard-exchange", action="store_true",
                    help="per-row checksums on every boundary wire; rows "
                         "failing verification fall back to the stale "
                         "buffer (one extra step of staleness) instead of "
                         "landing garbage — see README 'Fault tolerance'")
    ap.add_argument("--max-staleness", type=int, default=8,
                    help="effective-staleness bound of the guarded "
                         "exchange; exceeding it aborts the run loudly")
    ap.add_argument("--fault-rate", type=float, default=0.0,
                    help="i.i.d. per-(step,layer,direction,pair) exchange "
                         "fault probability injected into the wires "
                         "(testing/chaos; combine with --guard-exchange)")
    ap.add_argument("--fault-kind", default="drop",
                    choices=["drop", "corrupt", "delay"],
                    help="background fault kind for --fault-rate")
    ap.add_argument("--fault-seed", type=int, default=0)
    ap.add_argument("--elastic", action="store_true",
                    help="arm the elastic runtime (requires "
                         "--guard-exchange and --ckpt-every): a device "
                         "whose every forward exchange falls back "
                         "--elastic-detect-after consecutive steps is "
                         "declared lost; the trainer restores the latest "
                         "checkpoint, remaps its partitions onto the "
                         "survivors, and resumes — see docs/architecture.md "
                         "'Elasticity'")
    ap.add_argument("--elastic-detect-after", type=int, default=2,
                    help="consecutive whole-device fallback steps before a "
                         "device is declared lost")
    ap.add_argument("--elastic-warm", type=int, default=1,
                    help="staleness count stamped on remapped exchanges at "
                         "recovery (must be < --elastic-detect-after)")
    ap.add_argument("--elastic-max-recoveries", type=int, default=2,
                    help="device-loss recovery budget before the loss is "
                         "re-raised as fatal")
    ap.add_argument("--elastic-no-rejoin", action="store_true",
                    help="stay on the survivor layout instead of scaling "
                         "back up at a checkpoint boundary once the lost "
                         "device is healthy")
    ap.add_argument("--no-health", action="store_true",
                    help="disable the numerical health guard (skip-and-"
                         "rollback of non-finite steps; on by default)")
    ap.add_argument("--gamma", type=float, default=0.95)
    ap.add_argument("--epochs", type=int, default=300)
    ap.add_argument("--eval-every", type=int, default=20)
    ap.add_argument("--hidden", type=int, default=0)
    ap.add_argument("--layers", type=int, default=0)
    # lm
    ap.add_argument("--arch", default="qwen3-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    # common
    ap.add_argument("--lr", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0,
                    help="checkpoint the FULL training state (params, "
                         "optimizer, pipeline buffers, PRNG key, epoch) "
                         "into --ckpt-dir every N epochs (atomic saves)")
    ap.add_argument("--ckpt-keep", type=int, default=0,
                    help="retain only the newest N committed checkpoints "
                         "in --ckpt-dir (0 = keep everything)")
    ap.add_argument("--resume", action="store_true",
                    help="resume bit-exactly from the latest checkpoint "
                         "in --ckpt-dir (gcn workload)")
    args = ap.parse_args()
    if args.workload == "gcn":
        run_gcn(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
