"""Abstract input/param specs for the dry-run: ShapeDtypeStructs with
NamedShardings — weak-type-correct, shardable, zero allocation."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig, InputShape
from repro.models.model import LM


def batch_axes(mesh) -> tuple:
    """Data-parallel axes: ('pod','data') on the multi-pod mesh."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def adapt_spec(ps: P, mesh) -> P:
    """Map 'data' -> ('pod','data') on multi-pod meshes."""
    if "pod" not in mesh.axis_names:
        return ps
    out = []
    for entry in ps:
        if entry == "data":
            out.append(("pod", "data"))
        else:
            out.append(entry)
    return P(*out)


def with_sharding(tree_sds, tree_spec, mesh):
    """Attach NamedShardings to a ShapeDtypeStruct tree."""
    def leaf(sds, spec):
        spec = adapt_spec(spec, mesh)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(leaf, tree_sds, tree_spec,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def abstract_params(lm: LM, mesh):
    sds = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0)))
    return with_sharding(sds, lm.param_specs(), mesh)


def input_specs(cfg: ArchConfig, shape: InputShape, mesh) -> dict:
    """Model inputs for the given input shape, as sharded SDS."""
    bx = batch_axes(mesh)
    b = shape.global_batch
    dt = jnp.dtype(cfg.dtype)
    tok = jax.ShapeDtypeStruct(
        (b, shape.seq_len if shape.mode != "decode" else 1), jnp.int32,
        sharding=NamedSharding(mesh, P(bx if b > 1 else None, None)))
    batch = {"tokens": tok}
    if shape.mode != "decode":
        batch["labels"] = tok
    if cfg.is_encdec:
        batch["audio_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.num_audio_frames, cfg.d_model), dt,
            sharding=NamedSharding(mesh, P(bx if b > 1 else None, None, None)))
    if cfg.num_image_tokens:
        batch["image_embed"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), dt,
            sharding=NamedSharding(mesh, P(bx if b > 1 else None, None, None)))
    return batch


def abstract_caches(lm: LM, shape: InputShape, mesh):
    cfg = lm.cfg
    model_size = mesh.shape["model"]
    shard_kv = cfg.num_kv_heads % model_size == 0 and cfg.num_kv_heads >= model_size
    sds = jax.eval_shape(
        lambda: lm.init_caches(shape.global_batch, shape.seq_len))
    specs = lm.cache_specs(shard_kv)
    if shape.global_batch == 1:
        # batch axis unshardable: 'data' only ever marks the batch dim in
        # cache specs, so strip it everywhere (incl. stacked-layer specs)
        def fix(ps):
            return P(*[None if e == "data" else e for e in ps])
        specs = jax.tree.map(fix, specs, is_leaf=lambda x: isinstance(x, P))
    return with_sharding(sds, specs, mesh)
