"""Production-mesh dry-run for the PipeGCN core itself.

The graph is partitioned one-partition-per-chip: the 16×16 pod mesh flattens
to 256 partitions (the multi-pod mesh to 512), shard_map'ed over
("data","model") (+"pod"). Topology arrays are ShapeDtypeStructs sized from
the paper's largest setting (ogbn-papers100M scale per Tab. 3: 111M nodes /
3-layer / 48 hidden / feat 128), so this proves the production sharding +
collective program of the paper's own workload compiles.

Run: python -m repro.launch.dryrun_pipegcn [--multi-pod] [--variant pipegcn-gf]
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as PS

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN, ShardedData, Topology
from repro.launch.dryrun import collective_bytes
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)

# papers100M-scale per-partition sizing (111M nodes / 256 parts ≈ 434K inner;
# halo slots sized from METIS-like cut ratios at 0.4% per peer pair).
PROD = dict(max_inner=434_176, slot=2_048, max_nnz=6_553_600,
            feat_dim=128, hidden=48, num_layers=3, num_classes=172)
# Reddit-scale variant (Tab. 3 row 1) for the 2-pod mesh: smaller graph.
SMALL = dict(max_inner=1_024, slot=256, max_nnz=524_288,
             feat_dim=602, hidden=256, num_layers=4, num_classes=41)


def synthetic_topology_sds(mesh, sizes) -> tuple:
    n = int(np.prod(list(mesh.shape.values())))
    axes = tuple(mesh.axis_names)
    part = PS(axes)

    def sds(shape, dtype, spec):
        return jax.ShapeDtypeStruct(shape, dtype,
                                    sharding=NamedSharding(mesh, spec))

    mi, sl, nz = sizes["max_inner"], sizes["slot"], sizes["max_nnz"]
    topo = Topology(
        edge_row=sds((n, nz), jnp.int32, part),
        edge_col=sds((n, nz), jnp.int32, part),
        edge_w=sds((n, nz), jnp.float32, part),
        send_idx=sds((n, n, sl), jnp.int32, part),
        send_mask=sds((n, n, sl), jnp.bool_, part),
        inner_mask=sds((n, mi), jnp.bool_, part))
    data = ShardedData(
        x=sds((n, mi, sizes["feat_dim"]), jnp.float32, part),
        labels=sds((n, mi), jnp.int32, part),
        train_mask=sds((n, mi), jnp.bool_, part),
        eval_mask=sds((n, mi), jnp.bool_, part))
    return topo, data


def dryrun_pipegcn(multi_pod: bool, variant: str = "pipegcn",
                   sizes=None, compress: bool = False,
                   fuse: bool = True, overlap: str = "auto") -> dict:
    import dataclasses
    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = sizes or (SMALL if multi_pod else PROD)
    axes = tuple(mesh.axis_names)
    n = int(np.prod(list(mesh.shape.values())))

    topo_sds, data_sds = synthetic_topology_sds(mesh, sizes)
    mc = ModelConfig(kind="sage", feat_dim=sizes["feat_dim"],
                     hidden=sizes["hidden"], num_layers=sizes["num_layers"],
                     num_classes=sizes["num_classes"], dropout=0.0)
    pc = dataclasses.replace(PipeConfig.named(variant),
                             compress_boundary=compress,
                             fuse_exchange=fuse, overlap=overlap)
    split = None
    if overlap == "split-phase":
        # Synthetic split spec mirroring what split_spec_from derives from a
        # real rcm-layout graph: the boundary tail is the last row block,
        # the transpose cut sits at the last full inner block. The COO
        # engine's phased path only reads the row/col cuts, so the tile
        # counts are placeholders here.
        from repro.kernels.gcn_spmm import TILE, SplitSpec
        mi = sizes["max_inner"]
        hb0 = mi // TILE
        split = SplitSpec(row_tail=max(hb0 - 1, 1) * TILE,
                          col_tail=hb0 * TILE,
                          fwd_bnd_tiles=1, t_bnd_tiles=1)
    model = PipeGCN(mc, pc, split=split)
    params_sds = jax.eval_shape(
        lambda: model.init_params(jax.random.PRNGKey(0)))
    params_sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, PS())), params_sds)
    bufs_sds = jax.eval_shape(
        lambda: model.init_buffers(topo_sds, leading=True))
    bufs_sds = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, s.dtype, sharding=NamedSharding(mesh, PS(axes))),
        bufs_sds)
    key_sds = jax.ShapeDtypeStruct((2,), jnp.uint32,
                                   sharding=NamedSharding(mesh, PS()))

    step = model.make_spmd_step(mesh, topo_sds, axis_name=axes)
    # step is jitted; lower with SDS args
    lowered = step.lower(tuple(topo_sds), params_sds, bufs_sds,
                         tuple(data_sds), key_sds)
    compiled = lowered.compile()

    result = {"arch": f"pipegcn-{variant}", "multi_pod": multi_pod,
              "compress": compress, "fuse_exchange": pc.fuse_exchange,
              "chips": n, "sizes": sizes}
    # per-step boundary-collective count: jaxpr-traced (schedule truth) +
    # the analytic 2 (fused) vs 2L-1 (per-layer) expectation
    from repro.core.trace_utils import (collective_counts,
                                        expected_boundary_collectives)
    counts = collective_counts(step, topo_sds, params_sds, bufs_sds,
                               data_sds, key_sds)
    result["boundary_collectives_per_step"] = counts["all_to_all"]
    result["boundary_collectives_expected"] = expected_boundary_collectives(
        mc.num_layers, pc.fused, train=True)
    # traced overlap schedule: phase sizes + where the collectives sit in
    # the (aggregation scatter | exchange) event stream. The split only
    # repositions collectives — counts above must be unchanged either way.
    result["overlap"] = pc.overlap
    if model._split_active() is not None:
        from repro.core.trace_utils import traced_step_events
        mi = sizes["max_inner"]
        result["overlap_phase_rows"] = {
            "row_tail": split.row_tail,
            "fwd_boundary_rows": mi - split.row_tail,
            "fwd_interior_rows": split.row_tail,
            "col_tail": split.col_tail,
            "t_boundary_rows": mi - split.col_tail + n * sizes["slot"],
        }
        # COO engine: each phase is one segment_sum (a scatter-add eqn), so
        # an all_to_all between two scatter-adds was issued mid-layer.
        result["overlap_events"] = traced_step_events(
            step, topo_sds, params_sds, bufs_sds, data_sds, key_sds,
            names=("scatter-add", "all_to_all"))
    mem = compiled.memory_analysis()
    if mem is not None:
        result["bytes_per_device"] = int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0))
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # jax<=0.4.x returns [dict]
        cost = cost[0] if cost else {}
    if cost:
        result["flops_per_device"] = float(cost.get("flops", 0.0))
        result["bytes_accessed_per_device"] = float(
            cost.get("bytes accessed", 0.0))
    coll = collective_bytes(compiled.as_text())
    coll.pop("f32_activation_bytes", None)
    result["collective_bytes_per_device"] = coll
    result["collective_total_bytes"] = int(sum(coll.values()))
    # intended wire bytes of the boundary exchanges (the CPU backend promotes
    # bf16 collectives to f32, hiding compression in the HLO measurement)
    dims = [sizes["feat_dim"]] + [sizes["hidden"]] * (sizes["num_layers"] - 1)
    slots = n * sizes["slot"]
    fwd_w = sum(dims)
    bwd_w = sum(dims[1:])
    dtype_bytes = 2 if compress else 4
    result["boundary_wire_bytes"] = int(slots * (fwd_w + bwd_w) * dtype_bytes)
    result["t_collective_wire"] = (
        result["boundary_wire_bytes"]
        + coll.get("all-reduce", 0)) / ICI_BW
    result["t_compute"] = result.get("flops_per_device", 0) / PEAK_FLOPS_BF16
    result["t_memory"] = result.get("bytes_accessed_per_device", 0) / HBM_BW
    result["t_collective"] = result["collective_total_bytes"] / ICI_BW
    terms = {k: result[f"t_{k}"] for k in ("compute", "memory", "collective")}
    result["bottleneck"] = max(terms, key=terms.get)
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="pipegcn")
    ap.add_argument("--compress", action="store_true")
    ap.add_argument("--no-fuse", action="store_true",
                    help="per-layer blocking exchange (2L-1 collectives) "
                         "instead of the fused-deferred schedule (2)")
    ap.add_argument("--both", action="store_true",
                    help="also run the vanilla baseline for comparison")
    ap.add_argument("--overlap", default="auto",
                    choices=["auto", "none", "split-phase"],
                    help="split-phase overlap schedule: boundary phase, "
                         "issue exchange, interior phase behind it (the "
                         "dry-run synthesizes the split spec and reports "
                         "the traced phase sizes + collective positions)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    variants = [args.variant] + (["vanilla"] if args.both else [])
    results = []
    for v in variants:
        r = dryrun_pipegcn(args.multi_pod, v, compress=args.compress,
                           fuse=not args.no_fuse, overlap=args.overlap)
        results.append(r)
        print(f"[pipegcn dryrun OK] variant={v} chips={r['chips']} "
              f"bottleneck={r['bottleneck']} "
              f"boundary_colls={r['boundary_collectives_per_step']} "
              f"overlap={r['overlap']} "
              f"coll={r['collective_total_bytes']:,}B", flush=True)
        if "overlap_events" in r:
            print(f"  overlap schedule: phases {r['overlap_phase_rows']} "
                  f"events {' '.join('A' if e == 'all_to_all' else 'S' for e in r['overlap_events'])}",
                  flush=True)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        json.dump(results, open(args.out, "w"), indent=1)
        print("wrote", args.out)


if __name__ == "__main__":
    main()
