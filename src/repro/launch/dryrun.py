"""Multi-pod dry-run: lower + compile every (arch × input-shape) on the
production meshes, recording memory analysis, HLO cost analysis, and the
collective-traffic breakdown parsed from the partitioned HLO.

The XLA_FLAGS assignment below MUST run before any jax import (device count
locks on first init); this module is the only place that forces 512 host
devices — do not import it from tests or benchmarks.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
  python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512").strip()

import argparse
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, get_arch
from repro.launch.mesh import (HBM_BW, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.specs import (abstract_caches, abstract_params, batch_axes,
                                input_specs)
from repro.analysis.cost import analytic_cost
from repro.models.config import INPUT_SHAPES
from repro.models.model import LM
from repro.optim import adam

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _tuple_bytes(type_str: str) -> int:
    """Sum byte sizes of all array types in an HLO type string."""
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str, while_mult: int = 1) -> dict[str, int]:
    """Per-collective-type payload bytes (per device) from partitioned HLO.

    XLA counts a `while` (lax.scan) body once; collectives whose op_name
    metadata places them inside a loop body are multiplied by `while_mult`
    (= the layer-scan trip count of the model being analyzed).
    """
    out = {k: 0 for k in COLLECTIVE_OPS}
    out["f32_activation_bytes"] = 0   # candidates for bf16 on real TPU wire
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT )?[%\w.\-]+ = (.+?) (all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)"
                     r"(?:-start)?\(", line)
        if m:
            mult = while_mult if "/while/" in line else 1
            nbytes = _tuple_bytes(m.group(1)) * mult
            out[m.group(2)] += nbytes
            # The CPU backend promotes bf16 dots/collectives to f32; in-loop
            # activation collectives (dot partial sums, boundary payloads)
            # would travel as bf16 on TPU. Track them for the corrected term.
            if "/while/" in line and "f32[" in m.group(1):
                out["f32_activation_bytes"] += nbytes
    return out


def _fsdp_params(lm: LM, mesh):
    """ZeRO-3/FSDP layout: every weight sharded over ALL mesh axes on its
    first dimension divisible by the chip count (replicated otherwise).
    XLA then all-gathers each layer's weights at use and reduce-scatters
    grads — replacing tensor-parallel activation all-reduces."""
    chips = int(np.prod(list(mesh.shape.values())))
    flat = tuple(mesh.axis_names)
    sds = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0)))

    def spec_of(leaf):
        for dim, size in enumerate(leaf.shape):
            if size % chips == 0:
                entries = [None] * len(leaf.shape)
                entries[dim] = flat
                return NamedSharding(mesh, P(*entries))
        return NamedSharding(mesh, P())
    return jax.tree.map(
        lambda leaf: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype,
                                          sharding=spec_of(leaf)), sds)


def _build_step(lm: LM, shape, mesh, fsdp: bool = False):
    """Returns (fn, example_args) for the mode of this input shape."""
    cfg = lm.cfg
    params = _fsdp_params(lm, mesh) if fsdp else abstract_params(lm, mesh)
    batch = input_specs(cfg, shape, mesh)
    if fsdp:
        flat = tuple(mesh.axis_names)
        batch = jax.tree.map(
            lambda sds: jax.ShapeDtypeStruct(
                sds.shape, sds.dtype,
                sharding=NamedSharding(
                    mesh, P(*([flat] + [None] * (len(sds.shape) - 1))))),
            batch)

    if shape.mode == "train":
        opt = adam(1e-4)
        opt_state = jax.eval_shape(opt.init, params)
        opt_state = jax.tree.map(
            lambda s, p: jax.ShapeDtypeStruct(
                s.shape, s.dtype,
                sharding=(p.sharding if s.shape == p.shape
                          else NamedSharding(mesh, P()))),
            opt_state, type(opt_state)(step=jax.ShapeDtypeStruct((), jnp.int32),
                                       mu=params, nu=params))

        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(lm.loss_fn)(params, batch)
            new_params, new_state = opt.apply(params, grads, opt_state)
            return loss, new_params, new_state

        return train_step, (params, opt_state, batch)

    caches = abstract_caches(lm, shape, mesh)
    if shape.mode == "prefill":
        def prefill_step(params, batch, caches):
            return lm.prefill(params, batch, caches)
        return prefill_step, (params, batch, caches)

    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))

    def serve_step(params, token, caches, pos):
        return lm.decode_step(params, token, caches, pos)

    return serve_step, (params, batch["tokens"], caches, pos)


def variant_for(cfg, shape_name: str):
    """long_500k needs sub-quadratic attention: archs without a native
    sub-quadratic mixer run an explicit sliding-window decode variant
    (window 4096) — recorded as a variant in DESIGN.md §Arch-applicability."""
    if (shape_name == "long_500k" and cfg.sliding_window == 0
            and cfg.family != "ssm"):
        import dataclasses
        return dataclasses.replace(cfg, sliding_window=4096), "sw4096"
    return cfg, None


def opt_sharding_rules(mesh):
    """§Perf optimized activation sharding (Megatron-style residual +
    vocab-sharded logits); None entries fall back to GSPMD propagation."""
    from repro.launch.specs import batch_axes
    bx = batch_axes(mesh)
    return {
        "residual": NamedSharding(mesh, P(bx, None, None)),
        "logits": NamedSharding(mesh, P(bx, None, "model")),
        "moe_expert": NamedSharding(mesh, P("model", None, None)),
        # grouped routing: token groups track the data shards
        "moe_tokens": NamedSharding(mesh, P(bx, None, None)),
        "moe_gathered": NamedSharding(mesh, P(bx, "model", None, None)),
    }


def dryrun_one(arch_id: str, shape_name: str, multi_pod: bool = False,
               lower_only: bool = False, opt_sharding: bool = False,
               fsdp: bool = False) -> dict:
    from repro.models.shardctx import sharding_rules
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = INPUT_SHAPES[shape_name]
    cfg, variant = variant_for(get_arch(arch_id), shape_name)
    lm = LM(cfg)
    chips = int(np.prod(list(mesh.shape.values())))

    rules = opt_sharding_rules(mesh) if opt_sharding else None
    if fsdp:
        flat = tuple(mesh.axis_names)
        rules = {"residual": NamedSharding(mesh, P(flat, None, None)),
                 "logits": NamedSharding(mesh, P(flat, None, None))}
    if opt_sharding and cfg.num_experts:
        import dataclasses
        data_shards = mesh.shape["data"] * mesh.shape.get("pod", 1)
        cfg = dataclasses.replace(cfg, moe_groups=data_shards)
        lm = LM(cfg)
    t0 = time.perf_counter()
    with sharding_rules(rules):
        fn, args = _build_step(lm, shape, mesh, fsdp=fsdp)
        with jax.set_mesh(mesh):
            lowered = jax.jit(fn).lower(*args)
            t_lower = time.perf_counter() - t0
            result = {
                "arch": arch_id, "shape": shape_name, "mode": shape.mode,
                "variant": variant, "opt_sharding": opt_sharding,
                "fsdp": fsdp,
                "mesh": "x".join(str(s) for s in mesh.shape.values()),
                "chips": chips, "lower_s": round(t_lower, 1),
            }
            if lower_only:
                return result
            t1 = time.perf_counter()
            compiled = lowered.compile()
            result["compile_s"] = round(time.perf_counter() - t1, 1)

    mem = compiled.memory_analysis()
    if mem is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes"):
            v = getattr(mem, k, None)
            if v is not None:
                result[k] = int(v)
        result["bytes_per_device"] = int(
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0))

    # HLO cost analysis (recorded verbatim; NOTE: while/scan bodies counted
    # once — see EXPERIMENTS.md §Dry-run. Roofline compute/memory terms use
    # the analytic model below instead).
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # jax<=0.4.x returns [dict]
        cost = cost[0] if cost else {}
    if cost:
        result["hlo_flops_body_once"] = float(cost.get("flops", 0.0))
        result["hlo_bytes_body_once"] = float(cost.get("bytes accessed", 0.0))

    # layer-scan trip count for while-body collective correction
    scan_ns = [n for _, n in lm.groups if n > 1]
    if cfg.is_encdec:
        scan_ns += [n for _, n in lm.encoder_groups if n > 1]
    while_mult = max(scan_ns) if scan_ns else 1
    result["while_mult"] = while_mult
    coll = collective_bytes(compiled.as_text(), while_mult)
    f32_act = coll.pop("f32_activation_bytes")
    result["collective_bytes_per_device"] = coll
    result["collective_total_bytes"] = int(sum(coll.values()))
    # TPU wire-dtype correction: bf16 activations promoted to f32 by the CPU
    # backend travel at half the measured bytes on real hardware.
    result["collective_bytes_tpu_wire"] = int(
        result["collective_total_bytes"] - f32_act // 2)

    # analytic FLOPs / HBM bytes (global -> per device)
    ac = analytic_cost(cfg, shape)
    flops = ac["flops_global"] / chips
    bytes_hbm = ac["hbm_bytes_global"] / chips
    result["flops_per_device"] = flops
    result["hbm_bytes_per_device"] = bytes_hbm
    result["params_total"] = ac["params_total"]

    bytes_coll = result["collective_total_bytes"]
    result["t_compute"] = flops / PEAK_FLOPS_BF16
    result["t_memory"] = bytes_hbm / HBM_BW
    result["t_collective"] = bytes_coll / ICI_BW
    result["t_collective_tpu_wire"] = (
        result["collective_bytes_tpu_wire"] / ICI_BW)
    terms = {"compute": result["t_compute"], "memory": result["t_memory"],
             "collective": result["t_collective"]}
    result["bottleneck"] = max(terms, key=terms.get)

    # MODEL_FLOPS (6·N_active·D for train, 2·N_active per token for serve)
    n_active = _active_params(cfg)
    tokens = shape.global_batch * (shape.seq_len if shape.mode != "decode" else 1)
    mult = 6 if shape.mode == "train" else 2
    model_flops = mult * n_active * tokens
    result["model_flops_total"] = float(model_flops)
    result["model_flops_ratio"] = (
        float(model_flops / ac["flops_global"]) if ac["flops_global"] else 0.0)
    return result


def _active_params(cfg) -> int:
    """Parameter count active per token (MoE counts top-k+shared experts)."""
    lm = LM(cfg)
    sds = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0)))
    total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(sds)[0]:
        keys = [getattr(k, "key", getattr(k, "idx", None)) for k in path]
        n = int(np.prod(leaf.shape))
        if cfg.num_experts and any(k in ("wi", "wg", "wo") for k in keys) \
                and len(leaf.shape) >= 3 and leaf.shape[-3] == cfg.num_experts:
            n = n * cfg.experts_per_tok // cfg.num_experts
        total += n
    return total


def skip_reason(arch_id: str, shape_name: str) -> str | None:
    """Combos skipped by design (documented in DESIGN.md §Arch-applicability)."""
    return None   # all 40 combos lower: dense archs use the sliding-window
                  # decode variant for long_500k (see DESIGN.md)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--opt-sharding", action="store_true")
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    combos = []
    if args.all:
        for a in ARCH_IDS:
            for s in INPUT_SHAPES:
                combos.append((a, s, args.multi_pod))
    else:
        combos.append((args.arch, args.shape, args.multi_pod))

    results = []
    for arch, shape, mp in combos:
        tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
        try:
            r = dryrun_one(arch, shape, multi_pod=mp,
                           lower_only=args.lower_only,
                           opt_sharding=args.opt_sharding, fsdp=args.fsdp)
            results.append(r)
            print(f"[dryrun OK ] {tag}: lower={r.get('lower_s')}s "
                  f"compile={r.get('compile_s')}s "
                  f"bottleneck={r.get('bottleneck')}", flush=True)
        except Exception as e:
            results.append({"arch": arch, "shape": shape,
                            "multi_pod": mp, "error": str(e)[:2000]})
            print(f"[dryrun ERR] {tag}: {type(e).__name__}: {str(e)[:300]}",
                  flush=True)
            traceback.print_exc()
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
