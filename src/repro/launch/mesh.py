"""Production mesh construction.

Functions, not module constants — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).

Production target: TPU v5e, 256 chips/pod.
  single pod: (16, 16)    ("data", "model")
  two pods:   (2, 16, 16) ("pod", "data", "model")
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes):
    """Version-compat mesh construction: `axis_types` (Auto) where the
    installed JAX supports it (≥0.5), plain `jax.make_mesh` on 0.4.x."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(shape), tuple(axes),
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(num_devices: int | None = None, axis: str = "parts"):
    """1-D mesh over available (possibly forced-host) devices, for the
    PipeGCN SPMD backend and small-scale tests."""
    n = num_devices or len(jax.devices())
    return make_mesh((n,), (axis,))


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_BYTES = 16e9                # per chip
