"""Production mesh construction.

Functions, not module constants — importing this module never touches jax
device state (the dry-run must set XLA_FLAGS before first jax init).

Production target: TPU v5e, 256 chips/pod.
  single pod: (16, 16)    ("data", "model")
  two pods:   (2, 16, 16) ("pod", "data", "model")
"""
from __future__ import annotations

import jax


def make_mesh(shape, axes, devices=None):
    """Version-compat mesh construction: `axis_types` (Auto) where the
    installed JAX supports it (≥0.5), plain `jax.make_mesh` on 0.4.x.
    `devices` (optional) selects an explicit subset — needed when the mesh
    is smaller than the platform (multi-partition-per-device runs)."""
    try:
        from jax.sharding import AxisType
        return jax.make_mesh(tuple(shape), tuple(axes), devices=devices,
                             axis_types=(AxisType.Auto,) * len(axes))
    except (ImportError, TypeError):
        return jax.make_mesh(tuple(shape), tuple(axes), devices=devices)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(num_devices: int | None = None, axis: str = "parts"):
    """1-D mesh over available (possibly forced-host) devices, for the
    PipeGCN SPMD backend and small-scale tests."""
    n = num_devices or len(jax.devices())
    return make_mesh((n,), (axis,))


def partition_layout(num_parts: int, parts_per_device: int = 1,
                     num_devices: int | None = None) -> tuple[int, int]:
    """Device→partition mapping for the decoupled SPMD path.

    Returns (n_devices, n_local) with num_parts = n_devices * n_local;
    partition p lives on device p // n_local (device-major, matching how a
    (P, ...) leading-axis array shards over a 1-D mesh). The partition
    count is a convergence/accuracy knob (paper Tab. 4 sweeps 2–16), so it
    must not be pinned to whatever hardware is present."""
    if parts_per_device < 1:
        raise ValueError(f"parts_per_device must be >= 1, got {parts_per_device}")
    if num_parts % parts_per_device:
        raise ValueError(
            f"num_parts={num_parts} is not a multiple of "
            f"parts_per_device={parts_per_device}")
    n_dev = num_parts // parts_per_device
    avail = num_devices if num_devices is not None else len(jax.devices())
    if n_dev > avail:
        raise ValueError(
            f"num_parts={num_parts} / parts_per_device={parts_per_device} "
            f"needs {n_dev} devices but only {avail} are available — raise "
            "parts_per_device")
    return n_dev, parts_per_device


def make_partition_mesh(num_parts: int, parts_per_device: int = 1,
                        axis: str = "parts"):
    """1-D mesh sized num_parts // parts_per_device over the first devices,
    for `PipeGCN.make_spmd_step` with any partitions-per-device ratio."""
    n_dev, _ = partition_layout(num_parts, parts_per_device)
    return make_mesh((n_dev,), (axis,), devices=jax.devices()[:n_dev])


def make_survivor_mesh(plan, axis: str = "parts"):
    """1-D mesh over an ElasticPlan's surviving devices.

    When the survivor ids address devices the platform still exposes
    (the drill case: a *logical* loss on healthy hardware), the mesh is
    built from exactly those devices — deterministic, so a mid-run
    recovery and a fresh launch on the survivors pick identical
    hardware. Otherwise (the device really is gone and the remainder
    renumbered) the first ``plan.n_devices`` available devices serve."""
    devs = jax.devices()
    if plan.survivors[-1] < len(devs):
        sel = [devs[i] for i in plan.survivors]
    else:
        sel = devs[:plan.n_devices]
    if len(sel) < plan.n_devices:
        raise ValueError(
            f"survivor mesh needs {plan.n_devices} devices but only "
            f"{len(devs)} are available")
    return make_mesh((plan.n_devices,), (axis,), devices=sel)


# Hardware constants for the roofline model (TPU v5e).
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
HBM_BYTES = 16e9                # per chip
