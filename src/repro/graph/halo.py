"""Partitioned-graph construction: per-partition padded arrays + halo
(boundary-exchange) descriptors, ready for SPMD execution.

Terminology follows the paper (Alg. 1):
  inner nodes  V_i : nodes owned by partition i
  boundary set B_i : remote nodes partition i needs (its halo)
  S_{i,j} = B_j ∩ V_i : nodes partition i must SEND to partition j

All arrays are padded to identical sizes across partitions so a single SPMD
program (shard_map over the partition axis) can execute every partition:

  inner features   X        (P, max_inner, F)
  adjacency (COO)  row/col/w (P, max_nnz)   col indexes the COMBINED array
  send indices     send_idx (P, P, slot)    local inner row to send to peer j
  halo buffer      B        (P, P*slot, F)  received boundary features

The combined feature array of partition i is  [H_inner (max_inner) ; B (P*slot)],
so one sparse matmul implements  P_in·H + P_bd·B  exactly (Eq. 3): intra-
partition edges point at columns < max_inner, boundary edges at
max_inner + j*slot + k.  Padded edges carry weight 0 and point at column 0.
COO (padded to max_nnz) rather than ELL keeps memory bounded under power-law
degree skew.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph


@dataclasses.dataclass
class PartitionedGraph:
    """Padded per-partition graph shards (leading axis = partition).

    `layout` records the intra-partition node ordering the shards were
    built with ("natural" = sorted global id; "rcm" = bandwidth-reduced +
    halo-clustered, see repro.graph.reorder). `perm`/`inv_perm` are the
    per-partition permutations relating the two: `perm[i, k]` is the
    NATURAL local row of the node at reordered local row k, and
    `inv_perm` its inverse (both identity under the natural layout; -1 in
    the padding tail). Every consumer that routes through
    `part_of`/`local_of` — pack/unpack, the send/recv index tables, the
    COO and tile shards — already lives in the reordered space, so the
    permutation is only ever applied at build time and undone at the
    eval/metric boundary by `unpack_nodes`.
    """

    num_parts: int
    num_nodes: int                 # global node count
    max_inner: int
    slot: int                      # per-(i,j) halo slot count (uniform)
    max_nnz: int

    part_of: np.ndarray            # (N,) int32 owner partition
    local_of: np.ndarray           # (N,) int32 local inner row at owner
    inner_global: np.ndarray       # (P, max_inner) int32, -1 pad
    inner_mask: np.ndarray         # (P, max_inner) bool

    edge_row: np.ndarray           # (P, max_nnz) int32 local dst row
    edge_col: np.ndarray           # (P, max_nnz) int32 combined-array col
    edge_w: np.ndarray             # (P, max_nnz) float32 (0 = pad)

    send_idx: np.ndarray           # (P, P, slot) int32 local inner row, 0 pad
    send_mask: np.ndarray          # (P, P, slot) bool
    halo_owner_mask: np.ndarray    # (P, P*slot) bool: real halo entries of part i

    layout: str = "natural"        # intra-partition node ordering
    perm: np.ndarray | None = None      # (P, max_inner) int32: new -> natural
    inv_perm: np.ndarray | None = None  # (P, max_inner) int32: natural -> new
    # build_tile_topology output per tile size (see extract_partition_tiles):
    # trainer + dryrun + benchmarks in one process reuse one extraction.
    tile_cache: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def combined(self) -> int:
        """Size of the combined per-partition feature array."""
        return self.max_inner + self.num_parts * self.slot

    def pack_nodes(self, x: np.ndarray, fill: float = 0.0) -> np.ndarray:
        """Scatter a global (N, ...) array into (P, max_inner, ...)."""
        out_shape = (self.num_parts, self.max_inner) + x.shape[1:]
        out = np.full(out_shape, fill, dtype=x.dtype)
        out[self.part_of, self.local_of] = x
        return out

    def unpack_nodes(self, x: np.ndarray) -> np.ndarray:
        """Gather (P, max_inner, ...) back to global (N, ...)."""
        return np.asarray(x)[self.part_of, self.local_of]

    # -- statistics used by benchmarks ---------------------------------
    def halo_counts(self) -> np.ndarray:
        """(P,) number of real boundary nodes per partition."""
        return self.halo_owner_mask.reshape(self.num_parts, -1).sum(axis=1)

    def boundary_bytes_per_layer(self, feat_dim: int, dtype_bytes: int = 4) -> int:
        """Total payload exchanged per layer per direction (fwd or bwd)."""
        return int(self.send_mask.sum()) * feat_dim * dtype_bytes

    def padding_ratio(self) -> float:
        real = self.send_mask.sum()
        padded = self.send_mask.size
        return float(1.0 - real / max(padded, 1))


@dataclasses.dataclass
class PartitionTiles:
    """Stacked block-sparse tile streams, one row per partition.

    Built by `extract_partition_tiles` from the padded COO shards without
    ever materializing a dense matrix. All partitions are padded to the same
    tile count with zero tiles so the arrays stack into a leading partition
    axis (SPMD-ready, mirroring the COO shard layout). `t_*` arrays drive
    the transpose kernel (δcomb = Pᵀ·δz) over the same `vals` storage.
    """

    rows: np.ndarray      # (P, n_tiles) int32 row block, sorted per part
    cols: np.ndarray      # (P, n_tiles) int32 col block
    vals: np.ndarray      # (P, n_tiles, T, T) float32
    t_out: np.ndarray     # (P, n_tiles) int32 Pᵀ output block, sorted
    t_in: np.ndarray      # (P, n_tiles) int32 Pᵀ input block
    t_perm: np.ndarray    # (P, n_tiles) int32 per-partition index into vals

    # Interior/boundary phase split of the streams (split-phase overlap
    # schedule). None when the split is structurally infeasible (no sends,
    # or boundary rows start in row block 0 on every partition) — the
    # streams then carry the plain tail padding and only the unsplit
    # schedule may consume them. When set, the LAST fwd_bnd (t_bnd) slots
    # of every partition's forward (transpose) stream are exactly the
    # tiles with row block >= b0 (col block >= hb0) — uniform cut points,
    # enforced by the phase-aware group padding.
    b0: int | None = None       # first boundary row block (fwd phases)
    hb0: int | None = None      # first boundary col block (transpose phases)
    fwd_bnd: int | None = None  # boundary-suffix tiles, forward stream
    t_bnd: int | None = None    # boundary-suffix tiles, transpose stream

    @property
    def n_tiles(self) -> int:
        return self.rows.shape[1]


def boundary_row_split(pg: "PartitionedGraph", tile: int = 128) -> dict:
    """Interior/boundary row split of each partition's reordered node range.

    `first_send[i]` is the lowest local row partition i ever sends (== the
    head of its halo-clustered tail run under the rcm layout; scattered —
    usually 0 — under the natural layout). The split-phase schedule cuts
    uniformly at row block ``b0 = min_i first_send[i] // tile`` (forward)
    and col block ``hb0 = max_inner // tile`` (transpose: everything at or
    above the last full inner block feeds the gradient send or the halo).
    Partitions with no sends at all report first_send = max_inner and do
    not constrain b0 (degenerate single-partition case: every first_send
    is max_inner and `feasible` is False).
    """
    firsts = []
    for i in range(pg.num_parts):
        rows = pg.send_idx[:, i, :][pg.send_mask[:, i, :]]
        firsts.append(int(rows.min()) if rows.size else pg.max_inner)
    has_sends = bool(pg.send_mask.any())
    b0 = min(f // tile for f in firsts)
    hb0 = pg.max_inner // tile
    return {"first_send": firsts, "b0": b0, "hb0": hb0, "tile": tile,
            "feasible": has_sends and b0 >= 1 and hb0 >= 1
            and b0 * tile < pg.max_inner}


def extract_partition_tiles(pg: "PartitionedGraph",
                            tile: int | None = None) -> PartitionTiles:
    """Per-partition TILE×TILE tile extraction for the blocksparse engine.

    Each partition's padded COO shard (rows over inner nodes, columns over
    the combined [inner; halo] array) is bucketed into dense MXU-shaped
    tiles directly — O(nnz + n_tiles·T²), no dense (max_inner, combined)
    intermediate. Padded edges (weight 0) are dropped by the bucketing.

    The result is memoized on ``pg.tile_cache`` (keyed by tile size): the
    shards are immutable after build, and one process routinely constructs
    several engines over the same graph (trainer + eval + dryrun +
    benchmark sweeps), which would otherwise re-extract identical tiles.

    When the interior/boundary split is structurally feasible (see
    `boundary_row_split`) the cross-partition padding is PHASE-AWARE: each
    partition's streams are padded per phase group, so the boundary suffix
    starts at the same static slot everywhere and the split-phase overlap
    schedule can slice it with trace-time constants. The padded streams
    remain valid for the unsplit kernels (zero tiles, run grouping
    intact), so split and unsplit schedules share one topology
    bit-identically. Infeasible graphs fall back to the plain tail
    padding and report `fwd_bnd is None`.
    """
    from repro.kernels.gcn_spmm import (TILE, build_tile_topology,
                                        pad_tile_topology,
                                        pad_tile_topology_phased)
    tile = TILE if tile is None else tile
    cached = pg.tile_cache.get(tile)
    if cached is not None:
        return cached
    per = [build_tile_topology(pg.edge_row[i], pg.edge_col[i], pg.edge_w[i],
                               pg.max_inner, pg.combined, tile)
           for i in range(pg.num_parts)]
    split = boundary_row_split(pg, tile)
    meta: dict = dict(b0=None, hb0=None, fwd_bnd=None, t_bnd=None)
    if split["feasible"]:
        b0, hb0 = split["b0"], split["hb0"]
        cuts_f = [int(np.searchsorted(tt.rows, b0)) for tt in per]
        cuts_t = [int(np.searchsorted(tt.t_out, hb0)) for tt in per]
        n_int_f = max(cuts_f)
        n_bnd_f = max(tt.n_tiles - c for tt, c in zip(per, cuts_f))
        n_int_t = max(cuts_t)
        n_bnd_t = max(tt.n_tiles - c for tt, c in zip(per, cuts_t))
        # Both streams of one partition share the vals storage, so their
        # padded totals must agree; absorb the difference into the larger
        # schedule's interior group (pads there are cheapest to place).
        n_tiles = max(n_int_f + n_bnd_f, n_int_t + n_bnd_t)
        n_int_f += n_tiles - (n_int_f + n_bnd_f)
        n_int_t += n_tiles - (n_int_t + n_bnd_t)
        per = [pad_tile_topology_phased(tt, b0, hb0, n_int_f, n_bnd_f,
                                        n_int_t, n_bnd_t) for tt in per]
        meta = dict(b0=b0, hb0=hb0, fwd_bnd=n_bnd_f, t_bnd=n_bnd_t)
    else:
        n_tiles = max(tt.n_tiles for tt in per)
        per = [pad_tile_topology(tt, n_tiles) for tt in per]
    out = PartitionTiles(
        rows=np.stack([tt.rows for tt in per]),
        cols=np.stack([tt.cols for tt in per]),
        vals=np.stack([tt.vals for tt in per]),
        t_out=np.stack([tt.t_out for tt in per]),
        t_in=np.stack([tt.t_in for tt in per]),
        t_perm=np.stack([tt.t_perm for tt in per]),
        **meta)
    pg.tile_cache[tile] = out
    return out


def build_partitioned_graph(prop: CSRGraph, part: np.ndarray,
                            num_parts: int | None = None,
                            pad_multiple: int = 8,
                            layout: str = "natural") -> PartitionedGraph:
    """Build padded partition shards from a normalized propagation matrix.

    `prop` must already be normalized (weights = global P entries) so that
    the partition split preserves Eq. 3/4 semantics exactly.

    `layout` selects the intra-partition node ordering:
      "natural"  sorted global id (the historical order)
      "rcm"      RCM bandwidth reduction over the local subgraph + halo
                 clustering (repro.graph.reorder.partition_orders), and the
                 halo slots of each (receiver i, owner j) pair additionally
                 sorted by the first reordered row of i that consumes them —
                 together they shrink the nonempty-tile frontier the
                 block-sparse engines pay for. Numerically the layouts are
                 identical modulo the carried `perm`/`inv_perm`.
    """
    part = np.asarray(part, dtype=np.int32)
    n = prop.num_nodes
    p = int(part.max()) + 1 if num_parts is None else int(num_parts)

    # Local ordering of inner nodes: sorted global id, or the reordered
    # per-partition node lists. Everything downstream keys off
    # part_of/local_of, so the layout choice is fully absorbed here.
    if layout == "natural":
        inner_lists = [np.flatnonzero(part == i) for i in range(p)]
    elif layout == "rcm":
        from repro.graph.reorder import partition_orders
        inner_lists = partition_orders(prop, part, p)
    else:
        from repro.graph.reorder import LAYOUTS
        raise ValueError(f"unknown layout {layout!r}; have {LAYOUTS}")
    local_of = np.zeros(n, dtype=np.int32)
    for i in range(p):
        local_of[inner_lists[i]] = np.arange(len(inner_lists[i]),
                                             dtype=np.int32)
    inner_counts = np.array([len(v) for v in inner_lists])
    max_inner = int(-(-int(inner_counts.max()) // pad_multiple) * pad_multiple)

    inner_global = np.full((p, max_inner), -1, dtype=np.int32)
    inner_mask = np.zeros((p, max_inner), dtype=bool)
    perm = np.full((p, max_inner), -1, dtype=np.int32)
    inv_perm = np.full((p, max_inner), -1, dtype=np.int32)
    for i in range(p):
        k = inner_counts[i]
        inner_global[i, :k] = inner_lists[i]
        inner_mask[i, :k] = True
        # forward/inverse permutation vs the natural (sorted-global-id)
        # order — identity when layout == "natural"
        fwd = np.searchsorted(np.sort(inner_lists[i]), inner_lists[i])
        perm[i, :k] = fwd
        inv_perm[i, fwd] = np.arange(k, dtype=np.int32)

    # Edge lists per partition; boundary slot assignment per (owner j -> i).
    dst_all = np.repeat(np.arange(n, dtype=np.int64), np.diff(prop.indptr))
    src_all = prop.indices.astype(np.int64)
    w_all = prop.weights
    pi = part[dst_all]            # receiving partition of each edge
    pj = part[src_all]            # owning partition of each source

    # slot maps: for partition i and owner j, remote node -> slot k.
    # Natural layout keeps the historical sorted-global-id slot order; the
    # reordered layouts sort each (i, j) halo block by the FIRST reordered
    # row of i that consumes the node (global id as tie-break), so halo
    # columns cluster with their consuming row blocks and the P_bd tile
    # frontier shrinks along the column axis too.
    halo_nodes: list[list[np.ndarray]] = [[None] * p for _ in range(p)]  # type: ignore
    slot = 0
    for i in range(p):
        for j in range(p):
            if i == j:
                continue
            m = (pi == i) & (pj == j)
            uniq = np.unique(src_all[m])
            if layout != "natural" and len(uniq):
                # min consuming row per unique source WITHOUT ufunc.at
                # (the slow buffered scatter path — same finding as the
                # tile-extraction scatter): sort (slot_key, row) pairs and
                # take each group's first element.
                slot_key = np.searchsorted(uniq, src_all[m])
                rows_i = local_of[dst_all[m]].astype(np.int64)
                order = np.lexsort((rows_i, slot_key))
                starts = np.searchsorted(slot_key[order],
                                         np.arange(len(uniq)))
                first_row = rows_i[order][starts]
                uniq = uniq[np.lexsort((uniq, first_row))]
            halo_nodes[i][j] = uniq
            slot = max(slot, len(uniq))
    slot = max(int(-(-slot // pad_multiple) * pad_multiple), pad_multiple)

    send_idx = np.zeros((p, p, slot), dtype=np.int32)
    send_mask = np.zeros((p, p, slot), dtype=bool)
    halo_owner_mask = np.zeros((p, p * slot), dtype=bool)
    # slot_of[i][j]: dict-free vectorized lookup via searchsorted on halo_nodes
    for i in range(p):
        for j in range(p):
            if i == j:
                continue
            uniq = halo_nodes[i][j]
            k = len(uniq)
            if k == 0:
                continue
            # partition j sends these nodes to partition i
            send_idx[j, i, :k] = local_of[uniq]
            send_mask[j, i, :k] = True
            halo_owner_mask[i, j * slot:j * slot + k] = True

    # Per-partition COO with combined-array columns.
    rows_p: list[np.ndarray] = []
    cols_p: list[np.ndarray] = []
    ws_p: list[np.ndarray] = []
    for i in range(p):
        m = pi == i
        d, s, w = dst_all[m], src_all[m], w_all[m]
        row = local_of[d].astype(np.int64)
        col = np.empty(len(s), dtype=np.int64)
        is_local = part[s] == i
        col[is_local] = local_of[s[is_local]]
        for j in range(p):
            if j == i:
                continue
            mj = (~is_local) & (part[s] == j)
            if not mj.any():
                continue
            uniq = halo_nodes[i][j]
            # slot-of lookup valid for ANY slot order: search the sorted
            # view, then map the sorted position back to the slot index
            by_gid = np.argsort(uniq, kind="stable")
            k = by_gid[np.searchsorted(uniq[by_gid], s[mj])]
            col[mj] = max_inner + j * slot + k
        rows_p.append(row); cols_p.append(col); ws_p.append(w)

    max_nnz = int(-(-max(len(r) for r in rows_p) // pad_multiple) * pad_multiple)
    edge_row = np.zeros((p, max_nnz), dtype=np.int32)
    edge_col = np.zeros((p, max_nnz), dtype=np.int32)
    edge_w = np.zeros((p, max_nnz), dtype=np.float32)
    for i in range(p):
        k = len(rows_p[i])
        edge_row[i, :k] = rows_p[i]
        edge_col[i, :k] = cols_p[i]
        edge_w[i, :k] = ws_p[i]

    return PartitionedGraph(
        num_parts=p, num_nodes=n, max_inner=max_inner, slot=slot,
        max_nnz=max_nnz, part_of=part, local_of=local_of,
        inner_global=inner_global, inner_mask=inner_mask,
        edge_row=edge_row, edge_col=edge_col, edge_w=edge_w,
        send_idx=send_idx, send_mask=send_mask,
        halo_owner_mask=halo_owner_mask,
        layout=layout, perm=perm, inv_perm=inv_perm)
