"""Locality-aware per-partition node reordering for the block-sparse
aggregation engines.

The tile engines (repro.kernels.gcn_spmm) do work proportional to the
number of nonempty 128×128 tiles of each partition's propagation shard
P_local = [P_in | P_bd] over the combined [inner; halo] column space.
`build_partitioned_graph` historically ordered inner nodes by global id —
the arbitrary order the partitioner emits — which scatters both the
intra-partition edges and the halo-consuming rows across the tile grid.

This module computes a per-partition permutation of the inner nodes that
shrinks that tile frontier, composed of two standard layout moves
(Demirci et al., "Scalable Graph Convolutional Network Training on
Distributed-Memory Systems", 2022 — bandwidth-reducing reordering for
distributed SpMM):

  1. RCM bandwidth reduction over the LOCAL subgraph (intra-partition
     edges only): reverse Cuthill–McKee packs the P_in block toward the
     diagonal, so the intra-partition edges of a row block fall into few
     column blocks.
  2. Halo clustering: nodes incident to any cut edge (they consume halo
     columns and/or are sent to peers) are packed into one contiguous run
     at the tail, preserving their relative RCM order. The P_bd block's
     nonzeros then live in ~⌈boundary/128⌉ row blocks instead of being
     sprinkled over all of them, and the boundary-destined rows a peer
     gathers are contiguous.

The permutation never leaves the graph-build layer: features/labels/masks
are packed through `PartitionedGraph.pack_nodes` (which routes through
`part_of`/`local_of`) and results are unpacked — i.e. unpermuted — only at
the eval/metric boundary by `unpack_nodes`. Training numerics are
permutation-equivariant, so any layout is bit-identical modulo the
permutation (enforced at 1e-12 in f64 by tests/test_reorder.py and the
SPMD parity matrix).

Pure numpy, offline; no jax dependency.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph

#: Node layouts `build_partitioned_graph` accepts ("auto" is resolved to
#: one of these by `resolve_layout` before it reaches the builder).
LAYOUTS = ("natural", "rcm")

#: Aggregation engines that consume tile streams — the ones a reordered
#: layout actually speeds up (see repro.kernels.aggregate).
TILE_ENGINES = ("blocksparse", "fused")


def resolve_layout(layout: str, agg: str) -> str:
    """Resolve the user-facing layout knob ("natural" | "rcm" | "auto")
    to a concrete layout: "auto" picks "rcm" exactly when the selected
    aggregation engine consumes tiles. GraphDataPipeline.build resolves
    through this at pipeline construction; the trainer's consistency
    check then compares declared vs built layouts directly ("auto" there
    simply defers to whatever the pipeline carries)."""
    if layout == "auto":
        return "rcm" if agg in TILE_ENGINES else "natural"
    return layout


def _neighbors(indptr: np.ndarray, indices: np.ndarray,
               frontier: np.ndarray) -> np.ndarray:
    """Concatenated neighbor lists of `frontier`, preserving frontier order
    then adjacency order — one flat gather, no per-node Python loop."""
    counts = indptr[frontier + 1] - indptr[frontier]
    total = int(counts.sum())
    if total == 0:
        return np.empty(0, dtype=indices.dtype)
    starts = np.repeat(indptr[frontier], counts)
    run_starts = np.cumsum(counts) - counts
    offs = np.arange(total, dtype=np.int64) - np.repeat(run_starts, counts)
    return indices[starts + offs]


def _local_subgraph(nodes: np.ndarray, dst: np.ndarray,
                    src: np.ndarray, num_nodes: int):
    """Symmetrized intra-partition structure over `nodes`, in local ids.

    `dst`/`src` are the global COO endpoints of the (pre-filtered)
    intra-partition edges of this partition. Self-loops are dropped (they
    never affect a traversal order) and the structure is symmetrized so
    RCM sees an undirected graph even for asymmetric propagation weights.
    Returns (indptr, indices) CSR over len(nodes) local ids.
    """
    k = len(nodes)
    loc = np.full(num_nodes, -1, dtype=np.int64)
    loc[nodes] = np.arange(k)
    a = np.concatenate([loc[dst], loc[src]])
    b = np.concatenate([loc[src], loc[dst]])
    keep = a != b
    a, b = a[keep], b[keep]
    key = np.unique(a * k + b)
    a, b = key // k, key % k
    # bincount, not np.add.at — the buffered ufunc-at loop is the slow
    # scatter path (same finding as the tile-extraction scatter)
    indptr = np.zeros(k + 1, dtype=np.int64)
    indptr[1:] = np.cumsum(np.bincount(a, minlength=k))
    return indptr, b.astype(np.int64)


def rcm_order(indptr: np.ndarray, indices: np.ndarray) -> np.ndarray:
    """Reverse Cuthill–McKee over an undirected local graph.

    Per connected component: start from a minimum-degree node, BFS level
    by level with each level sorted by (degree, id) — the classic CM
    order, vectorized per level — then reverse the whole sequence.
    Returns all n local ids as a permutation (isolated nodes included).
    """
    n = len(indptr) - 1
    deg = np.diff(indptr)
    visited = np.zeros(n, dtype=bool)
    out = np.empty(n, dtype=np.int64)
    pos = 0
    for start in np.argsort(deg, kind="stable"):
        if visited[start]:
            continue
        visited[start] = True
        out[pos] = start
        pos += 1
        frontier = np.array([start], dtype=np.int64)
        while frontier.size:
            nbrs = _neighbors(indptr, indices, frontier)
            nbrs = np.unique(nbrs[~visited[nbrs]])
            if nbrs.size == 0:
                break
            nbrs = nbrs[np.lexsort((nbrs, deg[nbrs]))]
            visited[nbrs] = True
            out[pos:pos + len(nbrs)] = nbrs
            pos += len(nbrs)
            frontier = nbrs
    assert pos == n
    return out[::-1].copy()


def boundary_mask(prop: CSRGraph, part: np.ndarray) -> np.ndarray:
    """(N,) bool: nodes incident to at least one real cut edge in either
    direction — they consume halo columns and/or are gathered into a
    peer's halo. Under the rcm layout these are exactly the nodes packed
    into each partition's contiguous tail run, i.e. the rows the
    split-phase schedule's boundary phase must produce before the
    exchange can be issued."""
    part = np.asarray(part, dtype=np.int64)
    n = prop.num_nodes
    dst = np.repeat(np.arange(n, dtype=np.int64), np.diff(prop.indptr))
    src = prop.indices.astype(np.int64)
    cross = (part[dst] != part[src]) & (prop.weights != 0)
    out = np.zeros(n, dtype=bool)
    out[dst[cross]] = True       # consumes halo columns
    out[src[cross]] = True       # gathered into a peer's halo
    return out


def interior_boundary_counts(prop: CSRGraph, part: np.ndarray,
                             num_parts: int) -> list[tuple[int, int]]:
    """Per-partition (interior, boundary) node counts — the layout-level
    view of how much aggregation work the split-phase schedule can
    overlap with the exchange (interior share) vs must run before
    issuing it (boundary tail)."""
    part = np.asarray(part, dtype=np.int64)
    bnd = boundary_mask(prop, part)
    out = []
    for i in range(num_parts):
        m = part == i
        b = int(np.count_nonzero(bnd & m))
        out.append((int(np.count_nonzero(m)) - b, b))
    return out


def partition_orders(prop: CSRGraph, part: np.ndarray,
                     num_parts: int) -> list[np.ndarray]:
    """Per-partition node orders (arrays of GLOBAL ids, new local order).

    RCM over each partition's local subgraph, composed with halo
    clustering: boundary nodes (incident to at least one real cut edge,
    in either direction) are stably moved to the tail of the order. The
    relative RCM order inside each of the two groups is preserved, so the
    P_in block keeps most of its bandwidth reduction while the halo
    frontier collapses to one contiguous row run.
    """
    part = np.asarray(part, dtype=np.int64)
    n = prop.num_nodes
    dst = np.repeat(np.arange(n, dtype=np.int64), np.diff(prop.indptr))
    src = prop.indices.astype(np.int64)
    real = prop.weights != 0
    is_boundary = boundary_mask(prop, part)

    # Group intra-partition edges (and nodes) by owner ONCE — per-partition
    # masks over the global edge arrays would make the build O(P·E).
    intra_idx = np.flatnonzero((part[dst] == part[src]) & real)
    owner = part[dst[intra_idx]]
    e_order = np.argsort(owner, kind="stable")
    by_owner = intra_idx[e_order]
    e_bounds = np.searchsorted(owner[e_order], np.arange(num_parts + 1))
    node_by_part = np.argsort(part, kind="stable")   # ascending id per part
    n_bounds = np.searchsorted(part[node_by_part], np.arange(num_parts + 1))

    orders: list[np.ndarray] = []
    for i in range(num_parts):
        nodes = node_by_part[n_bounds[i]:n_bounds[i + 1]]  # natural order
        sel = by_owner[e_bounds[i]:e_bounds[i + 1]]
        indptr_l, indices_l = _local_subgraph(nodes, dst[sel], src[sel], n)
        loc = rcm_order(indptr_l, indices_l)
        bnd = is_boundary[nodes[loc]]
        loc = np.concatenate([loc[~bnd], loc[bnd]])  # stable interior|boundary
        orders.append(nodes[loc])
    return orders
