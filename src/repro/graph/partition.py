"""Graph partitioning with a METIS-like objective (minimize communication
volume under a balance constraint).

METIS itself is unavailable offline; we implement the same recipe the paper
relies on at a smaller scale: balanced BFS growth (Kernighan-style seeding)
followed by greedy boundary refinement that moves nodes to the neighboring
partition with the largest edge-cut gain, subject to balance.  The objective
the paper sets for METIS is *communication volume* — the number of replicated
boundary nodes — which edge-cut refinement tracks closely on these graphs.
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph


def edge_cut(g: CSRGraph, part: np.ndarray) -> int:
    dst = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    return int(np.sum(part[dst] != part[g.indices]))


def comm_volume(g: CSRGraph, part: np.ndarray, num_parts: int) -> int:
    """Total replicated boundary nodes = sum over partitions of |halo|."""
    dst = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    src = g.indices.astype(np.int64)
    cross = part[dst] != part[src]
    # Unique (receiving partition, remote node) pairs.
    key = part[dst][cross].astype(np.int64) * g.num_nodes + src[cross]
    return len(np.unique(key))


def _bfs_grow(g: CSRGraph, num_parts: int, rng: np.random.Generator) -> np.ndarray:
    """Grow num_parts balanced regions from spread-out seeds."""
    n = g.num_nodes
    part = np.full(n, -1, dtype=np.int32)
    target = -(-n // num_parts)
    sizes = np.zeros(num_parts, dtype=np.int64)
    # Seeds: farthest-point-ish sampling via random + degree.
    seeds = rng.choice(n, size=num_parts, replace=False)
    frontiers: list[list[int]] = [[int(s)] for s in seeds]
    for p, s in enumerate(seeds):
        if part[s] == -1:
            part[s] = p
            sizes[p] += 1
    active = True
    while active:
        active = False
        for p in range(num_parts):
            if sizes[p] >= target or not frontiers[p]:
                continue
            nxt: list[int] = []
            for v in frontiers[p]:
                s, e = g.indptr[v], g.indptr[v + 1]
                for u in g.indices[s:e]:
                    if part[u] == -1 and sizes[p] < target:
                        part[u] = p
                        sizes[p] += 1
                        nxt.append(int(u))
            frontiers[p] = nxt
            if nxt:
                active = True
    # Unreached nodes (disconnected): round-robin into smallest parts.
    for v in np.flatnonzero(part == -1):
        p = int(np.argmin(sizes))
        part[v] = p
        sizes[p] += 1
    return part


def _refine(g: CSRGraph, part: np.ndarray, num_parts: int,
            passes: int, imbalance: float) -> np.ndarray:
    """Greedy gain-based boundary refinement (one-sided KL/FM sweep)."""
    n = g.num_nodes
    max_size = int((n / num_parts) * (1 + imbalance)) + 1
    part = part.copy()
    for _ in range(passes):
        sizes = np.bincount(part, minlength=num_parts)
        moved = 0
        dst = np.repeat(np.arange(n), np.diff(g.indptr))
        boundary = np.unique(dst[part[dst] != part[g.indices]])
        for v in boundary:
            s, e = g.indptr[v], g.indptr[v + 1]
            nbr_parts = part[g.indices[s:e]]
            counts = np.bincount(nbr_parts, minlength=num_parts)
            home = part[v]
            best = home
            best_gain = 0
            for p in np.flatnonzero(counts):
                if p == home or sizes[p] + 1 > max_size:
                    continue
                gain = counts[p] - counts[home]
                if gain > best_gain:
                    best_gain, best = gain, p
            if best != home and sizes[home] > 1:
                sizes[home] -= 1
                sizes[best] += 1
                part[v] = best
                moved += 1
        if moved == 0:
            break
    return part


def partition_graph(g: CSRGraph, num_parts: int, seed: int = 0,
                    refine_passes: int = 4, imbalance: float = 0.05,
                    method: str = "bfs+refine") -> np.ndarray:
    """Partition nodes into num_parts balanced parts; returns part[v]."""
    if num_parts <= 1:
        return np.zeros(g.num_nodes, dtype=np.int32)
    if num_parts > g.num_nodes:
        raise ValueError("more partitions than nodes")
    rng = np.random.default_rng(seed)
    if method == "random":
        part = rng.integers(0, num_parts, size=g.num_nodes).astype(np.int32)
        # Rebalance exactly.
        order = rng.permutation(g.num_nodes)
        part = (np.arange(g.num_nodes) % num_parts)[np.argsort(order)].astype(np.int32)
        return part
    part = _bfs_grow(g, num_parts, rng)
    if "refine" in method:
        part = _refine(g, part, num_parts, refine_passes, imbalance)
    return part
