"""Graph partitioning with a METIS-like objective (minimize communication
volume under a balance constraint).

METIS itself is unavailable offline; we implement the same recipe the paper
relies on at a smaller scale: balanced BFS growth (Kernighan-style seeding)
followed by greedy boundary refinement that moves nodes to the neighboring
partition with the largest edge-cut gain, subject to balance.  The objective
the paper sets for METIS is *communication volume* — the number of replicated
boundary nodes — which edge-cut refinement tracks closely on these graphs.

Both phases are vectorized with numpy frontier expansion / delta-updated
gain tables and are BIT-IDENTICAL to the per-node Python loops they replaced
(kept below as ``_bfs_grow_loop`` / ``_refine_loop``: the equivalence oracle
for tests and the before/after baseline for the build-time record in
benchmarks/bench_kernels.py).
"""
from __future__ import annotations

import numpy as np

from repro.graph.csr import CSRGraph
from repro.graph.reorder import _neighbors


def edge_cut(g: CSRGraph, part: np.ndarray) -> int:
    dst = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    return int(np.sum(part[dst] != part[g.indices]))


def comm_volume(g: CSRGraph, part: np.ndarray, num_parts: int) -> int:
    """Total replicated boundary nodes = sum over partitions of |halo|."""
    dst = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    src = g.indices.astype(np.int64)
    cross = part[dst] != part[src]
    # Unique (receiving partition, remote node) pairs.
    key = part[dst][cross].astype(np.int64) * g.num_nodes + src[cross]
    return len(np.unique(key))


def _first_occurrence(a: np.ndarray) -> np.ndarray:
    """`a` with duplicates dropped, keeping the FIRST occurrence in place
    (np.unique alone would re-sort by value)."""
    _, first = np.unique(a, return_index=True)
    return a[np.sort(first)]


def _bfs_grow(g: CSRGraph, num_parts: int, rng: np.random.Generator) -> np.ndarray:
    """Grow num_parts balanced regions from spread-out seeds.

    Vectorized frontier expansion: each round expands a whole partition
    frontier with one flat neighbor gather + first-occurrence dedup,
    matching the sequential per-node loop exactly (same assignment order,
    same capacity cap), so the output is bit-identical to
    ``_bfs_grow_loop``.
    """
    n = g.num_nodes
    part = np.full(n, -1, dtype=np.int32)
    target = -(-n // num_parts)
    sizes = np.zeros(num_parts, dtype=np.int64)
    indices = g.indices.astype(np.int64)
    seeds = rng.choice(n, size=num_parts, replace=False)
    frontiers: list[np.ndarray] = [np.array([s], dtype=np.int64)
                                   for s in seeds]
    for p, s in enumerate(seeds):
        if part[s] == -1:
            part[s] = p
            sizes[p] += 1
    active = True
    while active:
        active = False
        for p in range(num_parts):
            if sizes[p] >= target or not len(frontiers[p]):
                continue
            cand = _neighbors(g.indptr, indices, frontiers[p])
            cand = _first_occurrence(cand)
            cand = cand[part[cand] == -1]
            nxt = cand[:target - sizes[p]]
            part[nxt] = p
            sizes[p] += len(nxt)
            frontiers[p] = nxt
            if len(nxt):
                active = True
    # Unreached nodes (disconnected): round-robin into smallest parts.
    for v in np.flatnonzero(part == -1):
        p = int(np.argmin(sizes))
        part[v] = p
        sizes[p] += 1
    return part


def _refine(g: CSRGraph, part: np.ndarray, num_parts: int,
            passes: int, imbalance: float) -> np.ndarray:
    """Greedy gain-based boundary refinement (one-sided KL/FM sweep).

    The per-node neighbor-partition histograms are built ONCE per pass with
    a vectorized scatter-add, then delta-updated as nodes move (only the
    histogram rows of a moved node's boundary neighbors change), so the
    sequential sweep keeps its exact semantics — same visit order, same
    tie-breaks, same interaction through sizes — at O(E_boundary + moves·deg)
    instead of O(n·deg) Python-interpreted work (bit-identical to
    ``_refine_loop``).
    """
    n = g.num_nodes
    max_size = int((n / num_parts) * (1 + imbalance)) + 1
    part = part.copy()
    dst_all = np.repeat(np.arange(n), np.diff(g.indptr))
    src_all = g.indices.astype(np.int64)
    for _ in range(passes):
        sizes = np.bincount(part, minlength=num_parts)
        boundary = np.unique(dst_all[part[dst_all] != part[src_all]])
        if not len(boundary):
            break
        nb = len(boundary)
        brow = np.full(n, -1, dtype=np.int64)
        brow[boundary] = np.arange(nb)
        on_b = brow[dst_all] >= 0
        e_b, e_src = brow[dst_all[on_b]], src_all[on_b]
        # Flat-key bincount, not 2-D np.add.at — the multi-index fancy-index
        # ufunc loop is the slow path (same finding as the tile-extraction
        # scatter in repro.kernels.gcn_spmm).
        counts = np.bincount(e_b * num_parts + part[e_src],
                             minlength=nb * num_parts).reshape(nb, num_parts)
        # Reverse index: for a moved node u, the histogram rows to patch are
        # the boundary rows having u as a neighbor.
        by_src = np.argsort(e_src, kind="stable")
        src_sorted, brow_sorted = e_src[by_src], e_b[by_src]
        lo_all = np.searchsorted(src_sorted, boundary)
        hi_all = np.searchsorted(src_sorted, boundary + 1)
        # The sweep itself runs entirely on Python scalars/lists (the
        # per-node numpy-call overhead was the remaining interpreted cost);
        # the move patches touch deg(v) rows each and moves are the minority.
        counts_l = counts.tolist()
        rows_l = brow_sorted.tolist()
        sizes_l = sizes.tolist()
        part_l = part.tolist()
        moved = 0
        for bi, v in enumerate(boundary.tolist()):
            row = counts_l[bi]
            home = part_l[v]
            best = home
            best_gain = 0
            for p in range(num_parts):
                if not row[p] or p == home or sizes_l[p] + 1 > max_size:
                    continue
                gain = row[p] - row[home]
                if gain > best_gain:
                    best_gain, best = gain, p
            if best != home and sizes_l[home] > 1:
                sizes_l[home] -= 1
                sizes_l[best] += 1
                part_l[v] = best
                moved += 1
                for r in rows_l[lo_all[bi]:hi_all[bi]]:
                    counts_l[r][home] -= 1
                    counts_l[r][best] += 1
        part = np.asarray(part_l, dtype=np.int32)
        if moved == 0:
            break
    return part


# ----------------------------------------------------------------------
# Reference implementations (the pre-vectorization per-node loops).
# Kept verbatim: tests assert the vectorized versions above are
# bit-identical, and benchmarks/bench_kernels.py records the before/after
# build time against them.
# ----------------------------------------------------------------------

def _bfs_grow_loop(g: CSRGraph, num_parts: int,
                   rng: np.random.Generator) -> np.ndarray:
    n = g.num_nodes
    part = np.full(n, -1, dtype=np.int32)
    target = -(-n // num_parts)
    sizes = np.zeros(num_parts, dtype=np.int64)
    seeds = rng.choice(n, size=num_parts, replace=False)
    frontiers: list[list[int]] = [[int(s)] for s in seeds]
    for p, s in enumerate(seeds):
        if part[s] == -1:
            part[s] = p
            sizes[p] += 1
    active = True
    while active:
        active = False
        for p in range(num_parts):
            if sizes[p] >= target or not frontiers[p]:
                continue
            nxt: list[int] = []
            for v in frontiers[p]:
                s, e = g.indptr[v], g.indptr[v + 1]
                for u in g.indices[s:e]:
                    if part[u] == -1 and sizes[p] < target:
                        part[u] = p
                        sizes[p] += 1
                        nxt.append(int(u))
            frontiers[p] = nxt
            if nxt:
                active = True
    for v in np.flatnonzero(part == -1):
        p = int(np.argmin(sizes))
        part[v] = p
        sizes[p] += 1
    return part


def _refine_loop(g: CSRGraph, part: np.ndarray, num_parts: int,
                 passes: int, imbalance: float) -> np.ndarray:
    n = g.num_nodes
    max_size = int((n / num_parts) * (1 + imbalance)) + 1
    part = part.copy()
    for _ in range(passes):
        sizes = np.bincount(part, minlength=num_parts)
        moved = 0
        dst = np.repeat(np.arange(n), np.diff(g.indptr))
        boundary = np.unique(dst[part[dst] != part[g.indices]])
        for v in boundary:
            s, e = g.indptr[v], g.indptr[v + 1]
            nbr_parts = part[g.indices[s:e]]
            counts = np.bincount(nbr_parts, minlength=num_parts)
            home = part[v]
            best = home
            best_gain = 0
            for p in np.flatnonzero(counts):
                if p == home or sizes[p] + 1 > max_size:
                    continue
                gain = counts[p] - counts[home]
                if gain > best_gain:
                    best_gain, best = gain, p
            if best != home and sizes[home] > 1:
                sizes[home] -= 1
                sizes[best] += 1
                part[v] = best
                moved += 1
        if moved == 0:
            break
    return part


def partition_graph(g: CSRGraph, num_parts: int, seed: int = 0,
                    refine_passes: int = 4, imbalance: float = 0.05,
                    method: str = "bfs+refine") -> np.ndarray:
    """Partition nodes into num_parts balanced parts; returns part[v]."""
    if num_parts <= 1:
        return np.zeros(g.num_nodes, dtype=np.int32)
    if num_parts > g.num_nodes:
        raise ValueError("more partitions than nodes")
    rng = np.random.default_rng(seed)
    if method == "random":
        part = rng.integers(0, num_parts, size=g.num_nodes).astype(np.int32)
        # Rebalance exactly.
        order = rng.permutation(g.num_nodes)
        part = (np.arange(g.num_nodes) % num_parts)[np.argsort(order)].astype(np.int32)
        return part
    part = _bfs_grow(g, num_parts, rng)
    if "refine" in method:
        part = _refine(g, part, num_parts, refine_passes, imbalance)
    return part
