"""Synthetic graph datasets standing in for Reddit / ogbn-products / Yelp /
ogbn-papers100M (none of which is available offline).

Each simulated dataset mimics the *shape* of the paper's Tab. 3 setup at a
CPU-tractable scale: community structure (so accuracy experiments are
meaningful), heavy-tailed degrees (R-MAT mix), train/val/test splits, and the
same model/optimizer hyper-parameter template.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.graph.csr import CSRGraph, coo_to_csr, symmetrize


def sbm_graph(num_nodes: int, num_blocks: int, p_in: float, p_out: float,
              rng: np.random.Generator) -> tuple[CSRGraph, np.ndarray]:
    """Stochastic block model; returns (undirected graph, block labels).

    Sparse sampling: expected-count binomial edge sampling per block pair,
    O(E) rather than O(N^2).
    """
    blocks = rng.integers(0, num_blocks, size=num_nodes)
    order = np.argsort(blocks, kind="stable")
    blocks_sorted = blocks[order]
    starts = np.searchsorted(blocks_sorted, np.arange(num_blocks))
    ends = np.searchsorted(blocks_sorted, np.arange(num_blocks) + 1)
    srcs, dsts = [], []
    for a in range(num_blocks):
        na = ends[a] - starts[a]
        for b in range(a, num_blocks):
            nb = ends[b] - starts[b]
            p = p_in if a == b else p_out
            pairs = na * nb if a != b else na * (na - 1) // 2
            if pairs <= 0 or p <= 0:
                continue
            m = rng.binomial(pairs, min(p, 1.0))
            if m == 0:
                continue
            i = order[starts[a] + rng.integers(0, na, size=m)]
            j = order[starts[b] + rng.integers(0, nb, size=m)]
            keep = i != j
            srcs.append(i[keep]); dsts.append(j[keep])
    src = np.concatenate(srcs) if srcs else np.zeros(0, np.int64)
    dst = np.concatenate(dsts) if dsts else np.zeros(0, np.int64)
    g = symmetrize(coo_to_csr(src, dst, num_nodes))
    return g, blocks


def grid_graph(grid_rows: int, grid_cols: int) -> CSRGraph:
    """4-neighbor 2D lattice (undirected), row-major node ids.

    Unlike the SBM/R-MAT mixes, a lattice cut by a balanced partitioner has
    a boundary that is O(sqrt(n)) of each partition — most nodes are
    interior. That is the regime PipeGCN targets (and the planar/mesh
    regime METIS-style partitioners are built for), and it is what the
    split-phase overlap schedule needs to be non-degenerate: the
    SBM/R-MAT sims are so well-mixed that nearly every node is boundary.
    """
    idx = np.arange(grid_rows * grid_cols, dtype=np.int64).reshape(
        grid_rows, grid_cols)
    src = np.concatenate([idx[:-1, :].ravel(), idx[:, :-1].ravel()])
    dst = np.concatenate([idx[1:, :].ravel(), idx[:, 1:].ravel()])
    return symmetrize(coo_to_csr(src, dst, grid_rows * grid_cols))


def rmat_graph(num_nodes: int, num_edges: int, rng: np.random.Generator,
               a: float = 0.57, b: float = 0.19, c: float = 0.19) -> CSRGraph:
    """R-MAT power-law graph (Chakrabarti et al.), undirected."""
    scale = int(np.ceil(np.log2(max(num_nodes, 2))))
    src = np.zeros(num_edges, dtype=np.int64)
    dst = np.zeros(num_edges, dtype=np.int64)
    for level in range(scale):
        r = rng.random(num_edges)
        bit_s = (r >= a + b).astype(np.int64)                # c or d quadrant
        r2 = rng.random(num_edges)
        bit_d = np.where(bit_s == 0, (r2 >= a / (a + b)).astype(np.int64),
                         (r2 >= c / max(c + (1 - a - b - c), 1e-9)).astype(np.int64))
        src = (src << 1) | bit_s
        dst = (dst << 1) | bit_d
    src %= num_nodes
    dst %= num_nodes
    keep = src != dst
    return symmetrize(coo_to_csr(src[keep], dst[keep], num_nodes))


@dataclasses.dataclass
class GraphDataset:
    """Full-graph node-classification dataset."""

    name: str
    graph: CSRGraph                # undirected, unnormalized adjacency
    features: np.ndarray           # (N, F) float32
    labels: np.ndarray             # (N,) int32 or (N, C) float32 (multilabel)
    train_mask: np.ndarray         # (N,) bool
    val_mask: np.ndarray
    test_mask: np.ndarray
    num_classes: int
    multilabel: bool = False

    @property
    def num_nodes(self) -> int:
        return self.graph.num_nodes

    @property
    def feat_dim(self) -> int:
        return self.features.shape[1]


def _class_features(blocks: np.ndarray, num_classes: int, feat_dim: int,
                    signal: float, rng: np.random.Generator) -> np.ndarray:
    """Gaussian features with class-mean signal (keeps accuracy runs meaningful)."""
    means = rng.normal(0.0, 1.0, size=(num_classes, feat_dim))
    x = rng.normal(0.0, 1.0, size=(len(blocks), feat_dim))
    return (x + signal * means[blocks]).astype(np.float32)


def _splits(n: int, rng: np.random.Generator,
            frac=(0.6, 0.2, 0.2)) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    perm = rng.permutation(n)
    n_tr = int(frac[0] * n)
    n_va = int(frac[1] * n)
    tr = np.zeros(n, bool); va = np.zeros(n, bool); te = np.zeros(n, bool)
    tr[perm[:n_tr]] = True
    va[perm[n_tr:n_tr + n_va]] = True
    te[perm[n_tr + n_va:]] = True
    return tr, va, te


def _make_sim(name: str, num_nodes: int, num_classes: int, feat_dim: int,
              avg_degree: float, signal: float, seed: int,
              multilabel: bool = False, rmat_frac: float = 0.3) -> GraphDataset:
    rng = np.random.default_rng(seed)
    # Community structure + a power-law overlay (heavy-tailed like Reddit).
    p_out = avg_degree * (1 - rmat_frac) * 0.25 / num_nodes
    p_in = (avg_degree * (1 - rmat_frac) * 0.75) * num_classes / num_nodes
    g_sbm, blocks = sbm_graph(num_nodes, num_classes, p_in, p_out, rng)
    g_rmat = rmat_graph(num_nodes, int(num_nodes * avg_degree * rmat_frac / 2), rng)
    src = np.concatenate([g_sbm.indices, g_rmat.indices]).astype(np.int64)
    dst1 = np.repeat(np.arange(num_nodes, dtype=np.int64), np.diff(g_sbm.indptr))
    dst2 = np.repeat(np.arange(num_nodes, dtype=np.int64), np.diff(g_rmat.indptr))
    g = coo_to_csr(src, np.concatenate([dst1, dst2]), num_nodes)
    feats = _class_features(blocks, num_classes, feat_dim, signal, rng)
    if multilabel:
        # Derive a second label bit-plane from parity of a random projection.
        proj = rng.normal(size=(feat_dim, num_classes)).astype(np.float32)
        extra = (feats @ proj > 0).astype(np.float32)
        labels = np.zeros((num_nodes, num_classes), np.float32)
        labels[np.arange(num_nodes), blocks] = 1.0
        labels = np.clip(labels + extra * 0.0 + (extra > 0.5) * (rng.random((num_nodes, num_classes)) < 0.15), 0, 1)
        labels[np.arange(num_nodes), blocks] = 1.0
    else:
        labels = blocks.astype(np.int32)
    tr, va, te = _splits(num_nodes, rng)
    return GraphDataset(name=name, graph=g, features=feats, labels=labels,
                        train_mask=tr, val_mask=va, test_mask=te,
                        num_classes=num_classes, multilabel=multilabel)


def _make_grid(name: str, grid: tuple[int, int], num_classes: int,
               feat_dim: int, signal: float, seed: int) -> GraphDataset:
    """Lattice dataset: spatial-quadrant labels (class = superblock of the
    grid) keep accuracy runs meaningful while the topology stays planar."""
    gr, gc = grid
    rng = np.random.default_rng(seed)
    g = grid_graph(gr, gc)
    side = int(round(num_classes ** 0.5))
    if side * side != num_classes:
        raise ValueError(f"grid datasets need a square num_classes, got "
                         f"{num_classes}")
    r, c = np.divmod(np.arange(gr * gc, dtype=np.int64), gc)
    blocks = np.minimum(r * side // gr, side - 1) * side \
        + np.minimum(c * side // gc, side - 1)
    feats = _class_features(blocks, num_classes, feat_dim, signal, rng)
    tr, va, te = _splits(gr * gc, rng)
    return GraphDataset(name=name, graph=g, features=feats,
                        labels=blocks.astype(np.int32),
                        train_mask=tr, val_mask=va, test_mask=te,
                        num_classes=num_classes, multilabel=False)


# name -> (factory, paper-analogue GraphSAGE model template from Tab. 3)
DATASETS: dict[str, dict] = {
    # Reddit: 233K nodes / 114M edges / 602 feats -> 8K nodes sim
    "reddit-sim": dict(num_nodes=8192, num_classes=16, feat_dim=128,
                       avg_degree=32.0, signal=0.8, seed=0,
                       model=dict(num_layers=4, hidden=256, lr=0.01, dropout=0.5)),
    # ogbn-products: 2.4M / 62M / 100 -> 16K sim
    "products-sim": dict(num_nodes=16384, num_classes=32, feat_dim=100,
                         avg_degree=16.0, signal=0.6, seed=1,
                         model=dict(num_layers=3, hidden=128, lr=0.003, dropout=0.3)),
    # Yelp: 716K / 7.0M / 300, multilabel F1-micro -> 8K sim
    "yelp-sim": dict(num_nodes=8192, num_classes=24, feat_dim=120,
                     avg_degree=10.0, signal=0.7, seed=2, multilabel=True,
                     model=dict(num_layers=4, hidden=512, lr=0.001, dropout=0.1)),
    # ogbn-papers100M: 111M / 1.6B / 128 -> 32K sim (bench/analysis only)
    "papers100m-sim": dict(num_nodes=32768, num_classes=64, feat_dim=128,
                           avg_degree=14.0, signal=0.5, seed=3,
                           model=dict(num_layers=3, hidden=48, lr=0.01, dropout=0.0)),
    # Tiny graphs for tests/examples.
    "tiny": dict(num_nodes=256, num_classes=4, feat_dim=16,
                 avg_degree=8.0, signal=1.0, seed=4,
                 model=dict(num_layers=2, hidden=32, lr=0.01, dropout=0.0)),
    "small": dict(num_nodes=2048, num_classes=8, feat_dim=32,
                  avg_degree=12.0, signal=0.8, seed=5,
                  model=dict(num_layers=3, hidden=64, lr=0.01, dropout=0.2)),
    # Planar lattices: low-boundary-fraction partitions (the mesh/planar
    # regime PipeGCN targets) — the datasets where the split-phase overlap
    # schedule has a real interior phase to hide the exchange behind.
    "grid-sim": dict(grid=(64, 64), num_classes=4, feat_dim=32,
                     signal=1.0, seed=6,
                     model=dict(num_layers=3, hidden=64, lr=0.01, dropout=0.2)),
    "grid-tiny": dict(grid=(48, 48), num_classes=4, feat_dim=16,
                      signal=1.0, seed=7,
                      model=dict(num_layers=2, hidden=16, lr=0.01, dropout=0.0)),
}


def make_dataset(name: str, **overrides) -> GraphDataset:
    if name not in DATASETS:
        raise KeyError(f"unknown dataset {name!r}; have {sorted(DATASETS)}")
    spec = {k: v for k, v in DATASETS[name].items() if k != "model"}
    spec.update(overrides)
    return _make_grid(name, **spec) if "grid" in spec else _make_sim(name, **spec)


def model_template(name: str) -> dict:
    return dict(DATASETS[name]["model"])
