"""Graph substrate: synthetic datasets, CSR graphs, partitioning, halo descriptors."""
from repro.graph.csr import CSRGraph, coo_to_csr, sym_normalized, mean_normalized
from repro.graph.synthetic import sbm_graph, rmat_graph, make_dataset, DATASETS, GraphDataset
from repro.graph.partition import partition_graph, edge_cut
from repro.graph.reorder import LAYOUTS, partition_orders, rcm_order
from repro.graph.halo import (PartitionedGraph, PartitionTiles,
                              build_partitioned_graph,
                              extract_partition_tiles)

__all__ = [
    "CSRGraph", "coo_to_csr", "sym_normalized", "mean_normalized",
    "sbm_graph", "rmat_graph", "make_dataset", "DATASETS", "GraphDataset",
    "partition_graph", "edge_cut",
    "LAYOUTS", "partition_orders", "rcm_order",
    "PartitionedGraph", "PartitionTiles", "build_partitioned_graph",
    "extract_partition_tiles",
]
