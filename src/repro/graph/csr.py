"""CSR graph container and propagation-matrix normalizations.

The propagation matrix P follows the paper (Appendix A.1):
  GCN:  P = D̃^{-1/2} Ã D̃^{-1/2},  Ã = A + I
  SAGE: P = D^{-1} A               (mean neighbor aggregator; self via concat)

Weights are computed on the *global* graph before partitioning so that the
per-partition split P = P_in + P_bd (paper notation) uses global degrees,
exactly as Eq. 3/4 (the 1/d_v terms are global).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class CSRGraph:
    """Weighted directed CSR graph (row -> weighted neighbor columns)."""

    indptr: np.ndarray   # (N+1,) int64
    indices: np.ndarray  # (E,)  int32  column ids
    weights: np.ndarray  # (E,)  float32

    @property
    def num_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def num_edges(self) -> int:
        return len(self.indices)

    def degrees(self) -> np.ndarray:
        return np.diff(self.indptr)

    def row(self, v: int) -> tuple[np.ndarray, np.ndarray]:
        s, e = self.indptr[v], self.indptr[v + 1]
        return self.indices[s:e], self.weights[s:e]

    def to_dense(self) -> np.ndarray:
        n = self.num_nodes
        out = np.zeros((n, n), dtype=np.float64)
        for v in range(n):
            cols, w = self.row(v)
            np.add.at(out[v], cols, w)
        return out


def coo_to_csr(src: np.ndarray, dst: np.ndarray, num_nodes: int,
               weights: np.ndarray | None = None,
               dedup: bool = True) -> CSRGraph:
    """Build CSR from COO edge list (rows=dst receives from cols=src).

    Row v of the result lists v's in-neighbors, which is what neighbor
    aggregation consumes (z_v = sum_u P[v,u] h_u).
    """
    src = np.asarray(src, dtype=np.int64)
    dst = np.asarray(dst, dtype=np.int64)
    if weights is None:
        weights = np.ones(len(src), dtype=np.float32)
    if dedup and len(src):
        key = dst * num_nodes + src
        key, idx = np.unique(key, return_index=True)
        src, dst, weights = src[idx], dst[idx], weights[idx]
    order = np.argsort(dst, kind="stable")
    src, dst, weights = src[order], dst[order], weights[order]
    indptr = np.zeros(num_nodes + 1, dtype=np.int64)
    np.add.at(indptr, dst + 1, 1)
    indptr = np.cumsum(indptr)
    return CSRGraph(indptr=indptr,
                    indices=src.astype(np.int32),
                    weights=weights.astype(np.float32))


def _coo_of(g: CSRGraph) -> tuple[np.ndarray, np.ndarray]:
    dst = np.repeat(np.arange(g.num_nodes, dtype=np.int64), np.diff(g.indptr))
    return g.indices.astype(np.int64), dst


def symmetrize(g: CSRGraph) -> CSRGraph:
    """Make the adjacency symmetric (undirected), unit weights."""
    src, dst = _coo_of(g)
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    return coo_to_csr(s2, d2, g.num_nodes)


def sym_normalized(g: CSRGraph, add_self_loops: bool = True) -> CSRGraph:
    """GCN propagation: D̃^{-1/2} Ã D̃^{-1/2}."""
    src, dst = _coo_of(g)
    n = g.num_nodes
    if add_self_loops:
        loop = np.arange(n, dtype=np.int64)
        src = np.concatenate([src, loop])
        dst = np.concatenate([dst, loop])
    base = coo_to_csr(src, dst, n)  # dedups
    src, dst = _coo_of(base)
    deg = np.bincount(dst, minlength=n).astype(np.float64)
    dinv = 1.0 / np.sqrt(np.maximum(deg, 1.0))
    w = (dinv[dst] * dinv[src]).astype(np.float32)
    return CSRGraph(indptr=base.indptr, indices=base.indices, weights=w)


def mean_normalized(g: CSRGraph) -> CSRGraph:
    """GraphSAGE mean aggregator: D^{-1} A (row-normalized, no self loop)."""
    deg = np.maximum(g.degrees(), 1).astype(np.float64)
    dst = np.repeat(np.arange(g.num_nodes), np.diff(g.indptr))
    w = (1.0 / deg[dst]).astype(np.float32)
    return CSRGraph(indptr=g.indptr, indices=g.indices, weights=w)
