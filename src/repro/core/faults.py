"""Declarative fault injection for the PipeGCN boundary exchange.

PipeGCN's bounded-staleness theorem makes a lost or corrupted boundary
exchange recoverable BY DESIGN: the receiver already tolerates payloads
that are one iteration old, so an invalid payload is just one extra step
of staleness (up to ``PipeConfig.max_staleness``). This module supplies
the faults to prove it: a :class:`FaultPlan` declares per-(step, layer,
direction, partition-pair) drop / corrupt / delay sites, compiles to
dense boolean tables (:class:`FaultTables`, a pytree traced through the
jitted step — the same trace handles any plan of the same horizon), and
:func:`apply_faults` injects them into the encoded wire arrays right
before the exchange on either backend.

Semantics of the three fault kinds:

``drop``     the payload never arrives: the wire row is zeroed and its
             checksum column (``guard_exchange``) is set to a value that
             cannot match, so the receiver flags every row invalid and
             falls back to its stale buffer. Without the guard the zeros
             land silently (chaos mode — the health guard's job).
``corrupt``  seeded pseudo-random XOR bit-flips over the wire bytes
             (``density`` = per-byte flip probability, each flipped byte
             XORed with a nonzero mask). Detected by the per-row checksum
             with probability ~1 - 2^-8 per row; an undetected row decodes
             to garbage, which is exactly the failure mode the checksum
             is there to bound.
``delay``    the payload arrives one step late. Every step re-sends fresh
             boundary data, so a one-step-late payload is superseded on
             arrival and the observable effect equals ``drop`` for that
             step; ``compile`` lowers it accordingly.

``device_down`` a whole DEVICE disappears: ``src`` names a device (not a
             partition) and the site lowers to persistent drops of every
             exchange leaving that device's partitions toward any
             off-device partition, both directions, every layer, for
             steps ``[step, until)`` (``until=None`` = never returns).
             This is the deterministic drill plane of the elastic
             runtime (repro.core.elastic): the guarded receiver sees a
             blanket fallback row for the device, which is exactly what
             a real device loss looks like from the survivors' side.
             ``compile`` needs ``parts_per_device`` to expand the device
             id to its partition block.

The flip streams are keyed by (seed, step, direction, layer, SOURCE
partition), so the injected bytes are identical across backends and
device layouts — a degraded sim run and a degraded SPMD run see the same
faults.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import byteify, unbyteify

#: Direction indices of the fault tables (axis 1).
FWD, BWD = 0, 1

KINDS = ("drop", "corrupt", "delay", "device_down")
DIRECTIONS = ("fwd", "bwd")


class StalenessExceededError(RuntimeError):
    """Effective staleness of some exchange exceeded PipeConfig.max_staleness."""


class FaultTables(NamedTuple):
    """Compiled, trace-compatible fault schedule (a jit-friendly pytree).

    ``drop`` / ``corrupt`` are bool ``(T, 2, L, P_src, P_dst)`` tables
    indexed by (step, direction, layer, source partition, destination
    partition); ``key`` seeds the corruption flip streams and ``density``
    is the per-byte flip probability (a traced f32 scalar). Steps beyond
    the horizon T are clamped to the last row.
    """

    drop: jax.Array
    corrupt: jax.Array
    key: jax.Array
    density: jax.Array


@dataclasses.dataclass(frozen=True)
class FaultSite:
    """One declarative fault: drop/corrupt/delay the (src -> dst) payload
    of ``layer`` in ``direction`` ("fwd"/"bwd") at ``step``.

    ``kind="device_down"`` reinterprets ``src`` as a DEVICE id and holds
    from ``step`` until ``until`` (exclusive; None = permanent); its
    ``layer``/``dst``/``direction`` are ignored — the outage blankets
    every exchange leaving the device (see :func:`device_down_site`).
    """

    step: int
    layer: int
    src: int
    dst: int
    direction: str = "fwd"
    kind: str = "drop"
    until: int | None = None

    def __post_init__(self):
        if self.direction not in DIRECTIONS:
            raise ValueError(f"unknown direction {self.direction!r}; "
                             f"have {DIRECTIONS}")
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"have {KINDS}")
        if self.until is not None:
            if self.kind != "device_down":
                raise ValueError(
                    f"until= is only meaningful for kind='device_down' "
                    f"(got kind={self.kind!r}) — point faults last one step")
            if self.until <= self.step:
                raise ValueError(
                    f"until={self.until} must be > step={self.step}")


def device_down_site(step: int, device: int,
                     until: int | None = None) -> FaultSite:
    """A whole-device outage site: device ``device`` drops every outbound
    exchange for steps ``[step, until)`` (None = never comes back)."""
    return FaultSite(step=step, layer=0, src=device, dst=0,
                     kind="device_down", until=until)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Declarative fault schedule: explicit ``sites`` plus an optional
    i.i.d. background ``rate`` of ``rate_kind`` faults over every
    (step, direction, layer, src != dst) site, seeded by ``seed``.

    ``density`` is the per-byte bit-flip probability of "corrupt" faults.
    An empty plan (no sites, rate 0) injects nothing; the trainer then
    skips compilation entirely so the traced step is byte-identical to a
    fault-free build.
    """

    sites: tuple = ()
    rate: float = 0.0
    rate_kind: str = "drop"
    seed: int = 0
    density: float = 0.02

    def __post_init__(self):
        if self.rate_kind not in KINDS:
            raise ValueError(f"unknown rate_kind {self.rate_kind!r}; "
                             f"have {KINDS}")
        if not 0.0 <= self.rate <= 1.0:
            raise ValueError(f"rate must be in [0, 1], got {self.rate}")
        if not 0.0 < self.density <= 1.0:
            raise ValueError(f"density must be in (0, 1], got {self.density}")
        object.__setattr__(self, "sites", tuple(self.sites))

    def is_empty(self) -> bool:
        """True when the plan injects nothing at any step."""
        return not self.sites and self.rate == 0.0

    def downed_devices(self, step: int) -> frozenset:
        """Device ids whose ``device_down`` window covers ``step`` — the
        health oracle the elastic trainer's rejoin decision consults."""
        return frozenset(
            s.src for s in self.sites
            if s.kind == "device_down" and s.step <= step
            and (s.until is None or step < s.until))

    def without_device_down(self) -> "FaultPlan":
        """This plan minus its device_down sites — what remains to inject
        after the elastic runtime has remapped the outage away."""
        return dataclasses.replace(
            self, sites=tuple(s for s in self.sites
                              if s.kind != "device_down"))

    def compile(self, num_steps: int, num_layers: int, num_parts: int,
                parts_per_device: int = 1) -> FaultTables:
        """Lower the plan to dense boolean tables over a ``num_steps``
        horizon ("delay" lowers to "drop"; "device_down" lowers to
        persistent cross-device drops over the device's
        ``parts_per_device`` partition block — see the module docstring)."""
        shape = (max(num_steps, 1), 2, num_layers, num_parts, num_parts)
        drop = np.zeros(shape, bool)
        corrupt = np.zeros(shape, bool)
        if self.rate > 0.0:
            rng = np.random.default_rng(self.seed)
            mask = rng.random(shape) < self.rate
            # background faults model the NETWORK: self-pairs never leave
            # the device, so only src != dst sites are eligible.
            eye = np.eye(num_parts, dtype=bool)
            mask &= ~eye[None, None, None]
            # layer 0 sends no backward gradient (Alg. 1 stops there).
            mask[:, BWD, 0] = False
            (corrupt if self.rate_kind == "corrupt" else drop)[:] = mask
        for s in self.sites:
            if s.kind == "device_down":
                if num_parts % parts_per_device:
                    raise ValueError(
                        f"num_parts={num_parts} is not a multiple of "
                        f"parts_per_device={parts_per_device}")
                n_dev = num_parts // parts_per_device
                if not 0 <= s.src < n_dev:
                    raise ValueError(
                        f"device_down site device {s.src} out of range for "
                        f"{n_dev} devices: {s}")
                lo = max(s.step, 0)
                hi = num_steps if s.until is None else min(s.until, num_steps)
                if lo >= hi:
                    continue
                on = np.zeros((num_parts,), bool)
                on[s.src * parts_per_device:(s.src + 1) * parts_per_device] \
                    = True
                # outbound only: the dead device's own (never-consumed)
                # inbound state is irrelevant to the survivors
                drop[lo:hi] |= np.outer(on, ~on)[None, None]
                continue
            if not (0 <= s.layer < num_layers and 0 <= s.src < num_parts
                    and 0 <= s.dst < num_parts):
                raise ValueError(f"fault site out of range: {s}")
            if 0 <= s.step < num_steps:
                d = FWD if s.direction == "fwd" else BWD
                tab = corrupt if s.kind == "corrupt" else drop
                tab[s.step, d, s.layer, s.src, s.dst] = True
        return FaultTables(drop=jnp.asarray(drop),
                           corrupt=jnp.asarray(corrupt),
                           key=jax.random.PRNGKey(self.seed),
                           density=jnp.float32(self.density))


def _flip_bytes(wire, key, density):
    """Seeded pseudo-random XOR bit-flips over a wire array's bytes: each
    byte is flipped with probability ``density``, XORed with a nonzero
    mask so a selected byte always changes."""
    b, it, dt = byteify(wire)
    sel = jax.random.bits(key, b.shape, jnp.uint8)
    val = jax.random.bits(jax.random.fold_in(key, 1), b.shape, jnp.uint8)
    thresh = jnp.clip(jnp.round(density * 256.0), 0, 255).astype(jnp.uint8)
    flip = jnp.where(sel < thresh, val | jnp.uint8(1), jnp.uint8(0))
    return unbyteify(b ^ flip, it, dt)


def _dropped_wire(wire, has_checksum: bool):
    """What a dropped payload decodes from: all-zero rows, with the
    checksum column (when the guard is on) set to 1 — the checksum of a
    zero row is 0, so every dropped row is guaranteed invalid."""
    z = jnp.zeros_like(wire)
    if has_checksum and wire.shape[-1]:
        z = z.at[..., -1].set(jnp.ones((), wire.dtype))
    return z


def apply_faults(wire, tables: FaultTables, step_idx, direction: int,
                 layer: int, part_ids, has_checksum: bool):
    """Inject this step's faults into one encoded wire array, sender-side.

    ``wire`` is the encoded send payload with trailing (P_dst, slot, W)
    axes and an optional leading source axis (sim: all P sources; SPMD
    n_local > 1: the co-resident sources); ``part_ids`` holds the GLOBAL
    source partition ids of that leading axis (a scalar for the flat SPMD
    layout). ``step_idx`` is a traced int32; steps past the table horizon
    clamp to the last row.
    """
    t = jnp.clip(step_idx, 0, tables.drop.shape[0] - 1)
    drop_full = tables.drop[t, direction, layer]        # (P_src, P_dst)
    corr_full = tables.corrupt[t, direction, layer]
    squeeze = jnp.ndim(part_ids) == 0
    ids = jnp.atleast_1d(part_ids)
    w = wire[None] if squeeze else wire                 # (S, P_dst, slot, W)
    drop = jnp.take(drop_full, ids, axis=0)             # (S, P_dst)
    corr = jnp.take(corr_full, ids, axis=0)
    base = jax.random.fold_in(jax.random.fold_in(jax.random.fold_in(
        tables.key, step_idx), direction), layer)
    keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(ids)
    corrupted = jax.vmap(lambda wi, ki: _flip_bytes(wi, ki, tables.density))(
        w, keys)
    out = jnp.where(corr[..., None, None], corrupted, w)
    out = jnp.where(drop[..., None, None],
                    _dropped_wire(w, has_checksum), out)
    return out[0] if squeeze else out
