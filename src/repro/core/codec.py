"""Pluggable boundary-traffic codecs for the PipeGCN exchange wire.

Every boundary payload (forward features, backward feature-gradients) goes
through exactly one codec before it touches a backend ``exchange`` /
``fused_exchange`` and through the matching ``decode`` right after — the
step math on either side always sees the model dtype. ``PipeConfig.wire``
selects the codec; the normative byte layouts live in ``docs/wire-format.md``.

Codecs
------
``f32``   identity pass-through. The wire array IS the payload (any float
          dtype — the f64 parity tests ride this path unchanged).
``bf16``  truncating cast to bfloat16 on the wire, cast back on receive.
          Exactly the historical ``compress_boundary`` behaviour.
``int8``  blockwise-scaled symmetric quantization, 1 byte per element plus
          a per-block f32 scale region (4 bytes per ``block`` columns).
``int4``  same, two elements packed per byte (low nibble = even column).

Quantized wire layout (per payload row, along the feature axis):

    [ payload bytes | scales region ]
      int8: F cols    4*ceil(F/block) cols (f32 scales bitcast to uint8)
      int4: ceil(F/2)

The scales ride INSIDE the wire array as trailing uint8 columns, so the
exchange itself stays a pure dtype-agnostic permutation of leading axes —
sim transpose, flat all_to_all, and the hierarchical n_local>1 exchange all
carry the scales for free, and the packed fused-exchange buffer simply
grows a scales region per layer slot (``pack_offsets`` over wire widths).

Quantization math (symmetric, zero-preserving): per block of ``block``
feature columns, ``scale = amax / qmax`` (``qmax`` = 127 for int8, 7 for
int4; all-zero blocks use scale 1 so zeros round-trip exactly) and
``q = clip(round(x / scale), -qmax, qmax)``. The reconstruction error is
bounded by ``scale / 2 = amax / (2*qmax)`` per element. Scales are stored
as float32 regardless of the payload dtype.

Encoding is deterministic, partition-local, element-wise-independent math,
so it commutes with the exchange and with the fused feature-axis packing:
fused and per-layer schedules stay bit-identical under every codec.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

#: Accepted ``PipeConfig.wire`` values ("auto" resolves per layer via
#: ``repro.analysis.cost.choose_wire_formats``).
WIRE_FORMATS = ("f32", "bf16", "int8", "int4")

#: Default feature-block size for the quantized scale vectors (one f32
#: scale per ``WIRE_BLOCK`` columns; clamped to the payload width).
WIRE_BLOCK = 128


def _nblocks(f: int, block: int) -> int:
    return -(-f // block) if f else 0


@dataclasses.dataclass(frozen=True)
class NativeCodec:
    """Identity codec: the payload ships in its own dtype (4 bytes/elem f32)."""

    name: str = "f32"

    def wire_width(self, f: int) -> int:
        """Feature columns the wire array carries for an f-wide payload."""
        return f

    def wire_bytes(self, f: int) -> float:
        """Bytes one f32 payload row of width f occupies on the wire."""
        return 4.0 * f

    def encode(self, x):
        """Pass the payload through unchanged."""
        return x

    def decode(self, wire, f: int, dtype):
        """Restore the pre-pack dtype (undoes fused-pack dtype promotion)."""
        return wire.astype(dtype)


@dataclasses.dataclass(frozen=True)
class Bf16Codec:
    """Truncating bfloat16 wire cast (the historical ``compress_boundary``)."""

    name: str = "bf16"

    def wire_width(self, f: int) -> int:
        """Feature columns on the wire (unchanged; the dtype halves bytes)."""
        return f

    def wire_bytes(self, f: int) -> float:
        """Bytes one payload row of width f occupies on the wire."""
        return 2.0 * f

    def encode(self, x):
        """Cast the payload to bfloat16."""
        return x.astype(jnp.bfloat16)

    def decode(self, wire, f: int, dtype):
        """Cast the received wire array back to the model dtype."""
        return wire.astype(dtype)


@dataclasses.dataclass(frozen=True)
class QuantCodec:
    """Blockwise-scaled symmetric int8/int4 quantization (uint8 wire).

    ``bits`` is 8 or 4; ``block`` is the feature-block size each f32 scale
    covers. See the module docstring for the exact wire layout and error
    bound; ``docs/wire-format.md`` is the normative spec.
    """

    bits: int = 8
    block: int = WIRE_BLOCK

    @property
    def name(self) -> str:
        """Wire-format name ("int8" / "int4")."""
        return f"int{self.bits}"

    @property
    def qmax(self) -> int:
        """Largest stored magnitude (127 for int8, 7 for int4)."""
        return (1 << (self.bits - 1)) - 1

    def payload_cols(self, f: int) -> int:
        """uint8 columns holding the quantized values themselves."""
        return f if self.bits == 8 else (f + 1) // 2

    def wire_width(self, f: int) -> int:
        """uint8 columns on the wire: payload + 4 per scale block."""
        return self.payload_cols(f) + 4 * _nblocks(f, self.block)

    def wire_bytes(self, f: int) -> float:
        """Bytes one payload row of width f occupies on the wire."""
        return float(self.wire_width(f))

    def _scales(self, x, f: int):
        """Per-block f32 scales of the (..., F) payload (zero blocks -> 1)."""
        nb = _nblocks(f, self.block)
        pad = nb * self.block - f
        xp = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
        xb = xp.reshape(x.shape[:-1] + (nb, self.block))
        amax = jnp.max(jnp.abs(xb), axis=-1)
        return jnp.where(amax > 0, amax / self.qmax, 1.0).astype(jnp.float32)

    def encode(self, x):
        """Quantize (..., F) to the (..., wire_width(F)) uint8 wire array."""
        f = x.shape[-1]
        if f == 0:
            return jnp.zeros(x.shape[:-1] + (0,), jnp.uint8)
        scale = self._scales(x, f)                          # (..., nb) f32
        sfull = jnp.repeat(scale, self.block, axis=-1)[..., :f]
        q = jnp.clip(jnp.round(x / sfull.astype(x.dtype)),
                     -self.qmax, self.qmax).astype(jnp.int8)
        if self.bits == 8:
            payload = jax.lax.bitcast_convert_type(q, jnp.uint8)
        else:
            if f % 2:
                q = jnp.pad(q, [(0, 0)] * (q.ndim - 1) + [(0, 1)])
            u = jax.lax.bitcast_convert_type(q, jnp.uint8)
            lo = u[..., 0::2] & 0xF
            hi = u[..., 1::2] & 0xF
            payload = lo | (hi << 4).astype(jnp.uint8)
        sbytes = jax.lax.bitcast_convert_type(scale, jnp.uint8)
        sbytes = sbytes.reshape(scale.shape[:-1] + (scale.shape[-1] * 4,))
        return jnp.concatenate([payload, sbytes], axis=-1)

    def decode(self, wire, f: int, dtype):
        """Dequantize the uint8 wire array back to a (..., F) ``dtype`` array."""
        if f == 0:
            return jnp.zeros(wire.shape[:-1] + (0,), dtype)
        nb = _nblocks(f, self.block)
        pc = self.payload_cols(f)
        payload, sbytes = wire[..., :pc], wire[..., pc:]
        scale = jax.lax.bitcast_convert_type(
            sbytes.reshape(sbytes.shape[:-1] + (nb, 4)), jnp.float32)
        if self.bits == 8:
            q = jax.lax.bitcast_convert_type(payload, jnp.int8)
            q = q.astype(jnp.int32)
        else:
            lo = (payload & 0xF).astype(jnp.int32)
            hi = ((payload >> 4) & 0xF).astype(jnp.int32)
            q = jnp.stack([lo, hi], axis=-1).reshape(
                payload.shape[:-1] + (2 * pc,))[..., :f]
            q = jnp.where(q >= 8, q - 16, q)
        sfull = jnp.repeat(scale, self.block, axis=-1)[..., :f]
        return (q.astype(dtype) * sfull.astype(dtype))


def row_checksum(wire):
    """Per-row checksum of a wire array: sum of the row's bytes mod 256.

    Computed over the exact bytes on the wire (floats are bitcast, not
    rounded), so any single flipped bit — and almost any burst of flips —
    changes the value. Returns an int32 array of shape ``wire.shape[:-1]``.
    """
    if wire.dtype == jnp.uint8:
        return jnp.sum(wire.astype(jnp.int32), axis=-1) % 256
    b = jax.lax.bitcast_convert_type(wire, jnp.uint8)   # (..., F, itemsize)
    return jnp.sum(b.astype(jnp.int32), axis=(-2, -1)) % 256


@dataclasses.dataclass(frozen=True)
class ChecksumCodec:
    """Guard wrapper (``PipeConfig.guard_exchange``): any inner codec plus
    ONE trailing checksum column per wire row.

    The column stores ``row_checksum`` of the inner wire row as a small
    integer VALUE (0..255) in the wire's own dtype — exactly representable
    in uint8, bfloat16, f32 and f64, so it survives the fused pack's float
    promotion bit-exactly (`decode_checked` casts the row back to the inner
    wire dtype before re-summing). Riding inside the wire array keeps the
    exchange a pure permutation: no extra collective, no side channel.

    ``name`` forwards the inner codec's (the step's dtype dispatch keys off
    it); widths/bytes grow by the one column.
    """

    inner: NativeCodec | Bf16Codec | QuantCodec

    @property
    def name(self) -> str:
        """The wrapped codec's wire-format name (the guard is orthogonal)."""
        return self.inner.name

    def wire_width(self, f: int) -> int:
        """Inner wire columns plus the checksum column."""
        return self.inner.wire_width(f) + 1

    def wire_bytes(self, f: int) -> float:
        """Inner wire bytes plus one column in the wire dtype."""
        extra = 1.0 if isinstance(self.inner, QuantCodec) else \
            self.inner.wire_bytes(1)
        return self.inner.wire_bytes(f) + extra

    def _wire_dtype(self, dtype):
        """The inner codec's on-wire dtype (to undo pack promotion)."""
        if isinstance(self.inner, QuantCodec):
            return jnp.uint8
        if isinstance(self.inner, Bf16Codec):
            return jnp.bfloat16
        return dtype

    def encode(self, x):
        """Inner-encode, then append the per-row checksum column."""
        wire = self.inner.encode(x)
        c = row_checksum(wire).astype(wire.dtype)
        return jnp.concatenate([wire, c[..., None]], axis=-1)

    def decode(self, wire, f: int, dtype):
        """Strip the checksum column and inner-decode (no verification —
        use ``decode_checked`` on the receive path)."""
        pc = self.inner.wire_width(f)
        inner_wire = wire[..., :pc].astype(self._wire_dtype(dtype))
        return self.inner.decode(inner_wire, f, dtype)

    def decode_checked(self, wire, f: int, dtype):
        """Decode AND verify: returns ``(payload, valid)`` where ``valid``
        is a per-row bool of shape ``wire.shape[:-1]`` — True iff the
        recomputed checksum matches the stored column (a corrupted stored
        column, including NaN, also reads as invalid)."""
        pc = self.inner.wire_width(f)
        inner_wire = wire[..., :pc].astype(self._wire_dtype(dtype))
        stored = wire[..., pc]
        valid = stored == row_checksum(inner_wire).astype(wire.dtype)
        return self.inner.decode(inner_wire, f, dtype), valid


def make_codec(wire: str, block: int = WIRE_BLOCK, guard: bool = False):
    """The codec instance for one resolved wire-format name; ``guard=True``
    wraps it in a :class:`ChecksumCodec` (one extra column per row)."""
    if wire == "f32":
        codec = NativeCodec()
    elif wire == "bf16":
        codec = Bf16Codec()
    elif wire == "int8":
        codec = QuantCodec(bits=8, block=block)
    elif wire == "int4":
        codec = QuantCodec(bits=4, block=block)
    else:
        raise ValueError(f"unknown wire format {wire!r}; have {WIRE_FORMATS}")
    return ChecksumCodec(codec) if guard else codec


# ----------------------------------------------------------------------
# Byte planarization for the packed fused-exchange buffer.
#
# A fused pack concatenates per-layer wire arrays along the feature axis.
# All-float plans keep the historical concat (dtype promotion is undone by
# each codec's decode, bit-identically); a plan that mixes quantized uint8
# wires with float wires would let the concat promote the raw bytes to
# floats — values survive, but every byte would ship 4-wide. These helpers
# bitcast float wires to uint8 columns instead, so a mixed "auto" plan
# still packs into one dense byte buffer.
# ----------------------------------------------------------------------

def byteify(wire):
    """(..., F) wire array -> ((..., F*itemsize) uint8, itemsize, dtype)."""
    if wire.dtype == jnp.uint8:
        return wire, 1, wire.dtype
    it = wire.dtype.itemsize
    b = jax.lax.bitcast_convert_type(wire, jnp.uint8)   # (..., F, itemsize)
    return b.reshape(wire.shape[:-1] + (wire.shape[-1] * it,)), it, wire.dtype


def unbyteify(bytes_arr, itemsize: int, dtype):
    """Inverse of ``byteify`` given the static (itemsize, dtype) record."""
    if itemsize == 1:
        return bytes_arr
    f = bytes_arr.shape[-1] // itemsize
    return jax.lax.bitcast_convert_type(
        bytes_arr.reshape(bytes_arr.shape[:-1] + (f, itemsize)), dtype)


def fused_exchange_encoded(backend, wires):
    """``backend.fused_exchange`` over already-encoded per-layer wires.

    Byte-planarizes exactly when the pack mixes quantized (uint8) and
    float wires; homogeneous plans (and legacy all-float mixed-precision
    packs) take the historical concat path unchanged, keeping the fused
    schedule bit-identical to the per-layer schedule under every codec.
    """
    dtypes = {w.dtype for w in wires}
    if len(dtypes) > 1 and any(d == jnp.dtype(jnp.uint8) for d in dtypes):
        planar = [byteify(w) for w in wires]
        recvs = backend.fused_exchange([b for b, _, _ in planar])
        return [unbyteify(r, it, dt)
                for r, (_, it, dt) in zip(recvs, planar)]
    return backend.fused_exchange(list(wires))
