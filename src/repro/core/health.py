"""Numerical health guards for the training loop.

A single non-finite loss or gradient poisons every subsequent step (Adam
moments, stale boundary buffers, params). :func:`health_check` is a
jit-compatible verdict on one step's outputs — finite loss, finite
gradients, finite floating buffers, and an optional global grad-norm
bound reusing the same norm the optimizer's ``clip_by_global_norm``
computes — and the trainer's skip-and-rollback policy
(:func:`repro.core.trainer.make_jitted_train_step` with ``health``)
selects between the updated and the previous state with a bitwise
``jnp.where``, so a healthy run is bit-identical to an unguarded one.

Escalation is host-side: :class:`HealthConfig.max_consecutive_anomalies`
back-to-back skipped steps raise :class:`TrainingAnomalyError` — a run
that can no longer produce a finite step should die loudly, not spin.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.optim.optimizers import global_norm


class TrainingAnomalyError(RuntimeError):
    """Too many consecutive non-finite / out-of-bound training steps."""


@dataclasses.dataclass(frozen=True)
class HealthConfig:
    """Policy knobs for the trainer's health guard.

    ``grad_norm_limit`` — reject steps whose global grad norm exceeds the
    bound (``None`` = finiteness only). ``max_consecutive_anomalies`` —
    consecutive skipped steps before :class:`TrainingAnomalyError`.
    """

    enabled: bool = True
    grad_norm_limit: float | None = None
    max_consecutive_anomalies: int = 25

    def __post_init__(self):
        if self.grad_norm_limit is not None and self.grad_norm_limit <= 0:
            raise ValueError("grad_norm_limit must be positive or None, "
                             f"got {self.grad_norm_limit}")
        if self.max_consecutive_anomalies < 1:
            raise ValueError("max_consecutive_anomalies must be >= 1, got "
                             f"{self.max_consecutive_anomalies}")


def _finite_tree(tree) -> jax.Array:
    """All-finite predicate over a pytree's floating leaves (integer
    leaves — e.g. the effective-staleness counters — are always fine)."""
    ok = jnp.bool_(True)
    for leaf in jax.tree.leaves(tree):
        if jnp.issubdtype(jnp.asarray(leaf).dtype, jnp.floating):
            ok &= jnp.all(jnp.isfinite(leaf))
    return ok


def health_check(loss, grads, buffers=None, grad_norm_limit=None):
    """Jit-compatible health verdict on one training step's outputs.

    Returns ``{"ok": bool[], "grad_norm": f32[]}``. ``ok`` requires a
    finite loss, finite gradients (a single Inf/NaN leaf drives the
    global norm non-finite, which the finiteness check catches), finite
    floating buffer leaves, and — when ``grad_norm_limit`` is set — a
    global norm at or under the bound.
    """
    gn = global_norm(grads)
    ok = jnp.isfinite(loss) & jnp.isfinite(gn)
    if buffers is not None:
        ok &= _finite_tree(buffers)
    if grad_norm_limit is not None:
        ok &= gn <= jnp.float32(grad_norm_limit)
    return {"ok": ok, "grad_norm": gn}


def tree_select(pred, on_true, on_false):
    """Leafwise ``jnp.where`` over matching pytrees — the rollback
    primitive: bitwise-identity on whichever branch is selected."""
    return jax.tree.map(lambda a, b: jnp.where(pred, a, b),
                        on_true, on_false)
