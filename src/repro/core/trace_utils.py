"""Jaxpr-level collective accounting for the PipeGCN step.

The fused deferred exchange collapses the per-step boundary collectives from
2L-1 blocking per-layer calls (L forward feature exchanges + L-1 backward
gradient exchanges) to exactly 2 (one packed exchange per direction). These
helpers trace a step function and count primitives in the jaxpr — the
regression test and the benchmark trajectory both pin the counts so the
fusion can never silently regress.

Counting happens at the jaxpr level (before XLA optimization), so it works
on any backend and any device count — an `all_to_all` over a 1-device mesh
axis is still one `all_to_all` eqn in the trace.
"""
from __future__ import annotations

import jax


def _iter_subjaxprs(v):
    """Yield every jaxpr reachable from an eqn-param value (jaxpr,
    ClosedJaxpr, or nested lists/tuples of either — covers shard_map,
    pjit, custom_vjp, scan and cond params)."""
    if hasattr(v, "eqns"):
        yield v
    elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
        yield v.jaxpr
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_subjaxprs(x)


def count_primitives(jaxpr, names) -> dict[str, int]:
    """Occurrences of each primitive name anywhere in `jaxpr` (recursing
    into nested jaxprs). Accepts a ClosedJaxpr or a raw jaxpr."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    counts = dict.fromkeys(names, 0)

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name in counts:
                counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                for sub in _iter_subjaxprs(v):
                    walk(sub)

    walk(jaxpr)
    return counts


def collective_counts(fn, *args) -> dict[str, int]:
    """Trace `fn(*args)` and count the inter-device collectives in its
    jaxpr: boundary exchanges (`all_to_all`) and reductions (`psum`)."""
    jx = jax.make_jaxpr(fn)(*args)
    return count_primitives(jx, ("all_to_all", "psum"))


def primitive_event_trace(jaxpr, names) -> list[str]:
    """The ORDERED sequence of `names` primitives in `jaxpr` — depth-first
    at each eqn's position (sub-jaxprs of pjit/shard_map/scan expand in
    place), so the list reflects jaxpr program order. This is what the
    split-phase schedule checker inspects: trace order is the order XLA
    receives, so a collective appearing between the boundary- and
    interior-phase `pallas_call`s proves it was ISSUED between them."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    wanted = set(names)
    events: list[str] = []

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name in wanted:
                events.append(eqn.primitive.name)
            for v in eqn.params.values():
                for sub in _iter_subjaxprs(v):
                    walk(sub)

    walk(jaxpr)
    return events


def traced_step_events(fn, *args,
                       names=("pallas_call", "all_to_all")) -> list[str]:
    """`primitive_event_trace` of a traced step function."""
    return primitive_event_trace(jax.make_jaxpr(fn)(*args), names)


def expected_split_events(num_layers: int, fused: bool,
                          train: bool = True) -> list[str]:
    """The ("pallas_call" | "all_to_all") event sequence of a split-phase
    step on a TILE engine under aggregate-first ordering (one kernel per
    phase; the layer-0 backward has no Pᵀ pass, Alg. 1 stops there).

    Forward, per-layer schedule: layer 0's exchange precedes the loop
    (its payload is x), then each layer runs [boundary kernel, next
    layer's exchange (if any), interior kernel]. Fused schedule: the one
    packed exchange is issued right after the LAST payload is gathered —
    between layer L-2's phases (pre-loop when L == 1). Backward mirrors
    it transposed down to layer 1, with the fused flush between layer 1's
    phases. Same collective COUNT as the unsplit schedule in every mode —
    the split only repositions each collective between a phase pair.
    """
    L = num_layers
    P, A = "pallas_call", "all_to_all"
    ev: list[str] = []
    if fused and L == 1:
        ev += [A]
    if not fused:
        ev += [A]
    for ell in range(L):
        ev += [P]
        if fused:
            if L > 1 and ell == L - 2:
                ev += [A]
        elif ell < L - 1:
            ev += [A]
        ev += [P]
    if not train:
        return ev
    for ell in reversed(range(1, L)):
        ev += [P]
        if (not fused) or ell == 1:
            ev += [A]
        ev += [P]
    return ev


def check_split_schedule(model, mesh, topo, data, axis_name="parts",
                         train: bool = True) -> list[str]:
    """Trace a split-phase `make_spmd_step` and assert its boundary
    collectives sit BETWEEN the phase kernels exactly as scheduled
    (`expected_split_events`). Returns the traced event list."""
    step = model.make_spmd_step(mesh, topo, axis_name, train=train)
    params = model.init_params(jax.random.PRNGKey(0))
    buffers = model.init_buffers(topo)
    events = traced_step_events(step, topo, params, buffers, data,
                                jax.random.PRNGKey(0))
    expected = expected_split_events(model.model.num_layers,
                                     model.pipe.fused, train=train)
    if events != expected:
        raise AssertionError(
            f"split-phase schedule mismatch:\n  traced   {events}\n"
            f"  expected {expected}")
    return events


def expected_boundary_collectives(num_layers: int, fused: bool,
                                  train: bool = True) -> int:
    """The collective-count math of the two communication schedules.

    Per-layer (blocking): L forward feature exchanges + (L-1) backward
    gradient exchanges = 2L-1 per training step (L at eval).
    Fused-deferred (stale mode): 1 packed forward + 1 packed backward = 2
    per training step (1 at eval); a 1-layer model has no gradient sends,
    so its backward collective vanishes in both schedules.
    """
    L = num_layers
    if fused:
        fwd, bwd = 1, (1 if L > 1 else 0)
    else:
        fwd, bwd = L, L - 1
    return fwd + (bwd if train else 0)


def traced_step_collectives(model, mesh, topo, data, axis_name="parts",
                            train: bool = True) -> dict[str, int]:
    """Collective counts of a traced `PipeGCN.make_spmd_step` jaxpr, with
    freshly initialized params/buffers as example arguments."""
    step = model.make_spmd_step(mesh, topo, axis_name, train=train)
    params = model.init_params(jax.random.PRNGKey(0))
    buffers = model.init_buffers(topo)
    return collective_counts(step, topo, params, buffers, data,
                             jax.random.PRNGKey(0))


def traced_wire_bytes(fn, *args) -> int:
    """Total bytes-on-wire of every `all_to_all` in a traced `fn(*args)`.

    Sums, over each all_to_all eqn anywhere in the jaxpr (recursing into
    shard_map/pjit bodies), the operand's per-device element count times
    its dtype itemsize — i.e. the bytes ONE device hands the collective
    per step. This is the quantity the boundary codecs shrink: a bf16
    wire halves it, int8/int4 shrink it ~4x/~8x (plus the scale region),
    and feature slicing shrinks the payload width itself. Shape-and-dtype
    static, so the figure is exact, device-free, and diffable in CI."""
    jx = jax.make_jaxpr(fn)(*args)
    total = 0

    def walk(jxr):
        nonlocal total
        for eqn in jxr.eqns:
            if eqn.primitive.name == "all_to_all":
                for v in eqn.invars:
                    total += int(v.aval.size) * v.aval.dtype.itemsize
            for v in eqn.params.values():
                for sub in _iter_subjaxprs(v):
                    walk(sub)

    walk(jx.jaxpr)
    return total


def traced_step_wire_bytes(model, mesh, topo, data, axis_name="parts",
                           train: bool = True) -> int:
    """`traced_wire_bytes` of a `PipeGCN.make_spmd_step` with fresh
    params/buffers — the per-device boundary bytes one training (or eval)
    step puts on the wire under the model's codec/slicing config."""
    step = model.make_spmd_step(mesh, topo, axis_name, train=train)
    params = model.init_params(jax.random.PRNGKey(0))
    buffers = model.init_buffers(topo)
    return traced_wire_bytes(step, topo, params, buffers, data,
                             jax.random.PRNGKey(0))
