"""Jaxpr-level collective accounting for the PipeGCN step.

The fused deferred exchange collapses the per-step boundary collectives from
2L-1 blocking per-layer calls (L forward feature exchanges + L-1 backward
gradient exchanges) to exactly 2 (one packed exchange per direction). These
helpers trace a step function and count primitives in the jaxpr — the
regression test and the benchmark trajectory both pin the counts so the
fusion can never silently regress.

Counting happens at the jaxpr level (before XLA optimization), so it works
on any backend and any device count — an `all_to_all` over a 1-device mesh
axis is still one `all_to_all` eqn in the trace.
"""
from __future__ import annotations

import jax


def _iter_subjaxprs(v):
    """Yield every jaxpr reachable from an eqn-param value (jaxpr,
    ClosedJaxpr, or nested lists/tuples of either — covers shard_map,
    pjit, custom_vjp, scan and cond params)."""
    if hasattr(v, "eqns"):
        yield v
    elif hasattr(v, "jaxpr") and hasattr(v.jaxpr, "eqns"):
        yield v.jaxpr
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_subjaxprs(x)


def count_primitives(jaxpr, names) -> dict[str, int]:
    """Occurrences of each primitive name anywhere in `jaxpr` (recursing
    into nested jaxprs). Accepts a ClosedJaxpr or a raw jaxpr."""
    if hasattr(jaxpr, "jaxpr"):
        jaxpr = jaxpr.jaxpr
    counts = dict.fromkeys(names, 0)

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name in counts:
                counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                for sub in _iter_subjaxprs(v):
                    walk(sub)

    walk(jaxpr)
    return counts


def collective_counts(fn, *args) -> dict[str, int]:
    """Trace `fn(*args)` and count the inter-device collectives in its
    jaxpr: boundary exchanges (`all_to_all`) and reductions (`psum`)."""
    jx = jax.make_jaxpr(fn)(*args)
    return count_primitives(jx, ("all_to_all", "psum"))


def expected_boundary_collectives(num_layers: int, fused: bool,
                                  train: bool = True) -> int:
    """The collective-count math of the two communication schedules.

    Per-layer (blocking): L forward feature exchanges + (L-1) backward
    gradient exchanges = 2L-1 per training step (L at eval).
    Fused-deferred (stale mode): 1 packed forward + 1 packed backward = 2
    per training step (1 at eval); a 1-layer model has no gradient sends,
    so its backward collective vanishes in both schedules.
    """
    L = num_layers
    if fused:
        fwd, bwd = 1, (1 if L > 1 else 0)
    else:
        fwd, bwd = L, L - 1
    return fwd + (bwd if train else 0)


def traced_step_collectives(model, mesh, topo, data, axis_name="parts",
                            train: bool = True) -> dict[str, int]:
    """Collective counts of a traced `PipeGCN.make_spmd_step` jaxpr, with
    freshly initialized params/buffers as example arguments."""
    step = model.make_spmd_step(mesh, topo, axis_name, train=train)
    params = model.init_params(jax.random.PRNGKey(0))
    buffers = model.init_buffers(topo)
    return collective_counts(step, topo, params, buffers, data,
                             jax.random.PRNGKey(0))
