"""PipeGCN core: the paper's contribution as a composable JAX module."""
from repro.core.config import ModelConfig, PipeConfig
from repro.core.elastic import (DeviceLossError, ElasticConfig, ElasticPlan)
from repro.core.faults import (FaultPlan, FaultSite, FaultTables,
                               StalenessExceededError, device_down_site)
from repro.core.health import (HealthConfig, TrainingAnomalyError,
                               health_check)
from repro.core.pipegcn import (PipeGCN, ShardedData, Topology,
                                SimBackend, SpmdBackend,
                                shard_data, topology_from)
from repro.core.module import make_pipegcn_loss
from repro.core.trainer import (TrainResult, make_jitted_train_step,
                                make_spmd_train_step, train_pipegcn)

__all__ = ["ModelConfig", "PipeConfig", "PipeGCN", "ShardedData", "Topology",
           "SimBackend", "SpmdBackend", "shard_data", "topology_from",
           "TrainResult", "make_jitted_train_step", "make_spmd_train_step",
           "train_pipegcn", "make_pipegcn_loss",
           "FaultPlan", "FaultSite", "FaultTables", "StalenessExceededError",
           "device_down_site",
           "DeviceLossError", "ElasticConfig", "ElasticPlan",
           "HealthConfig", "TrainingAnomalyError", "health_check"]
