"""PipeGCN core: partition-parallel full-graph GCN training with pipelined
(one-iteration-deferred) boundary feature / feature-gradient communication,
per the paper's Alg. 1 and Eq. 3–4, plus the §3.4 EMA smoothing.

Design notes
------------
* Staleness in feature *gradients* breaks `jax.grad` semantics (a cotangent
  produced at iteration t must be applied at t+1 on a different device), so —
  exactly like the paper's Alg. 1 — the backward pass is written by hand.
  With ``PipeConfig.vanilla()`` the same code performs synchronous exchanges
  and is verified against ``jax.grad`` of a pure forward to float64 tolerance.

* One implementation, two backends:
    - ``sim``  : partitions as a leading axis; exchange = transpose. 1 device.
    - ``spmd`` : runs inside ``jax.shard_map``; exchange = ``lax.all_to_all``.
  The layer math is shared; only the 4 sync points differ (feature exchange,
  gradient exchange, weight-grad reduce, loss reduce).

* The aggregation SpMM (Eq. 3 forward, Eq. 4 transpose) is pluggable:
  ``ModelConfig.agg`` selects between the padded-COO ``segment_sum`` engine
  ("coo", the verified fallback), the MXU-shaped Pallas block-sparse engine
  ("blocksparse"), and the fused aggregate⊗transform engine ("fused", which
  contracts the dense layer weight in the same Pallas grid pass — see
  repro.kernels.gcn_spmm / aggregate). The tile engines need tile streams
  on the Topology — ``topology_from(pg, with_tiles=True)`` attaches them.
  All engines run under both backends; the layer math never sees the
  storage format.

* The layer matmul ORDER is itself a knob (``ModelConfig.matmul_order``):
  aggregate-first (z = P·H then z·W, the paper's Eq. 3 order),
  transform-first (H·W then P·(H·W) — cheaper when F_out < F_in), or
  "auto", which resolves per layer from the static FLOP model in
  ``repro.analysis.cost`` (``layer_orders``). Under transform-first the
  aggregation residual z is never materialized; the weight gradient is
  computed as combᵀ·(Pᵀ·du) instead of zᵀ·du.

* Pipeline state (the "stale buffers") is explicit and threaded through the
  step function — this is what makes the deferred collectives free of data
  dependence on current-iteration compute (the XLA scheduler can overlap
  them, which is the TPU-native analogue of the paper's second cudaStream).

* Fused deferred exchange (``PipeConfig.fuse_exchange``, default on): in
  stale mode no current-step compute consumes the exchange results, so the
  per-layer sends are packed along the feature axis (static offset table,
  see ``pack_offsets``) and shipped in ONE collective after the forward
  plus ONE after the backward — 2 per step instead of 2L-1 — with the
  unpacked results landing straight in the t+1 FIFOs/EMA buffers. Packing
  commutes with the exchange (pure data movement), so the schedules are
  bit-identical; vanilla mode keeps the blocking per-layer exchange.

State layout (per layer ℓ = 1..L; widths follow the layer inputs):
  feat_buf[ℓ] : (P*slot, F_{ℓ-1})  stale boundary features   (Eq. 3 h^(t-1))
  grad_buf[ℓ] : (max_inner, F_{ℓ-1}) stale boundary-gradient contributions,
                already exchanged+scattered to owner rows    (Eq. 4 δ^(t-1))
With smoothing on, the same buffers hold the EMA (γ·old + (1−γ)·fresh);
receiver-side EMA is equivalent to the paper's per-node EMA because the
exchange+scatter is a fixed linear map.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.codec import fused_exchange_encoded, make_codec
from repro.core.config import ModelConfig, PipeConfig
from repro.core.faults import BWD, FWD, apply_faults
from repro.graph.halo import PartitionedGraph, extract_partition_tiles
from repro.kernels.aggregate import get_engine
from repro.kernels.gcn_spmm import TILE, SplitSpec


def _shard_map(f, mesh, in_specs, out_specs):
    """Version-compat shard_map: `jax.shard_map` (with check_vma) on new
    JAX, `jax.experimental.shard_map.shard_map` (with check_rep) on 0.4.x."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


class Topology(NamedTuple):
    """Device-ready padded partition topology (leading axis = partition).

    The COO fields are always present; the `tile_*` fields (block-sparse
    streams for the Pallas engine, see repro.kernels.gcn_spmm) are attached
    by ``topology_from(pg, with_tiles=True)`` and stay None otherwise —
    None fields are empty pytree subtrees, so every jit/shard_map/tree_map
    over a Topology works unchanged with or without tiles.
    """

    edge_row: jax.Array    # (P, max_nnz) int32
    edge_col: jax.Array    # (P, max_nnz) int32 (combined-array columns)
    edge_w: jax.Array      # (P, max_nnz) f32
    send_idx: jax.Array    # (P, P, slot) int32
    send_mask: jax.Array   # (P, P, slot) bool
    inner_mask: jax.Array  # (P, max_inner) bool
    tile_rows: jax.Array | None = None    # (P, n_tiles) int32
    tile_cols: jax.Array | None = None    # (P, n_tiles) int32
    tile_vals: jax.Array | None = None    # (P, n_tiles, T, T) f32
    tile_t_out: jax.Array | None = None   # (P, n_tiles) int32
    tile_t_in: jax.Array | None = None    # (P, n_tiles) int32
    tile_t_perm: jax.Array | None = None  # (P, n_tiles) int32

    @property
    def num_parts(self) -> int:
        # peer axis: works both with ((P), P, slot) and squeezed (P, slot)
        return self.send_idx.shape[-2]

    @property
    def max_inner(self) -> int:
        return self.inner_mask.shape[-1]

    @property
    def slot(self) -> int:
        return self.send_idx.shape[-1]

    @property
    def halo_size(self) -> int:
        return self.num_parts * self.slot


class ShardedData(NamedTuple):
    """Per-partition node data (leading axis = partition)."""

    x: jax.Array           # (P, max_inner, F)
    labels: jax.Array      # (P, max_inner) int32 or (P, max_inner, C) f32
    train_mask: jax.Array  # (P, max_inner) bool
    eval_mask: jax.Array   # (P, max_inner) bool (val or test)


def topology_from(pg: PartitionedGraph, with_tiles: bool = False) -> Topology:
    """Lift a PartitionedGraph to device arrays; `with_tiles=True` also
    extracts the block-sparse tile streams the "blocksparse" engine needs."""
    tiles = {}
    if with_tiles:
        pt = extract_partition_tiles(pg)
        tiles = dict(tile_rows=jnp.asarray(pt.rows),
                     tile_cols=jnp.asarray(pt.cols),
                     tile_vals=jnp.asarray(pt.vals),
                     tile_t_out=jnp.asarray(pt.t_out),
                     tile_t_in=jnp.asarray(pt.t_in),
                     tile_t_perm=jnp.asarray(pt.t_perm))
    return Topology(
        edge_row=jnp.asarray(pg.edge_row), edge_col=jnp.asarray(pg.edge_col),
        edge_w=jnp.asarray(pg.edge_w), send_idx=jnp.asarray(pg.send_idx),
        send_mask=jnp.asarray(pg.send_mask),
        inner_mask=jnp.asarray(pg.inner_mask), **tiles)


def shard_data(pg: PartitionedGraph, x, labels, train_mask, eval_mask) -> ShardedData:
    return ShardedData(
        x=jnp.asarray(pg.pack_nodes(np.asarray(x, np.float32))),
        labels=jnp.asarray(pg.pack_nodes(np.asarray(labels))),
        train_mask=jnp.asarray(pg.pack_nodes(np.asarray(train_mask))),
        eval_mask=jnp.asarray(pg.pack_nodes(np.asarray(eval_mask))))


# ----------------------------------------------------------------------
# Per-partition primitives (no partition axis; sim backend vmaps them).
# The SpMM itself (z = P·comb and δcomb = Pᵀ·δz) lives behind the
# aggregation-engine interface in repro.kernels.aggregate.
# ----------------------------------------------------------------------

def _gather_send(h, send_idx, send_mask):
    """(max_inner,F) -> (P, slot, F) payload for each peer."""
    p, slot = send_idx.shape
    out = h[send_idx.reshape(-1)].reshape(p, slot, -1)
    return jnp.where(send_mask[..., None], out, 0.0)


def _gather_send_tail(h_tail, send_idx, send_mask, row_tail):
    """`_gather_send` reading from a boundary-phase tail slice.

    `h_tail` holds only rows [row_tail, max_inner) of the layer output —
    exactly the rows the boundary phase produced. Every REAL send index is
    >= row_tail by construction of the split (`boundary_row_split`); padded
    (masked-out) slots carry index 0, which is clamped onto the first tail
    row and then zeroed by the mask, exactly like `_gather_send` does."""
    p, slot = send_idx.shape
    idx = jnp.maximum(send_idx.reshape(-1) - row_tail, 0)
    out = h_tail[idx].reshape(p, slot, -1)
    return jnp.where(send_mask[..., None], out, 0.0)


def split_spec_from(pg: PartitionedGraph, tile: int = TILE) -> SplitSpec | None:
    """The split-phase schedule spec of a partitioned graph, or None when
    the split is infeasible (P=1 / no sends / boundary rows not clustered
    into a proper tail — see `repro.graph.halo.boundary_row_split`). The
    tile-group sizes come from the same memoized `extract_partition_tiles`
    call that `topology_from(pg, with_tiles=True)` uses, so the phase cut
    and the padded tile streams are consistent by construction."""
    pt = extract_partition_tiles(pg, tile)
    if pt.fwd_bnd is None:
        return None
    return SplitSpec(row_tail=pt.b0 * tile, col_tail=pt.hb0 * tile,
                     fwd_bnd_tiles=pt.fwd_bnd, t_bnd_tiles=pt.t_bnd)


def _scatter_recv(contrib, send_idx, send_mask, max_inner):
    """(P, slot, F) received gradient blocks -> (max_inner, F) scatter-add."""
    p, slot, f = contrib.shape
    contrib = jnp.where(send_mask[..., None], contrib, 0.0)
    flat_idx = send_idx.reshape(-1)
    return jnp.zeros((max_inner, f), contrib.dtype).at[flat_idx].add(
        contrib.reshape(p * slot, f))


def _scatter_invalid_rows(inv, send_idx, max_inner):
    """(P, slot) invalid-contribution mask -> (max_inner,) owner rows whose
    `_scatter_recv` sum is incomplete (any contributing slot was invalid).
    Those rows fall back to the stale buffer wholesale — a partial sum is
    not one-step-stale data, it is wrong data."""
    return jnp.zeros((max_inner,), bool).at[send_idx.reshape(-1)].max(
        inv.reshape(-1))


# ----------------------------------------------------------------------
# Hierarchical exchange: P partitions on P // n_local devices.
#
# Partition p lives on device p // n_local (device-major layout, matching
# how a (P, ...) array shards over a 1-D mesh axis). Per device, the send
# tensor s[l, j] is the payload from co-resident partition l to global
# partition j. The exchange blocks the global P axis as (n_dev, n_local):
# the two local axes are permuted by pure reshapes/transposes (the
# co-resident partition pairs — including the whole exchange when
# n_dev == 1 — never touch the interconnect; XLA's AllToAll keeps the
# self-chunk in HBM) and only the device axis crosses the wire, in ONE
# all_to_all of (n_local x n_local) blocks. Boundary traffic per device
# stays O(P * slot * F) with no redundant self-sends.
# ----------------------------------------------------------------------

def _hier_pack(s, n_local):
    """(n_local, P, ...) send tensor -> (n_dev, l_src, l_dst, ...) blocks,
    device-major along axis 0 (the only axis the all_to_all splits)."""
    n_dev = s.shape[1] // n_local
    a = s.reshape((n_local, n_dev, n_local) + s.shape[2:])
    return jnp.swapaxes(a, 0, 1)


def _hier_unpack(recv, n_local):
    """(n_dev, l_src, l_dst, ...) received blocks -> (n_local, P, ...):
    row l = payloads addressed to co-resident partition l, indexed by
    global sender id (device-major, matching the send layout)."""
    n_dev = recv.shape[0]
    r = jnp.moveaxis(recv, 2, 0)
    return r.reshape((n_local, n_dev * n_local) + recv.shape[3:])


def hierarchical_exchange(s, axis_name, n_local):
    """Per-device exchange of (n_local, P, slot, F) boundary payloads:
    local shuffle (reshape/transpose) for co-resident partition pairs fused
    with a single inter-device all_to_all for the remote blocks."""
    blocks = _hier_pack(s, n_local)
    recv = jax.lax.all_to_all(blocks, axis_name, 0, 0, tiled=True)
    return _hier_unpack(recv, n_local)


def hierarchical_exchange_host(S):
    """Single-process reference evaluation of `hierarchical_exchange` on a
    global (n_dev, n_local, P, ...) payload with the device axis explicit:
    the all_to_all is replaced by its definition (device d's chunk j lands
    on device j at position d, i.e. a transpose of the two device axes)."""
    n_local = S.shape[1]
    blocks = jax.vmap(lambda s: _hier_pack(s, n_local))(S)
    recv = jnp.swapaxes(blocks, 0, 1)
    return jax.vmap(lambda r: _hier_unpack(r, n_local))(recv)


def flat_exchange_reference(S):
    """The flat global exchange R[i, j] = S[j, i] over global partition ids,
    reshaped to the same (n_dev, n_local, P, ...) device layout — the
    specification `hierarchical_exchange` must match."""
    n_dev, n_local, p = S.shape[:3]
    flat = S.reshape((n_dev * n_local, p) + S.shape[3:])
    return jnp.swapaxes(flat, 0, 1).reshape(S.shape)


# ----------------------------------------------------------------------
# Fused deferred exchange: packing per-layer payloads into one collective.
#
# In stale mode the exchanged boundary data is consumed only at step t+1,
# so the per-layer sends have no consumer inside the current step — they
# can be concatenated along the feature axis (layer widths differ; the
# offset table is static at trace time) and shipped in a single collective
# per direction. The exchange is pure data movement, so packing commutes
# with it exactly: fused and per-layer schedules are bit-identical.
# ----------------------------------------------------------------------

def pack_widths(payloads) -> tuple[int, ...]:
    """Static per-layer feature widths of a payload list (the pack layout)."""
    return tuple(int(p.shape[-1]) for p in payloads)


def pack_offsets(widths) -> tuple[int, ...]:
    """Static start offset of each layer's slice in the packed feature axis."""
    out, off = [], 0
    for w in widths:
        out.append(off)
        off += int(w)
    return tuple(out)


def pack_payloads(payloads):
    """Per-layer (..., P, slot, F_l) sends -> one (..., P, slot, ΣF_l)."""
    if len(payloads) == 1:
        return payloads[0]
    return jnp.concatenate(payloads, axis=-1)


def unpack_payloads(packed, widths):
    """Inverse of `pack_payloads` given the static width table."""
    if len(widths) == 1:
        return [packed]
    offsets = pack_offsets(widths)
    return [jax.lax.slice_in_dim(packed, o, o + w, axis=packed.ndim - 1)
            for o, w in zip(offsets, widths)]


# ----------------------------------------------------------------------
# Backends: the four sync points.
# ----------------------------------------------------------------------

class _ExchangeBase:
    """Shared fused-exchange, layered on each backend's `exchange`."""

    def fused_exchange(self, payloads):
        """Exchange a list of per-layer (..., P, slot, F_l) payloads in ONE
        collective: pack along the feature axis, exchange the packed buffer
        once, unpack at the static offsets. Exactly equivalent to
        [self.exchange(p) for p in payloads]."""
        recv = self.exchange(pack_payloads(payloads))
        return unpack_payloads(recv, pack_widths(payloads))


class SimBackend(_ExchangeBase):
    """Partitions as leading axis on a single device."""

    is_spmd = False
    lead_axis = True   # arrays carry a leading (local-)partition axis

    def pmap(self, f):
        return jax.vmap(f)

    def exchange(self, s):
        # s: (P_dev, P_peer, slot, F); R[i, j] = S[j, i]
        return jnp.swapaxes(s, 0, 1)

    def part_ids(self, num_parts):
        """Global partition id of every leading-axis slot (all P here)."""
        return jnp.arange(num_parts)

    def psum(self, x):
        return jnp.sum(x, axis=0)

    def psum_scalar(self, x):
        return jnp.sum(x)

    def dropout_mask(self, key, rate, shape_per_part, num_parts):
        shape = (num_parts,) + tuple(shape_per_part)
        keep = jax.random.bernoulli(key, 1.0 - rate, shape)
        return keep.astype(jnp.float32) / (1.0 - rate)


class SpmdBackend(_ExchangeBase):
    """Runs inside shard_map over `axis_name` (a mesh axis or tuple of axes
    — the production mesh flattens ("data","model") into the partition
    axis). With `n_local` > 1 each device hosts n_local co-resident
    partitions as a leading local axis (same layout the sim backend uses
    for all P), and the boundary exchange goes hierarchical: a local
    shuffle for co-resident pairs + one inter-device all_to_all."""

    is_spmd = True

    def __init__(self, axis_name="parts", n_local: int = 1):
        self.axis_name = axis_name
        self.n_local = n_local
        self.lead_axis = n_local > 1

    def pmap(self, f):
        return f

    def _global_part_offset(self):
        """Global partition id of this device's local partition 0."""
        return jax.lax.axis_index(self.axis_name) * self.n_local

    def part_ids(self, num_parts):
        """Global partition ids this device sends as: a traced scalar for
        the flat layout, a (n_local,) vector for co-resident partitions."""
        base = self._global_part_offset()
        if not self.lead_axis:
            return base
        return base + jnp.arange(self.n_local)

    def exchange(self, s):
        # s: (P, slot, F) per device, or (n_local, P, slot, F) when >1
        # partition is co-resident.
        if not self.lead_axis:
            return jax.lax.all_to_all(s, self.axis_name, 0, 0, tiled=True)
        return hierarchical_exchange(s, self.axis_name, self.n_local)

    def psum(self, x):
        if self.lead_axis:                 # fold co-resident partitions first
            x = jnp.sum(x, axis=0)
        return jax.lax.psum(x, self.axis_name)

    def psum_scalar(self, x):
        return jax.lax.psum(x, self.axis_name)

    def dropout_mask(self, key, rate, shape_per_part, num_parts):
        base = self._global_part_offset()
        if not self.lead_axis:
            key = jax.random.fold_in(key, base)
            keep = jax.random.bernoulli(key, 1.0 - rate, tuple(shape_per_part))
            return keep.astype(jnp.float32) / (1.0 - rate)
        # One independent stream per global partition id, so the mask a
        # partition sees is invariant to how partitions map onto devices.
        keys = jax.vmap(lambda l: jax.random.fold_in(key, base + l))(
            jnp.arange(self.n_local))
        keep = jax.vmap(
            lambda k: jax.random.bernoulli(k, 1.0 - rate,
                                           tuple(shape_per_part)))(keys)
        return keep.astype(jnp.float32) / (1.0 - rate)


# ----------------------------------------------------------------------
# Losses (masked, globally normalized).
# ----------------------------------------------------------------------

def _ce_loss_and_grad(logits, labels, mask, total, backend):
    """Masked softmax cross-entropy; returns (local_sum, dlogits)."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None].astype(jnp.int32),
                             axis=-1)[..., 0]
    loss_local = jnp.sum((lse - ll) * mask)
    probs = jax.nn.softmax(logits, axis=-1)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=logits.dtype)
    dlogits = (probs - onehot) * mask[..., None] / total
    return loss_local, dlogits


def _bce_loss_and_grad(logits, labels, mask, total, backend):
    """Masked multi-label sigmoid BCE (Yelp-style); total counts node·class."""
    z, y = logits, labels.astype(logits.dtype)
    per = jnp.maximum(z, 0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))
    loss_local = jnp.sum(per * mask[..., None])
    dlogits = (jax.nn.sigmoid(z) - y) * mask[..., None] / total
    return loss_local, dlogits


# ----------------------------------------------------------------------
# The module.
# ----------------------------------------------------------------------

@dataclasses.dataclass
class PipeGCN:
    """Composable partition-parallel GCN with pipelined communication.

    All methods are pure; state (params / pipeline buffers / rng) is explicit
    so the step can be jitted, shard_mapped, scanned, and checkpointed.
    """

    model: ModelConfig
    pipe: PipeConfig
    # Split-phase overlap spec (ISSUE 6) — static trace-time constants from
    # `split_spec_from(pg)`; None disables the split regardless of
    # `pipe.overlap` (the schedule falls back to the unsplit `_step_impl`
    # body, e.g. for P=1 or layouts without a clustered boundary tail).
    split: SplitSpec | None = None

    # ---------------- parameters & state ----------------

    def init_params(self, key: jax.Array, dtype=jnp.float32) -> dict:
        params = {}
        for ell, (fin, fout) in enumerate(self.model.layer_dims()):
            fan_in = 2 * fin if self.model.kind == "sage" else fin
            key, sub = jax.random.split(key)
            scale = jnp.sqrt(2.0 / (fan_in + fout)).astype(dtype)
            params[f"w{ell}"] = jax.random.normal(sub, (fan_in, fout), dtype) * scale
            params[f"b{ell}"] = jnp.zeros((fout,), dtype)
        return params

    def init_buffers(self, topo: Topology, dtype=jnp.float32,
                     leading: bool = True) -> dict:
        """Zero pipeline state (Alg. 1 line 6: boundary features start at 0).

        With staleness_steps k>1, each buffer is a FIFO queue along a new
        leading axis of size k (slot 0 = oldest = consumed).

        Buffer widths follow `payload_widths`: the layer input width fin,
        except for sliced layers (`PipeConfig.slice_boundary`), whose
        exchange — and therefore whose stale state — carries the
        post-transform width fout.

        Under `guard_exchange` the dict gains an "es" leaf: int32
        consecutive-fallback counters of shape (2, L, P) per partition —
        (direction, layer, peer) — with NO staleness-queue axis (the
        counter tracks the stream, not one queue slot)."""
        p = topo.num_parts
        k = self.pipe.staleness_steps
        q = (k,) if k > 1 else ()
        lead = q + ((p,) if leading else ())
        feat, grad = [], []
        for w in self.payload_widths(topo):
            feat.append(jnp.zeros(lead + (topo.halo_size, w), dtype))
            grad.append(jnp.zeros(lead + (topo.max_inner, w), dtype))
        out = {"feat": tuple(feat), "grad": tuple(grad)}
        if self.pipe.guard_exchange:
            out["es"] = jnp.zeros(
                ((p,) if leading else ()) + (2, self.model.num_layers, p),
                jnp.int32)
        return out

    # ---------------- pipeline-buffer semantics ----------------

    def _consume_buffer(self, buf):
        """The stale state a step reads: t-k (FIFO head) or t-1 (plain/EMA)."""
        return buf[0] if self.pipe.staleness_steps > 1 else buf

    def _update_buffer(self, buf, fresh, smooth: bool):
        """Next-step buffer from the freshly exchanged payload: FIFO push,
        EMA (γ·old + (1−γ)·fresh), or plain replacement."""
        if self.pipe.staleness_steps > 1:
            return jnp.concatenate([buf[1:], fresh[None]], axis=0)
        if smooth:
            return self.pipe.gamma * buf + (1 - self.pipe.gamma) * fresh
        return fresh

    def _update_buffer_guarded(self, buf, fresh, smooth: bool, valid):
        """`_update_buffer` with per-row fallback (guard_exchange): rows of
        `fresh` whose checksum failed keep their previous value — the FIFO
        re-pushes the newest entry, EMA/replace keep the old row — so a lost
        payload is one extra step of staleness, not a zero/garbage write.
        `valid=None` (guard off) and all-True masks are bitwise identical
        to the unguarded update (pure `jnp.where` select semantics)."""
        if valid is None:
            return self._update_buffer(buf, fresh, smooth)
        v = valid[..., None]
        if self.pipe.staleness_steps > 1:
            pushed = jnp.where(v, fresh, buf[-1])
            return jnp.concatenate([buf[1:], pushed[None]], axis=0)
        if smooth:
            upd = self.pipe.gamma * buf + (1 - self.pipe.gamma) * fresh
            return jnp.where(v, upd, buf)
        return jnp.where(v, fresh, buf)

    # ---------------- shared layer math ----------------

    @property
    def engine(self):
        """The aggregation engine selected by ``ModelConfig.agg``."""
        return get_engine(self.model.agg)

    def _agg_slice(self, topo: Topology):
        """The Topology fields the selected engine consumes (still carrying
        the leading partition axis; sliced/vmapped by the backend)."""
        engine = self.engine
        tslice = tuple(getattr(topo, f) for f in engine.fields)
        if any(t is None for t in tslice):
            raise ValueError(
                f"aggregation engine {engine.name!r} needs Topology fields "
                f"{engine.fields}, but some are None — build the topology "
                "with topology_from(pg, with_tiles=True) or "
                f"GraphDataPipeline.build(..., agg={engine.name!r})")
        return tslice

    def _split_active(self) -> SplitSpec | None:
        """The SplitSpec the step should run with, or None for unsplit.

        "none" and a missing spec always mean unsplit; "split-phase" uses
        the spec whenever one exists (degenerate graphs still fall back —
        there is no boundary tail to phase); "auto" additionally requires
        an engine that consumes tile streams (the split only repositions
        collectives around the tile phases; for COO it is a pure masking
        overhead, kept reachable via the explicit "split-phase" for the
        cross-engine parity tests). Feature slicing always disables the
        split: the sliced send only exists after the dense transform, so
        there is no boundary-first phase to overlap (the explicit
        "split-phase" + slice_boundary combination is already rejected by
        PipeConfig). The guarded exchange also disables the split: the
        split body has no validity-mask path (and PipeConfig rejects the
        explicit combination)."""
        if (self.pipe.overlap == "none" or self.split is None
                or self.pipe.slice_boundary or self.pipe.guard_exchange):
            return None
        if self.pipe.overlap == "split-phase":
            return self.split
        from repro.graph.reorder import TILE_ENGINES
        return self.split if self.engine.name in TILE_ENGINES else None

    def layer_orders(self, topo: Topology, train: bool = True,
                     fused: bool | None = None) -> tuple[str, ...]:
        """Per-layer matmul ordering the step actually runs with.

        `_base_orders` resolves the ModelConfig knob ("auto" via the static
        cost model, with wire-byte pricing folded in when slice_boundary is
        on); on top of that, every SLICED layer is forced to
        "transform-first" in every mode — the sliced exchange and its stale
        buffers carry the post-transform width, so the order backing them
        must not drift between train/eval or across `fused` overrides
        (buffer shapes are part of the step signature)."""
        orders = self._base_orders(topo, train=train, fused=fused)
        sl = self.sliced_layers(topo)
        if not sl:
            return orders
        return tuple("transform-first" if ell in sl else o
                     for ell, o in enumerate(orders))

    def sliced_layers(self, topo: Topology) -> frozenset:
        """Layers whose boundary exchange ships the post-transform width.

        Empty unless `PipeConfig.slice_boundary`. A layer is sliced when
        the TRAIN-mode base ordering picks transform-first for it and
        fout <= fin (slicing a widening layer would grow the wire). Layer 0
        never slices: its payload is the raw input features, needed at full
        width on the consumer. Computed from `_base_orders(train=True)`
        only, so the sliced set — and with it every buffer width — is
        identical for train and eval steps."""
        if not self.pipe.slice_boundary:
            return frozenset()
        dims = self.model.layer_dims()
        orders = self._base_orders(topo, train=True)
        return frozenset(
            ell for ell in range(1, self.model.num_layers)
            if orders[ell] == "transform-first"
            and dims[ell][1] <= dims[ell][0])

    def payload_widths(self, topo: Topology) -> tuple[int, ...]:
        """Per-layer feature width of the boundary exchange payload: fin,
        or fout for sliced layers. Stale buffers, wire-format resolution,
        and the byte accounting all key off this table."""
        dims = self.model.layer_dims()
        sl = self.sliced_layers(topo)
        return tuple(dims[ell][1] if ell in sl else dims[ell][0]
                     for ell in range(self.model.num_layers))

    def wire_codecs(self, topo: Topology) -> tuple:
        """Per-layer boundary codec (repro.core.codec) the step encodes
        with. A concrete `PipeConfig.wire` applies uniformly; "auto" picks
        per layer by wire bytes over the payload widths
        (repro.analysis.cost.choose_wire_formats — int4 is explicit-only).
        Under `guard_exchange` every codec is wrapped in a ChecksumCodec
        (one extra wire column per row, verified on decode)."""
        L = self.model.num_layers
        g = self.pipe.guard_exchange
        if self.pipe.wire != "auto":
            return (make_codec(self.pipe.wire, self.pipe.wire_block,
                               guard=g),) * L
        from repro.analysis.cost import choose_wire_formats
        fmts = choose_wire_formats(self.payload_widths(topo),
                                   block=self.pipe.wire_block)
        return tuple(make_codec(f, self.pipe.wire_block, guard=g)
                     for f in fmts)

    def _base_orders(self, topo: Topology, train: bool = True,
                     fused: bool | None = None) -> tuple[str, ...]:
        """Per-layer matmul ordering, resolved statically (trace-time).

        "auto" feeds the static FLOP model (`repro.analysis.cost`) the
        shard's effective sparse work: n_tiles·T² for the tile engines
        (padded tiles do real MXU work — computed via `tile_density`), the
        padded COO length otherwise. Everything here is a Python int from
        array *shapes*, so the choice is identical on every backend and
        every partition and never enters the traced program.

        `fused` overrides the cost model's fused-epilogue assumption: the
        split-phase schedule runs the fused engine through the composed
        phased path (the in-kernel epilogue would write garbage through
        the dense weight for out-of-phase rows), so it prices fused=False.
        """
        mo = self.model.matmul_order
        L = self.model.num_layers
        if mo != "auto":
            return (mo,) * L
        engine = self.engine
        combined = topo.max_inner + topo.halo_size
        from repro.graph.reorder import TILE_ENGINES
        if engine.name in TILE_ENGINES and topo.tile_rows is not None:
            # MEASURED tile stream length of this very topology (every
            # stored tile does a full T×T MXU contraction per feature
            # column) — with a reordered layout this is the post-reorder
            # tile count, not a uniform-density estimate, so the argmin
            # tracks the layout. The propagation shard is shared by every
            # layer; the per-layer list keeps the cost-model contract
            # explicit.
            nnz_eff = [topo.tile_rows.shape[-1] * TILE * TILE] * L
        else:
            nnz_eff = [topo.edge_row.shape[-1]] * L       # padded COO work
        from repro.analysis.cost import choose_gcn_orders
        if fused is None:
            fused = engine.name == "fused"
        kw = {}
        if self.pipe.slice_boundary:
            # Co-decision with the wire codec: price each ordering's
            # boundary bytes (transform-first ships the sliced fout width)
            # so "auto" weighs comm against FLOPs. Formats here resolve on
            # the UNSLICED fin widths — the sliced set is itself derived
            # from this choice, so pricing must not depend on it.
            from repro.analysis.cost import (DEFAULT_FLOPS_PER_WIRE_BYTE,
                                             choose_wire_formats,
                                             wire_bytes_per_row)
            if self.pipe.wire == "auto":
                fmts = choose_wire_formats(
                    [f for f, _ in self.model.layer_dims()],
                    block=self.pipe.wire_block)
            else:
                fmts = (self.pipe.wire,) * L
            kw = dict(
                slot_rows=float(topo.halo_size),
                wire_bytes_fn=lambda ell, f, fmts=fmts: wire_bytes_per_row(
                    fmts[ell], f, self.pipe.wire_block),
                slice_boundary=True,
                comm_flops_per_byte=DEFAULT_FLOPS_PER_WIRE_BYTE)
        return choose_gcn_orders(self.model.layer_dims(), topo.max_inner,
                                 combined, nnz_eff, train=train,
                                 fused=fused, tile=TILE, **kw)

    def _layer_forward(self, tslice, w, b, h_prev, halo, drop_mask,
                       order: str = "aggregate-first",
                       fuse_relu: bool = False, with_z: bool = True):
        """One GCN/SAGE layer on one partition. Returns (u, (comb, z)).

        `order` picks the contraction of P·comb·W: aggregate-first routes
        through ``engine.aggregate_transform`` (the fused engine contracts
        the weight inside the Pallas grid pass; other engines compose),
        transform-first applies the dense matmul before the SpMM. z is the
        aggregation residual the aggregate-first backward needs for the
        weight gradient — None under transform-first (gw is computed from
        comb and Pᵀ·du there) or when `with_z=False` (eval). With
        `fuse_relu` the returned u is already activated — inside the fused
        kernel's epilogue when possible (GCN kind, aggregate-first), as a
        plain jnp op otherwise.
        """
        max_inner = h_prev.shape[0]
        fin = h_prev.shape[-1]
        comb = jnp.concatenate([h_prev, halo], axis=0)
        if drop_mask is not None:
            comb = comb * drop_mask
        sage = self.model.kind == "sage"
        w1 = w[:fin] if sage else w
        applied_act = False
        if order == "transform-first":
            u = self.engine.spmm(tslice, comb @ w1, max_inner) + b
            z = None
        else:
            in_kernel_relu = fuse_relu and not sage
            u, z = self.engine.aggregate_transform(
                tslice, comb, w1, b, max_inner,
                relu=in_kernel_relu, with_z=with_z)
            applied_act = in_kernel_relu
        if sage:
            u = u + comb[:max_inner] @ w[fin:]
        if fuse_relu and not applied_act:
            u = jax.nn.relu(u)
        return u, (comb, z)

    def _layer_backward(self, tslice, w, du, comb, z, drop_mask, max_inner,
                        order: str = "aggregate-first",
                        need_dcomb: bool = True):
        """Manual VJP of one layer, weight gradient included. Returns
        (gW, dH_inner_local, dB_halo); the d-terms are None when
        `need_dcomb=False` (layer 0 — Alg. 1 stops the backward there,
        though transform-first still needs Pᵀ·du for its weight gradient).
        """
        combined = comb.shape[0]
        fin = comb.shape[-1]
        sage = self.model.kind == "sage"
        w1 = w[:fin] if sage else w
        if order == "transform-first":
            dhw = self.engine.spmm_t(tslice, du, combined)
            gw = comb.T @ dhw                 # = zᵀ·du without z: combᵀPᵀdu
            if sage:
                gw = jnp.concatenate([gw, comb[:max_inner].T @ du], axis=0)
            if not need_dcomb:
                return gw, None, None
            dcomb = dhw @ w1.T
        else:
            gw = z.T @ du
            if sage:
                gw = jnp.concatenate([gw, comb[:max_inner].T @ du], axis=0)
            if not need_dcomb:
                return gw, None, None
            dcomb = self.engine.aggregate_transform_t(tslice, du, w1,
                                                      combined)
        if sage:
            dcomb = dcomb.at[:max_inner].add(du @ w[fin:].T)
        if drop_mask is not None:
            dcomb = dcomb * drop_mask
        return gw, dcomb[:max_inner], dcomb[max_inner:]

    # ---------------- forward/backward step (per partition view) --------

    def _step_impl(self, backend, topo: Topology, params, buffers, data,
                   key, train: bool, step_idx=None, faults=None):
        """Runs per-partition under `backend`. In sim the arrays keep their
        leading partition axis and per-partition ops are vmapped; in spmd this
        body executes inside shard_map with squeezed arrays.

        `faults` (a compiled FaultTables) injects drop/corrupt faults into
        the encoded wires at `step_idx`; under `pipe.guard_exchange` the
        decode verifies per-row checksums and failed rows fall back to
        their stale buffer entry (see faults.py / _update_buffer_guarded).
        `faults=None` traces exactly the historical fault-free step."""
        sp = self._split_active()
        if sp is not None and faults is None:
            # the split schedule has no injection points; numerics are
            # identical, so a faulted run just takes the unsplit body
            return self._step_impl_split(backend, topo, params, buffers,
                                         data, key, train, sp)
        L = self.model.num_layers
        dims = self.model.layer_dims()
        pipe = self.pipe
        P = topo.num_parts
        max_inner = topo.max_inner

        tslice = self._agg_slice(topo)
        send_idx, send_mask = topo.send_idx, topo.send_mask
        lead = backend.lead_axis
        if lead:
            gather = jax.vmap(_gather_send)
            scatter = jax.vmap(partial(_scatter_recv, max_inner=max_inner))
            scatter_inv = jax.vmap(
                partial(_scatter_invalid_rows, max_inner=max_inner))
        else:
            gather = _gather_send
            scatter = partial(_scatter_recv, max_inner=max_inner)
            scatter_inv = partial(_scatter_invalid_rows, max_inner=max_inner)

        guard = pipe.guard_exchange
        pids = backend.part_ids(P) if faults is not None else None
        # per-layer peer-validity verdicts (guard only): bool (..., P) per
        # direction, folded into the "es" consecutive-fallback counters
        feat_pv = [None] * L
        grad_pv = [None] * L

        h = data.x
        fuse = pipe.fused        # stale + fuse_exchange: deferred collectives
        orders = self.layer_orders(topo, train=train)   # static, per layer
        sliced = self.sliced_layers(topo)
        codecs = self.wire_codecs(topo)
        pw = self.payload_widths(topo)
        sage = self.model.kind == "sage"
        residuals = []
        new_feat = []
        pending_feat = []        # fused mode: per-layer wires, exchanged once
        feat_dtypes = []         # ... and their pre-encode dtypes
        dropout_rate = self.model.dropout if train else 0.0

        def ship_feat(ell, payload):
            """Encode one layer's (..., P, slot, pw) feature send, exchange
            it (or queue it for the fused collective), decode, and return
            the (..., P*slot, pw) halo the layer consumes this step."""
            dtype = payload.dtype
            wire = codecs[ell].encode(payload)
            if faults is not None:
                wire = apply_faults(wire, faults, step_idx, FWD, ell,
                                    pids, guard)
            if fuse:
                # Stale mode: the exchange result is consumed only at t+1,
                # so defer the wire into the packed buffer and read this
                # step's halo straight from the pipeline state.
                pending_feat.append(wire)
                feat_dtypes.append(dtype)
                new_feat.append(None)   # filled after the fused exchange
                return self._consume_buffer(buffers["feat"][ell])
            fresh, vrows = land_feat(ell, backend.exchange(wire), dtype)
            if pipe.stale:
                halo = self._consume_buffer(buffers["feat"][ell])
                new_feat.append(self._update_buffer_guarded(
                    buffers["feat"][ell], fresh, pipe.smooth_feat, vrows))
            else:
                halo = fresh
                new_feat.append(buffers["feat"][ell])
            return halo

        def land_feat(ell, recv, dtype):
            """Decode one received feature wire to the (..., P·slot, pw)
            halo layout; under the guard also verify per-row checksums,
            returning the (..., P·slot) valid-row mask and folding the
            per-peer verdict into `feat_pv`."""
            if guard:
                fresh, valid = codecs[ell].decode_checked(recv, pw[ell],
                                                          dtype)
                feat_pv[ell] = jnp.all(valid, axis=-1)
                vrows = valid.reshape(valid.shape[:-2] + (P * topo.slot,))
            else:
                fresh = codecs[ell].decode(recv, pw[ell], dtype)
                vrows = None
            fresh = fresh.reshape(fresh.shape[:-3] + (P * topo.slot, pw[ell]))
            return fresh, vrows

        for ell in range(L):
            fin, fout = dims[ell]
            if dropout_rate > 0.0:
                dkey = jax.random.fold_in(key, ell)
                dm = backend.dropout_mask(
                    dkey, dropout_rate,
                    (max_inner + P * topo.slot, fin), P)
            else:
                dm = None

            act = ell < L - 1
            # Eval never needs residuals: skip the z output (the fused
            # kernel then skips its HBM write) and fuse the ReLU epilogue.
            fuse_relu = act and not train
            if ell in sliced:
                # Sliced boundary (order forced transform-first): transform
                # the inner rows FIRST and ship the fout-wide result rows —
                # the consumer aggregates already-transformed halo rows, so
                # the wire carries fout <= fin columns. Dropout applies
                # owner-side before the transform (a halo row arrives with
                # its owner's inner-row mask baked in, instead of the
                # consumer's halo mask) — identical to the unsliced
                # schedule at dropout 0.
                w, b = params[f"w{ell}"], params[f"b{ell}"]
                w1 = w[:fin] if sage else w
                h_in = h * dm[..., :max_inner, :] if dm is not None else h
                hw = h_in @ w1
                halo = ship_feat(ell, gather(hw, send_idx, send_mask))
                src = jnp.concatenate([hw, halo], axis=-2)
                if not lead:
                    u = self.engine.spmm(tslice, src, max_inner) + b
                else:
                    u = jax.vmap(lambda ts, s: self.engine.spmm(
                        ts, s, max_inner))(tslice, src) + b
                if sage:
                    u = u + h_in @ w[fin:]
                if fuse_relu:
                    u = jax.nn.relu(u)
                # residual slot 0 holds the masked inner rows (the sliced
                # backward needs h_in, never the full comb)
                residuals.append((h_in, None, u, dm))
            else:
                halo = ship_feat(ell, gather(h, send_idx, send_mask))
                if not lead:
                    u, (comb, z) = self._layer_forward(
                        tslice, params[f"w{ell}"], params[f"b{ell}"], h,
                        halo, dm, order=orders[ell], fuse_relu=fuse_relu,
                        with_z=train)
                else:
                    fwd = jax.vmap(
                        lambda ts, h_, halo_, dm_, w_=params[f"w{ell}"],
                               b_=params[f"b{ell}"], o_=orders[ell]:
                        self._layer_forward(ts, w_, b_, h_, halo_, dm_,
                                            order=o_, fuse_relu=fuse_relu,
                                            with_z=train),
                        in_axes=(0, 0, 0, 0 if dm is not None else None))
                    u, (comb, z) = fwd(tslice, h, halo, dm)
                residuals.append((comb, z, u, dm))
            h = jax.nn.relu(u) if act and not fuse_relu else u

        if fuse:
            # ONE collective for all L layers' boundary features, issued
            # after the last layer. Nothing downstream of it is consumed
            # this step (results land in the t+1 buffers), so XLA is free
            # to overlap it with the loss/backward/optimizer compute.
            for ell, recv in enumerate(
                    fused_exchange_encoded(backend, pending_feat)):
                # decode restores the layer's own pre-pack dtype: undoes
                # the wire encoding AND any promotion from packing layers
                # of different dtypes into one buffer
                fresh, vrows = land_feat(ell, recv, feat_dtypes[ell])
                new_feat[ell] = self._update_buffer_guarded(
                    buffers["feat"][ell], fresh, pipe.smooth_feat, vrows)

        logits = h

        # -- loss ---------------------------------------------------------
        mask = data.train_mask.astype(logits.dtype)
        if self.model.multilabel:
            count_local = jnp.sum(mask) * self.model.num_classes
        else:
            count_local = jnp.sum(mask)
        total = jnp.maximum(backend.psum_scalar(count_local), 1.0)
        loss_fn = _bce_loss_and_grad if self.model.multilabel else _ce_loss_and_grad
        loss_local, dlogits = loss_fn(logits, data.labels, mask, total, backend)
        loss = backend.psum_scalar(loss_local) / total

        if not train:
            return loss, logits, None, None

        # -- manual backward (Alg. 1 lines 17–30) --------------------------
        grads = {}
        new_grad = [None] * L
        pending_grad = []        # fused mode: (ell, wire, dtype), one exchange
        combined = max_inner + P * topo.slot

        def ship_grad(ell, db, compute_dtype):
            """Encode one layer's (..., P, slot, pw) gradient send, exchange
            it (or queue it for the fused collective), decode, scatter to
            owner rows, and return the contribution the backward consumes
            this step (stale buffer in pipelined mode, fresh in vanilla)."""
            # dtype the scatter sees: the payload's own under the identity
            # codec, the compute dtype after any lossy wire
            dtype = db.dtype if codecs[ell].name == "f32" else compute_dtype
            wire = codecs[ell].encode(db)
            if faults is not None:
                wire = apply_faults(wire, faults, step_idx, BWD, ell,
                                    pids, guard)
            if fuse:
                # Deferred: the stale contribution comes from the t-1 (or
                # t-k) buffer; the fresh wire joins the packed buffer for
                # the single post-backward collective.
                pending_grad.append((ell, wire, dtype))
                return self._consume_buffer(buffers["grad"][ell])
            fresh_contrib, vrows = land_grad(ell, backend.exchange(wire),
                                             dtype)
            if pipe.stale:
                contrib = self._consume_buffer(buffers["grad"][ell])
                new_grad[ell] = self._update_buffer_guarded(
                    buffers["grad"][ell], fresh_contrib, pipe.smooth_grad,
                    vrows)
            else:
                contrib = fresh_contrib
                new_grad[ell] = buffers["grad"][ell]
            return contrib

        def land_grad(ell, recv, dtype):
            """Decode one received gradient wire and scatter it to owner
            rows. Under the guard, rows failing their checksum are zeroed
            before the scatter-add and every owner row any of them touched
            is marked invalid (a partial peer sum is wrong, not stale);
            the per-peer verdict lands in `grad_pv` (masked pad slots are
            exempt — they carry no data)."""
            if not guard:
                db_recv = codecs[ell].decode(recv, pw[ell], dtype)
                return scatter(db_recv, send_idx, send_mask), None
            db_recv, valid = codecs[ell].decode_checked(recv, pw[ell], dtype)
            inv = (~valid) & send_mask.astype(bool)
            grad_pv[ell] = ~jnp.any(inv, axis=-1)
            db_recv = jnp.where(valid[..., None], db_recv, 0)
            fresh_contrib = scatter(db_recv, send_idx, send_mask)
            return fresh_contrib, ~scatter_inv(inv, send_idx)

        j = dlogits
        for ell in reversed(range(L)):
            comb, z, u, dm = residuals[ell]
            du = j if ell == L - 1 else j * (u > 0).astype(j.dtype)
            grads[f"b{ell}"] = backend.psum(jnp.sum(du, axis=-2))
            if ell in sliced:
                # Sliced backward (transform-first, fout-wide exchange):
                # ship the PRE-w1 halo rows of dhw = Pᵀ·du back to their
                # owners and fold the (stale) owner contributions into the
                # inner dhw rows before the weight gradient and the w1ᵀ
                # application — scatter commutes with both by linearity, so
                # vanilla mode reproduces the unsliced step exactly.
                fin, fout = dims[ell]
                w = params[f"w{ell}"]
                w1 = w[:fin] if sage else w
                h_in = comb      # residual slot 0 = masked inner rows
                if not lead:
                    dhw = self.engine.spmm_t(tslice, du, combined)
                else:
                    dhw = jax.vmap(lambda ts, d: self.engine.spmm_t(
                        ts, d, combined))(tslice, du)
                db = dhw[..., max_inner:, :]
                db = db.reshape(db.shape[:-2] + (P, topo.slot, fout))
                contrib = ship_grad(ell, db, j.dtype)
                dhw_eff = dhw[..., :max_inner, :] + contrib
                gw = jnp.swapaxes(h_in, -1, -2) @ dhw_eff
                if sage:
                    gw = jnp.concatenate(
                        [gw, jnp.swapaxes(h_in, -1, -2) @ du], axis=-2)
                grads[f"w{ell}"] = backend.psum(gw)
                dh = dhw_eff @ w1.T
                if sage:
                    dh = dh + du @ w[fin:].T
                if dm is not None:
                    dh = dh * dm[..., :max_inner, :]
                j = dh           # owner contributions already folded in
                continue
            need_dcomb = ell > 0    # Alg. 1 stops the backward at layer 0
            if not lead:
                gw_local, dh_local, db = self._layer_backward(
                    tslice, params[f"w{ell}"], du, comb, z, dm, max_inner,
                    order=orders[ell], need_dcomb=need_dcomb)
            else:
                bwd = jax.vmap(
                    lambda ts, du_, comb_, z_, dm_, w_=params[f"w{ell}"],
                           o_=orders[ell]:
                    self._layer_backward(ts, w_, du_, comb_, z_, dm_,
                                         max_inner, order=o_,
                                         need_dcomb=need_dcomb),
                    in_axes=(0, 0, 0, 0 if z is not None else None,
                             0 if dm is not None else None))
                gw_local, dh_local, db = bwd(tslice, du, comb, z, dm)
            grads[f"w{ell}"] = backend.psum(gw_local)
            if ell == 0:
                new_grad[ell] = buffers["grad"][ell]
                break
            db = db.reshape(db.shape[:-2] + (P, topo.slot, dims[ell][0]))
            # -- boundary gradient communication ---------------------------
            j = dh_local + ship_grad(ell, db, j.dtype)

        if fuse and pending_grad:
            # ONE collective for all L-1 boundary-gradient sends (layer 0
            # sends nothing — Alg. 1 stops its backward at the first layer).
            recvs = fused_exchange_encoded(backend,
                                           [w_ for _, w_, _ in pending_grad])
            for (ell, _, dtype), recv in zip(pending_grad, recvs):
                # decode restores this layer's pre-pack dtype (see forward)
                fresh_contrib, vrows = land_grad(ell, recv, dtype)
                new_grad[ell] = self._update_buffer_guarded(
                    buffers["grad"][ell], fresh_contrib, pipe.smooth_grad,
                    vrows)

        new_buffers = {"feat": tuple(new_feat), "grad": tuple(new_grad)}
        if guard:
            # Consecutive-fallback counters per (direction, layer, peer):
            # a valid arrival resets to 0, a fallback increments. Layer 0
            # ships no backward gradient — always "valid". Partition-local
            # bookkeeping: no extra collective enters the step.
            ones = jnp.ones_like(feat_pv[0])
            gv = [pv if pv is not None else ones for pv in grad_pv]
            ok = jnp.stack([jnp.stack(feat_pv, axis=-2),
                            jnp.stack(gv, axis=-2)], axis=-3)
            new_buffers["es"] = jnp.where(ok, 0, buffers["es"] + 1)
        return loss, logits, grads, new_buffers

    # ---------------- split-phase step (ISSUE 6) ----------------

    def _step_impl_split(self, backend, topo: Topology, params, buffers,
                         data, key, train: bool, sp: SplitSpec):
        """`_step_impl` under the split-phase overlap schedule.

        Each layer's aggregation is cut into a *boundary* phase (the tile
        groups whose output rows feed the send gather: rows >= sp.row_tail
        forward, comb rows >= sp.col_tail transposed) and an *interior*
        phase. Per layer the boundary phase runs FIRST, the rows the next
        exchange needs are gathered from its tail, the collective is issued
        (or, in fused mode, the single packed collective once the last
        payload is ready), and only then does the interior phase — the bulk
        of the SpMM — execute: the collective is in flight behind it. The
        received halo is consumed strictly later (the next layer in vanilla
        mode; step t+1 in stale mode), so nothing waits on the wire.

        Numerics: each phase is bit-identical to the unsplit kernel on its
        own rows and the dense transform/activation/gather/scatter algebra
        is row-local, so reassembling [interior; boundary] reproduces the
        unsplit step exactly — the split only REPOSITIONS each collective
        between the two phase kernels (counts are unchanged; see
        trace_utils.expected_split_events). The fused engine runs through
        the composed phased path (its in-kernel epilogue would push
        unspecified out-of-phase rows through the dense weight), hence
        `layer_orders(..., fused=False)`.
        """
        L = self.model.num_layers
        dims = self.model.layer_dims()
        pipe = self.pipe
        P = topo.num_parts
        max_inner = topo.max_inner
        combined = max_inner + P * topo.slot
        rt, ct = sp.row_tail, sp.col_tail
        sage = self.model.kind == "sage"
        engine = self.engine

        tslice = self._agg_slice(topo)
        send_idx, send_mask = topo.send_idx, topo.send_mask
        lead = backend.lead_axis
        if lead:
            gather = jax.vmap(_gather_send)
            gather_tail = jax.vmap(partial(_gather_send_tail, row_tail=rt))
            scatter = jax.vmap(partial(_scatter_recv, max_inner=max_inner))
        else:
            gather = _gather_send
            gather_tail = partial(_gather_send_tail, row_tail=rt)
            scatter = partial(_scatter_recv, max_inner=max_inner)

        def spmm_phase(src, phase):
            if lead:
                return jax.vmap(lambda ts, s, p_=phase: engine.spmm_phased(
                    ts, s, max_inner, sp, p_))(tslice, src)
            return engine.spmm_phased(tslice, src, max_inner, sp, phase)

        def spmm_t_phase(src, phase):
            if lead:
                return jax.vmap(lambda ts, s, p_=phase: engine.spmm_t_phased(
                    ts, s, combined, sp, p_))(tslice, src)
            return engine.spmm_t_phased(tslice, src, combined, sp, phase)

        fuse = pipe.fused
        # fused=False: the split runs the composed (non-epilogue) path.
        orders = self.layer_orders(topo, train=train, fused=False)
        # Slicing never reaches the split (`_split_active` rejects it), but
        # every wire codec does: the phase split repositions the exchange,
        # the codec only changes what the exchange carries.
        codecs = self.wire_codecs(topo)
        residuals = []
        new_feat = [None] * L
        pending_feat = []
        feat_dtypes = []
        dropout_rate = self.model.dropout if train else 0.0

        # -- boundary feature communication helpers ------------------------
        # land_feat: per-layer schedule — exchange now, land into halo/buffer.
        # defer_feat: fused schedule — queue the payload, read stale state.
        # flush_feat: the ONE packed collective, payload order [0..L-1]
        # (identical to the unsplit fused pack, hence bit-identical).
        def land_feat(ell, send, send_dtype):
            fresh = codecs[ell].decode(backend.exchange(send), dims[ell][0],
                                       send_dtype)
            fresh = fresh.reshape(
                fresh.shape[:-3] + (P * topo.slot, dims[ell][0]))
            if pipe.stale:
                halo = self._consume_buffer(buffers["feat"][ell])
                new_feat[ell] = self._update_buffer(
                    buffers["feat"][ell], fresh, pipe.smooth_feat)
            else:
                halo = fresh
                new_feat[ell] = buffers["feat"][ell]
            return halo

        def defer_feat(ell, send, send_dtype):
            pending_feat.append(send)
            feat_dtypes.append(send_dtype)
            return self._consume_buffer(buffers["feat"][ell])

        def flush_feat():
            for ell, fresh in enumerate(
                    fused_exchange_encoded(backend, pending_feat)):
                fresh = codecs[ell].decode(fresh, dims[ell][0],
                                           feat_dtypes[ell])
                fresh = fresh.reshape(
                    fresh.shape[:-3] + (P * topo.slot, dims[ell][0]))
                new_feat[ell] = self._update_buffer(
                    buffers["feat"][ell], fresh, pipe.smooth_feat)

        def prep_send(ell, payload):
            return codecs[ell].encode(payload), payload.dtype

        # -- forward -------------------------------------------------------
        # Layer 0's payload is x itself — available before any compute, so
        # its exchange is issued (or queued) ahead of the loop. For L == 1
        # the fused pack is complete right away and flushes here too.
        h = data.x
        send, send_dtype = prep_send(0, gather(h, send_idx, send_mask))
        if fuse:
            halo = defer_feat(0, send, send_dtype)
            if L == 1:
                flush_feat()
        else:
            halo = land_feat(0, send, send_dtype)

        for ell in range(L):
            fin, fout = dims[ell]
            w, b = params[f"w{ell}"], params[f"b{ell}"]
            w1 = w[:fin] if sage else w
            if dropout_rate > 0.0:
                dkey = jax.random.fold_in(key, ell)
                dm = backend.dropout_mask(
                    dkey, dropout_rate, (combined, fin), P)
            else:
                dm = None
            comb = jnp.concatenate([h, halo], axis=-2)
            if dm is not None:
                comb = comb * dm
            order = orders[ell]
            src = comb @ w1 if order == "transform-first" else comb
            act = ell < L - 1

            # boundary phase: only rows [rt, max_inner) of raw_b are valid.
            raw_b = spmm_phase(src, "boundary")
            tail_b = raw_b[..., rt:, :]
            u_bt = tail_b + b if order == "transform-first" else tail_b @ w1 + b
            if sage:
                u_bt = u_bt + comb[..., rt:max_inner, :] @ w[fin:]
            h_bt = jax.nn.relu(u_bt) if act else u_bt

            # issue the NEXT layer's exchange between the phases: its
            # payload rows all live in the tail just produced.
            if ell + 1 < L:
                send, send_dtype = prep_send(
                    ell + 1, gather_tail(h_bt, send_idx, send_mask))
                if fuse:
                    halo = defer_feat(ell + 1, send, send_dtype)
                    if ell + 1 == L - 1:
                        flush_feat()   # last payload queued -> issue now
                else:
                    halo = land_feat(ell + 1, send, send_dtype)

            # interior phase overlaps the in-flight collective.
            raw_i = spmm_phase(src, "interior")
            head_i = raw_i[..., :rt, :]
            if order == "transform-first":
                u_ih = head_i + b
                z = None
            else:
                u_ih = head_i @ w1 + b
                z = (jnp.concatenate([head_i, tail_b], axis=-2)
                     if train else None)
            if sage:
                u_ih = u_ih + comb[..., :rt, :] @ w[fin:]
            u = jnp.concatenate([u_ih, u_bt], axis=-2)
            residuals.append((comb, z, u, dm))
            h = jnp.concatenate([jax.nn.relu(u_ih), h_bt], axis=-2) if act else u

        logits = h

        # -- loss ---------------------------------------------------------
        mask = data.train_mask.astype(logits.dtype)
        if self.model.multilabel:
            count_local = jnp.sum(mask) * self.model.num_classes
        else:
            count_local = jnp.sum(mask)
        total = jnp.maximum(backend.psum_scalar(count_local), 1.0)
        loss_fn = _bce_loss_and_grad if self.model.multilabel else _ce_loss_and_grad
        loss_local, dlogits = loss_fn(logits, data.labels, mask, total, backend)
        loss = backend.psum_scalar(loss_local) / total

        if not train:
            return loss, logits, None, None

        # -- manual backward ----------------------------------------------
        # Transposed mirror of the forward: the boundary phase of Pᵀ·δ
        # produces comb rows >= ct — a superset of the halo rows that form
        # the gradient send — so the exchange is issued (fused: flushed at
        # the LAST backward layer ell == 1) between the transpose phases.
        grads = {}
        new_grad = [None] * L
        pending_grad = []

        def flush_grad():
            recvs = fused_exchange_encoded(backend,
                                           [d for _, d, _ in pending_grad])
            for (ell, _, db_dtype), db_recv in zip(pending_grad, recvs):
                db_recv = codecs[ell].decode(db_recv, dims[ell][0], db_dtype)
                fresh_contrib = scatter(db_recv, send_idx, send_mask)
                new_grad[ell] = self._update_buffer(
                    buffers["grad"][ell], fresh_contrib, pipe.smooth_grad)

        j = dlogits
        for ell in reversed(range(L)):
            comb, z, u, dm = residuals[ell]
            fin, _ = dims[ell]
            w = params[f"w{ell}"]
            w1 = w[:fin] if sage else w
            du = j if ell == L - 1 else j * (u > 0).astype(j.dtype)
            grads[f"b{ell}"] = backend.psum(jnp.sum(du, axis=-2))
            if ell == 0:
                # Alg. 1 stops the backward at layer 0: weight grad only,
                # no Pᵀ pass under aggregate-first — reuse the unsplit
                # per-layer backward (need_dcomb=False).
                if not lead:
                    gw_local, _, _ = self._layer_backward(
                        tslice, w, du, comb, z, dm, max_inner,
                        order=orders[0], need_dcomb=False)
                else:
                    bwd = jax.vmap(
                        lambda ts, du_, comb_, z_, dm_, w_=w:
                        self._layer_backward(ts, w_, du_, comb_, z_, dm_,
                                             max_inner, order=orders[0],
                                             need_dcomb=False),
                        in_axes=(0, 0, 0, 0 if z is not None else None,
                                 0 if dm is not None else None))
                    gw_local, _, _ = bwd(tslice, du, comb, z, dm)
                grads[f"w{ell}"] = backend.psum(gw_local)
                new_grad[0] = buffers["grad"][0]
                break

            order = orders[ell]
            # ONE dense op ahead of both phases under aggregate-first
            # (δhw = du·w1ᵀ); transform-first transposes du raw and applies
            # w1ᵀ per phase (the pre-w1 pieces also feed the weight grad).
            src_t = du if order == "transform-first" else du @ w1.T
            if sage:
                sage_t = du @ w[fin:].T

            # boundary phase: comb rows [ct, combined) valid.
            raw_tb = spmm_t_phase(src_t, "boundary")
            dhw_b = raw_tb[..., ct:, :]
            d_bt = dhw_b @ w1.T if order == "transform-first" else dhw_b
            if sage:
                d_bt = d_bt.at[..., :max_inner - ct, :].add(
                    sage_t[..., ct:, :])
            if dm is not None:
                d_bt = d_bt * dm[..., ct:, :]

            # gradient send = the halo rows of the boundary phase; issue
            # the exchange before the interior phase runs.
            db = d_bt[..., max_inner - ct:, :]
            db = db.reshape(db.shape[:-2] + (P, topo.slot, fin))
            db_dtype = db.dtype if codecs[ell].name == "f32" else j.dtype
            wire = codecs[ell].encode(db)
            if fuse:
                pending_grad.append((ell, wire, db_dtype))
                contrib = self._consume_buffer(buffers["grad"][ell])
                if ell == 1:
                    flush_grad()   # last backward payload -> issue now
            else:
                db_recv = codecs[ell].decode(backend.exchange(wire), fin,
                                             db_dtype)
                fresh_contrib = scatter(db_recv, send_idx, send_mask)
                if pipe.stale:
                    contrib = self._consume_buffer(buffers["grad"][ell])
                    new_grad[ell] = self._update_buffer(
                        buffers["grad"][ell], fresh_contrib, pipe.smooth_grad)
                else:
                    contrib = fresh_contrib
                    new_grad[ell] = buffers["grad"][ell]

            # interior phase overlaps the in-flight gradient exchange.
            raw_ti = spmm_t_phase(src_t, "interior")
            dhw_i = raw_ti[..., :ct, :]
            if order == "transform-first":
                d_ih = dhw_i @ w1.T
                dhw_full = jnp.concatenate([dhw_i, dhw_b], axis=-2)
                gw = jnp.swapaxes(comb, -1, -2) @ dhw_full
            else:
                d_ih = dhw_i
                gw = jnp.swapaxes(z, -1, -2) @ du
            if sage:
                gw = jnp.concatenate(
                    [gw, jnp.swapaxes(comb[..., :max_inner, :], -1, -2) @ du],
                    axis=-2)
                d_ih = d_ih + sage_t[..., :ct, :]
            if dm is not None:
                d_ih = d_ih * dm[..., :ct, :]
            grads[f"w{ell}"] = backend.psum(gw)
            j = jnp.concatenate(
                [d_ih, d_bt[..., :max_inner - ct, :]], axis=-2) + contrib

        new_buffers = {"feat": tuple(new_feat), "grad": tuple(new_grad)}
        return loss, logits, grads, new_buffers

    # ---------------- public API ----------------

    def train_step(self, topo: Topology, params, buffers, data: ShardedData,
                   key: jax.Array, step_idx=None, faults=None):
        """Sim-backend step over (P, ...) arrays. Returns
        (loss, grads, new_buffers, logits). `faults` (compiled
        FaultTables) + `step_idx` inject that step's exchange faults."""
        backend = SimBackend()
        loss, logits, grads, new_buffers = self._step_impl(
            backend, topo, params, buffers, data, key, train=True,
            step_idx=step_idx, faults=faults)
        return loss, grads, new_buffers, logits

    def forward(self, topo: Topology, params, data: ShardedData):
        """Inference forward with synchronous (fresh) exchange — used for
        evaluation, like the paper's test-time behaviour."""
        fresh_self = dataclasses.replace(self, pipe=PipeConfig.vanilla())
        backend = SimBackend()
        buffers = fresh_self.init_buffers(topo)
        loss, logits, _, _ = fresh_self._step_impl(
            backend, topo, params, buffers, data, jax.random.PRNGKey(0),
            train=False)
        return loss, logits

    # -- SPMD (shard_map) construction ---------------------------------

    def make_spmd_step(self, mesh, topo: Topology, axis_name="parts",
                       train: bool = True):
        """Build a jitted shard_map step over a 1-D partition mesh axis.

        Arrays with leading partition axis are sharded over `axis_name`;
        params are replicated; the returned function has the same signature
        as `train_step` (plus data), operating on global arrays.

        The partition count is decoupled from the device count: with
        P = num_parts a multiple of the mesh size, each device hosts
        n_local = P // n_devices co-resident partitions (device-major:
        partition p on device p // n_local) and the boundary exchange runs
        hierarchically (`hierarchical_exchange`).
        """
        from jax.sharding import PartitionSpec as PS

        pspec = PS(axis_name)
        axes = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
        n_devices = 1
        for a in axes:
            n_devices *= mesh.shape[a]
        if topo.num_parts % n_devices:
            raise ValueError(
                f"num_parts={topo.num_parts} must be a multiple of the mesh "
                f"size {n_devices} (axes {axes})")
        n_local = topo.num_parts // n_devices
        backend = SpmdBackend(axis_name, n_local=n_local)

        kq = self.pipe.staleness_steps

        def per_device(topo_l, params, buffers, data, key, step_idx, faults):
            # shard_map leaves a leading axis of size n_local = P/num_devices.
            # n_local == 1: squeeze it and run the per-partition body.
            # n_local  > 1: keep it — _step_impl treats it exactly like the
            # sim backend's partition axis (vmapped layer math), with the
            # collectives local-axis-aware. Buffer queues (k-step staleness)
            # carry the partition axis at position 1 in both cases; the "es"
            # counters (guard_exchange) never grow a queue axis.
            if n_local == 1:
                topo1 = jax.tree.map(lambda x: x[0], tuple(topo_l))
                bsq = (lambda x: x[:, 0]) if kq > 1 else (lambda x: x[0])
                bufs1 = {k: jax.tree.map(
                    (lambda x: x[0]) if k == "es" else bsq, v)
                    for k, v in buffers.items()}
                data1 = jax.tree.map(lambda x: x[0], tuple(data))
                loss, logits, grads, newb = self._step_impl(
                    backend, Topology(*topo1), params, bufs1,
                    ShardedData(*data1), key, train,
                    step_idx=step_idx, faults=faults)
                logits = logits[None]
                bex = (lambda x: x[:, None]) if kq > 1 else (lambda x: x[None])
                if newb is not None:
                    newb = {k: jax.tree.map(
                        (lambda x: x[None]) if k == "es" else bex, v)
                        for k, v in newb.items()}
            else:
                loss, logits, grads, newb = self._step_impl(
                    backend, Topology(*topo_l), params, buffers,
                    ShardedData(*data), key, train,
                    step_idx=step_idx, faults=faults)
            return loss, logits, grads, newb

        def step(topo_g, params, buffers, data, key, step_idx=None,
                 faults=None):
            bspec = PS(None, axis_name) if kq > 1 else pspec

            def buf_specs(bufs):
                # "es" counters carry the partition axis first (no queue
                # axis), every other buffer follows the k-aware bspec
                return {k: jax.tree.map(
                    lambda _: (pspec if k == "es" else bspec), v)
                    for k, v in bufs.items()}

            f = _shard_map(
                per_device, mesh=mesh,
                in_specs=(jax.tree.map(lambda _: pspec, tuple(topo_g)),
                          jax.tree.map(lambda _: PS(), params),
                          buf_specs(buffers),
                          jax.tree.map(lambda _: pspec, tuple(data)),
                          PS(), PS(), PS()),
                out_specs=(PS(), pspec,
                           jax.tree.map(lambda _: PS(), params) if train else PS(),
                           buf_specs(buffers) if train else PS()))
            return f(tuple(topo_g), params, buffers, tuple(data), key,
                     step_idx, faults)

        return jax.jit(step)
