"""Elastic training runtime: survive device loss by remapping partitions.

PipeGCN's bounded-staleness theorems price every boundary exchange in
*iterations of staleness*, not in availability — so a lost device is not
a fatal event but an extreme staleness event: the partitions it hosted
are merely VERY stale on the survivors. This module turns that
observation into the availability story:

1. :class:`ElasticPlan` — given the survivor set, remap the lost
   device's ``n_local`` partitions onto the survivors. The device-major
   layout (partition p lives on device ``p // n_local``) is preserved by
   APPENDING padded idle partitions at the end of the flat partition
   axis when the real count does not divide the survivor count. Real
   partitions keep their ids and their order, so ``edge_col`` halo
   offsets, ``send_idx`` peer ordering, and compiled fault tables stay
   valid; the pads are masked out of everything (all-False send/inner
   masks, zero edges and tiles), so they are idle slots, not
   participants. Re-sharding `Topology`/`ShardedData`/pipeline buffers
   is therefore pure array padding (:func:`remap_topology`,
   :func:`remap_data`, :func:`remap_buffers`) — the partitioned graph is
   never rebuilt, and :meth:`ElasticPlan.device_view` reuses
   ``graph_pipeline.to_local_layout`` for the physical per-device view.
2. :func:`detect_device_loss` — detection rides the guarded exchange's
   per-exchange ``es`` counters (PR 9): a device is declared down once
   EVERY forward exchange out of it has fallen back ``detect_after``
   consecutive steps on every off-device destination. Scattered faults
   never blanket a whole device row, so they keep degrading gracefully
   under the ordinary staleness budget.
3. Staleness-escalated warm recovery — buffer rows restored from a
   checkpoint for remapped partitions are marked with ``warm_staleness``
   consecutive-fallback counts (:func:`warm_mark`): stale-but-usable,
   and ``PipeConfig.max_staleness`` bounds the warmup window (an
   exchange that keeps failing after recovery starts its countdown from
   ``warm_staleness``, not zero). Mid-run recovery and a fresh launch at
   the smaller device count route through the SAME
   restore → remap → mark path, which is what makes post-remap training
   bitwise identical between the two (the gate in
   ``tests/test_elastic.py``).
4. Rejoin — at a checkpoint boundary the trainer unmaps the live state
   back to the flat layout (:func:`unmap_buffers` strips the pads) and
   resumes on the original device count, warm-marking the partitions
   that moved home.
"""
from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.faults import FWD, FaultTables, StalenessExceededError
from repro.core.pipegcn import ShardedData, Topology


class DeviceLossError(StalenessExceededError):
    """A whole device's exchanges went stale: staleness escalated to loss.

    Subclasses :class:`StalenessExceededError` because device loss IS the
    extreme case of the staleness contract breaking — but carries enough
    structure (`device`, the ORIGINAL device id; `survivors`; the
    detection `epoch`) for the trainer to recover instead of aborting.
    """

    def __init__(self, message: str, device: int, survivors, epoch: int):
        super().__init__(message)
        self.device = int(device)
        self.survivors = tuple(survivors)
        self.epoch = int(epoch)


@dataclasses.dataclass(frozen=True)
class ElasticConfig:
    """Elastic-runtime policy knobs (`train_pipegcn(elastic=...)`).

    ``detect_after`` — consecutive whole-device fallback steps before a
    device is declared lost; ``warm_staleness`` — the es count stamped on
    remapped exchanges at recovery (must stay BELOW ``detect_after`` or a
    freshly recovered run would re-detect its own warm marks);
    ``max_recoveries`` — recovery budget before the loss is re-raised;
    ``rejoin`` — scale back up at a checkpoint boundary once the lost
    device is healthy again; ``parts_per_device`` — device granularity of
    the sim backend (mesh runs infer it from the mesh size).
    """

    enabled: bool = True
    detect_after: int = 2
    warm_staleness: int = 1
    max_recoveries: int = 2
    rejoin: bool = True
    parts_per_device: int = 1

    def __post_init__(self):
        if self.detect_after < 1:
            raise ValueError(
                f"detect_after must be >= 1, got {self.detect_after}")
        if not 0 <= self.warm_staleness < self.detect_after:
            raise ValueError(
                f"warm_staleness={self.warm_staleness} must be in "
                f"[0, detect_after={self.detect_after}) — a recovered run "
                "must not re-detect its own warm marks")
        if self.max_recoveries < 0:
            raise ValueError(
                f"max_recoveries must be >= 0, got {self.max_recoveries}")
        if self.parts_per_device < 1:
            raise ValueError(
                f"parts_per_device must be >= 1, got {self.parts_per_device}")


@dataclasses.dataclass(frozen=True)
class ElasticPlan:
    """Survivor remap of ``num_parts`` device-major partitions.

    The original layout has ``orig_devices`` devices hosting
    ``num_parts // orig_devices`` partitions each; ``survivors`` names
    the original device ids still alive. The remapped layout keeps the
    flat partition order and pads it to ``padded_parts`` (the smallest
    multiple of ``len(survivors)`` ≥ ``num_parts``), so survivor number
    ``d`` (positional) hosts padded partitions
    ``[d*n_local, (d+1)*n_local)`` — pads are idle slots masked out of
    the exchange. A plan with all devices surviving is the identity
    (``pad_parts == 0`` and every remap function returns its input).
    """

    num_parts: int
    orig_devices: int
    survivors: tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "survivors",
                           tuple(sorted(set(int(s) for s in self.survivors))))
        if self.orig_devices < 1 or self.num_parts % self.orig_devices:
            raise ValueError(
                f"num_parts={self.num_parts} is not a multiple of "
                f"orig_devices={self.orig_devices}")
        # reuse the canonical layout validation (device-major contract)
        from repro.launch.mesh import partition_layout
        partition_layout(self.num_parts, self.num_parts // self.orig_devices,
                         num_devices=self.orig_devices)
        if not self.survivors:
            raise ValueError("survivor set is empty — nothing to remap onto")
        if any(not 0 <= s < self.orig_devices for s in self.survivors):
            raise ValueError(
                f"survivors {self.survivors} out of range for "
                f"orig_devices={self.orig_devices}")

    # ---------------- derived layout ----------------

    @property
    def orig_n_local(self) -> int:
        """Partitions per device in the original layout."""
        return self.num_parts // self.orig_devices

    @property
    def n_devices(self) -> int:
        """Survivor count (the remapped mesh size)."""
        return len(self.survivors)

    @property
    def n_local(self) -> int:
        """Partitions per survivor (real + pad) in the remapped layout."""
        return math.ceil(self.num_parts / self.n_devices)

    @property
    def padded_parts(self) -> int:
        """Size of the remapped flat partition axis (pads appended)."""
        return self.n_devices * self.n_local

    @property
    def pad_parts(self) -> int:
        """Number of appended idle pad partitions."""
        return self.padded_parts - self.num_parts

    @property
    def lost(self) -> tuple[int, ...]:
        """Original device ids NOT in the survivor set."""
        return tuple(d for d in range(self.orig_devices)
                     if d not in self.survivors)

    def assignment(self) -> tuple[tuple[int, ...], ...]:
        """Real partition ids hosted by each survivor (positional), in
        device-major order; pads are omitted."""
        return tuple(
            tuple(p for p in range(d * self.n_local, (d + 1) * self.n_local)
                  if p < self.num_parts)
            for d in range(self.n_devices))

    def moved_partitions(self) -> frozenset:
        """Real partitions whose hosting device changed under the plan —
        the rows whose restored buffer state is warm-marked."""
        return frozenset(
            p for p in range(self.num_parts)
            if self.survivors[p // self.n_local] != p // self.orig_n_local)

    def device_view(self, tree, axis: int = 0):
        """Physical (n_devices, n_local, …) per-survivor view of a
        remapped flat-partition pytree (via graph_pipeline.to_local_layout)."""
        from repro.data.graph_pipeline import to_local_layout
        return to_local_layout(tree, self.n_local, axis=axis)


# ---------------- remap / unmap (pure padding) ----------------


def _pad_axis(x, axis: int, extra: int):
    if extra == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, extra)
    return jnp.pad(x, widths)


def remap_topology(topo: Topology, plan: ElasticPlan) -> Topology:
    """Pad a Topology to the plan's survivor layout.

    Leading partition axis and the ``send_idx``/``send_mask`` peer axis
    grow to ``padded_parts``; pad partitions carry zero edges/tiles and
    all-False masks, so they aggregate nothing, send nothing valid, and
    (`inner_mask=False`) contribute nothing to loss or eval.
    """
    if topo.num_parts != plan.num_parts:
        raise ValueError(
            f"topology has {topo.num_parts} partitions, plan remaps "
            f"{plan.num_parts}")
    pad = plan.pad_parts
    if pad == 0:
        return topo

    def lead(x):
        return None if x is None else _pad_axis(x, 0, pad)

    return topo._replace(
        edge_row=lead(topo.edge_row), edge_col=lead(topo.edge_col),
        edge_w=lead(topo.edge_w),
        send_idx=_pad_axis(_pad_axis(topo.send_idx, 0, pad), 1, pad),
        send_mask=_pad_axis(_pad_axis(topo.send_mask, 0, pad), 1, pad),
        inner_mask=lead(topo.inner_mask),
        tile_rows=lead(topo.tile_rows), tile_cols=lead(topo.tile_cols),
        tile_vals=lead(topo.tile_vals), tile_t_out=lead(topo.tile_t_out),
        tile_t_in=lead(topo.tile_t_in), tile_t_perm=lead(topo.tile_t_perm))


def unmap_topology(topo: Topology, plan: ElasticPlan) -> Topology:
    """Inverse of :func:`remap_topology`: strip the pad partitions."""
    p = plan.num_parts
    if topo.num_parts == p:
        return topo

    def lead(x):
        return None if x is None else x[:p]

    return topo._replace(
        edge_row=lead(topo.edge_row), edge_col=lead(topo.edge_col),
        edge_w=lead(topo.edge_w),
        send_idx=topo.send_idx[:p, :p], send_mask=topo.send_mask[:p, :p],
        inner_mask=lead(topo.inner_mask),
        tile_rows=lead(topo.tile_rows), tile_cols=lead(topo.tile_cols),
        tile_vals=lead(topo.tile_vals), tile_t_out=lead(topo.tile_t_out),
        tile_t_in=lead(topo.tile_t_in), tile_t_perm=lead(topo.tile_t_perm))


def remap_data(data: ShardedData, plan: ElasticPlan) -> ShardedData:
    """Pad every leading-partition data array with zero rows (labels 0,
    masks False) — pads never enter loss or metrics."""
    pad = plan.pad_parts
    if pad == 0:
        return data
    return jax.tree.map(lambda a: _pad_axis(a, 0, pad), data)


def unmap_data(data: ShardedData, plan: ElasticPlan) -> ShardedData:
    """Inverse of :func:`remap_data`: strip the pad partitions."""
    if data.x.shape[0] == plan.num_parts:
        return data
    return jax.tree.map(lambda a: a[:plan.num_parts], data)


def remap_buffers(buffers: dict, plan: ElasticPlan) -> dict:
    """Pad the pipeline staleness state to the survivor layout.

    Feature buffers ``(k?, P, P*slot, w)`` grow on BOTH the partition
    axis and the peer-major halo axis (pad peers append ``pad*slot``
    zero rows at the end — real halo offsets are untouched); gradient
    buffers ``(k?, P, max_inner, w)`` grow on the partition axis; the
    ``es`` counters ``(P, 2, L, P)`` grow on both partition axes.
    """
    pad = plan.pad_parts
    if pad == 0:
        return buffers

    def feat(x):
        slot = x.shape[-2] // plan.num_parts
        x = _pad_axis(x, x.ndim - 3, pad)
        return _pad_axis(x, x.ndim - 2, pad * slot)

    def grad(x):
        return _pad_axis(x, x.ndim - 3, pad)

    out = {"feat": tuple(feat(b) for b in buffers["feat"]),
           "grad": tuple(grad(b) for b in buffers["grad"])}
    if "es" in buffers:
        out["es"] = _pad_axis(_pad_axis(buffers["es"], 0, pad), 3, pad)
    return out


def unmap_buffers(buffers: dict, plan: ElasticPlan) -> dict:
    """Inverse of :func:`remap_buffers`: strip pad partitions and pad
    halo rows, restoring the flat original layout."""
    p = plan.num_parts
    if buffers["feat"] and buffers["feat"][0].shape[-3] == p:
        return buffers

    def feat(x):
        slot = x.shape[-2] // plan.padded_parts
        return x[(Ellipsis, slice(0, p), slice(0, p * slot), slice(None))]

    def grad(x):
        return x[(Ellipsis, slice(0, p), slice(None), slice(None))]

    out = {"feat": tuple(feat(b) for b in buffers["feat"]),
           "grad": tuple(grad(b) for b in buffers["grad"])}
    if "es" in buffers:
        out["es"] = buffers["es"][:p, :, :, :p]
    return out


def warm_mark(buffers: dict, moved, warm: int, num_real: int) -> dict:
    """Escalate the es counters of every exchange touching a ``moved``
    partition to at least ``warm`` consecutive fallbacks.

    The restored rows of a remapped partition are checkpoint-old —
    stale-but-usable, exactly what a ``warm``-deep fallback streak means
    to the guarded exchange: consumers keep using them, and
    ``max_staleness`` bounds how much longer they may keep failing
    before the run aborts. Pads (ids ≥ ``num_real``) are never marked.
    """
    if warm <= 0 or not moved or "es" not in buffers:
        return buffers
    es = buffers["es"]
    lead = es.shape[0]
    m = np.zeros((lead,), bool)
    m[list(moved)] = True
    real = np.zeros((lead,), bool)
    real[:num_real] = True
    touch = (m[:, None] | m[None, :]) & real[:, None] & real[None, :]
    touch = jnp.asarray(touch[:, None, None, :])           # (dst, 1, 1, src)
    stamp = jnp.where(touch, jnp.asarray(warm, es.dtype), 0)
    return {**buffers, "es": jnp.maximum(es, stamp)}


def mask_pad_faults(tables: FaultTables, num_real: int) -> FaultTables:
    """Zero every compiled fault site whose source or destination is a
    pad partition (id ≥ ``num_real``) — pads ship all-zero masked
    payloads, and faulting them would leak spurious es counts into the
    staleness bookkeeping of a remapped run."""

    def cut(t):
        return (t.at[..., num_real:, :].set(False)
                 .at[..., :, num_real:].set(False))

    return tables._replace(drop=cut(tables.drop), corrupt=cut(tables.corrupt))


def detect_device_loss(es, n_local: int, num_real: int,
                       threshold: int = 2) -> int | None:
    """Scan one step's es counters for a whole-device outage.

    ``es`` is the (padded) ``(P, 2, L, P)`` counter array, ``n_local``
    the partitions-per-device of the CURRENT layout, ``num_real`` the
    real (unpadded) partition count. Returns the positional index of the
    first device whose every forward exchange to every off-device real
    destination has ≥ ``threshold`` consecutive fallbacks, else None —
    the min over the device's whole (dst, layer, src) block, so a
    scattered fault plan (which leaves some exchange healthy) never
    trips it.
    """
    es = np.asarray(es)
    n_dev = es.shape[0] // n_local
    for d in range(n_dev):
        srcs = [p for p in range(d * n_local, (d + 1) * n_local)
                if p < num_real]
        dsts = [q for q in range(num_real) if q // n_local != d]
        if not srcs or not dsts:
            continue
        sub = es[np.ix_(dsts)][:, FWD][..., srcs]      # (dst, L, src)
        if sub.size and int(sub.min()) >= threshold:
            return d
    return None
