"""Full-graph training driver: PipeGCN step + optimizer + eval loop.

This is the reference trainer used by examples, accuracy benchmarks, and the
convergence experiments (paper Tab. 4 / Fig. 4/9 analogues).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax

from repro.core.config import ModelConfig, PipeConfig
from repro.core.pipegcn import PipeGCN, Topology
from repro.optim import Optimizer, adam


@dataclasses.dataclass
class TrainResult:
    """Outcome of one `train_pipegcn` run: the eval-metric trajectory
    (`history` lists loss / val_acc / test_acc / epoch), the final
    parameters, the last metric dict, and the wall-clock epoch rate."""

    history: dict          # lists: loss, val_acc, test_acc, epoch_time
    params: dict
    final_metrics: dict
    epochs_per_sec: float


def make_jitted_train_step(model: PipeGCN, opt: Optimizer):
    """(topo, params, opt_state, buffers, data, key)
    -> (loss, params, opt_state, buffers).

    Topology and data are traced arguments (not closure constants) so XLA
    does not constant-fold the graph structure into the executable."""

    def step(topo, params, opt_state, buffers, data, key):
        loss, grads, new_buffers, _ = model.train_step(topo, params, buffers,
                                                       data, key)
        new_params, new_opt_state = opt.apply(params, grads, opt_state)
        return loss, new_params, new_opt_state, new_buffers

    return jax.jit(step, donate_argnums=(3,))


def make_spmd_train_step(model: PipeGCN, opt: Optimizer, mesh, topo: Topology,
                         axis_name: str = "parts"):
    """`make_jitted_train_step` analogue on a device mesh: the PipeGCN step
    runs under shard_map over `axis_name` (any partitions-per-device ratio,
    see `PipeGCN.make_spmd_step`); the optimizer update applies to the
    replicated grads. Same signature/returns as the sim-backend step."""
    spmd_step = model.make_spmd_step(mesh, topo, axis_name, train=True)

    def step(topo, params, opt_state, buffers, data, key):
        loss, _, grads, new_buffers = spmd_step(topo, params, buffers, data,
                                                key)
        new_params, new_opt_state = opt.apply(params, grads, opt_state)
        return loss, new_params, new_opt_state, new_buffers

    return jax.jit(step, donate_argnums=(3,))


def train_pipegcn(pipeline, model_cfg: ModelConfig,
                  pipe_cfg: PipeConfig, epochs: int, lr: float = 0.01,
                  seed: int = 0, eval_every: int = 10,
                  log: Callable[[str], None] | None = None,
                  mesh=None, axis_name: str = "parts") -> TrainResult:
    """Reference training loop. With `mesh=None` the step runs on the sim
    backend (single device, partitions vmapped); passing a mesh runs the
    same model under shard_map — partitions need only be a multiple of the
    mesh size (multi-partition-per-device SPMD). Eval stays on the sim
    backend either way (global arrays round-trip between backends)."""
    split = pipeline.split_spec() if hasattr(pipeline, "split_spec") else None
    model = PipeGCN(model_cfg, pipe_cfg, split=split)
    topo = pipeline.topo
    # Fail fast (before tracing) if the selected aggregation engine needs
    # Topology fields the pipeline was not built with.
    model._agg_slice(topo)
    # ... and if the config EXPLICITLY declares a node layout that is not
    # the one the pipeline was actually built with. The layout lives in
    # the data, so a drifting ModelConfig.layout must be loud — but
    # "auto" means "defer to the pipeline" here: any built layout is
    # numerically valid under any engine (the LAYOUT parity cells prove
    # coo-on-rcm exact), so auto must not reject a shared pipeline.
    have = getattr(pipeline, "layout", "natural")
    if model_cfg.layout != "auto" and model_cfg.layout != have:
        raise ValueError(
            f"ModelConfig.layout={model_cfg.layout!r} but the pipeline "
            f"was built with layout={have!r}; pass the same layout to "
            "GraphDataPipeline.build (or use layout=\"auto\")")
    if log:
        from repro.core.trace_utils import expected_boundary_collectives
        n_coll = expected_boundary_collectives(model_cfg.num_layers,
                                               pipe_cfg.fused, train=True)
        sched = "fused-deferred" if pipe_cfg.fused else "per-layer"
        where = (f"{n_coll} boundary collectives/train step"
                 if mesh is not None else
                 f"{n_coll} boundary exchanges/train step, local on the "
                 "sim backend")
        log(f"comm schedule: {sched} ({where}, L={model_cfg.num_layers})")
        sp = model._split_active()
        if sp is not None:
            log(f"overlap schedule: split-phase (fwd boundary "
                f"{sp.fwd_bnd_tiles} tiles @ rows>={sp.row_tail}, "
                f"transpose boundary {sp.t_bnd_tiles} tiles @ "
                f"cols>={sp.col_tail}; collectives issued between phases)")
        else:
            why = ("disabled" if pipe_cfg.overlap == "none" else
                   "no feasible split" if split is None else
                   f"engine {model_cfg.agg!r} has no tile phases")
            log(f"overlap schedule: unsplit ({why})")
        # under the split the fused epilogue is bypassed, so report the
        # orders the split step actually resolves (fused=False pricing)
        orders = model.layer_orders(topo, train=True,
                                    fused=False if sp is not None else None)
        how = ("static FLOP model" if model_cfg.matmul_order == "auto"
               else "forced")
        log(f"matmul order ({how}, agg={model_cfg.agg}): "
            + " ".join(f"L{i}:{'PH.W' if o == 'aggregate-first' else 'P.HW'}"
                       for i, o in enumerate(orders)))
        if pipe_cfg.wire != "f32" or pipe_cfg.slice_boundary:
            codecs = model.wire_codecs(topo)
            widths = model.payload_widths(topo)
            sl = model.sliced_layers(topo)
            log("boundary wire: " + " ".join(
                f"L{i}:{c.name}x{w}{'s' if i in sl else ''}"
                for i, (c, w) in enumerate(zip(codecs, widths)))
                + (" (s = sliced to the post-transform width)" if sl else ""))
        layout = getattr(pipeline, "layout", "natural")
        if topo.tile_rows is not None:
            from repro.analysis.cost import graph_layout_report
            rep = graph_layout_report(pipeline.pg)
            log(f"graph layout: {layout} ({rep['tiles']} nonempty tiles, "
                f"bandwidth {rep['bandwidth']}, "
                f"{rep['halo_runs']} halo row runs)")
        else:
            log(f"graph layout: {layout}")
    params = model.init_params(jax.random.PRNGKey(seed))
    opt = adam(lr)
    opt_state = opt.init(params)
    buffers = model.init_buffers(topo)
    step = (make_spmd_train_step(model, opt, mesh, topo, axis_name)
            if mesh is not None else make_jitted_train_step(model, opt))
    fwd = jax.jit(lambda t, p, d: model.forward(t, p, d)[1])

    history = {"loss": [], "val_acc": [], "test_acc": [], "epoch": []}
    key = jax.random.PRNGKey(seed + 1)
    t0 = time.perf_counter()
    for epoch in range(epochs):
        key, sub = jax.random.split(key)
        loss, params, opt_state, buffers = step(topo, params, opt_state,
                                                buffers, pipeline.train_data,
                                                sub)
        if epoch % eval_every == 0 or epoch == epochs - 1:
            logits = fwd(topo, params, pipeline.val_data)
            m = pipeline.metric(logits)
            history["loss"].append(float(loss))
            history["val_acc"].append(m["val"])
            history["test_acc"].append(m["test"])
            history["epoch"].append(epoch)
            if log:
                log(f"epoch {epoch:5d} loss {float(loss):.4f} "
                    f"val {m['val']:.4f} test {m['test']:.4f}")
    dt = time.perf_counter() - t0
    final = pipeline.metric(fwd(topo, params, pipeline.val_data))
    return TrainResult(history=history, params=params, final_metrics=final,
                       epochs_per_sec=epochs / dt)
