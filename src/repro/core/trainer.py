"""Full-graph training driver: PipeGCN step + optimizer + eval loop.

This is the reference trainer used by examples, accuracy benchmarks, and the
convergence experiments (paper Tab. 4 / Fig. 4/9 analogues).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.config import ModelConfig, PipeConfig
from repro.core.faults import FaultPlan, StalenessExceededError
from repro.core.health import (HealthConfig, TrainingAnomalyError,
                               health_check, tree_select)
from repro.core.pipegcn import PipeGCN, Topology
from repro.optim import Optimizer, adam


@dataclasses.dataclass
class TrainResult:
    """Outcome of one `train_pipegcn` run: the eval-metric trajectory
    (`history` lists loss / val_acc / test_acc / epoch), the final
    parameters, the last metric dict, the wall-clock epoch rate, the
    health/guard anomaly counters (skipped_steps, max_consecutive,
    exchange_fallbacks, max_effective_staleness — the latter two only
    under `guard_exchange`), and the checkpoint step the run resumed
    from (None for a fresh run)."""

    history: dict          # lists: loss, val_acc, test_acc, epoch_time
    params: dict
    final_metrics: dict
    epochs_per_sec: float
    anomalies: dict = dataclasses.field(default_factory=dict)
    resumed_from: int | None = None


def make_jitted_train_step(model: PipeGCN, opt: Optimizer,
                           health: HealthConfig | None = None):
    """(topo, params, opt_state, buffers, data, key[, step_idx, faults])
    -> (loss, params, opt_state, buffers[, report]).

    Topology and data are traced arguments (not closure constants) so XLA
    does not constant-fold the graph structure into the executable.

    With `health` (an enabled HealthConfig) the step health-checks the
    update (repro.core.health) and ROLLS BACK in-graph: a non-finite /
    out-of-bound step returns the previous params/opt_state/buffers
    bitwise (select semantics) plus a fifth element, the
    ``{"ok", "grad_norm"}`` report. `step_idx` + `faults` (compiled
    FaultTables) inject that step's exchange faults; both default to None
    which traces the historical fault-free step."""
    guarded = health is not None and health.enabled
    limit = health.grad_norm_limit if guarded else None

    def step(topo, params, opt_state, buffers, data, key, step_idx=None,
             faults=None):
        loss, grads, new_buffers, _ = model.train_step(
            topo, params, buffers, data, key, step_idx=step_idx,
            faults=faults)
        new_params, new_opt_state = opt.apply(params, grads, opt_state)
        if not guarded:
            return loss, new_params, new_opt_state, new_buffers
        rep = health_check(loss, grads, new_buffers, grad_norm_limit=limit)
        ok = rep["ok"]
        new_params = tree_select(ok, new_params, params)
        new_opt_state = tree_select(ok, new_opt_state, opt_state)
        new_buffers = tree_select(ok, new_buffers, buffers)
        return loss, new_params, new_opt_state, new_buffers, rep

    return jax.jit(step, donate_argnums=(3,))


def make_spmd_train_step(model: PipeGCN, opt: Optimizer, mesh, topo: Topology,
                         axis_name: str = "parts",
                         health: HealthConfig | None = None):
    """`make_jitted_train_step` analogue on a device mesh: the PipeGCN step
    runs under shard_map over `axis_name` (any partitions-per-device ratio,
    see `PipeGCN.make_spmd_step`); the optimizer update applies to the
    replicated grads. Same signature/returns as the sim-backend step
    (health rollback and fault injection included)."""
    spmd_step = model.make_spmd_step(mesh, topo, axis_name, train=True)
    guarded = health is not None and health.enabled
    limit = health.grad_norm_limit if guarded else None

    def step(topo, params, opt_state, buffers, data, key, step_idx=None,
             faults=None):
        loss, _, grads, new_buffers = spmd_step(topo, params, buffers, data,
                                                key, step_idx, faults)
        new_params, new_opt_state = opt.apply(params, grads, opt_state)
        if not guarded:
            return loss, new_params, new_opt_state, new_buffers
        rep = health_check(loss, grads, new_buffers, grad_norm_limit=limit)
        ok = rep["ok"]
        new_params = tree_select(ok, new_params, params)
        new_opt_state = tree_select(ok, new_opt_state, opt_state)
        new_buffers = tree_select(ok, new_buffers, buffers)
        return loss, new_params, new_opt_state, new_buffers, rep

    return jax.jit(step, donate_argnums=(3,))


def _check_staleness(es, pipe_cfg: PipeConfig, anomalies: dict, epoch: int):
    """Host-side guard bookkeeping on one step's "es" counters; raises
    StalenessExceededError once any exchange's effective staleness
    (FIFO depth + consecutive fallbacks) exceeds `max_staleness`."""
    es = np.asarray(es)
    anomalies["exchange_fallbacks"] += int((es > 0).sum())
    worst = int(es.max()) if es.size else 0
    eff = pipe_cfg.staleness_steps + worst
    anomalies["max_effective_staleness"] = max(
        anomalies["max_effective_staleness"], eff)
    if eff > pipe_cfg.max_staleness:
        dst, d, ell, src = np.unravel_index(int(es.argmax()), es.shape)
        raise StalenessExceededError(
            f"effective staleness {eff} exceeds max_staleness="
            f"{pipe_cfg.max_staleness} at epoch {epoch}: the "
            f"{'forward feature' if d == 0 else 'backward gradient'} "
            f"exchange of layer {ell} from partition {src} to partition "
            f"{dst} has fallen back {worst} consecutive steps on top of "
            f"the base staleness {pipe_cfg.staleness_steps}; the bounded-"
            "staleness convergence contract no longer holds")


def train_pipegcn(pipeline, model_cfg: ModelConfig,
                  pipe_cfg: PipeConfig, epochs: int, lr: float = 0.01,
                  seed: int = 0, eval_every: int = 10,
                  log: Callable[[str], None] | None = None,
                  mesh=None, axis_name: str = "parts",
                  health: HealthConfig | None = None,
                  faults: FaultPlan | None = None,
                  ckpt_dir: str | None = None, checkpoint_every: int = 0,
                  resume: bool = False) -> TrainResult:
    """Reference training loop. With `mesh=None` the step runs on the sim
    backend (single device, partitions vmapped); passing a mesh runs the
    same model under shard_map — partitions need only be a multiple of the
    mesh size (multi-partition-per-device SPMD). Eval stays on the sim
    backend either way (global arrays round-trip between backends).

    Fault tolerance (ISSUE 9):
      * `health` — numerical guard policy; None means HealthConfig()
        (guards ON: non-finite steps are skipped with bitwise rollback
        and counted in TrainResult.anomalies). Pass
        HealthConfig(enabled=False) to opt out.
      * `faults` — a declarative FaultPlan compiled over the epoch horizon
        and injected into every exchange (repro.core.faults); combine
        with `pipe_cfg.guard_exchange` for detect-and-fall-back behaviour.
      * `ckpt_dir` + `checkpoint_every` — atomically checkpoint the FULL
        training state (params, opt_state, buffers, PRNG key, epoch)
        every N epochs; `resume=True` restores the latest checkpoint and
        continues BIT-EXACTLY (the saved key is the already-advanced
        split chain, so the resumed run draws the same subkeys an
        uninterrupted run would)."""
    split = pipeline.split_spec() if hasattr(pipeline, "split_spec") else None
    model = PipeGCN(model_cfg, pipe_cfg, split=split)
    topo = pipeline.topo
    # Fail fast (before tracing) if the selected aggregation engine needs
    # Topology fields the pipeline was not built with.
    model._agg_slice(topo)
    # ... and if the config EXPLICITLY declares a node layout that is not
    # the one the pipeline was actually built with. The layout lives in
    # the data, so a drifting ModelConfig.layout must be loud — but
    # "auto" means "defer to the pipeline" here: any built layout is
    # numerically valid under any engine (the LAYOUT parity cells prove
    # coo-on-rcm exact), so auto must not reject a shared pipeline.
    have = getattr(pipeline, "layout", "natural")
    if model_cfg.layout != "auto" and model_cfg.layout != have:
        raise ValueError(
            f"ModelConfig.layout={model_cfg.layout!r} but the pipeline "
            f"was built with layout={have!r}; pass the same layout to "
            "GraphDataPipeline.build (or use layout=\"auto\")")
    if log:
        from repro.core.trace_utils import expected_boundary_collectives
        n_coll = expected_boundary_collectives(model_cfg.num_layers,
                                               pipe_cfg.fused, train=True)
        sched = "fused-deferred" if pipe_cfg.fused else "per-layer"
        where = (f"{n_coll} boundary collectives/train step"
                 if mesh is not None else
                 f"{n_coll} boundary exchanges/train step, local on the "
                 "sim backend")
        log(f"comm schedule: {sched} ({where}, L={model_cfg.num_layers})")
        sp = model._split_active()
        if sp is not None:
            log(f"overlap schedule: split-phase (fwd boundary "
                f"{sp.fwd_bnd_tiles} tiles @ rows>={sp.row_tail}, "
                f"transpose boundary {sp.t_bnd_tiles} tiles @ "
                f"cols>={sp.col_tail}; collectives issued between phases)")
        else:
            why = ("disabled" if pipe_cfg.overlap == "none" else
                   "no feasible split" if split is None else
                   f"engine {model_cfg.agg!r} has no tile phases")
            log(f"overlap schedule: unsplit ({why})")
        # under the split the fused epilogue is bypassed, so report the
        # orders the split step actually resolves (fused=False pricing)
        orders = model.layer_orders(topo, train=True,
                                    fused=False if sp is not None else None)
        how = ("static FLOP model" if model_cfg.matmul_order == "auto"
               else "forced")
        log(f"matmul order ({how}, agg={model_cfg.agg}): "
            + " ".join(f"L{i}:{'PH.W' if o == 'aggregate-first' else 'P.HW'}"
                       for i, o in enumerate(orders)))
        if pipe_cfg.wire != "f32" or pipe_cfg.slice_boundary:
            codecs = model.wire_codecs(topo)
            widths = model.payload_widths(topo)
            sl = model.sliced_layers(topo)
            log("boundary wire: " + " ".join(
                f"L{i}:{c.name}x{w}{'s' if i in sl else ''}"
                for i, (c, w) in enumerate(zip(codecs, widths)))
                + (" (s = sliced to the post-transform width)" if sl else ""))
        layout = getattr(pipeline, "layout", "natural")
        if topo.tile_rows is not None:
            from repro.analysis.cost import graph_layout_report
            rep = graph_layout_report(pipeline.pg)
            log(f"graph layout: {layout} ({rep['tiles']} nonempty tiles, "
                f"bandwidth {rep['bandwidth']}, "
                f"{rep['halo_runs']} halo row runs)")
        else:
            log(f"graph layout: {layout}")
    if health is None:
        health = HealthConfig()
    hc = health if health.enabled else None
    params = model.init_params(jax.random.PRNGKey(seed))
    opt = adam(lr)
    opt_state = opt.init(params)
    buffers = model.init_buffers(topo)
    step = (make_spmd_train_step(model, opt, mesh, topo, axis_name,
                                 health=hc)
            if mesh is not None
            else make_jitted_train_step(model, opt, health=hc))
    fwd = jax.jit(lambda t, p, d: model.forward(t, p, d)[1])

    tables = None
    if faults is not None and not faults.is_empty():
        tables = faults.compile(epochs, model_cfg.num_layers, topo.num_parts)
        if log:
            n = int(np.asarray(tables.drop).sum() +
                    np.asarray(tables.corrupt).sum())
            log(f"fault injection: {n} faulted exchange sites over "
                f"{epochs} epochs"
                + (", guard_exchange ON (checksum + stale fallback)"
                   if pipe_cfg.guard_exchange else
                   ", guard_exchange OFF (faults land undetected)"))

    key = jax.random.PRNGKey(seed + 1)
    start_epoch = 0
    resumed_from = None
    if resume:
        if not ckpt_dir:
            raise ValueError("resume=True requires ckpt_dir")
        from repro.checkpoint import latest_step, restore_checkpoint
        last = latest_step(ckpt_dir)
        if last is not None:
            template = {"params": params, "opt_state": opt_state,
                        "buffers": buffers, "key": key,
                        "epoch": jnp.zeros((), jnp.int32)}
            state = restore_checkpoint(ckpt_dir, last, template)
            params, opt_state = state["params"], state["opt_state"]
            buffers, key = state["buffers"], state["key"]
            start_epoch = int(state["epoch"])
            resumed_from = last
            if log:
                log(f"resumed from checkpoint step {last} "
                    f"(continuing at epoch {start_epoch})")

    anomalies = {"skipped_steps": 0, "max_consecutive": 0}
    if pipe_cfg.guard_exchange:
        anomalies["exchange_fallbacks"] = 0
        anomalies["max_effective_staleness"] = pipe_cfg.staleness_steps
    consec = 0
    last_metric, last_metric_epoch = None, -1
    history = {"loss": [], "val_acc": [], "test_acc": [], "epoch": []}
    t0 = time.perf_counter()
    for epoch in range(start_epoch, epochs):
        key, sub = jax.random.split(key)
        if tables is not None:
            out = step(topo, params, opt_state, buffers,
                       pipeline.train_data, sub,
                       jnp.asarray(epoch, jnp.int32), tables)
        else:
            out = step(topo, params, opt_state, buffers,
                       pipeline.train_data, sub)
        if hc is not None:
            loss, params, opt_state, buffers, rep = out
            if not bool(rep["ok"]):
                anomalies["skipped_steps"] += 1
                consec += 1
                anomalies["max_consecutive"] = max(
                    anomalies["max_consecutive"], consec)
                if consec >= hc.max_consecutive_anomalies:
                    raise TrainingAnomalyError(
                        f"{consec} consecutive unhealthy training steps "
                        f"(epoch {epoch}, loss {float(loss)}, grad norm "
                        f"{float(rep['grad_norm'])}); aborting instead of "
                        "spinning on a poisoned run")
            else:
                consec = 0
        else:
            loss, params, opt_state, buffers = out
        if pipe_cfg.guard_exchange:
            _check_staleness(buffers["es"], pipe_cfg, anomalies, epoch)
        if epoch % eval_every == 0 or epoch == epochs - 1:
            logits = fwd(topo, params, pipeline.val_data)
            m = pipeline.metric(logits)
            last_metric, last_metric_epoch = m, epoch
            history["loss"].append(float(loss))
            history["val_acc"].append(m["val"])
            history["test_acc"].append(m["test"])
            history["epoch"].append(epoch)
            if log:
                line = (f"epoch {epoch:5d} loss {float(loss):.4f} "
                        f"val {m['val']:.4f} test {m['test']:.4f}")
                if anomalies["skipped_steps"]:
                    line += f" anomalies {anomalies['skipped_steps']}"
                if pipe_cfg.guard_exchange and anomalies["exchange_fallbacks"]:
                    line += (f" fallbacks {anomalies['exchange_fallbacks']}"
                             f" es {anomalies['max_effective_staleness']}"
                             f"/{pipe_cfg.max_staleness}")
                log(line)
        if (ckpt_dir and checkpoint_every
                and (epoch + 1) % checkpoint_every == 0):
            from repro.checkpoint import save_checkpoint
            # the saved key is ALREADY advanced past this epoch's split,
            # so a resumed run continues the exact subkey sequence
            save_checkpoint(ckpt_dir, epoch + 1, {
                "params": params, "opt_state": opt_state,
                "buffers": buffers, "key": key,
                "epoch": jnp.asarray(epoch + 1, jnp.int32)})
    dt = time.perf_counter() - t0
    if last_metric_epoch == epochs - 1:
        final = last_metric    # the last epoch already ran this eval
    else:
        final = pipeline.metric(fwd(topo, params, pipeline.val_data))
    ran = max(epochs - start_epoch, 0)
    return TrainResult(history=history, params=params, final_metrics=final,
                       epochs_per_sec=ran / dt if dt > 0 and ran else 0.0,
                       anomalies=anomalies, resumed_from=resumed_from)
