"""Full-graph training driver: PipeGCN step + optimizer + eval loop.

This is the reference trainer used by examples, accuracy benchmarks, and the
convergence experiments (paper Tab. 4 / Fig. 4/9 analogues).
"""
from __future__ import annotations

import dataclasses
import signal
import threading
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import elastic as elastic_mod
from repro.core.config import ModelConfig, PipeConfig
from repro.core.elastic import ElasticConfig, ElasticPlan
from repro.core.faults import FaultPlan, StalenessExceededError
from repro.core.health import (HealthConfig, TrainingAnomalyError,
                               health_check, tree_select)
from repro.core.pipegcn import PipeGCN, Topology
from repro.optim import Optimizer, adam


@dataclasses.dataclass
class TrainResult:
    """Outcome of one `train_pipegcn` run: the eval-metric trajectory
    (`history` lists loss / val_acc / test_acc / epoch), the final
    parameters, the last metric dict, the wall-clock epoch rate, the
    health/guard anomaly counters (skipped_steps, max_consecutive,
    exchange_fallbacks, max_effective_staleness — the latter two only
    under `guard_exchange`; device_losses/rejoins under an enabled
    ElasticConfig), the checkpoint step the run resumed from (None for a
    fresh run), how many elastic device-loss recoveries ran, and whether
    the run exited early on a SIGTERM/SIGINT (`preempted`, after writing
    a final checkpoint)."""

    history: dict          # lists: loss, val_acc, test_acc, epoch_time
    params: dict
    final_metrics: dict
    epochs_per_sec: float
    anomalies: dict = dataclasses.field(default_factory=dict)
    resumed_from: int | None = None
    recoveries: int = 0
    preempted: bool = False


def make_jitted_train_step(model: PipeGCN, opt: Optimizer,
                           health: HealthConfig | None = None):
    """(topo, params, opt_state, buffers, data, key[, step_idx, faults])
    -> (loss, params, opt_state, buffers[, report]).

    Topology and data are traced arguments (not closure constants) so XLA
    does not constant-fold the graph structure into the executable.

    With `health` (an enabled HealthConfig) the step health-checks the
    update (repro.core.health) and ROLLS BACK in-graph: a non-finite /
    out-of-bound step returns the previous params/opt_state/buffers
    bitwise (select semantics) plus a fifth element, the
    ``{"ok", "grad_norm"}`` report. `step_idx` + `faults` (compiled
    FaultTables) inject that step's exchange faults; both default to None
    which traces the historical fault-free step."""
    guarded = health is not None and health.enabled
    limit = health.grad_norm_limit if guarded else None

    def step(topo, params, opt_state, buffers, data, key, step_idx=None,
             faults=None):
        loss, grads, new_buffers, _ = model.train_step(
            topo, params, buffers, data, key, step_idx=step_idx,
            faults=faults)
        new_params, new_opt_state = opt.apply(params, grads, opt_state)
        if not guarded:
            return loss, new_params, new_opt_state, new_buffers
        rep = health_check(loss, grads, new_buffers, grad_norm_limit=limit)
        ok = rep["ok"]
        new_params = tree_select(ok, new_params, params)
        new_opt_state = tree_select(ok, new_opt_state, opt_state)
        new_buffers = tree_select(ok, new_buffers, buffers)
        return loss, new_params, new_opt_state, new_buffers, rep

    return jax.jit(step, donate_argnums=(3,))


def make_spmd_train_step(model: PipeGCN, opt: Optimizer, mesh, topo: Topology,
                         axis_name: str = "parts",
                         health: HealthConfig | None = None):
    """`make_jitted_train_step` analogue on a device mesh: the PipeGCN step
    runs under shard_map over `axis_name` (any partitions-per-device ratio,
    see `PipeGCN.make_spmd_step`); the optimizer update applies to the
    replicated grads. Same signature/returns as the sim-backend step
    (health rollback and fault injection included)."""
    spmd_step = model.make_spmd_step(mesh, topo, axis_name, train=True)
    guarded = health is not None and health.enabled
    limit = health.grad_norm_limit if guarded else None

    def step(topo, params, opt_state, buffers, data, key, step_idx=None,
             faults=None):
        loss, _, grads, new_buffers = spmd_step(topo, params, buffers, data,
                                                key, step_idx, faults)
        new_params, new_opt_state = opt.apply(params, grads, opt_state)
        if not guarded:
            return loss, new_params, new_opt_state, new_buffers
        rep = health_check(loss, grads, new_buffers, grad_norm_limit=limit)
        ok = rep["ok"]
        new_params = tree_select(ok, new_params, params)
        new_opt_state = tree_select(ok, new_opt_state, opt_state)
        new_buffers = tree_select(ok, new_buffers, buffers)
        return loss, new_params, new_opt_state, new_buffers, rep

    return jax.jit(step, donate_argnums=(3,))


def _check_staleness(es, pipe_cfg: PipeConfig, anomalies: dict, epoch: int):
    """Host-side guard bookkeeping on one step's "es" counters; raises
    StalenessExceededError once any exchange's effective staleness
    (FIFO depth + consecutive fallbacks) exceeds `max_staleness`."""
    es = np.asarray(es)
    anomalies["exchange_fallbacks"] += int((es > 0).sum())
    worst = int(es.max()) if es.size else 0
    eff = pipe_cfg.staleness_steps + worst
    anomalies["max_effective_staleness"] = max(
        anomalies["max_effective_staleness"], eff)
    if eff > pipe_cfg.max_staleness:
        dst, d, ell, src = np.unravel_index(int(es.argmax()), es.shape)
        raise StalenessExceededError(
            f"effective staleness {eff} exceeds max_staleness="
            f"{pipe_cfg.max_staleness} at epoch {epoch}: the "
            f"{'forward feature' if d == 0 else 'backward gradient'} "
            f"exchange of layer {ell} from partition {src} to partition "
            f"{dst} has fallen back {worst} consecutive steps on top of "
            f"the base staleness {pipe_cfg.staleness_steps}; the bounded-"
            "staleness convergence contract no longer holds")


def train_pipegcn(pipeline, model_cfg: ModelConfig,
                  pipe_cfg: PipeConfig, epochs: int, lr: float = 0.01,
                  seed: int = 0, eval_every: int = 10,
                  log: Callable[[str], None] | None = None,
                  mesh=None, axis_name: str = "parts",
                  health: HealthConfig | None = None,
                  faults: FaultPlan | None = None,
                  ckpt_dir: str | None = None, checkpoint_every: int = 0,
                  resume: bool = False, checkpoint_keep: int | None = None,
                  elastic: ElasticConfig | None = None,
                  elastic_plan: ElasticPlan | None = None) -> TrainResult:
    """Reference training loop. With `mesh=None` the step runs on the sim
    backend (single device, partitions vmapped); passing a mesh runs the
    same model under shard_map — partitions need only be a multiple of the
    mesh size (multi-partition-per-device SPMD). Eval stays on the sim
    backend either way (global arrays round-trip between backends).

    Fault tolerance (ISSUE 9):
      * `health` — numerical guard policy; None means HealthConfig()
        (guards ON: non-finite steps are skipped with bitwise rollback
        and counted in TrainResult.anomalies). Pass
        HealthConfig(enabled=False) to opt out.
      * `faults` — a declarative FaultPlan compiled over the epoch horizon
        and injected into every exchange (repro.core.faults); combine
        with `pipe_cfg.guard_exchange` for detect-and-fall-back behaviour.
      * `ckpt_dir` + `checkpoint_every` — atomically checkpoint the FULL
        training state (params, opt_state, buffers, PRNG key, epoch)
        every N epochs; `resume=True` restores the latest checkpoint and
        continues BIT-EXACTLY (the saved key is the already-advanced
        split chain, so the resumed run draws the same subkeys an
        uninterrupted run would). `checkpoint_keep` prunes all but the
        newest N committed checkpoints after each save.

    Elasticity (ISSUE 10, repro.core.elastic):
      * `elastic` — an enabled ElasticConfig arms device-loss detection
        (requires `pipe_cfg.guard_exchange`): once every forward exchange
        out of one device has fallen back `detect_after` consecutive
        steps, the trainer restores the latest checkpoint, remaps the
        lost device's partitions onto the survivors (padded idle slots
        for uneven fits), warm-marks the remapped exchanges with
        `warm_staleness` es counts, rebuilds the mesh/step, and resumes —
        then scales back up at a checkpoint boundary once the device is
        healthy (`rejoin`). Checkpoints are ALWAYS written in the flat
        original layout, so any device count can restore them.
      * `elastic_plan` — start directly on a survivor layout (a fresh
        launch at the smaller device count, e.g. after a crash): with
        `resume=True` this routes through the same restore → remap →
        warm-mark path as a mid-run recovery, which makes the two
        bitwise identical from the shared checkpoint on. On a mesh
        backend, pass the matching `launch.mesh.make_survivor_mesh(plan)`
        as `mesh`.

    Preemption: SIGTERM/SIGINT (main thread only) finishes the in-flight
    epoch, writes a final checkpoint (when checkpointing is configured),
    and returns cleanly with `TrainResult.preempted=True`."""
    split = pipeline.split_spec() if hasattr(pipeline, "split_spec") else None
    model = PipeGCN(model_cfg, pipe_cfg, split=split)
    topo = pipeline.topo
    # Fail fast (before tracing) if the selected aggregation engine needs
    # Topology fields the pipeline was not built with.
    model._agg_slice(topo)
    # ... and if the config EXPLICITLY declares a node layout that is not
    # the one the pipeline was actually built with. The layout lives in
    # the data, so a drifting ModelConfig.layout must be loud — but
    # "auto" means "defer to the pipeline" here: any built layout is
    # numerically valid under any engine (the LAYOUT parity cells prove
    # coo-on-rcm exact), so auto must not reject a shared pipeline.
    have = getattr(pipeline, "layout", "natural")
    if model_cfg.layout != "auto" and model_cfg.layout != have:
        raise ValueError(
            f"ModelConfig.layout={model_cfg.layout!r} but the pipeline "
            f"was built with layout={have!r}; pass the same layout to "
            "GraphDataPipeline.build (or use layout=\"auto\")")
    if log:
        from repro.core.trace_utils import expected_boundary_collectives
        n_coll = expected_boundary_collectives(model_cfg.num_layers,
                                               pipe_cfg.fused, train=True)
        sched = "fused-deferred" if pipe_cfg.fused else "per-layer"
        where = (f"{n_coll} boundary collectives/train step"
                 if mesh is not None else
                 f"{n_coll} boundary exchanges/train step, local on the "
                 "sim backend")
        log(f"comm schedule: {sched} ({where}, L={model_cfg.num_layers})")
        sp = model._split_active()
        if sp is not None:
            log(f"overlap schedule: split-phase (fwd boundary "
                f"{sp.fwd_bnd_tiles} tiles @ rows>={sp.row_tail}, "
                f"transpose boundary {sp.t_bnd_tiles} tiles @ "
                f"cols>={sp.col_tail}; collectives issued between phases)")
        else:
            why = ("disabled" if pipe_cfg.overlap == "none" else
                   "no feasible split" if split is None else
                   f"engine {model_cfg.agg!r} has no tile phases")
            log(f"overlap schedule: unsplit ({why})")
        # under the split the fused epilogue is bypassed, so report the
        # orders the split step actually resolves (fused=False pricing)
        orders = model.layer_orders(topo, train=True,
                                    fused=False if sp is not None else None)
        how = ("static FLOP model" if model_cfg.matmul_order == "auto"
               else "forced")
        log(f"matmul order ({how}, agg={model_cfg.agg}): "
            + " ".join(f"L{i}:{'PH.W' if o == 'aggregate-first' else 'P.HW'}"
                       for i, o in enumerate(orders)))
        if pipe_cfg.wire != "f32" or pipe_cfg.slice_boundary:
            codecs = model.wire_codecs(topo)
            widths = model.payload_widths(topo)
            sl = model.sliced_layers(topo)
            log("boundary wire: " + " ".join(
                f"L{i}:{c.name}x{w}{'s' if i in sl else ''}"
                for i, (c, w) in enumerate(zip(codecs, widths)))
                + (" (s = sliced to the post-transform width)" if sl else ""))
        layout = getattr(pipeline, "layout", "natural")
        if topo.tile_rows is not None:
            from repro.analysis.cost import graph_layout_report
            rep = graph_layout_report(pipeline.pg)
            log(f"graph layout: {layout} ({rep['tiles']} nonempty tiles, "
                f"bandwidth {rep['bandwidth']}, "
                f"{rep['halo_runs']} halo row runs)")
        else:
            log(f"graph layout: {layout}")
    if health is None:
        health = HealthConfig()
    hc = health if health.enabled else None

    P = topo.num_parts
    el_on = elastic is not None and elastic.enabled
    if elastic_plan is not None and not el_on:
        raise ValueError("elastic_plan requires an enabled ElasticConfig "
                         "(pass elastic=ElasticConfig(...))")
    if el_on:
        if not pipe_cfg.guard_exchange:
            raise ValueError(
                "the elastic runtime detects device loss through the "
                "guarded exchange's es counters; set "
                "PipeConfig.guard_exchange=True")
        if (pipe_cfg.staleness_steps + elastic.detect_after
                > pipe_cfg.max_staleness):
            raise ValueError(
                f"elastic detect_after={elastic.detect_after} can never "
                f"fire: staleness_steps={pipe_cfg.staleness_steps} + "
                f"detect_after exceeds max_staleness="
                f"{pipe_cfg.max_staleness}, so the run would abort first")
    plan = elastic_plan
    if plan is not None and plan.num_parts != P:
        raise ValueError(f"elastic_plan remaps {plan.num_parts} partitions "
                         f"but the pipeline has {P}")
    # original device granularity: what "one device" means to the
    # device_down fault plane and the loss detector
    if plan is not None:
        orig_devices = plan.orig_devices
    elif mesh is not None:
        orig_devices = int(mesh.devices.size)
    elif el_on:
        orig_devices = P // elastic.parts_per_device
    else:
        orig_devices = P
    if orig_devices < 1 or P % orig_devices:
        raise ValueError(
            f"num_parts={P} is not a multiple of the device count "
            f"{orig_devices}")
    orig_ppd = P // orig_devices

    params = model.init_params(jax.random.PRNGKey(seed))
    opt = adam(lr)
    opt_state = opt.init(params)
    mesh0 = mesh
    topo_run, train_run, val_run = topo, pipeline.train_data, pipeline.val_data
    if plan is not None:
        if mesh is not None and int(mesh.devices.size) != plan.n_devices:
            raise ValueError(
                f"mesh has {int(mesh.devices.size)} devices but the plan's "
                f"survivor set has {plan.n_devices} — pass "
                "launch.mesh.make_survivor_mesh(plan)")
        topo_run = elastic_mod.remap_topology(topo, plan)
        train_run = elastic_mod.remap_data(pipeline.train_data, plan)
        val_run = elastic_mod.remap_data(pipeline.val_data, plan)
    buffers = model.init_buffers(topo_run)

    def build_step(m, t):
        return (make_spmd_train_step(model, opt, m, t, axis_name, health=hc)
                if m is not None
                else make_jitted_train_step(model, opt, health=hc))

    step = build_step(mesh, topo_run)
    fwd = jax.jit(lambda t, p, d: model.forward(t, p, d)[1])

    def build_tables(active_plan):
        # with a plan active the lost device is already remapped away, so
        # its device_down sites are moot; pad partitions never carry real
        # faults (mask_pad_faults) — their idle wires must stay valid
        if faults is None or faults.is_empty():
            return None
        fp = faults if active_plan is None else faults.without_device_down()
        if fp.is_empty():
            return None
        if active_plan is None:
            return fp.compile(epochs, model_cfg.num_layers, P,
                              parts_per_device=orig_ppd)
        tab = fp.compile(epochs, model_cfg.num_layers,
                         active_plan.padded_parts,
                         parts_per_device=active_plan.n_local)
        return elastic_mod.mask_pad_faults(tab, P)

    tables = build_tables(plan)
    if tables is not None and log:
        n = int(np.asarray(tables.drop).sum() +
                np.asarray(tables.corrupt).sum())
        log(f"fault injection: {n} faulted exchange sites over "
            f"{epochs} epochs"
            + (", guard_exchange ON (checksum + stale fallback)"
               if pipe_cfg.guard_exchange else
               ", guard_exchange OFF (faults land undetected)"))

    key = jax.random.PRNGKey(seed + 1)
    start_epoch = 0
    resumed_from = None

    def flat_template():
        # checkpoints are ALWAYS written in the flat original layout
        # (remapped runs unmap before saving), so one template serves
        # every device count
        return {"params": params, "opt_state": opt_state,
                "buffers": model.init_buffers(topo), "key": key,
                "epoch": jnp.zeros((), jnp.int32)}

    def apply_plan_state(flat_bufs, p):
        # the ONE restore → remap → warm-mark path shared by mid-run
        # recovery and a fresh survivor-layout launch: routing both
        # through it is what makes them bitwise identical
        b = elastic_mod.remap_buffers(flat_bufs, p)
        return elastic_mod.warm_mark(b, p.moved_partitions(),
                                     elastic.warm_staleness if el_on else 0,
                                     P)

    if resume:
        if not ckpt_dir:
            raise ValueError("resume=True requires ckpt_dir")
        from repro.checkpoint import latest_step, restore_checkpoint
        last = latest_step(ckpt_dir)
        if last is not None:
            state = restore_checkpoint(ckpt_dir, last, flat_template())
            params, opt_state = state["params"], state["opt_state"]
            key = state["key"]
            buffers = (apply_plan_state(state["buffers"], plan)
                       if plan is not None else state["buffers"])
            start_epoch = int(state["epoch"])
            resumed_from = last
            if log:
                log(f"resumed from checkpoint step {last} "
                    f"(continuing at epoch {start_epoch})")

    anomalies = {"skipped_steps": 0, "max_consecutive": 0}
    if pipe_cfg.guard_exchange:
        anomalies["exchange_fallbacks"] = 0
        anomalies["max_effective_staleness"] = pipe_cfg.staleness_steps
    if el_on:
        anomalies["device_losses"] = []
        anomalies["rejoins"] = 0

    def save_state(step_no):
        from repro.checkpoint import save_checkpoint
        # the saved key is ALREADY advanced past this epoch's split,
        # so a resumed run continues the exact subkey sequence
        flat = (elastic_mod.unmap_buffers(buffers, plan)
                if plan is not None else buffers)
        save_checkpoint(ckpt_dir, step_no, {
            "params": params, "opt_state": opt_state, "buffers": flat,
            "key": key, "epoch": jnp.asarray(step_no, jnp.int32)},
            keep_last=checkpoint_keep)
        return flat

    def device_back(at_step):
        lost = set(range(orig_devices)) - set(plan.survivors)
        if faults is not None and faults.downed_devices(at_step) & lost:
            return False
        if mesh0 is not None and len(jax.devices()) < int(mesh0.devices.size):
            return False
        return True

    stop_signals: list = []
    sig_handlers = {}
    if threading.current_thread() is threading.main_thread():
        for signum in (signal.SIGTERM, signal.SIGINT):
            try:
                sig_handlers[signum] = signal.signal(
                    signum, lambda s, _f: stop_signals.append(s))
            except (ValueError, OSError):
                pass

    consec = 0
    recoveries = 0
    preempted = False
    cur_survivors = (plan.survivors if plan is not None
                     else tuple(range(orig_devices)))
    cur_n_local = plan.n_local if plan is not None else orig_ppd
    last_metric, last_metric_epoch = None, -1
    history = {"loss": [], "val_acc": [], "test_acc": [], "epoch": []}
    t0 = time.perf_counter()
    epoch = start_epoch
    try:
        while epoch < epochs:
            try:
                key, sub = jax.random.split(key)
                if tables is not None:
                    out = step(topo_run, params, opt_state, buffers,
                               train_run, sub,
                               jnp.asarray(epoch, jnp.int32), tables)
                else:
                    out = step(topo_run, params, opt_state, buffers,
                               train_run, sub)
                if hc is not None:
                    loss, params, opt_state, buffers, rep = out
                    if not bool(rep["ok"]):
                        anomalies["skipped_steps"] += 1
                        consec += 1
                        anomalies["max_consecutive"] = max(
                            anomalies["max_consecutive"], consec)
                        if consec >= hc.max_consecutive_anomalies:
                            raise TrainingAnomalyError(
                                f"{consec} consecutive unhealthy training "
                                f"steps (epoch {epoch}, loss {float(loss)}, "
                                f"grad norm {float(rep['grad_norm'])}); "
                                "aborting instead of spinning on a "
                                "poisoned run")
                    else:
                        consec = 0
                else:
                    loss, params, opt_state, buffers = out
                if pipe_cfg.guard_exchange:
                    es_host = np.asarray(buffers["es"])
                    if el_on:
                        # device loss pre-empts the staleness abort: a
                        # blanket whole-device fallback row is an outage
                        # to recover from, not a contract violation
                        down = elastic_mod.detect_device_loss(
                            es_host, cur_n_local, P, elastic.detect_after)
                        if down is not None:
                            dev = (cur_survivors[down] if plan is not None
                                   else down)
                            rest = tuple(s for s in cur_survivors
                                         if s != dev)
                            raise elastic_mod.DeviceLossError(
                                f"device {dev} detected down at epoch "
                                f"{epoch}: every forward exchange out of "
                                f"it has fallen back >= "
                                f"{elastic.detect_after} consecutive steps",
                                dev, rest, epoch)
                    _check_staleness(es_host, pipe_cfg, anomalies, epoch)
                if epoch % eval_every == 0 or epoch == epochs - 1:
                    logits = fwd(topo_run, params, val_run)
                    m = pipeline.metric(logits)
                    last_metric, last_metric_epoch = m, epoch
                    history["loss"].append(float(loss))
                    history["val_acc"].append(m["val"])
                    history["test_acc"].append(m["test"])
                    history["epoch"].append(epoch)
                    if log:
                        line = (f"epoch {epoch:5d} loss {float(loss):.4f} "
                                f"val {m['val']:.4f} test {m['test']:.4f}")
                        if anomalies["skipped_steps"]:
                            line += f" anomalies {anomalies['skipped_steps']}"
                        if (pipe_cfg.guard_exchange
                                and anomalies["exchange_fallbacks"]):
                            line += (
                                f" fallbacks {anomalies['exchange_fallbacks']}"
                                f" es {anomalies['max_effective_staleness']}"
                                f"/{pipe_cfg.max_staleness}")
                        log(line)
                saved = False
                if (ckpt_dir and checkpoint_every
                        and (epoch + 1) % checkpoint_every == 0):
                    flat = save_state(epoch + 1)
                    saved = True
                    if (plan is not None and el_on and elastic.rejoin
                            and device_back(epoch + 1)):
                        # rejoin: the just-saved flat state IS the live
                        # state unmapped — resume it on the full device
                        # count, warm-marking the partitions moving home
                        moved = plan.moved_partitions()
                        buffers = elastic_mod.warm_mark(
                            flat, moved, elastic.warm_staleness, P)
                        topo_run, train_run, val_run = (
                            topo, pipeline.train_data, pipeline.val_data)
                        plan = None
                        cur_survivors = tuple(range(orig_devices))
                        cur_n_local = orig_ppd
                        if mesh0 is not None:
                            step = build_step(mesh0, topo_run)
                        tables = build_tables(None)
                        anomalies["rejoins"] += 1
                        if log:
                            log(f"rejoin: scaled back up to {orig_devices} "
                                f"devices at checkpoint step {epoch + 1} "
                                f"({len(moved)} partitions warm-marked)")
                if stop_signals:
                    if ckpt_dir and checkpoint_every and not saved:
                        save_state(epoch + 1)
                    preempted = True
                    if log:
                        log(f"preempted (signal {int(stop_signals[0])}): "
                            f"epoch {epoch} finished, final checkpoint "
                            "written, exiting cleanly")
                    break
                epoch += 1
            except elastic_mod.DeviceLossError as err:
                if not el_on:
                    raise
                if recoveries >= elastic.max_recoveries:
                    raise
                if not ckpt_dir:
                    raise RuntimeError(
                        "elastic recovery needs a checkpoint to restore "
                        "from — run with ckpt_dir + checkpoint_every"
                    ) from err
                from repro.checkpoint import latest_step, restore_checkpoint
                last = latest_step(ckpt_dir)
                if last is None:
                    raise RuntimeError(
                        "device lost before the first checkpoint landed — "
                        "nothing to recover from") from err
                if not err.survivors:
                    raise RuntimeError(
                        "no surviving devices to remap onto") from err
                plan = ElasticPlan(num_parts=P, orig_devices=orig_devices,
                                   survivors=err.survivors)
                state = restore_checkpoint(ckpt_dir, last, flat_template())
                params, opt_state = state["params"], state["opt_state"]
                key = state["key"]
                buffers = apply_plan_state(state["buffers"], plan)
                epoch = int(state["epoch"])
                topo_run = elastic_mod.remap_topology(topo, plan)
                train_run = elastic_mod.remap_data(pipeline.train_data, plan)
                val_run = elastic_mod.remap_data(pipeline.val_data, plan)
                cur_survivors = plan.survivors
                cur_n_local = plan.n_local
                if mesh0 is not None:
                    from repro.launch.mesh import make_survivor_mesh
                    step = build_step(make_survivor_mesh(plan, axis_name),
                                      topo_run)
                tables = build_tables(plan)
                recoveries += 1
                consec = 0
                anomalies["device_losses"].append({
                    "device": err.device, "detected_epoch": err.epoch,
                    "resumed_from": int(last),
                    "survivors": list(plan.survivors)})
                if log:
                    log(f"device {err.device} lost at epoch {err.epoch}: "
                        f"remapped {P} partitions onto survivors "
                        f"{list(plan.survivors)} ({plan.n_local}/device, "
                        f"{plan.pad_parts} pad), restored checkpoint step "
                        f"{last}, resuming at epoch {epoch}")
    finally:
        for signum, h in sig_handlers.items():
            signal.signal(signum, h)
    dt = time.perf_counter() - t0
    if last_metric_epoch == epochs - 1:
        final = last_metric    # the last epoch already ran this eval
    else:
        final = pipeline.metric(fwd(topo_run, params, val_run))
    ran = max(epochs - start_epoch, 0)
    return TrainResult(history=history, params=params, final_metrics=final,
                       epochs_per_sec=ran / dt if dt > 0 and ran else 0.0,
                       anomalies=anomalies, resumed_from=resumed_from,
                       recoveries=recoveries, preempted=preempted)
