"""Composable `jax.grad`-compatible wrapper around the PipeGCN step.

The hand-written Alg. 1 backward cannot be derived by autodiff (stale
gradient routing), but it can be *packaged* as a `jax.custom_vjp` so the
pipelined loss composes with standard JAX training code:

    loss_fn = make_pipegcn_loss(model, topo)
    (loss, new_buffers), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(params, buffers, data, key)

The VJP w.r.t. `params` is exactly the Alg. 1 gradient (computed in the
forward pass and replayed in the backward); buffers/data/key receive zero
cotangents (pipeline state is non-differentiable by the paper's semantics).
Cotangent scaling is honored, so this also composes under outer losses of
the form `g(loss_fn(...))`.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.pipegcn import PipeGCN, Topology


def make_pipegcn_loss(model: PipeGCN, topo: Topology):
    """Returns loss_fn(params, buffers, data, key) -> (loss, new_buffers),
    differentiable w.r.t. params via the Alg. 1 manual backward."""

    @jax.custom_vjp
    def loss_fn(params, buffers, data, key):
        loss, _, _, new_buffers = model.train_step(topo, params, buffers,
                                                   data, key)
        return loss, new_buffers

    def fwd(params, buffers, data, key):
        loss, grads, new_buffers, _ = model.train_step(topo, params, buffers,
                                                       data, key)
        return (loss, new_buffers), (grads, buffers)

    def bwd(residual, cotangents):
        grads, buffers = residual
        ct_loss, _ct_buffers = cotangents
        d_params = jax.tree.map(lambda g: g * ct_loss, grads)
        d_buffers = jax.tree.map(jnp.zeros_like, buffers)
        return d_params, d_buffers, None, None

    loss_fn.defvjp(fwd, bwd)
    return loss_fn
