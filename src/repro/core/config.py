"""Configuration dataclasses for the PipeGCN core."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """GCN / GraphSAGE model per the paper (§2, Tab. 3)."""

    kind: str = "sage"             # "gcn" (σ(PHW)) or "sage" (σ([PH; H]W))
    feat_dim: int = 128
    hidden: int = 256
    num_layers: int = 4
    num_classes: int = 16
    dropout: float = 0.5
    multilabel: bool = False       # sigmoid BCE (Yelp) vs softmax CE
    # Aggregation engine for the Eq. 3/4 SpMM: "coo" (segment_sum fallback),
    # "blocksparse" (Pallas MXU kernels; Topology must carry tiles), or
    # "fused" (blocksparse tiles + single-pass aggregate⊗transform kernels
    # with the dense weight contracted in the same grid pass).
    agg: str = "coo"
    # Matmul ordering of the layer pair P·H·W (Demirci et al.: a first-order
    # FLOP knob — P·(H·W) costs 2·nnz·F_out where (P·H)·W costs 2·nnz·F_in):
    #   "aggregate-first"  z = P·H, then u = z·W   (the paper's Eq. 3 order)
    #   "transform-first"  hw = H·W, then u = P·hw
    #   "auto"             per-layer argmin-FLOPs via the static cost model
    #                      (repro.analysis.cost.choose_gcn_orders)
    matmul_order: str = "aggregate-first"
    # Intra-partition node layout the graph pipeline builds the shards with
    # (repro.graph.reorder): "natural" keeps the partitioner's sorted-
    # global-id order; "rcm" applies RCM bandwidth reduction + halo
    # clustering (fewer nonempty tiles for the tile engines, numerically
    # invisible); "auto" — the default — resolves to "rcm" exactly when
    # `agg` consumes tiles at pipeline build (GraphDataPipeline.build
    # takes the same knob). This field declares the layout config-side:
    # train_pipegcn fails fast when an EXPLICIT declaration disagrees
    # with the layout the pipeline was built with, while "auto" defers to
    # the pipeline (any built layout is numerically valid under any
    # engine), so a default-constructed config never trips the check.
    layout: str = "auto"

    ORDERS = ("aggregate-first", "transform-first", "auto")
    LAYOUTS = ("natural", "rcm", "auto")

    def __post_init__(self):
        if self.matmul_order not in self.ORDERS:
            raise ValueError(
                f"unknown matmul_order {self.matmul_order!r}; "
                f"have {self.ORDERS}")
        if self.layout not in self.LAYOUTS:
            raise ValueError(
                f"unknown layout {self.layout!r}; have {self.LAYOUTS}")

    def layer_dims(self) -> list[tuple[int, int]]:
        """[(fan_in_of_aggregated, fan_out)] per layer (pre-concat dims)."""
        dims = [self.feat_dim] + [self.hidden] * (self.num_layers - 1) + [self.num_classes]
        return [(dims[i], dims[i + 1]) for i in range(self.num_layers)]


@dataclasses.dataclass(frozen=True)
class PipeConfig:
    """Staleness / smoothing switches.

    stale=False                       -> vanilla partition-parallel training
    stale=True                        -> PipeGCN
    stale=True + smooth_grad (γ)      -> PipeGCN-G
    stale=True + smooth_feat (γ)      -> PipeGCN-F
    stale=True + both                 -> PipeGCN-GF
    """

    stale: bool = True
    smooth_feat: bool = False
    smooth_grad: bool = False
    gamma: float = 0.95            # paper default decay rate
    # DEPRECATED alias for wire="bf16" (the original App. C bf16 switch).
    # Setting it normalizes `wire` below; new code should set `wire`.
    compress_boundary: bool = False
    # Boundary wire format (repro.core.codec): what every exchanged
    # feature/gradient payload is encoded to on the wire. "f32" (default)
    # ships the native dtype; "bf16" halves the bytes (the old
    # compress_boundary); "int8"/"int4" are blockwise-scaled quantization
    # (~4x/~8x smaller, per-`wire_block` f32 scales ride in the payload);
    # "auto" picks per layer via the cost model's byte pricing
    # (repro.analysis.cost.choose_wire_formats — int4 stays explicit-only).
    wire: str = "f32"
    # Feature-block size of the quantized scale vectors: one f32 scale per
    # `wire_block` feature columns (per boundary row). Only int8/int4 use it.
    wire_block: int = 128
    # Feature-dimension slicing ("Slicing Input Features...", arXiv
    # 2408.11500): layers the cost model runs transform-first ship the
    # post-transform width F_out <= F_in — the consumer aggregates the
    # already-transformed halo rows. Exact for vanilla/eval; under
    # staleness the halo transform uses last step's weights (same
    # one-iteration-stale contract as the features themselves). Layer 0
    # always ships raw input features; incompatible with overlap=
    # "split-phase" (slicing moves the send after the transform, so the
    # boundary-first phase split has nothing to overlap).
    slice_boundary: bool = False
    # Beyond-paper (App. C "increase the pipeline depth" future work):
    # consume boundary data from k iterations ago — k-1 extra iterations of
    # compute available to hide one exchange. k=1 is the paper's PipeGCN.
    staleness_steps: int = 1
    # Fused deferred exchange: in stale mode the exchanged boundary payloads
    # are only consumed at step t+1 (Alg. 1), so per-layer sends can be
    # packed along the feature axis and shipped in ONE collective per
    # direction (1 forward + 1 backward vs 2L-1 blocking per-layer
    # collectives), scheduled off the critical path. Numerically identical
    # to the per-layer schedule; no effect when stale=False (vanilla mode
    # needs fresh per-layer exchanges on the critical path).
    fuse_exchange: bool = True
    # Split-phase overlap (ISSUE 6): compute the boundary-phase SpMM (the
    # halo-clustered tail runs of the rcm tile stream) first, issue the
    # exchange for the NEXT consumer immediately, and run the interior
    # phase — the bulk of the aggregation — while the collective is in
    # flight. "none" keeps the unsplit schedule; "split-phase" forces the
    # split (requires a PipeGCN built with a SplitSpec — see
    # core.pipegcn.split_spec_from); "auto" (default) enables it exactly
    # when a split spec is available AND the aggregation engine consumes
    # tiles (the engines whose streams the phase split actually
    # reorders). Numerically the split is bit-identical to the unsplit
    # schedule; it only repositions each collective between the two
    # phases (collective COUNTS are unchanged in every mode).
    overlap: str = "auto"
    # Guarded exchange (ISSUE 9): append a per-row checksum column to every
    # wire payload (docs/wire-format.md §2.2) and verify it on decode. A
    # row that fails verification is treated as lost: the receiver falls
    # back to its last-good stale entry, so the payload's EFFECTIVE
    # staleness grows by one. Buffers gain an "es" counter leaf tracking
    # consecutive fallbacks per (partition, direction, layer, peer); the
    # trainer raises faults.StalenessExceededError once
    # staleness_steps + max(es) exceeds `max_staleness`. With no faults
    # injected the guard is bitwise invisible (select semantics) and adds
    # no collectives. Requires stale=True — vanilla mode has no stale
    # buffer to fall back to.
    guard_exchange: bool = False
    # Bound on the effective staleness the guarded run tolerates before
    # dying loudly (PipeGCN's convergence proof assumes bounded staleness;
    # unbounded fallback would silently void it).
    max_staleness: int = 8

    OVERLAPS = ("auto", "none", "split-phase")
    WIRES = ("f32", "bf16", "int8", "int4", "auto")

    def __post_init__(self):
        if self.overlap not in self.OVERLAPS:
            raise ValueError(
                f"unknown overlap {self.overlap!r}; have {self.OVERLAPS}")
        if self.wire not in self.WIRES:
            raise ValueError(
                f"unknown wire {self.wire!r}; have {self.WIRES}")
        if self.wire_block < 1:
            raise ValueError(f"wire_block must be >= 1, got {self.wire_block}")
        if self.compress_boundary:
            if self.wire == "f32":
                object.__setattr__(self, "wire", "bf16")
            elif self.wire != "bf16":
                raise ValueError(
                    "compress_boundary is a deprecated alias for wire='bf16' "
                    f"and conflicts with wire={self.wire!r}")
        if self.guard_exchange:
            if not self.stale:
                raise ValueError(
                    "guard_exchange requires stale=True: vanilla mode has "
                    "no stale buffer to fall back to when a payload fails "
                    "its checksum")
            if self.overlap == "split-phase":
                raise ValueError(
                    "guard_exchange is incompatible with overlap="
                    "'split-phase' (the split schedule lands payloads "
                    "mid-phase, before the checksum verdict exists); use "
                    "overlap='auto'/'none'")
            if self.max_staleness < self.staleness_steps:
                raise ValueError(
                    f"max_staleness ({self.max_staleness}) must be >= "
                    f"staleness_steps ({self.staleness_steps}): the FIFO "
                    "depth alone already implies that much staleness")
        if self.slice_boundary and self.overlap == "split-phase":
            raise ValueError(
                "slice_boundary is incompatible with overlap='split-phase' "
                "(the sliced send happens after the transform, leaving no "
                "boundary-first phase to overlap); use overlap='auto'/'none'")

    @property
    def fused(self) -> bool:
        """Whether the step actually runs the fused-deferred schedule."""
        return self.stale and self.fuse_exchange

    @staticmethod
    def vanilla() -> "PipeConfig":
        return PipeConfig(stale=False)

    @staticmethod
    def named(name: str, gamma: float = 0.95) -> "PipeConfig":
        name = name.lower()
        table = {
            "gcn": PipeConfig(stale=False),
            "vanilla": PipeConfig(stale=False),
            "pipegcn": PipeConfig(stale=True),
            "pipegcn-g": PipeConfig(stale=True, smooth_grad=True, gamma=gamma),
            "pipegcn-f": PipeConfig(stale=True, smooth_feat=True, gamma=gamma),
            "pipegcn-gf": PipeConfig(stale=True, smooth_feat=True,
                                     smooth_grad=True, gamma=gamma),
        }
        if name not in table:
            raise KeyError(f"unknown variant {name!r}; have {sorted(table)}")
        return table[name]
