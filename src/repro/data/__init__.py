from repro.data.tokens import TokenStream, synthetic_token_batches
from repro.data.graph_pipeline import GraphDataPipeline

__all__ = ["TokenStream", "synthetic_token_batches", "GraphDataPipeline"]
