from repro.data.tokens import TokenStream, synthetic_token_batches
from repro.data.graph_pipeline import (GraphDataPipeline, from_local_layout,
                                       to_local_layout)

__all__ = ["TokenStream", "synthetic_token_batches", "GraphDataPipeline",
           "to_local_layout", "from_local_layout"]
