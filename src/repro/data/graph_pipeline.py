"""End-to-end graph data pipeline: dataset -> normalization -> partition ->
padded shards -> device arrays. One call site for every example/benchmark.

The `agg` knob mirrors ``ModelConfig.agg``: building with
``agg="blocksparse"`` or ``agg="fused"`` additionally extracts the
per-partition block-sparse tile streams onto the Topology, so any
aggregation engine can run on the same partitioned graph (the COO shards
are always present)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipegcn import ShardedData, Topology, shard_data, topology_from
from repro.graph.csr import mean_normalized, sym_normalized
from repro.graph.halo import PartitionedGraph, build_partitioned_graph
from repro.graph.partition import partition_graph
from repro.graph.synthetic import GraphDataset, make_dataset


def to_local_layout(tree, n_local: int, axis: int = 0):
    """Reshape every (…, P, …) leading-partition array in a pytree to the
    physical per-device view (…, n_dev, n_local, …) used by the
    multi-partition-per-device SPMD path (device-major: partition p lives
    on device p // n_local). `axis` is the partition axis (0 for Topology /
    ShardedData arrays, 1 for k-step staleness buffer queues)."""

    def r(x):
        p = x.shape[axis]
        if p % n_local:
            raise ValueError(
                f"partition axis {axis} has size {p}, not a multiple of "
                f"n_local={n_local}")
        shape = x.shape[:axis] + (p // n_local, n_local) + x.shape[axis + 1:]
        return x.reshape(shape)

    return jax.tree.map(r, tree)


def from_local_layout(tree, axis: int = 0):
    """Inverse of `to_local_layout`: merge the (n_dev, n_local) pair at
    `axis` back into a flat partition axis."""

    def r(x):
        shape = (x.shape[:axis] + (x.shape[axis] * x.shape[axis + 1],)
                 + x.shape[axis + 2:])
        return x.reshape(shape)

    return jax.tree.map(r, tree)


@dataclasses.dataclass
class GraphDataPipeline:
    """Device-ready view of one partitioned graph dataset: the Topology,
    the three ShardedData splits (train/val/test share the packed
    feature/label arrays), and the build-time knobs that shaped them
    (`agg` engine, resolved node `layout`). Construct via `build`; eval
    metrics route back through `metric` (unpacks the node permutation)."""

    dataset: GraphDataset
    pg: PartitionedGraph
    topo: Topology
    train_data: ShardedData
    val_data: ShardedData
    test_data: ShardedData
    agg: str = "coo"
    layout: str = "natural"        # resolved node layout ("auto" never stored)

    @staticmethod
    def build(name_or_ds, num_parts: int, kind: str = "sage",
              seed: int = 0, partition_method: str = "bfs+refine",
              agg: str = "coo", layout: str = "auto") -> "GraphDataPipeline":
        """`layout` picks the intra-partition node order ("natural" | "rcm"
        | "auto"): "rcm" applies the bandwidth-reducing + halo-clustering
        permutation of repro.graph.reorder — fewer nonempty tiles for the
        block-sparse engines, numerically invisible everywhere — and
        "auto" (the default, matching ModelConfig.layout and the CLI)
        resolves to "rcm" exactly when the selected aggregation engine
        consumes tiles. Features/labels/masks are remapped ONCE here
        (pack_nodes routes through the reordered local_of); results are
        unpermuted only at the eval/metric boundary (`metric` goes
        through unpack_nodes)."""
        ds = (make_dataset(name_or_ds) if isinstance(name_or_ds, str)
              else name_or_ds)
        from repro.graph.reorder import TILE_ENGINES, resolve_layout
        layout = resolve_layout(layout, agg)
        prop = mean_normalized(ds.graph) if kind == "sage" else sym_normalized(ds.graph)
        part = partition_graph(ds.graph, num_parts, seed=seed,
                               method=partition_method)
        pg = build_partitioned_graph(prop, part, num_parts, layout=layout)
        topo = topology_from(pg, with_tiles=(agg in TILE_ENGINES))
        # x/labels/train_mask are split-independent: pack them ONCE and share
        # the arrays across the three views; only eval_mask differs per split.
        base = shard_data(pg, ds.features, ds.labels, ds.train_mask,
                          ds.val_mask)
        return GraphDataPipeline(
            dataset=ds, pg=pg, topo=topo,
            train_data=base._replace(eval_mask=base.train_mask),
            val_data=base,
            test_data=base._replace(
                eval_mask=jnp.asarray(pg.pack_nodes(np.asarray(ds.test_mask)))),
            agg=agg, layout=layout)

    def split_spec(self):
        """`SplitSpec` of this pipeline's partitioned graph for the
        split-phase overlap schedule (`PipeConfig.overlap`), or None when
        the split is infeasible (single partition, no boundary sends, or a
        layout whose boundary rows are not clustered into a tail — e.g.
        "natural"). Memoized with the tile extraction on `pg`, so calling
        this after `build` costs nothing for tile-engine pipelines."""
        from repro.core.pipegcn import split_spec_from
        return split_spec_from(self.pg)

    def device_layout(self, num_devices: int):
        """Explicit (n_dev, n_local, ...) per-device view of (topo, data)
        for num_devices hosts — the physical layout `make_spmd_step` induces
        when sharding the flat partition axis over a smaller mesh."""
        if self.topo.num_parts % num_devices:
            raise ValueError(
                f"num_parts={self.topo.num_parts} is not a multiple of "
                f"num_devices={num_devices}")
        n_local = self.topo.num_parts // num_devices
        topo = Topology(*to_local_layout(tuple(self.topo), n_local))
        data = ShardedData(*to_local_layout(tuple(self.train_data), n_local))
        return topo, data

    def elastic_views(self, plan):
        """Remapped (topo, train_data, val_data) for an
        `repro.core.elastic.ElasticPlan` — the padded survivor layout of
        this pipeline's device arrays (pads appended and masked out; the
        partitioned graph is NOT rebuilt)."""
        from repro.core.elastic import remap_data, remap_topology
        return (remap_topology(self.topo, plan),
                remap_data(self.train_data, plan),
                remap_data(self.val_data, plan))

    def metric(self, logits_packed) -> dict:
        """Global accuracy (single-label) or F1-micro (multilabel) on
        train/val/test splits, computed from packed (P, max_inner, C)
        logits. Logits from an elastically remapped run carry extra pad
        partitions; only the real leading `num_parts` rows are unpacked."""
        ds = self.dataset
        logits = self.pg.unpack_nodes(
            np.asarray(logits_packed)[:self.pg.num_parts])
        out = {}
        for split, mask in (("train", ds.train_mask), ("val", ds.val_mask),
                            ("test", ds.test_mask)):
            if ds.multilabel:
                pred = logits[mask] > 0
                true = ds.labels[mask] > 0.5
                tp = np.sum(pred & true)
                fp = np.sum(pred & ~true)
                fn = np.sum(~pred & true)
                out[split] = float(2 * tp / max(2 * tp + fp + fn, 1))
            else:
                pred = logits[mask].argmax(-1)
                out[split] = float(np.mean(pred == ds.labels[mask]))
        return out
