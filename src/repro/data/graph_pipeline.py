"""End-to-end graph data pipeline: dataset -> normalization -> partition ->
padded shards -> device arrays. One call site for every example/benchmark.

The `agg` knob mirrors ``ModelConfig.agg``: building with
``agg="blocksparse"`` additionally extracts the per-partition block-sparse
tile streams onto the Topology, so either aggregation engine can run on the
same partitioned graph (the COO shards are always present)."""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.pipegcn import ShardedData, Topology, shard_data, topology_from
from repro.graph.csr import mean_normalized, sym_normalized
from repro.graph.halo import PartitionedGraph, build_partitioned_graph
from repro.graph.partition import partition_graph
from repro.graph.synthetic import GraphDataset, make_dataset


@dataclasses.dataclass
class GraphDataPipeline:
    dataset: GraphDataset
    pg: PartitionedGraph
    topo: Topology
    train_data: ShardedData
    val_data: ShardedData
    test_data: ShardedData
    agg: str = "coo"

    @staticmethod
    def build(name_or_ds, num_parts: int, kind: str = "sage",
              seed: int = 0, partition_method: str = "bfs+refine",
              agg: str = "coo") -> "GraphDataPipeline":
        ds = (make_dataset(name_or_ds) if isinstance(name_or_ds, str)
              else name_or_ds)
        prop = mean_normalized(ds.graph) if kind == "sage" else sym_normalized(ds.graph)
        part = partition_graph(ds.graph, num_parts, seed=seed,
                               method=partition_method)
        pg = build_partitioned_graph(prop, part, num_parts)
        topo = topology_from(pg, with_tiles=(agg == "blocksparse"))
        mk = lambda m: shard_data(pg, ds.features, ds.labels, ds.train_mask, m)
        return GraphDataPipeline(
            dataset=ds, pg=pg, topo=topo,
            train_data=mk(ds.val_mask),
            val_data=mk(ds.val_mask),
            test_data=mk(ds.test_mask), agg=agg)

    def metric(self, logits_packed) -> dict:
        """Global accuracy (single-label) or F1-micro (multilabel) on
        train/val/test splits, computed from packed (P, max_inner, C) logits."""
        ds = self.dataset
        logits = self.pg.unpack_nodes(np.asarray(logits_packed))
        out = {}
        for split, mask in (("train", ds.train_mask), ("val", ds.val_mask),
                            ("test", ds.test_mask)):
            if ds.multilabel:
                pred = logits[mask] > 0
                true = ds.labels[mask] > 0.5
                tp = np.sum(pred & true)
                fp = np.sum(pred & ~true)
                fn = np.sum(~pred & true)
                out[split] = float(2 * tp / max(2 * tp + fp + fn, 1))
            else:
                pred = logits[mask].argmax(-1)
                out[split] = float(np.mean(pred == ds.labels[mask]))
        return out
