"""Token data pipeline for the assigned transformer architectures.

Offline container: batches are synthesized from a deterministic counter-based
generator (structured enough that loss decreases: Zipf-distributed unigrams
mixed with copy patterns, so a model can learn local statistics).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    """Endless synthetic token batches for the LM workload: Zipf-distributed
    ids with injected copy structure, yielded as (batch_size, seq_len)
    input/target dicts. Deterministic per `seed`."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0

    def __iter__(self):
        rng = np.random.default_rng(self.seed)
        zipf_p = 1.0 / np.arange(1, self.vocab_size + 1) ** 1.1
        zipf_p /= zipf_p.sum()
        while True:
            base = rng.choice(self.vocab_size, p=zipf_p,
                              size=(self.batch_size, self.seq_len))
            # inject copy structure: second half repeats first half shifted
            half = self.seq_len // 2
            base[:, half:half * 2] = base[:, :half]
            yield {"tokens": base.astype(np.int32),
                   "labels": np.roll(base, -1, axis=1).astype(np.int32)}


def synthetic_token_batches(vocab_size: int, seq_len: int, batch_size: int,
                            num_batches: int, seed: int = 0):
    it = iter(TokenStream(vocab_size, seq_len, batch_size, seed))
    return [next(it) for _ in range(num_batches)]
