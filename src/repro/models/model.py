"""Language-model assembly for the assigned architecture pool.

A model is a list of *layer groups*: maximal runs of identical layer specs.
Runs of length ≥ 2 are executed with ``lax.scan`` over stacked parameters
(keeps HLO small enough to SPMD-partition 64-layer models); singleton runs
are applied directly. Heterogeneous archs (recurrentgemma's r-r-a pattern,
llama-vision's every-5th cross-attn layer) fall out of the same grouping.

Three entry points per model:
  loss_fn(params, batch)                  training forward + CE loss
  prefill(params, batch)                  fill caches, return last logits
  decode_step(params, token, caches, pos) one-token serve step
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import attention as attn
from repro.models import mla as mla_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssd as ssd_mod
from repro.models.config import ArchConfig
from repro.models.layers import (apply_embed, apply_mlp, apply_norm,
                                 embed_spec, init_embed, init_mlp, init_norm,
                                 make_dense, mlp_spec, norm_spec,
                                 sinusoidal_positions)
from repro.models.shardctx import constrain

MOE_AUX_COEF = 0.01


class LayerSpec(NamedTuple):
    """Shape of one decoder layer: which sequence mixer it runs, whether a
    cross-attention sublayer follows, and which FFN kind closes it."""

    mixer: str          # attn | mla | ssd | rglru | xattn
    cross: bool         # additional cross-attn sublayer (whisper decoder)
    ffn: str            # dense | moe | none
    causal: bool = True


def decoder_layer_specs(cfg: ArchConfig) -> list[LayerSpec]:
    kinds = cfg.layer_kinds()
    specs = []
    for i, kind in enumerate(kinds):
        mixer = kind
        if cfg.use_mla and kind == "attn":
            mixer = "mla"
        ffn = "none" if cfg.family == "ssm" else cfg.ffn_kind(i)
        cross = cfg.is_encdec   # whisper decoder: self + cross each layer
        specs.append(LayerSpec(mixer, cross, ffn, causal=True))
    return specs


def group_specs(specs: list[LayerSpec]) -> list[tuple[LayerSpec, int]]:
    groups: list[tuple[LayerSpec, int]] = []
    for s in specs:
        if groups and groups[-1][0] == s:
            groups[-1] = (s, groups[-1][1] + 1)
        else:
            groups.append((s, 1))
    return groups


# ------------------------------------------------------------------ layers

def init_layer(key, cfg: ArchConfig, spec: LayerSpec, dtype):
    ks = jax.random.split(key, 6)
    p: dict[str, Any] = {"ln1": init_norm(dtype, cfg.d_model, cfg.norm)}
    if spec.mixer == "attn":
        p["mixer"] = attn.init_attention(ks[0], cfg, dtype)
    elif spec.mixer == "xattn":
        p["mixer"] = attn.init_attention(ks[0], cfg, dtype, cross=True)
    elif spec.mixer == "mla":
        p["mixer"] = mla_mod.init_mla(ks[0], cfg, dtype)
    elif spec.mixer == "ssd":
        p["mixer"] = ssd_mod.init_ssd(ks[0], cfg, dtype)
    elif spec.mixer == "rglru":
        p["mixer"] = rglru_mod.init_rglru(ks[0], cfg, dtype)
    else:
        raise ValueError(spec.mixer)
    if spec.cross:
        p["lnx"] = init_norm(dtype, cfg.d_model, cfg.norm)
        p["xattn"] = attn.init_attention(ks[1], cfg, dtype, cross=True)
    if spec.ffn != "none":
        p["ln2"] = init_norm(dtype, cfg.d_model, cfg.norm)
        if spec.ffn == "moe":
            p["ffn"] = moe_mod.init_moe(ks[2], cfg, dtype)
        else:
            p["ffn"] = init_mlp(ks[2], dtype, cfg.d_model, cfg.d_ff, cfg.act,
                                bias=(cfg.norm == "layernorm"))
    return p


def layer_spec_tree(cfg: ArchConfig, spec: LayerSpec):
    p: dict[str, Any] = {"ln1": norm_spec(cfg.norm)}
    if spec.mixer in ("attn", "xattn"):
        p["mixer"] = attn.attention_spec(cfg, cross=spec.mixer == "xattn")
    elif spec.mixer == "mla":
        p["mixer"] = mla_mod.mla_spec(cfg)
    elif spec.mixer == "ssd":
        p["mixer"] = ssd_mod.ssd_spec(cfg)
    elif spec.mixer == "rglru":
        p["mixer"] = rglru_mod.rglru_spec(cfg)
    if spec.cross:
        p["lnx"] = norm_spec(cfg.norm)
        p["xattn"] = attn.attention_spec(cfg, cross=True)
    if spec.ffn != "none":
        p["ln2"] = norm_spec(cfg.norm)
        p["ffn"] = (moe_mod.moe_spec(cfg) if spec.ffn == "moe"
                    else mlp_spec(cfg.act, bias=(cfg.norm == "layernorm")))
    return p


def apply_layer(p, cfg: ArchConfig, spec: LayerSpec, x, positions, memory,
                gated_cross: bool, moe_dropless: bool = False):
    """Full-sequence layer (train / prefill-without-cache)."""
    aux = jnp.zeros((), jnp.float32)
    h = apply_norm(p["ln1"], x, cfg.norm)
    if spec.mixer == "attn":
        mix = attn.self_attention(p["mixer"], cfg, h, positions,
                                  use_rope=cfg.use_rope, causal=spec.causal)
    elif spec.mixer == "xattn":
        mix = attn.cross_attention(p["mixer"], cfg, h, memory,
                                   gated=gated_cross)
    elif spec.mixer == "mla":
        mix = mla_mod.mla_self_attention(p["mixer"], cfg, h, positions)
    elif spec.mixer == "ssd":
        mix, _ = ssd_mod.ssd_forward(p["mixer"], cfg, h)
    elif spec.mixer == "rglru":
        mix, _ = rglru_mod.rglru_forward(p["mixer"], cfg, h)
    x = x + mix
    if spec.cross:
        xh = apply_norm(p["lnx"], x, cfg.norm)
        x = x + attn.cross_attention(p["xattn"], cfg, xh, memory)
    if spec.ffn != "none":
        fh = apply_norm(p["ln2"], x, cfg.norm)
        if spec.ffn == "moe":
            f, aux = moe_mod.apply_moe(p["ffn"], cfg, fh,
                                       dropless=moe_dropless)
        else:
            f = apply_mlp(p["ffn"], fh, cfg.act)
        x = x + f
    return x, aux


# ------------------------------------------------------------------ caches

def init_layer_cache(cfg: ArchConfig, spec: LayerSpec, batch, max_len, dtype):
    c: dict[str, Any] = {}
    if spec.mixer == "attn":
        c["kv"] = attn.init_kv_cache(cfg, batch, max_len, dtype)
    elif spec.mixer == "mla":
        c["kv"] = mla_mod.init_mla_cache(cfg, batch, max_len, dtype)
    elif spec.mixer == "ssd":
        c["ssm"] = ssd_mod.init_ssd_cache(cfg, batch, dtype)
    elif spec.mixer == "rglru":
        c["lru"] = rglru_mod.init_rglru_cache(cfg, batch, dtype)
    if spec.mixer == "xattn" or spec.cross:
        k = cfg.num_kv_heads
        hd = cfg.resolved_head_dim
        mem_len = (cfg.num_audio_frames if cfg.is_encdec
                   else cfg.num_image_tokens)
        c["xkv"] = {"k": jnp.zeros((batch, mem_len, k, hd), dtype),
                    "v": jnp.zeros((batch, mem_len, k, hd), dtype)}
    return c


def layer_cache_spec(cfg: ArchConfig, spec: LayerSpec, shard_kv_heads: bool):
    c: dict[str, Any] = {}
    if spec.mixer == "attn":
        c["kv"] = attn.kv_cache_spec(cfg, shard_kv_heads)
    elif spec.mixer == "mla":
        c["kv"] = mla_mod.mla_cache_spec(cfg)
    elif spec.mixer == "ssd":
        c["ssm"] = ssd_mod.ssd_cache_spec(cfg)
    elif spec.mixer == "rglru":
        c["lru"] = rglru_mod.rglru_cache_spec(cfg)
    if spec.mixer == "xattn" or spec.cross:
        mem_len = (cfg.num_audio_frames if cfg.is_encdec
                   else cfg.num_image_tokens)
        if shard_kv_heads:
            xs = P("data", None, "model", None)
        elif mem_len % 16 == 0:
            xs = P("data", "model", None, None)
        else:   # memory is small (encoder frames): replicate across model
            xs = P("data", None, None, None)
        c["xkv"] = {"k": xs, "v": xs}
    return c


def _fill_xkv(p, cfg, memory):
    """Precompute cross-attention K/V from memory (paper-standard serving)."""
    k = memory @ p["wk"]
    v = memory @ p["wv"]
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    kh = k.reshape(*memory.shape[:-1], cfg.num_kv_heads, cfg.resolved_head_dim)
    vh = v.reshape(*memory.shape[:-1], cfg.num_kv_heads, cfg.resolved_head_dim)
    return {"k": kh, "v": vh}


def _cached_cross_attention(p, cfg: ArchConfig, x, xkv, gated: bool):
    b = x.shape[0]
    q = x @ p["wq"]
    if cfg.qkv_bias:
        q = q + p["bq"]
    q = q.reshape(b, x.shape[1], cfg.num_heads, cfg.resolved_head_dim)
    if cfg.qk_norm:
        from repro.models.layers import rms_head_norm
        q = rms_head_norm(p["qnorm"], q)
    scores = attn._gqa_scores(q, xkv["k"]).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = attn._gqa_out(probs, xkv["v"], cfg.num_heads)
    out = out.reshape(b, x.shape[1], -1) @ p["wo"]
    if gated:
        out = jnp.tanh(p["gate"]).astype(out.dtype) * out
    return out


def apply_layer_prefill(p, cfg, spec, x, positions, memory, cache,
                        gated_cross: bool):
    h = apply_norm(p["ln1"], x, cfg.norm)
    newc = dict(cache)
    if spec.mixer == "attn":
        mix, newc["kv"] = attn.prefill_attention(p["mixer"], cfg, h, positions,
                                                 cache["kv"],
                                                 use_rope=cfg.use_rope)
    elif spec.mixer == "mla":
        mix = mla_mod.mla_self_attention(p["mixer"], cfg, h, positions)
        c_kv, k_rope = mla_mod._latents(p["mixer"], cfg, h, positions)
        length = cache["kv"]["c_kv"].shape[1]
        newc["kv"] = {
            "c_kv": jax.lax.dynamic_update_slice(
                cache["kv"]["c_kv"], c_kv[:, -length:], (0, 0, 0)),
            "k_rope": jax.lax.dynamic_update_slice(
                cache["kv"]["k_rope"], k_rope[:, -length:], (0, 0, 0))}
    elif spec.mixer == "ssd":
        mix, state = ssd_mod.ssd_forward(p["mixer"], cfg, h)
        conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
        _, xbc, _ = ssd_mod._split_proj(p["mixer"], cfg, h)
        newc["ssm"] = {"state": state.astype(jnp.float32),
                       "conv": xbc[:, -(cfg.ssm_conv - 1):]}
    elif spec.mixer == "rglru":
        mix, state = rglru_mod.rglru_forward(p["mixer"], cfg, h)
        xr = h @ p["mixer"]["in_rec"]
        newc["lru"] = {"state": state, "conv": xr[:, -(cfg.conv1d_width - 1):]}
    elif spec.mixer == "xattn":
        newc["xkv"] = _fill_xkv(p["mixer"], cfg, memory)
        mix = _cached_cross_attention(p["mixer"], cfg, h, newc["xkv"],
                                      gated_cross)
    x = x + mix
    if spec.cross:
        newc["xkv"] = _fill_xkv(p["xattn"], cfg, memory)
        xh = apply_norm(p["lnx"], x, cfg.norm)
        x = x + _cached_cross_attention(p["xattn"], cfg, xh, newc["xkv"], False)
    if spec.ffn != "none":
        fh = apply_norm(p["ln2"], x, cfg.norm)
        if spec.ffn == "moe":
            f, _ = moe_mod.apply_moe(p["ffn"], cfg, fh, dropless=True)
        else:
            f = apply_mlp(p["ffn"], fh, cfg.act)
        x = x + f
    return x, newc


def apply_layer_decode(p, cfg, spec, x, cache, pos, gated_cross: bool):
    h = apply_norm(p["ln1"], x, cfg.norm)
    newc = dict(cache)
    if spec.mixer == "attn":
        mix, newc["kv"] = attn.decode_attention(p["mixer"], cfg, h,
                                                cache["kv"], pos,
                                                use_rope=cfg.use_rope)
    elif spec.mixer == "mla":
        mix, newc["kv"] = mla_mod.mla_decode(p["mixer"], cfg, h,
                                             cache["kv"], pos)
    elif spec.mixer == "ssd":
        mix, newc["ssm"] = ssd_mod.ssd_decode(p["mixer"], cfg, h, cache["ssm"])
    elif spec.mixer == "rglru":
        mix, newc["lru"] = rglru_mod.rglru_decode(p["mixer"], cfg, h,
                                                  cache["lru"])
    elif spec.mixer == "xattn":
        mix = _cached_cross_attention(p["mixer"], cfg, h, cache["xkv"],
                                      gated_cross)
    x = x + mix
    if spec.cross:
        xh = apply_norm(p["lnx"], x, cfg.norm)
        x = x + _cached_cross_attention(p["xattn"], cfg, xh, cache["xkv"], False)
    if spec.ffn != "none":
        fh = apply_norm(p["ln2"], x, cfg.norm)
        if spec.ffn == "moe":
            f, _ = moe_mod.apply_moe(p["ffn"], cfg, fh, dropless=True)
        else:
            f = apply_mlp(p["ffn"], fh, cfg.act)
        x = x + f
    return x, newc


# ------------------------------------------------------------------ model

@dataclasses.dataclass(frozen=True)
class LM:
    """Decoder-only / encoder-decoder LM over the assigned arch pool."""

    cfg: ArchConfig

    # ------------- construction -------------

    @property
    def dtype(self):
        return jnp.dtype(self.cfg.dtype)

    @property
    def groups(self) -> list[tuple[LayerSpec, int]]:
        return group_specs(decoder_layer_specs(self.cfg))

    @property
    def encoder_groups(self) -> list[tuple[LayerSpec, int]]:
        if not self.cfg.is_encdec:
            return []
        spec = LayerSpec("attn", False, "dense", causal=False)
        return [(spec, self.cfg.encoder_layers)]

    def init_params(self, key) -> dict:
        cfg, dtype = self.cfg, self.dtype
        keys = jax.random.split(key, 8)
        params: dict[str, Any] = {
            "embed": init_embed(keys[0], dtype, cfg.padded_vocab, cfg.d_model),
            "final_norm": init_norm(dtype, cfg.d_model, cfg.norm),
        }
        if not cfg.tie_embeddings:
            params["head"] = {"w": make_dense(keys[1],
                                              (cfg.d_model, cfg.padded_vocab),
                                              dtype, scale=0.02)}

        def stack_init(spec, n, key):
            if n == 1:
                return init_layer(key, cfg, spec, dtype)
            return jax.vmap(lambda k: init_layer(k, cfg, spec, dtype))(
                jax.random.split(key, n))

        params["layers"] = [stack_init(spec, n, jax.random.fold_in(keys[2], i))
                            for i, (spec, n) in enumerate(self.groups)]
        if cfg.is_encdec:
            params["enc_layers"] = [
                stack_init(spec, n, jax.random.fold_in(keys[3], i))
                for i, (spec, n) in enumerate(self.encoder_groups)]
            params["enc_norm"] = init_norm(dtype, cfg.d_model, cfg.norm)
        return params

    def param_specs(self) -> dict:
        cfg = self.cfg
        specs: dict[str, Any] = {
            "embed": embed_spec(),
            "final_norm": norm_spec(cfg.norm),
        }
        if not cfg.tie_embeddings:
            specs["head"] = {"w": P(None, "model")}

        def stacked(spec, n):
            tree = layer_spec_tree(cfg, spec)
            if n == 1:
                return tree
            return jax.tree.map(
                lambda ps: P(*((None,) + tuple(ps))), tree,
                is_leaf=lambda x: isinstance(x, P))

        specs["layers"] = [stacked(spec, n) for spec, n in self.groups]
        if cfg.is_encdec:
            specs["enc_layers"] = [stacked(spec, n)
                                   for spec, n in self.encoder_groups]
            specs["enc_norm"] = norm_spec(cfg.norm)
        return specs

    # ------------- embedding / memory -------------

    def _embed(self, params, tokens, positions):
        cfg = self.cfg
        x = apply_embed(params["embed"], tokens)
        x = constrain(x, "residual")
        if cfg.scale_embed:
            x = x * jnp.sqrt(cfg.d_model).astype(x.dtype)
        if not cfg.use_rope:
            pe = jnp.asarray(sinusoidal_positions(int(1), cfg.d_model))
            # computed on the fly from positions (supports decode at any pos)
            pos_emb = _sinusoid_at(positions, cfg.d_model).astype(x.dtype)
            x = x + pos_emb
        return x

    def _encode(self, params, memory_embed):
        """Run the (whisper) encoder over stubbed frame embeddings."""
        cfg = self.cfg
        s = memory_embed.shape[1]
        pe = _sinusoid_at(jnp.arange(s)[None], cfg.d_model)
        x = memory_embed + pe.astype(memory_embed.dtype)
        for gp, (spec, n) in zip(params["enc_layers"], self.encoder_groups):
            x = self._group_forward(gp, spec, n, x,
                                    jnp.arange(s), None)[0]
        return apply_norm(params["enc_norm"], x, cfg.norm)

    def _memory(self, params, batch):
        cfg = self.cfg
        if cfg.is_encdec:
            return self._encode(params, batch["audio_embed"])
        if cfg.num_image_tokens:
            return batch["image_embed"]
        return None

    # ------------- grouped execution -------------

    def _group_forward(self, gp, spec, n, x, positions, memory,
                       moe_dropless=False):
        cfg = self.cfg
        gated = bool(cfg.cross_attn_every)

        def body(carry, lp):
            carry = constrain(carry, "residual")
            out, aux = apply_layer(lp, cfg, spec, carry, positions, memory,
                                   gated, moe_dropless)
            return constrain(out, "residual"), aux

        if cfg.remat:
            body = jax.checkpoint(body)
        if n == 1:
            x, aux = body(x, gp)
            return x, aux
        x, auxs = jax.lax.scan(body, x, gp)
        return x, jnp.sum(auxs)

    # ------------- public entry points -------------

    def forward_logits(self, params, batch, moe_dropless=False):
        """Full-sequence forward -> (logits, moe_aux)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        positions = jnp.arange(tokens.shape[1])
        memory = self._memory(params, batch)
        x = self._embed(params, tokens, positions[None])
        aux_total = jnp.zeros((), jnp.float32)
        for gp, (spec, n) in zip(params["layers"], self.groups):
            x, aux = self._group_forward(gp, spec, n, x, positions, memory,
                                         moe_dropless)
            aux_total = aux_total + aux
        x = apply_norm(params["final_norm"], x, cfg.norm)
        return self._logits(params, x), aux_total

    def loss_fn(self, params, batch):
        """Training forward + causal CE loss. batch: tokens, labels [+stubs]."""
        logits, aux_total = self.forward_logits(params, batch)
        labels = batch["labels"]
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        ll = jnp.take_along_axis(
            logits.astype(jnp.float32),
            labels[..., None].astype(jnp.int32), axis=-1)[..., 0]
        loss = jnp.mean(lse - ll)
        return loss + MOE_AUX_COEF * aux_total

    def _logits(self, params, x):
        if self.cfg.tie_embeddings:
            logits = x @ params["embed"]["table"].T
        else:
            logits = x @ params["head"]["w"]
        logits = constrain(logits, "logits")
        if self.cfg.padded_vocab != self.cfg.vocab_size:
            pad_mask = jnp.arange(self.cfg.padded_vocab) < self.cfg.vocab_size
            logits = jnp.where(pad_mask, logits, -1e30)
        return logits

    # ------------- serving -------------

    def init_caches(self, batch: int, max_len: int):
        dtype = self.dtype
        caches = []
        for spec, n in self.groups:
            one = lambda: init_layer_cache(self.cfg, spec, batch, max_len,
                                           dtype)
            if n == 1:
                caches.append(one())
            else:
                caches.append(jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (n,) + x.shape), one()))
        return caches

    def cache_specs(self, shard_kv_heads: bool):
        out = []
        for spec, n in self.groups:
            tree = layer_cache_spec(self.cfg, spec, shard_kv_heads)
            if n > 1:
                tree = jax.tree.map(
                    lambda ps: P(*((None,) + tuple(ps))), tree,
                    is_leaf=lambda x: isinstance(x, P))
            out.append(tree)
        return out

    def prefill(self, params, batch, caches):
        """Run the full prompt, filling caches; returns (last_logits, caches)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        positions = jnp.arange(tokens.shape[1])
        memory = self._memory(params, batch)
        x = self._embed(params, tokens, positions[None])
        gated = bool(cfg.cross_attn_every)
        new_caches = []
        for gp, cache, (spec, n) in zip(params["layers"], caches, self.groups):
            def body(carry, xs):
                lp, c = xs
                carry = constrain(carry, "residual")
                out, newc = apply_layer_prefill(lp, cfg, spec, carry,
                                                positions, memory, c, gated)
                return constrain(out, "residual"), newc
            if cfg.remat:
                body = jax.checkpoint(body)
            if n == 1:
                x, newc = body(x, (gp, cache))
            else:
                x, newc = jax.lax.scan(body, x, (gp, cache))
            new_caches.append(newc)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        return self._logits(params, x[:, -1:]), new_caches

    def decode_step(self, params, token, caches, pos, batch_extras=None):
        """One serve step: token (B,1) at absolute position `pos`."""
        cfg = self.cfg
        posv = jnp.full((token.shape[0], 1), pos)
        x = self._embed(params, token, posv)
        gated = bool(cfg.cross_attn_every)
        new_caches = []
        for gp, cache, (spec, n) in zip(params["layers"], caches, self.groups):
            def body(carry, xs):
                lp, c = xs
                carry = constrain(carry, "residual")
                out, newc = apply_layer_decode(lp, cfg, spec, carry, c, pos,
                                               gated)
                return constrain(out, "residual"), newc
            if n == 1:
                x, newc = body(x, (gp, cache))
            else:
                x, newc = jax.lax.scan(body, x, (gp, cache))
            new_caches.append(newc)
        x = apply_norm(params["final_norm"], x, cfg.norm)
        return self._logits(params, x), new_caches


def _sinusoid_at(positions, dim):
    """Sinusoidal embedding evaluated at given positions: (..., S) -> (..., S, dim)."""
    half = dim // 2
    i = jnp.arange(half, dtype=jnp.float32)
    inv = 1.0 / (10000.0 ** (2 * i / dim))
    ang = positions[..., None].astype(jnp.float32) * inv
    out = jnp.zeros(positions.shape + (dim,), jnp.float32)
    out = out.at[..., 0::2].set(jnp.sin(ang))
    out = out.at[..., 1::2].set(jnp.cos(ang))
    return out
