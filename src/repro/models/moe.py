"""Mixture-of-Experts FFN with top-k token-choice routing and capacity-bound
dispatch (gather → grouped expert GEMM → weighted scatter-add).

Dispatch is expressed as dense gathers so it lowers cleanly under SPMD with
experts sharded on the `model` mesh axis (expert parallelism → the gathers
become all-to-alls, the paper-typical MoE communication pattern).  Capacity
dropping is weight-prioritized (per-expert top-C over routed tokens), the
standard TPU-friendly formulation.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import apply_mlp, init_mlp, make_dense, mlp_spec
from repro.models.shardctx import constrain


def init_moe(key, cfg: ArchConfig, dtype):
    e, d, f = cfg.num_experts, cfg.d_model, cfg.moe_d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": make_dense(ks[0], (d, e), dtype, scale=0.02),
        "wi": make_dense(ks[1], (e, d, f), dtype),
        "wg": make_dense(ks[2], (e, d, f), dtype),
        "wo": make_dense(ks[3], (e, f, d), dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], dtype, d,
                               f * cfg.num_shared_experts, act="swiglu")
    return p


def moe_spec(cfg: ArchConfig):
    p = {"router": P(None, None),
         "wi": P("model", None, None),
         "wg": P("model", None, None),
         "wo": P("model", None, None)}
    if cfg.num_shared_experts:
        p["shared"] = mlp_spec(act="swiglu")
    return p


def apply_moe(p, cfg: ArchConfig, x, dropless: bool = False):
    """x: (B, S, D) -> (B, S, D); also returns aux (load-balance stats).

    dropless=True sets capacity = num tokens (exact, no dropping) — used on
    the decode path where a dropped token would corrupt generation.

    cfg.moe_groups > 1 routes within token groups (GShard-style device-local
    capacity): the dispatch gather/scatter stays shard-local under SPMD,
    replacing a full-tensor all-reduce per layer with local movement. With
    dropless=True grouped and global routing are exactly equivalent.
    """
    b, s, d = x.shape
    t = b * s
    g = max(1, min(cfg.moe_groups, t))
    if g > 1 and t % g == 0:
        out, aux = _moe_grouped(p, cfg, x.reshape(g, t // g, d), dropless)
        return out.reshape(b, s, d).astype(x.dtype), aux
    out, aux = _moe_block(p, cfg, x.reshape(t, d), dropless)
    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_grouped(p, cfg: ArchConfig, xg, dropless: bool):
    """Group-local routing, written natively in 4D so SPMD keeps the
    dispatch gather/scatter local to each token group (= data shard) and
    the expert GEMMs sharded over the `model` axis."""
    g, tg, d = xg.shape
    e, k = cfg.num_experts, cfg.experts_per_tok
    xg = constrain(xg, "moe_tokens")                           # (G,Tg,D)

    logits = (xg @ p["router"]).astype(jnp.float32)            # (G,Tg,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                     # (G,Tg,k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    combine = jnp.zeros((g, tg, e), jnp.float32)
    gi = jnp.arange(g)[:, None, None]
    ti = jnp.arange(tg)[None, :, None]
    combine = combine.at[gi, ti, top_i].set(top_w)             # (G,Tg,E)

    if dropless:
        cap = tg
    else:
        cap = int(max(1, round(tg * k / e * cfg.capacity_factor)))
        cap = min(cap, tg)
    score = jnp.where(combine > 0, combine, -1.0)
    score = jnp.swapaxes(score, 1, 2)                          # (G,E,Tg)
    sel_w, sel_t = jax.lax.top_k(score, cap)                   # (G,E,C)
    valid = sel_w > 0

    gathered = jnp.take_along_axis(xg[:, None], sel_t[..., None], axis=2)
    gathered = constrain(gathered, "moe_gathered")             # (G,E,C,D)
    h = jnp.einsum("gecd,edf->gecf", gathered, p["wi"])
    hh = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", gathered, p["wg"])
    y = jnp.einsum("gecf,efd->gecd", hh, p["wo"])
    y = constrain(y, "moe_gathered")
    y = y * (sel_w * valid)[..., None].astype(y.dtype)

    out = jnp.zeros((g, tg, d), y.dtype)
    out = out.at[gi[..., None], sel_t[..., None],
                 jnp.arange(d)[None, None, None]].add(y)
    out = constrain(out, "moe_tokens")

    if cfg.num_shared_experts:
        out = out + apply_mlp(p["shared"], xg, act="swiglu")

    density = jnp.mean((combine > 0).astype(jnp.float32), axis=(0, 1))
    mean_prob = jnp.mean(probs, axis=(0, 1))
    aux_loss = e * jnp.sum(density * mean_prob)
    return out, aux_loss


def _moe_block(p, cfg: ArchConfig, xf, dropless: bool):
    """Routing + expert compute for one token block xf: (T, D)."""
    t, d = xf.shape
    e, k = cfg.num_experts, cfg.experts_per_tok

    logits = (xf @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(probs, k)                    # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # (T, E) combine weights restricted to the top-k choices
    combine = jnp.zeros((t, e), jnp.float32)
    combine = combine.at[jnp.arange(t)[:, None], top_i].set(top_w)

    # capacity: per-expert top-C tokens by combine weight
    if dropless:
        cap = t
    else:
        cap = int(max(1, round(t * k / e * cfg.capacity_factor)))
        cap = min(cap, t)
    score = jnp.where(combine.T > 0, combine.T, -1.0)         # (E, T)
    sel_w, sel_t = jax.lax.top_k(score, cap)                  # (E, C)
    valid = sel_w > 0

    gathered = constrain(xf[sel_t], "moe_expert")             # (E, C, D)
    h = jnp.einsum("ecd,edf->ecf", gathered, p["wi"])
    g = jnp.einsum("ecd,edf->ecf", gathered, p["wg"])
    h = jax.nn.silu(h) * g
    y = jnp.einsum("ecf,efd->ecd", h, p["wo"])                # (E, C, D)
    y = constrain(y, "moe_expert")
    y = y * (sel_w * valid)[..., None].astype(y.dtype)

    out = jnp.zeros((t, d), y.dtype).at[sel_t.reshape(-1)].add(
        y.reshape(e * cap, d))

    if cfg.num_shared_experts:
        out = out + apply_mlp(p["shared"], xf, act="swiglu")

    # aux stats for the load-balance loss (Switch-style)
    density = jnp.mean((combine > 0).astype(jnp.float32), axis=0)   # frac routed
    mean_prob = jnp.mean(probs, axis=0)
    aux_loss = e * jnp.sum(density * mean_prob)
    return out, aux_loss
