"""Shared neural-net layers: norms, RoPE, MLPs, embeddings.

Parameters are plain dict pytrees; every init function has a matching
`*_spec` producing jax.sharding.PartitionSpec leaves for the dry-run
sharding rules (model axis = tensor parallel, data axis = batch/sequence).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P


def make_dense(key, shape, dtype, scale=None):
    scale = scale if scale is not None else (1.0 / np.sqrt(shape[0]))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------- norms

def init_norm(dtype, dim, kind="rmsnorm"):
    p = {"scale": jnp.ones((dim,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((dim,), dtype)
    return p


def norm_spec(kind="rmsnorm"):
    p = {"scale": P(None)}
    if kind == "layernorm":
        p["bias"] = P(None)
    return p


def apply_norm(p, x, kind="rmsnorm", eps=1e-6):
    xf = x.astype(jnp.float32)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_head_norm(scale, x, eps=1e-6):
    """Per-head RMS norm over head_dim (qwen3 qk_norm). x: (..., H, hd)."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------- RoPE

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / theta ** (np.arange(0, head_dim, 2, dtype=np.float64) / head_dim)


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(hd, theta), jnp.float32)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]                               # (..., S, 1, hd/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., ::2], x[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    out = jnp.stack([y1, y2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, dim: int) -> np.ndarray:
    pos = np.arange(seq_len)[:, None]
    i = np.arange(dim // 2)[None, :]
    angle = pos / 10000 ** (2 * i / dim)
    out = np.zeros((seq_len, dim), np.float32)
    out[:, 0::2] = np.sin(angle)
    out[:, 1::2] = np.cos(angle)
    return out


# ---------------------------------------------------------------- MLP

def init_mlp(key, dtype, d_model, d_ff, act="swiglu", bias=False):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {}
    if act in ("swiglu", "geglu"):
        p["wi"] = make_dense(k1, (d_model, d_ff), dtype)
        p["wg"] = make_dense(k2, (d_model, d_ff), dtype)
    else:
        p["wi"] = make_dense(k1, (d_model, d_ff), dtype)
    p["wo"] = make_dense(k3, (d_ff, d_model), dtype)
    if bias:
        p["bi"] = jnp.zeros((d_ff,), dtype)
        p["bo"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_spec(act="swiglu", bias=False):
    p = {"wi": P(None, "model"), "wo": P("model", None)}
    if act in ("swiglu", "geglu"):
        p["wg"] = P(None, "model")
    if bias:
        p["bi"] = P("model")
        p["bo"] = P(None)
    return p


def apply_mlp(p, x, act="swiglu"):
    h = x @ p["wi"]
    if "bi" in p:
        h = h + p["bi"]
    if act == "swiglu":
        h = jax.nn.silu(h) * (x @ p["wg"])
    elif act == "geglu":
        h = jax.nn.gelu(h) * (x @ p["wg"])
    else:
        h = jax.nn.gelu(h)
    out = h @ p["wo"]
    if "bo" in p:
        out = out + p["bo"]
    return out


# ---------------------------------------------------------------- embed/unembed

def init_embed(key, dtype, vocab, d_model):
    return {"table": make_dense(key, (vocab, d_model), dtype, scale=0.02)}


def embed_spec():
    return {"table": P("model", None)}


def apply_embed(p, tokens):
    return p["table"][tokens]


def unembed_logits(embed_params, head, x, tie: bool):
    if tie:
        return x @ embed_params["table"].T
    return x @ head["w"]
