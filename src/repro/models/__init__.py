"""Assigned-architecture model zoo (dense / MoE / MLA / SSM / hybrid / VLM /
enc-dec) with train, prefill, and decode entry points."""
from repro.models.config import ArchConfig, InputShape, INPUT_SHAPES
from repro.models.model import LM

__all__ = ["ArchConfig", "InputShape", "INPUT_SHAPES", "LM"]
