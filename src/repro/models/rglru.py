"""RecurrentGemma recurrent block — RG-LRU (arXiv:2402.19427).

Block: x -> (gate branch: linear+GeLU) ⊙ (recurrent branch: linear ->
causal conv1d -> RG-LRU) -> output linear.

RG-LRU per channel:
  r_t = σ(W_r x_t),  i_t = σ(W_i x_t)
  log a_t = -c · softplus(Λ) · r_t          (c = 8)
  h_t = a_t · h_{t-1} + sqrt(1 - a_t²) · (i_t ⊙ x_t)

Training uses an associative scan over the length axis (sub-quadratic,
parallel); decode is the single-step recurrence.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import make_dense

_C = 8.0


def init_rglru(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    w = cfg.lru_width or d
    ks = jax.random.split(key, 6)
    return {
        "in_gate": make_dense(ks[0], (d, w), dtype),
        "in_rec": make_dense(ks[1], (d, w), dtype),
        "conv_w": make_dense(ks[2], (cfg.conv1d_width, w), dtype, scale=0.2),
        "conv_b": jnp.zeros((w,), dtype),
        "w_r": make_dense(ks[3], (w, w), dtype),
        "w_i": make_dense(ks[4], (w, w), dtype),
        "lam": jnp.full((w,), 0.7, jnp.float32),   # Λ init within (0,1) band
        "out": make_dense(ks[5], (w, d), dtype),
    }


def rglru_spec(cfg: ArchConfig):
    return {"in_gate": P(None, "model"), "in_rec": P(None, "model"),
            "conv_w": P(None, "model"), "conv_b": P("model"),
            "w_r": P(None, "model"), "w_i": P(None, "model"),
            "lam": P("model"), "out": P("model", None)}


def _conv(p, x):
    k = p["conv_w"].shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i:i + x.shape[1]] * p["conv_w"][i]
               for i in range(k)) + p["conv_b"]


def _gates(p, x):
    r = jax.nn.sigmoid((x @ p["w_r"]).astype(jnp.float32))
    i = jax.nn.sigmoid((x @ p["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.clip(1.0 - jnp.square(a), 1e-12, None))
    return a, mult * i * x.astype(jnp.float32)


def rglru_forward(p, cfg: ArchConfig, u):
    """(B, L, D) -> (B, L, D); returns final recurrent state (B, W)."""
    gate = jax.nn.gelu(u @ p["in_gate"])
    x = _conv(p, u @ p["in_rec"])
    a, b = _gates(p, x)                    # (B, L, W) f32 each

    # associative scan of h_t = a_t h_{t-1} + b_t
    def comb(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    state = h[:, -1]
    y = (h.astype(u.dtype) * gate) @ p["out"]
    return y, state


def init_rglru_cache(cfg: ArchConfig, batch: int, dtype):
    w = cfg.lru_width or cfg.d_model
    return {"state": jnp.zeros((batch, w), jnp.float32),
            "conv": jnp.zeros((batch, cfg.conv1d_width - 1, w), dtype)}


def rglru_cache_spec(cfg: ArchConfig):
    return {"state": P("data", "model"), "conv": P("data", None, "model")}


def rglru_decode(p, cfg: ArchConfig, u, cache):
    gate = jax.nn.gelu(u @ p["in_gate"])              # (B, 1, W)
    xr = u @ p["in_rec"]
    hist = jnp.concatenate([cache["conv"], xr], axis=1)
    x = (jnp.sum(hist * p["conv_w"][None], axis=1, keepdims=True)
         + p["conv_b"])
    a, b = _gates(p, x)                               # (B, 1, W)
    state = a[:, 0] * cache["state"] + b[:, 0]
    y = (state[:, None].astype(u.dtype) * gate) @ p["out"]
    return y, {"state": state, "conv": hist[:, 1:]}
