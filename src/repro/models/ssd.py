"""Mamba-2 SSD (state-space duality) mixer — arXiv:2405.21060.

Chunked SSD algorithm: within-chunk attention-like dual form + inter-chunk
recurrence over chunk states via `lax.scan` (sequential in the number of
chunks only).  Decode is the pure recurrent form with a (B, H, P, N) state
and a conv ring buffer.

Shapes: d_inner = expand·d_model, H = d_inner/headdim heads, P = headdim,
N = ssm_state, G = ssm_groups (B/C shared across H/G heads per group).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import make_dense


def init_ssd(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    di = cfg.d_inner
    h = cfg.ssm_nheads
    g, n = cfg.ssm_groups, cfg.ssm_state
    conv_ch = di + 2 * g * n
    ks = jax.random.split(key, 4)
    proj_out = 2 * di + 2 * g * n + h        # [z, x, B, C, dt]
    return {
        "in_proj": make_dense(ks[0], (d, proj_out), dtype),
        "conv_w": make_dense(ks[1], (cfg.ssm_conv, conv_ch), dtype, scale=0.2),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "d_skip": jnp.ones((h,), jnp.float32),
        "out_proj": make_dense(ks[2], (di, d), dtype),
        "norm_scale": jnp.ones((di,), dtype),   # gated RMSNorm before out_proj
    }


def ssd_spec(cfg: ArchConfig):
    return {"in_proj": P(None, "model"), "conv_w": P(None, "model"),
            "conv_b": P("model"), "a_log": P("model"), "dt_bias": P("model"),
            "d_skip": P("model"), "out_proj": P("model", None),
            "norm_scale": P("model")}


def _split_proj(p, cfg: ArchConfig, u):
    di, g, n, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_nheads
    zxbcdt = u @ p["in_proj"]
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * g * n]
    dt = zxbcdt[..., -h:]
    return z, xbc, dt


def _causal_conv(p, xbc):
    """Depthwise causal conv1d, width K: y_t = sum_k w_k x_{t-K+1+k}."""
    k = p["conv_w"].shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * p["conv_w"][i] for i in range(k))
    return jax.nn.silu(out + p["conv_b"])


def _gated_norm(p, y, z, eps=1e-6):
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * p["norm_scale"].astype(jnp.float32)
            ).astype(y.dtype)


def ssd_forward(p, cfg: ArchConfig, u):
    """Training/prefill: (B, L, D) -> (B, L, D), returns final ssm state."""
    bsz, L0, _ = u.shape
    di, g, n, h, hp = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                       cfg.ssm_nheads, cfg.ssm_headdim)
    q = cfg.ssm_chunk
    # pad ragged tails; padded steps get dt=0 (decay 1, contribution 0) so
    # the final state equals the state at the last real token.
    L = -(-L0 // q) * q
    pad = L - L0
    if pad:
        u = jnp.pad(u, ((0, 0), (0, pad), (0, 0)))
    nc = L // q

    z, xbc, dt = _split_proj(p, cfg, u)
    xbc = _causal_conv(p, xbc)
    x = xbc[..., :di].reshape(bsz, L, h, hp)
    b_in = xbc[..., di:di + g * n].reshape(bsz, L, g, n)
    c_in = xbc[..., di + g * n:].reshape(bsz, L, g, n)
    # broadcast groups over heads
    rep = h // g
    b_h = jnp.repeat(b_in, rep, axis=2)          # (B, L, H, N)
    c_h = jnp.repeat(c_in, rep, axis=2)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B, L, H)
    if pad:
        live = (jnp.arange(L) < L0).astype(dt.dtype)
        dt = dt * live[None, :, None]
    a = -jnp.exp(p["a_log"])                                      # (H,)
    dta = dt * a                                                  # log decay
    xdt = x * dt[..., None].astype(x.dtype)                       # dt-scaled input

    # chunk views
    def chunks(t, d_extra):
        return t.reshape((bsz, nc, q) + t.shape[2:])
    xc = chunks(xdt, 2)                    # (B, C#, Q, H, P)
    bc = chunks(b_h, 2)                    # (B, C#, Q, H, N)
    cc = chunks(c_h, 2)
    dtac = dta.reshape(bsz, nc, q, h)      # (B, C#, Q, H)

    seg = jnp.cumsum(dtac, axis=2)                             # (B,C#,Q,H)
    seg_last = seg[:, :, -1:]                                  # (B,C#,1,H)

    # intra-chunk (dual / attention-like) term
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]        # (B,C#,Qi,Qj,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    decay = jnp.where(causal[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bcihn,bcjhn->bcijh", cc, bc) * decay.astype(cc.dtype)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc)

    # chunk states: S_c = sum_j exp(seg_last - seg_j) * x_j ⊗ B_j
    w = jnp.exp(seg_last - seg)                                # (B,C#,Q,H)
    states = jnp.einsum("bcjh,bcjhp,bcjhn->bchpn", w.astype(xc.dtype), xc,
                        bc).astype(jnp.float32)

    # inter-chunk recurrence over chunk states (f32 carry for stability and
    # so the scan carry dtype is invariant under bf16 activations)
    chunk_decay = jnp.exp(seg_last[:, :, 0]).astype(jnp.float32)  # (B,C#,H)

    def scan_fn(s_prev, inp):
        st, dec = inp
        s_new = s_prev * dec[..., None, None] + st
        return s_new, s_prev

    init = jnp.zeros((bsz, h, hp, n), states.dtype)
    s_final, s_prevs = jax.lax.scan(
        scan_fn, init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    s_prevs = jnp.moveaxis(s_prevs, 0, 1)                      # (B,C#,H,P,N)

    # inter-chunk contribution: C_i · (exp(seg_i) * S_prev)
    y_inter = jnp.einsum("bcihn,bchpn->bcihp",
                         cc * jnp.exp(seg)[..., None].astype(cc.dtype),
                         s_prevs.astype(cc.dtype))

    y = (y_intra + y_inter).reshape(bsz, L, h, hp)
    y = y + x * p["d_skip"][None, None, :, None].astype(x.dtype)
    y = y.reshape(bsz, L, di)
    y = _gated_norm(p, y, z)
    out = y @ p["out_proj"]
    if pad:
        out = out[:, :L0]
    return out, s_final


# --------------------------------------------------------------- decode

def init_ssd_cache(cfg: ArchConfig, batch: int, dtype):
    h, hp, n = cfg.ssm_nheads, cfg.ssm_headdim, cfg.ssm_state
    conv_ch = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {"state": jnp.zeros((batch, h, hp, n), jnp.float32),
            "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype)}


def ssd_cache_spec(cfg: ArchConfig):
    return {"state": P("data", "model", None, None),
            "conv": P("data", None, "model")}


def ssd_decode(p, cfg: ArchConfig, u, cache):
    """One token: u (B, 1, D) -> (B, 1, D); updates (state, conv ring)."""
    bsz = u.shape[0]
    di, g, n, h, hp = (cfg.d_inner, cfg.ssm_groups, cfg.ssm_state,
                       cfg.ssm_nheads, cfg.ssm_headdim)
    z, xbc, dt = _split_proj(p, cfg, u)
    # conv over (cached K-1 inputs, current)
    hist = jnp.concatenate([cache["conv"], xbc], axis=1)       # (B, K, ch)
    k = p["conv_w"].shape[0]
    conv_out = jnp.sum(hist * p["conv_w"][None], axis=1, keepdims=True)
    xbc_t = jax.nn.silu(conv_out + p["conv_b"])
    new_conv = hist[:, 1:]

    x = xbc_t[..., :di].reshape(bsz, h, hp)
    b_t = jnp.repeat(xbc_t[..., di:di + g * n].reshape(bsz, g, n), h // g, 1)
    c_t = jnp.repeat(xbc_t[..., di + g * n:].reshape(bsz, g, n), h // g, 1)

    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = jnp.exp(dt * -jnp.exp(p["a_log"]))                              # decay
    xdt = x.astype(jnp.float32) * dt[..., None]
    state = (cache["state"] * a[..., None, None]
             + jnp.einsum("bhp,bhn->bhpn", xdt, b_t.astype(jnp.float32)))
    y = jnp.einsum("bhn,bhpn->bhp", c_t.astype(jnp.float32), state)
    y = y + x.astype(jnp.float32) * p["d_skip"][None, :, None]
    y = y.reshape(bsz, 1, di).astype(u.dtype)
    y = _gated_norm(p, y, z)
    return y @ p["out_proj"], {"state": state, "conv": new_conv}
