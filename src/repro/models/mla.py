"""Multi-head Latent Attention (MLA) — DeepSeek-V2 (arXiv:2405.04434).

KV is compressed into a kv_lora_rank latent c_kv plus a shared RoPE key
k_rope; the decode cache stores only (c_kv, k_rope) per token — the paper's
93 % KV-cache reduction. Per-head keys/values are re-expanded from the latent
with up-projections (faithful math; the latent-space absorbed-matmul decode
optimization is a kernel-level rewrite that does not change semantics).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, make_dense

NEG_INF = -1e30


def init_mla(key, cfg: ArchConfig, dtype):
    d = cfg.d_model
    h = cfg.num_heads
    qk_nope, qk_rope, v_dim = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    r = cfg.kv_lora_rank
    ks = jax.random.split(key, 8)
    p = {}
    if cfg.q_lora_rank:
        p["wq_a"] = make_dense(ks[0], (d, cfg.q_lora_rank), dtype)
        p["wq_b"] = make_dense(ks[1], (cfg.q_lora_rank, h * (qk_nope + qk_rope)), dtype)
    else:
        p["wq"] = make_dense(ks[0], (d, h * (qk_nope + qk_rope)), dtype)
    p["wkv_a"] = make_dense(ks[2], (d, r), dtype)            # latent down-proj
    p["wk_rope"] = make_dense(ks[3], (d, qk_rope), dtype)    # shared rope key
    p["wk_b"] = make_dense(ks[4], (r, h * qk_nope), dtype)   # latent -> k_nope
    p["wv_b"] = make_dense(ks[5], (r, h * v_dim), dtype)     # latent -> v
    p["wo"] = make_dense(ks[6], (h * v_dim, d), dtype)
    return p


def mla_spec(cfg: ArchConfig):
    p = {"wkv_a": P(None, None), "wk_rope": P(None, None),
         "wk_b": P(None, "model"), "wv_b": P(None, "model"),
         "wo": P("model", None)}
    if cfg.q_lora_rank:
        p.update(wq_a=P(None, None), wq_b=P(None, "model"))
    else:
        p["wq"] = P(None, "model")
    return p


def _queries(p, cfg: ArchConfig, x, positions):
    h = cfg.num_heads
    qk_nope, qk_rope = cfg.qk_nope_dim, cfg.qk_rope_dim
    if cfg.q_lora_rank:
        q = (x @ p["wq_a"]) @ p["wq_b"]
    else:
        q = x @ p["wq"]
    q = q.reshape(*x.shape[:-1], h, qk_nope + qk_rope)
    q_nope, q_rope = q[..., :qk_nope], q[..., qk_nope:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _latents(p, cfg: ArchConfig, x, positions):
    c_kv = x @ p["wkv_a"]                                   # (B,S,r)
    k_rope = x @ p["wk_rope"]                               # (B,S,rope)
    k_rope = apply_rope(k_rope[..., None, :], positions,
                        cfg.rope_theta)[..., 0, :]
    return c_kv, k_rope


def _expand(p, cfg: ArchConfig, c_kv):
    h = cfg.num_heads
    k_nope = (c_kv @ p["wk_b"]).reshape(*c_kv.shape[:-1], h, cfg.qk_nope_dim)
    v = (c_kv @ p["wv_b"]).reshape(*c_kv.shape[:-1], h, cfg.v_head_dim)
    return k_nope, v


def _attend(p, cfg, q_nope, q_rope, k_nope, k_rope, v, mask):
    scale = 1.0 / jnp.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = (jnp.einsum("bshd,bthd->bsht", q_nope, k_nope)
              + jnp.einsum("bshd,btd->bsht", q_rope, k_rope)) * scale
    scores = jnp.where(mask, scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bsht,bthd->bshd", probs, v)
    return out.reshape(*out.shape[:-2], -1) @ p["wo"]


def mla_self_attention(p, cfg: ArchConfig, x, positions):
    """Training / prefill full-sequence MLA."""
    q_nope, q_rope = _queries(p, cfg, x, positions)
    c_kv, k_rope = _latents(p, cfg, x, positions)
    k_nope, v = _expand(p, cfg, c_kv)
    s = x.shape[1]
    from repro.models import attention as attn_mod
    if s > attn_mod.BLOCKWISE_THRESHOLD and s % attn_mod.Q_BLOCK == 0:
        # expanded MLA is standard MHA: concat nope+rope dims, pad v to match
        h = cfg.num_heads
        q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_full = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_rope[..., None, :],
                                      k_nope.shape[:-1] + (cfg.qk_rope_dim,))],
            axis=-1)
        out = attn_mod.blockwise_attention(q_full, k_full, v, positions,
                                           causal=True,
                                           window=cfg.sliding_window)
        return out.reshape(*x.shape[:-1], -1) @ p["wo"]
    mask = positions[None, :] <= positions[:, None]
    if cfg.sliding_window:
        mask &= positions[:, None] - positions[None, :] < cfg.sliding_window
    return _attend(p, cfg, q_nope, q_rope, k_nope, k_rope, v,
                   mask[None, :, None, :])


def init_mla_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    return {"c_kv": jnp.zeros((batch, length, cfg.kv_lora_rank), dtype),
            "k_rope": jnp.zeros((batch, length, cfg.qk_rope_dim), dtype)}


def mla_cache_spec(cfg: ArchConfig):
    # latent dims are small; shard cache length over model when batch is thin
    return {"c_kv": P("data", "model", None),
            "k_rope": P("data", "model", None)}


def mla_decode(p, cfg: ArchConfig, x, cache, pos):
    b = x.shape[0]
    length = cache["c_kv"].shape[1]
    posv = jnp.full((b, 1), pos)
    q_nope, q_rope = _queries(p, cfg, x, posv)
    c_new, kr_new = _latents(p, cfg, x, posv)
    slot = (pos % length) if cfg.sliding_window else pos
    c_kv = jax.lax.dynamic_update_slice(cache["c_kv"], c_new, (0, slot, 0))
    k_rope = jax.lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, slot, 0))
    k_nope, v = _expand(p, cfg, c_kv)
    idx = jnp.arange(length)
    if cfg.sliding_window:
        written = jnp.where(idx <= slot, idx + (pos - slot),
                            idx + (pos - slot) - length)
        valid = written >= 0
    else:
        valid = idx <= pos
    out = _attend(p, cfg, q_nope, q_rope, k_nope, k_rope, v,
                  valid[None, None, None, :])
    return out, {"c_kv": c_kv, "k_rope": k_rope}
