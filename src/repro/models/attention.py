"""Attention: GQA self-attention (full / causal / sliding-window), cross-
attention, and single-token decode against full or ring (sliding-window)
KV caches. Pure-jnp reference math; the Pallas flash kernel plugs in at the
model level for the prefill hot path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, make_dense, rms_head_norm

NEG_INF = -1e30


def init_attention(key, cfg: ArchConfig, dtype, cross: bool = False):
    d, h, k = cfg.d_model, cfg.num_heads, cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    keys = jax.random.split(key, 4)
    p = {
        "wq": make_dense(keys[0], (d, h * hd), dtype),
        "wk": make_dense(keys[1], (d, k * hd), dtype),
        "wv": make_dense(keys[2], (d, k * hd), dtype),
        "wo": make_dense(keys[3], (h * hd, d), dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), dtype)
        p["bk"] = jnp.zeros((k * hd,), dtype)
        p["bv"] = jnp.zeros((k * hd,), dtype)
    if cfg.qk_norm:
        p["qnorm"] = jnp.ones((hd,), dtype)
        p["knorm"] = jnp.ones((hd,), dtype)
    if cross:
        p["gate"] = jnp.zeros((), dtype)   # llama-vision tanh gate
    return p


def attention_spec(cfg: ArchConfig, cross: bool = False):
    p = {"wq": P(None, "model"), "wk": P(None, "model"),
         "wv": P(None, "model"), "wo": P("model", None)}
    if cfg.qkv_bias:
        p.update(bq=P("model"), bk=P("model"), bv=P("model"))
    if cfg.qk_norm:
        p.update(qnorm=P(None), knorm=P(None))
    if cross:
        p["gate"] = P()
    return p


def _project_qkv(p, cfg: ArchConfig, xq, xkv):
    h, k, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = xq @ p["wq"]
    kk = xkv @ p["wk"]
    v = xkv @ p["wv"]
    if cfg.qkv_bias:
        q, kk, v = q + p["bq"], kk + p["bk"], v + p["bv"]
    q = q.reshape(*xq.shape[:-1], h, hd)
    kk = kk.reshape(*xkv.shape[:-1], k, hd)
    v = v.reshape(*xkv.shape[:-1], k, hd)
    if cfg.qk_norm:
        q = rms_head_norm(p["qnorm"], q)
        kk = rms_head_norm(p["knorm"], kk)
    return q, kk, v


def _gqa_scores(q, k):
    """q: (B,S,H,hd), k: (B,T,K,hd) -> (B,S,K,G,T) grouped scores."""
    b, s, h, hd = q.shape
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b, s, kheads, g, hd)
    return jnp.einsum("bskgd,btkd->bskgt", qg, k) / jnp.sqrt(hd).astype(q.dtype)


def _gqa_out(probs, v, h):
    b, s, kheads, g, t = probs.shape
    out = jnp.einsum("bskgt,btkd->bskgd", probs, v)
    return out.reshape(b, s, h, -1)


# Sequences longer than this use the blockwise online-softmax path (never
# materializes the S×S score matrix) — the pure-jnp analogue of the Pallas
# flash kernel, and its numerical oracle.
BLOCKWISE_THRESHOLD = 4096
Q_BLOCK = 1024
KV_BLOCK = 1024


def blockwise_attention(q, k, v, positions, causal: bool, window: int,
                        q_block: int = Q_BLOCK, kv_block: int = KV_BLOCK):
    """Online-softmax attention over (q, kv) blocks.

    q: (B,S,H,hd), k/v: (B,T,K,hd) -> (B,S,H,hd). positions: (S,) == (T,).
    """
    b, s, h, hd = q.shape
    t, kheads = k.shape[1], k.shape[2]
    vd = v.shape[-1]                       # may differ from hd (MLA)
    g = h // kheads
    assert s % q_block == 0 and t % kv_block == 0, (s, t)
    nq, nk = s // q_block, t // kv_block
    scale = 1.0 / jnp.sqrt(hd)

    qb = q.reshape(b, nq, q_block, kheads, g, hd)
    kb = k.reshape(b, nk, kv_block, kheads, hd)
    vb = v.reshape(b, nk, kv_block, kheads, vd)
    posq = positions.reshape(nq, q_block)
    posk = positions.reshape(nk, kv_block) if t == s else \
        jnp.arange(t).reshape(nk, kv_block)

    def q_step(_, qi):
        q_i, pos_i = qi

        def kv_step(carry, ki):
            m, l, acc = carry
            k_j, v_j, pos_j = ki
            sc = jnp.einsum("bqkgd,bckd->bqkgc", q_i, k_j).astype(jnp.float32)
            sc = sc * scale
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= pos_j[None, :] <= pos_i[:, None]
            if window:
                mask &= pos_i[:, None] - pos_j[None, :] < window
            sc = jnp.where(mask[None, :, None, None, :], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(sc - m_new[..., None])
            l_new = l * alpha + pexp.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqkgc,bckd->bqkgd", pexp.astype(v_j.dtype), v_j
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, q_block, kheads, g), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, q_block, kheads, g), jnp.float32)
        a0 = jnp.zeros((b, q_block, kheads, g, vd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0), posk))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None,
                           (jnp.moveaxis(qb, 1, 0), posq))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, vd)
    return out


def self_attention(p, cfg: ArchConfig, x, positions, use_rope: bool = True,
                   causal: bool = True):
    """Full-sequence self-attention (train / prefill)."""
    q, k, v = _project_qkv(p, cfg, x, x)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    s = x.shape[1]
    if s > BLOCKWISE_THRESHOLD and s % Q_BLOCK == 0:
        out = blockwise_attention(q, k, v, positions, causal,
                                  cfg.sliding_window)
        return out.reshape(*x.shape[:-1], -1) @ p["wo"]
    scores = _gqa_scores(q, k).astype(jnp.float32)
    tpos = positions
    mask = jnp.ones((x.shape[1], x.shape[1]), bool)
    if causal:
        mask &= tpos[None, :] <= tpos[:, None]
    if cfg.sliding_window:
        mask &= tpos[:, None] - tpos[None, :] < cfg.sliding_window
    scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v, cfg.num_heads)
    return out.reshape(*x.shape[:-1], -1) @ p["wo"]


def cross_attention(p, cfg: ArchConfig, x, memory, gated: bool = False):
    """Cross-attention to encoder / vision memory (no RoPE)."""
    q, k, v = _project_qkv(p, cfg, x, memory)
    scores = _gqa_scores(q, k).astype(jnp.float32)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v, cfg.num_heads)
    out = out.reshape(*x.shape[:-1], -1) @ p["wo"]
    if gated:
        out = jnp.tanh(p["gate"]).astype(out.dtype) * out
    return out


# ------------------------------------------------------------------ caches

def init_kv_cache(cfg: ArchConfig, batch: int, max_len: int, dtype):
    """Full cache, or ring cache of size sliding_window when set."""
    length = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    k = cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    return {"k": jnp.zeros((batch, length, k, hd), dtype),
            "v": jnp.zeros((batch, length, k, hd), dtype)}


def kv_cache_spec(cfg: ArchConfig, shard_heads: bool):
    """Shard kv-head axis when it divides the mesh; else shard cache length."""
    if shard_heads:
        return {"k": P("data", None, "model", None),
                "v": P("data", None, "model", None)}
    return {"k": P("data", "model", None, None),
            "v": P("data", "model", None, None)}


def decode_attention(p, cfg: ArchConfig, x, cache, pos, use_rope: bool = True):
    """One-token decode: x (B,1,D); cache holds `pos` previous tokens.

    Returns (out, new_cache).  Ring-buffer writes when sliding_window is set.
    """
    b = x.shape[0]
    length = cache["k"].shape[1]
    q, k_new, v_new = _project_qkv(p, cfg, x, x)
    if use_rope:
        posv = jnp.full((b, 1), pos)
        q = apply_rope(q, posv, cfg.rope_theta)
        k_new = apply_rope(k_new, posv, cfg.rope_theta)
    slot = (pos % length) if cfg.sliding_window else pos
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new, (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new, (0, slot, 0, 0))
    scores = _gqa_scores(q, k_cache).astype(jnp.float32)   # (B,1,K,G,T)
    idx = jnp.arange(length)
    if cfg.sliding_window:
        # ring: slot t holds absolute position  p_t = t + floor((pos-t)/L)*L...
        # validity: the ring contains the last `length` positions <= pos.
        written = jnp.where(idx <= slot, idx + (pos - slot),
                            idx + (pos - slot) - length)
        valid = written >= 0
    else:
        valid = idx <= pos
    scores = jnp.where(valid[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v_cache, cfg.num_heads)
    out = out.reshape(b, 1, -1) @ p["wo"]
    return out, {"k": k_cache, "v": v_cache}


def prefill_attention(p, cfg: ArchConfig, x, positions, cache, use_rope=True):
    """Full-sequence attention that also fills the KV cache."""
    q, k, v = _project_qkv(p, cfg, x, x)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    s = x.shape[1]
    scores = _gqa_scores(q, k).astype(jnp.float32)
    tpos = positions
    mask = tpos[None, :] <= tpos[:, None]
    if cfg.sliding_window:
        mask &= tpos[:, None] - tpos[None, :] < cfg.sliding_window
    scores = jnp.where(mask[None, :, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v, cfg.num_heads).reshape(*x.shape[:-1], -1) @ p["wo"]
    length = cache["k"].shape[1]
    if cfg.sliding_window and length < s:
        k_w, v_w = k[:, -length:], v[:, -length:]
        # ring layout: absolute position t sits at slot t % length
        start = s - length
        slots = (jnp.arange(length) + start) % length
        k_cache = jnp.zeros_like(cache["k"]).at[:, slots].set(k_w)
        v_cache = jnp.zeros_like(cache["v"]).at[:, slots].set(v_w)
    else:
        k_cache = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
    return out, {"k": k_cache, "v": v_cache}
