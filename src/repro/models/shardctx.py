"""Activation-sharding rules, injected contextually.

The baseline dry-run lets GSPMD propagate shardings from params/inputs
alone. The §Perf-optimized configuration installs explicit rules
(Megatron-style: residual stream data-sharded and replicated over `model`;
logits vocab-sharded), applied via `constrain()` calls inside the model.
Rules default to None so tests and single-device runs are unaffected.
"""
from __future__ import annotations

import contextlib
import contextvars

import jax

_RULES: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "shard_rules", default=None)


@contextlib.contextmanager
def sharding_rules(rules: dict | None):
    tok = _RULES.set(rules)
    try:
        yield
    finally:
        _RULES.reset(tok)


def constrain(x, name: str):
    rules = _RULES.get()
    if rules is None:
        return x
    sh = rules.get(name)
    if sh is None:
        return x
    return jax.lax.with_sharding_constraint(x, sh)
