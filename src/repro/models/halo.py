"""Beyond-paper: PipeGCN-style *stale halo* for sequence-parallel
sliding-window attention (DESIGN.md §2.5).

Sequence parallelism shards the token axis across devices. Sliding-window
attention (window W) then has a PipeGCN-shaped dependency: the first W
queries of shard i attend to the last W keys/values of shard i−1 — a
boundary/halo set, exactly like boundary nodes in partition-parallel GCN.

  sync mode : halo K/V fetched with ppermute every step (vanilla GCN analogue;
              exchange is on the critical path).
  stale mode: the halo consumed at step t is the one produced at t−1
              (PipeGCN analogue; the ppermute has no data dependence on
              step-t compute and overlaps it). Optional EMA smoothing over
              the halo (PipeGCN-F analogue, §3.4).

Staleness semantics follow PipeGCN-F (feature staleness): the stale halo is
a constant w.r.t. the current step (`stop_gradient`), i.e. the halo gradient
term is dropped rather than deferred. The full deferred-gradient semantics
(PipeGCN-G) is implemented for the GCN core in repro/core; transplanting the
deferred *attention* VJP is future work and noted in DESIGN.md.

The halo buffer is pipeline state threaded through the train step, like
`PipeGCN.init_buffers`.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import apply_rope

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class HaloConfig:
    """Config of the halo-attention demo model: a windowed-attention LM
    whose cross-shard key/value halo is exchanged PipeGCN-style (`stale`
    defers it one step; `smooth`/`gamma` apply the EMA variant)."""

    d_model: int = 128
    num_heads: int = 4
    num_layers: int = 2
    window: int = 32
    vocab: int = 256
    stale: bool = True          # PipeGCN-style deferral
    smooth: bool = False        # EMA over the halo (PipeGCN-F)
    gamma: float = 0.9

    @property
    def head_dim(self):
        return self.d_model // self.num_heads


def init_params(key, cfg: HaloConfig, dtype=jnp.float32):
    ks = jax.random.split(key, 2 + cfg.num_layers)
    def dense(k, shape):
        return jax.random.normal(k, shape, dtype) / np.sqrt(shape[0])
    params = {"embed": dense(ks[0], (cfg.vocab, cfg.d_model)),
              "head": dense(ks[1], (cfg.d_model, cfg.vocab))}
    for ell in range(cfg.num_layers):
        kk = jax.random.split(ks[2 + ell], 5)
        params[f"l{ell}"] = {
            "wq": dense(kk[0], (cfg.d_model, cfg.d_model)),
            "wk": dense(kk[1], (cfg.d_model, cfg.d_model)),
            "wv": dense(kk[2], (cfg.d_model, cfg.d_model)),
            "wo": dense(kk[3], (cfg.d_model, cfg.d_model)),
            "wf": dense(kk[4], (cfg.d_model, 4 * cfg.d_model)),
            "wf2": dense(jax.random.fold_in(kk[4], 1),
                         (4 * cfg.d_model, cfg.d_model)),
            # T5-style relative position bias over the window (makes
            # position-targeted retrieval directly learnable in the demo)
            "rb": jnp.zeros((cfg.num_heads, cfg.window + 1), dtype),
        }
    return params


def init_halo_buffers(cfg: HaloConfig, local_len: int, batch: int,
                      num_shards: int, dtype=jnp.float32):
    """Stale halo K/V per layer, with leading shard axis (like sim backend)."""
    w, h, hd = cfg.window, cfg.num_heads, cfg.head_dim
    return [
        {"k": jnp.zeros((num_shards, batch, w, h, hd), dtype),
         "v": jnp.zeros((num_shards, batch, w, h, hd), dtype)}
        for _ in range(cfg.num_layers)
    ]


def _local_window_attention(q, k, v, k_halo, v_halo, pos0, window,
                            rel_bias=None):
    """Causal sliding-window attention where the key set is
    [halo (W tokens ending at pos0-1) ; local (S_loc tokens from pos0)]."""
    b, s, h, hd = q.shape
    w = k_halo.shape[1]
    kk = jnp.concatenate([k_halo, k], axis=1)
    vv = jnp.concatenate([v_halo, v], axis=1)
    qpos = pos0 + jnp.arange(s)
    kpos = jnp.concatenate([pos0 - w + jnp.arange(w), pos0 + jnp.arange(s)])
    scores = jnp.einsum("bshd,bthd->bsht", q, kk) / np.sqrt(hd)
    rel = qpos[:, None] - kpos[None, :]
    if rel_bias is not None:
        idx = jnp.clip(rel, 0, rel_bias.shape[1] - 1)
        bias = jnp.moveaxis(rel_bias.T[idx], -1, 1)   # (s,t,h)->(s,h,t)
        scores = scores + bias[None]                  # (b,s,h,t)
    mask = (rel >= 0) & (rel < window)
    scores = jnp.where(mask[None, :, None, :], scores.astype(jnp.float32),
                       NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bsht,bthd->bshd", probs, vv)


def _exchange_halo(k_tail, v_tail, backend_axis):
    """Fetch the left neighbor's window tail. Shard 0 receives zeros."""
    if backend_axis is None:                  # sim backend (leading axis)
        shift = lambda x: jnp.concatenate(
            [jnp.zeros_like(x[:1]), x[:-1]], axis=0)
        return shift(k_tail), shift(v_tail)
    n = jax.lax.axis_size(backend_axis)
    perm = [(i, i + 1) for i in range(n - 1)]
    k_h = jax.lax.ppermute(k_tail, backend_axis, perm)
    v_h = jax.lax.ppermute(v_tail, backend_axis, perm)
    return k_h, v_h


def forward(params, cfg: HaloConfig, tokens, halo_bufs, pos0,
            backend_axis=None):
    """Per-shard forward. tokens: (B, S_loc) (leading shard axis in sim mode
    is handled by the caller via vmap-like broadcasting below).

    Returns (logits, new_halo_bufs).
    """
    sim = backend_axis is None
    w = cfg.window
    x = params["embed"][tokens]
    s_loc = tokens.shape[-1]
    # absolute positions (per shard) for RoPE; halo K arrives pre-roped with
    # the neighbor's absolute positions, so offsets stay consistent.
    if sim:
        positions = pos0[:, None, None] + jnp.arange(s_loc)[None, None, :]
    else:
        positions = (pos0 + jnp.arange(s_loc))[None, :]
    new_bufs = []
    for ell in range(cfg.num_layers):
        p = params[f"l{ell}"]
        h = cfg.num_heads
        q = (x @ p["wq"]).reshape(*x.shape[:-1], h, cfg.head_dim)
        k = (x @ p["wk"]).reshape(*x.shape[:-1], h, cfg.head_dim)
        v = (x @ p["wv"]).reshape(*x.shape[:-1], h, cfg.head_dim)
        q = apply_rope(q, positions, 10000.0)
        k = apply_rope(k, positions, 10000.0)
        tail_k = k[..., -w:, :, :] if not sim else k[:, :, -w:]
        tail_v = v[..., -w:, :, :] if not sim else v[:, :, -w:]
        fresh_k, fresh_v = _exchange_halo(tail_k, tail_v, backend_axis)
        if cfg.stale:
            use_k = jax.lax.stop_gradient(halo_bufs[ell]["k"])
            use_v = jax.lax.stop_gradient(halo_bufs[ell]["v"])
            if cfg.smooth:
                new_k = cfg.gamma * halo_bufs[ell]["k"] + (1 - cfg.gamma) * fresh_k
                new_v = cfg.gamma * halo_bufs[ell]["v"] + (1 - cfg.gamma) * fresh_v
            else:
                new_k, new_v = fresh_k, fresh_v
            new_bufs.append({"k": jax.lax.stop_gradient(new_k),
                             "v": jax.lax.stop_gradient(new_v)})
        else:
            use_k, use_v = fresh_k, fresh_v
            new_bufs.append(halo_bufs[ell])
        if sim:
            att = jax.vmap(
                lambda q_, k_, v_, hk, hv, p0:
                _local_window_attention(q_, k_, v_, hk, hv, p0, w, p["rb"])
            )(q, k, v, use_k, use_v, pos0)
        else:
            att = _local_window_attention(q, k, v, use_k, use_v, pos0, w,
                                          p["rb"])
        att = att.reshape(*x.shape)
        x = x + att @ p["wo"]
        x = x + jax.nn.gelu(x @ p["wf"]) @ p["wf2"]
    return x @ params["head"], new_bufs


def make_sim_train_step(cfg: HaloConfig, num_shards: int, lr: float = 1e-3):
    """Single-device reference: shards as a leading axis (like PipeGCN sim).

    tokens/labels: (num_shards, B, S_loc); pos0: (num_shards,) start offset.
    Returns (init_opt_state, step) with an Adam optimizer.
    """
    from repro.optim import adam
    opt = adam(lr)

    def loss_fn(params, tokens, labels, bufs, pos0):
        logits, new_bufs = forward(params, cfg, tokens, bufs, pos0,
                                   backend_axis=None)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), -1)
        ll = jnp.take_along_axis(
            logits.astype(jnp.float32), labels[..., None], -1)[..., 0]
        return jnp.mean(lse - ll), new_bufs

    @jax.jit
    def step(params, opt_state, tokens, labels, bufs, pos0):
        (loss, new_bufs), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, tokens, labels, bufs, pos0)
        params, opt_state = opt.apply(params, grads, opt_state)
        return loss, params, opt_state, new_bufs

    return opt.init, step
