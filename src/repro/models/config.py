"""Architecture configuration for the assigned model pool.

Every field maps to a published spec; the per-arch instantiations (with
citations) live in ``repro/configs/<id>.py``.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Static architecture description of one assigned transformer/SSM/MoE
    model family — every structural knob the LM builder consumes, with
    `reduced()` producing the small-config variant the tests train."""

    arch_id: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio

    num_layers: int = 12
    d_model: int = 1024
    num_heads: int = 8
    num_kv_heads: int = 8
    head_dim: int = 0                # 0 -> d_model // num_heads
    d_ff: int = 4096
    vocab_size: int = 32000

    # attention details
    qkv_bias: bool = False
    qk_norm: bool = False            # per-head RMSNorm on q,k (qwen3)
    rope_theta: float = 10000.0
    sliding_window: int = 0          # 0 = full attention
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "swiglu"              # swiglu | gelu | geglu
    tie_embeddings: bool = False
    use_rope: bool = True            # whisper: sinusoidal/learned instead
    scale_embed: bool = False        # gemma-style sqrt(d_model) embed scale

    # MoE
    num_experts: int = 0
    experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int = 0                # expert hidden (d_ff of each expert)
    first_dense_layers: int = 0      # deepseek: first k layers use dense FFN
    capacity_factor: float = 1.25
    moe_groups: int = 1              # >1: route within token groups (device-
                                     # local capacity, GShard-style) — keeps
                                     # the dispatch gather shard-local

    # MLA (deepseek-v2)
    use_mla: bool = False
    q_lora_rank: int = 0             # 0 = full-rank q projection
    kv_lora_rank: int = 0
    qk_nope_dim: int = 0
    qk_rope_dim: int = 0
    v_head_dim: int = 0

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 128

    # hybrid (recurrentgemma)
    pattern: tuple[str, ...] = ()    # repeating unit of mixer kinds, e.g.
                                     # ("rglru","rglru","attn"); empty = homogeneous
    lru_width: int = 0
    conv1d_width: int = 4

    # VLM (llama-3.2-vision)
    cross_attn_every: int = 0        # a cross-attn layer every k-th layer
    num_image_tokens: int = 0

    # enc-dec (whisper)
    encoder_layers: int = 0
    num_audio_frames: int = 0        # encoder sequence (stubbed embeddings)

    # numerics
    dtype: str = "bfloat16"
    remat: bool = True

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Embedding/logit rows padded so 16-way tensor sharding divides
        evenly (Megatron-style padded vocab). Padded logits are masked."""
        mult = 2048 if self.vocab_size >= 2048 else 128
        return -(-self.vocab_size // mult) * mult

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_headdim

    def layer_kinds(self) -> list[str]:
        """Mixer kind per decoder layer."""
        n = self.num_layers
        if self.family == "ssm":
            return ["ssd"] * n
        if self.pattern:
            out = [self.pattern[i % len(self.pattern)] for i in range(n)]
            return out
        if self.cross_attn_every:
            # llama-3.2-vision: cross-attention every k-th layer (layer
            # indices k-1, 2k-1, ...)
            return ["xattn" if (i + 1) % self.cross_attn_every == 0 else "attn"
                    for i in range(n)]
        return ["attn"] * n

    def ffn_kind(self, layer_idx: int) -> str:
        if self.num_experts and layer_idx >= self.first_dense_layers:
            return "moe"
        return "dense"

    def reduced(self, **overrides) -> "ArchConfig":
        """Smoke-test variant: tiny dims, same family/kinds."""
        kw = dict(
            num_layers=min(self.num_layers, len(self.pattern) or 2)
            if self.pattern else 2,
            d_model=min(self.d_model, 128),
            num_heads=min(self.num_heads, 4),
            num_kv_heads=max(1, min(self.num_kv_heads,
                                    min(self.num_heads, 4) // 2)),
            head_dim=32,
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=min(self.sliding_window, 16) if self.sliding_window else 0,
            dtype="float32", remat=False,
        )
        if self.pattern:
            kw["num_layers"] = len(self.pattern)
        if self.num_experts:
            kw.update(num_experts=4, experts_per_tok=2,
                      num_shared_experts=min(self.num_shared_experts, 1),
                      moe_d_ff=64, first_dense_layers=min(self.first_dense_layers, 1))
        if self.use_mla:
            kw.update(kv_lora_rank=32, q_lora_rank=0, qk_nope_dim=32,
                      qk_rope_dim=16, v_head_dim=32)
        if self.family == "ssm":
            kw.update(d_model=128, ssm_state=16, ssm_headdim=32, ssm_chunk=8)
        if self.lru_width:
            kw["lru_width"] = kw["d_model"]
        if self.cross_attn_every:
            kw.update(num_layers=self.cross_attn_every,
                      num_image_tokens=8)
        if self.encoder_layers:
            kw.update(encoder_layers=2, num_audio_frames=12)
        kw.update(overrides)
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One named workload shape (sequence length, global batch, and
    train/prefill/decode mode) from the INPUT_SHAPES registry."""

    name: str
    seq_len: int
    global_batch: int
    mode: str                        # train | prefill | decode


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}
