"""Optimizers and LR schedules (self-contained; no optax offline)."""
from repro.optim.optimizers import (Optimizer, adam, adamw, sgd,
                                    constant_schedule, cosine_schedule,
                                    linear_warmup_cosine, global_norm,
                                    clip_by_global_norm)

__all__ = ["Optimizer", "adam", "adamw", "sgd", "constant_schedule",
           "cosine_schedule", "linear_warmup_cosine", "global_norm",
           "clip_by_global_norm"]
