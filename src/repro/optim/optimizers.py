"""Minimal, jit-friendly optimizer library (optax-style pure functions).

The paper trains every model with Adam (Tab. 3); AdamW/SGD are provided for
the transformer architectures and ablations.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

Schedule = Callable[[jax.Array], jax.Array]


def constant_schedule(lr: float) -> Schedule:
    return lambda step: jnp.asarray(lr, jnp.float32)


def cosine_schedule(lr: float, total_steps: int, final_frac: float = 0.0) -> Schedule:
    def f(step):
        t = jnp.clip(step / max(total_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1 - final_frac) * cos)
    return f


def linear_warmup_cosine(lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1) -> Schedule:
    cos = cosine_schedule(lr, max(total_steps - warmup, 1), final_frac)
    def f(step):
        wu = lr * jnp.minimum(step / max(warmup, 1), 1.0)
        return jnp.where(step < warmup, wu, cos(step - warmup))
    return f


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda x: x * scale.astype(x.dtype), tree), norm


class OptState(NamedTuple):
    """Optimizer state threaded through `Optimizer.apply`: the step counter
    and the first/second moment pytrees (nu is empty for plain SGD)."""

    step: jax.Array
    mu: dict          # first moment (or momentum)
    nu: dict          # second moment (empty dict for sgd)


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """apply(params, grads, state) -> (new_params, new_state)."""

    init: Callable
    apply: Callable
    name: str = "opt"


def adam(schedule: Schedule | float, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0,
         max_grad_norm: float | None = None, decoupled: bool = False) -> Optimizer:
    if not callable(schedule):
        schedule = constant_schedule(float(schedule))

    def init(params):
        z = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=z,
                        nu=jax.tree.map(jnp.zeros_like, z))

    def apply(params, grads, state: OptState):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr = schedule(step)
        b1t = 1 - b1 ** step.astype(jnp.float32)
        b2t = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g32 = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g32
            v = b2 * v + (1 - b2) * jnp.square(g32)
            mhat = m / b1t
            vhat = v / b2t
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                if decoupled:       # AdamW
                    delta = delta + weight_decay * p.astype(jnp.float32)
                else:               # L2-coupled
                    delta = delta + 0.0  # coupled decay folded into grads upstream
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        flat_v = tdef.flatten_up_to(state.nu)
        out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        return new_p, OptState(step=step, mu=new_m, nu=new_v)

    return Optimizer(init=init, apply=apply,
                     name="adamw" if decoupled and weight_decay else "adam")


def adamw(schedule, weight_decay: float = 0.01, **kw) -> Optimizer:
    return adam(schedule, weight_decay=weight_decay, decoupled=True, **kw)


def sgd(schedule: Schedule | float, momentum: float = 0.0,
        max_grad_norm: float | None = None) -> Optimizer:
    if not callable(schedule):
        schedule = constant_schedule(float(schedule))

    def init(params):
        mu = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return OptState(step=jnp.zeros((), jnp.int32), mu=mu, nu={})

    def apply(params, grads, state: OptState):
        if max_grad_norm is not None:
            grads, _ = clip_by_global_norm(grads, max_grad_norm)
        step = state.step + 1
        lr = schedule(step)

        def upd(p, g, m):
            m = momentum * m + g.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * m).astype(p.dtype), m

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_m = tdef.flatten_up_to(state.mu)
        out = [upd(p, g, m) for p, g, m in zip(flat_p, flat_g, flat_m)]
        return (tdef.unflatten([o[0] for o in out]),
                OptState(step=step, mu=tdef.unflatten([o[1] for o in out]), nu={}))

    return Optimizer(init=init, apply=apply, name="sgd")
